//! Quickstart: generate an eGPU FFT program, run it on the simulated
//! SM, check the numerics, and read the paper-style profile.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, reference, Cpx};

fn main() -> anyhow::Result<()> {
    // 1. Pick a design point: 1024-point FFT, radix-16 kernels, on the
    //    eGPU with both paper enhancements (virtual-banked memory +
    //    complex functional units).
    let variant = Variant::DP_VM_COMPLEX;
    let cfg = SmConfig::for_radix(variant, 16);
    let fp = fft::generate(&cfg, 1024, 16)?;
    println!(
        "generated `{}`: {} instructions, {} passes (radices {:?})",
        fp.program.name,
        fp.program.len(),
        fp.plan.n_passes(),
        fp.plan.passes.iter().map(|p| p.radix).collect::<Vec<_>>(),
    );

    // 2. Make a test signal and run it through the simulated SM.
    let signal = reference::test_signal(1024, 42);
    let input: Vec<(f32, f32)> = signal.iter().map(|c| c.to_f32_pair()).collect();
    let run = fft::run_fft(&fp, &cfg, &input)?;

    // 3. Validate against the reference FFT.
    let got: Vec<Cpx> = run
        .output
        .iter()
        .map(|&(re, im)| Cpx::new(re as f64, im as f64))
        .collect();
    let err = reference::rms_rel_error(&got, &reference::fft(&signal));
    println!("numerics: rms error vs reference = {err:.2e}");
    assert!(err < fft::F32_TOL);

    // 4. The paper-style profile (one column of Table 3).
    println!("\n{}", run.profile);
    println!(
        "\n(Table 3 reports {:.2} us / {:.2}% efficiency for this point\n\
         on the authors' Agilex hardware — see EXPERIMENTS.md.)",
        12.65, 27.40
    );

    // 5. Peek at the first instructions of the generated assembly.
    println!("\nfirst 12 instructions:");
    for line in fp.program.listing().lines().take(13) {
        println!("  {line}");
    }
    Ok(())
}
