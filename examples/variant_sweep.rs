//! The paper's full §6 campaign: 48 combinations of FFT decomposition,
//! points and processor architecture, printed as a compact summary —
//! the data behind Tables 1–3 plus the radix-2 runs the paper measured
//! but omitted "for brevity".
//!
//! ```sh
//! cargo run --release --example variant_sweep
//! ```

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, FftPlan};

fn main() -> anyhow::Result<()> {
    println!(
        "{:>6} {:>6} {:<16} {:>9} {:>10} {:>11} {:>9}",
        "points", "radix", "variant", "cycles", "time(us)", "eff(%)", "mem(%)"
    );
    let mut combos = 0;
    for radix in [2usize, 4, 8, 16] {
        for points in [256usize, 512, 1024, 4096] {
            // the paper's table space: 512 only for radix-8
            if points == 512 && radix != 8 {
                continue;
            }
            let mut best: Option<(String, f64)> = None;
            for variant in Variant::ALL6 {
                let cfg = SmConfig::for_radix(variant, radix);
                if variant.vm {
                    let plan = FftPlan::new(points, radix, cfg.threads)?;
                    if !plan.passes.iter().any(|p| p.vm_eligible) {
                        continue; // the paper's "-" cells
                    }
                }
                let (profile, err) = fft::validate(&cfg, points, radix, 1)?;
                assert!(err < fft::F32_TOL, "{points}/{radix}/{variant}: {err}");
                println!(
                    "{:>6} {:>6} {:<16} {:>9} {:>10.2} {:>11.2} {:>9.2}",
                    points,
                    radix,
                    variant.name(),
                    profile.total(),
                    profile.time_us(),
                    profile.efficiency_pct(),
                    profile.memory_pct()
                );
                combos += 1;
                let eff = profile.efficiency_pct();
                if best.as_ref().map(|(_, e)| eff > *e).unwrap_or(true) {
                    best = Some((variant.name(), eff));
                }
            }
            if let Some((name, eff)) = best {
                println!("{:>6} {:>6} best: {name} @ {eff:.2}%\n", points, radix);
            }
        }
    }
    println!("{combos} design points simulated (numerics validated on every one)");
    Ok(())
}
