//! Domain scenario: a multi-pass spectral pipeline on ONE soft
//! processor — the workload class the paper's introduction motivates
//! ("applications where multiple algorithmic passes are applied to the
//! same data, especially if those passes are not known in advance of
//! runtime"): the eGPU runs forward FFT, spectral filtering and inverse
//! FFT back-to-back with *no hardware reconfiguration*, something a
//! fixed-function FFT IP core cannot do alone.
//!
//! Pipeline: noisy multi-tone signal → window → FFT (eGPU program) →
//! band mask (host, standing in for a second eGPU kernel) → inverse FFT
//! (the *same* eGPU FFT program via the conjugation identity
//! IFFT(x) = conj(FFT(conj(x)))/N) → SNR comparison.
//!
//! ```sh
//! cargo run --release --example spectral_pipeline
//! ```

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, FftProgram};
use egpu_fft::profile::Profile;

const N: usize = 1024;

fn run_egpu_fft(
    fp: &FftProgram,
    cfg: &SmConfig,
    input: &[(f32, f32)],
) -> anyhow::Result<(Vec<(f32, f32)>, Profile)> {
    let run = fft::run_fft(fp, cfg, input)?;
    Ok((run.output, run.profile))
}

fn main() -> anyhow::Result<()> {
    let variant = Variant::DP_VM_COMPLEX;
    let cfg = SmConfig::for_radix(variant, 16);
    let fp = fft::generate(&cfg, N, 16)?;

    // ---- build a noisy two-tone signal ----
    let mut x = vec![(0.0f32, 0.0f32); N];
    let mut noise_state = 0x1234_5678_u64;
    let mut noise = || {
        noise_state ^= noise_state >> 12;
        noise_state ^= noise_state << 25;
        noise_state ^= noise_state >> 27;
        ((noise_state.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 23) as f32)
            - 1.0
    };
    for (t, xt) in x.iter_mut().enumerate() {
        let th1 = 2.0 * std::f32::consts::PI * 37.0 * t as f32 / N as f32;
        let th2 = 2.0 * std::f32::consts::PI * 293.0 * t as f32 / N as f32;
        // tone at bin 37 (wanted) + tone at 293 (interferer) + noise
        xt.0 = th1.cos() + 0.8 * th2.cos() + 0.30 * noise();
        xt.1 = th1.sin() + 0.8 * th2.sin() + 0.30 * noise();
    }

    // ---- pass 1: window (Hann), on the host for brevity ----
    let windowed: Vec<(f32, f32)> = x
        .iter()
        .enumerate()
        .map(|(t, &(re, im))| {
            let w = 0.5 - 0.5 * (2.0 * std::f32::consts::PI * t as f32 / N as f32).cos();
            (re * w, im * w)
        })
        .collect();

    // ---- pass 2: forward FFT on the eGPU ----
    let (spec, p_fwd) = run_egpu_fft(&fp, &cfg, &windowed)?;
    let peak = spec
        .iter()
        .enumerate()
        .max_by(|a, b| mag2(a.1).total_cmp(&mag2(b.1)))
        .unwrap()
        .0;
    println!("forward FFT on {variant}: peak bin {peak} (expect 37)");
    assert_eq!(peak, 37);

    // ---- pass 3: spectral mask — keep a band around the wanted tone ----
    let band = 16usize;
    let masked: Vec<(f32, f32)> = spec
        .iter()
        .enumerate()
        .map(|(k, &v)| {
            let d = k.min(N - k).abs_diff(0); // distance from DC going up
            let keep = (k as i64 - 37).unsigned_abs() as usize <= band
                || (N - k).abs_diff(0) == 0 && d == 0;
            if keep {
                v
            } else {
                (0.0, 0.0)
            }
        })
        .collect();

    // ---- pass 4: inverse FFT on the SAME eGPU program ----
    let conj_in: Vec<(f32, f32)> = masked.iter().map(|&(re, im)| (re, -im)).collect();
    let (y_conj, p_inv) = run_egpu_fft(&fp, &cfg, &conj_in)?;
    let y: Vec<(f32, f32)> = y_conj
        .iter()
        .map(|&(re, im)| (re / N as f32, -im / N as f32))
        .collect();

    // ---- measure: interferer + noise suppressed, tone preserved ----
    let tone: Vec<(f32, f32)> = (0..N)
        .map(|t| {
            let th = 2.0 * std::f32::consts::PI * 37.0 * t as f32 / N as f32;
            let w = 0.5 - 0.5 * (2.0 * std::f32::consts::PI * t as f32 / N as f32).cos();
            (th.cos() * w, th.sin() * w)
        })
        .collect();
    let err_before: f32 = windowed
        .iter()
        .zip(&tone)
        .map(|(a, b)| mag2(&(a.0 - b.0, a.1 - b.1)))
        .sum::<f32>()
        / N as f32;
    let err_after: f32 = y
        .iter()
        .zip(&tone)
        .map(|(a, b)| mag2(&(a.0 - b.0, a.1 - b.1)))
        .sum::<f32>()
        / N as f32;
    let improvement_db = 10.0 * (err_before / err_after).log10();
    println!("interference+noise power vs clean tone:");
    println!("  before filtering: {err_before:.4}");
    println!("  after  filtering: {err_after:.4}  ({improvement_db:.1} dB improvement)");
    assert!(improvement_db > 10.0, "pipeline should clean the signal");

    // ---- the soft-processor argument in numbers ----
    let total_us = p_fwd.time_us() + p_inv.time_us();
    println!("\neGPU virtual time: fwd {:.2} us + inv {:.2} us = {total_us:.2} us",
        p_fwd.time_us(), p_inv.time_us());
    println!(
        "one {} instance ran FFT, filter prep and IFFT with zero reconfiguration;\n\
         a streaming FFT IP would need a second core (or double-buffered reuse)\n\
         plus external filtering logic for the same pipeline.",
        variant
    );
    Ok(())
}

fn mag2(v: &(f32, f32)) -> f32 {
    v.0 * v.0 + v.1 * v.1
}
