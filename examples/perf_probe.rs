use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, reference};
use egpu_fft::sim::Sm;
use std::time::Instant;

fn main() {
    let cfg = SmConfig::for_radix(Variant::DP, 16);
    let fp = fft::generate(&cfg, 4096, 16).unwrap();
    let input: Vec<(f32, f32)> =
        reference::test_signal(4096, 3).iter().map(|c| c.to_f32_pair()).collect();
    let iters = 2000;

    let t0 = Instant::now();
    for _ in 0..iters { let sm = Sm::new(cfg); std::hint::black_box(&sm); }
    println!("Sm::new           {:>8.1} us", t0.elapsed().as_secs_f64()*1e6/iters as f64);

    let mut sm = Sm::new(cfg);
    sm.seed_thread_ids();
    let t0 = Instant::now();
    for _ in 0..iters { fft::load_workspace(&mut sm, &fp, &input).unwrap(); }
    println!("load_workspace    {:>8.1} us", t0.elapsed().as_secs_f64()*1e6/iters as f64);

    let t0 = Instant::now();
    for _ in 0..iters { sm.run(&fp.program, fp.plan.threads).unwrap(); }
    println!("Sm::run           {:>8.1} us", t0.elapsed().as_secs_f64()*1e6/iters as f64);

    let t0 = Instant::now();
    for _ in 0..iters { let _ = fft::read_output(&sm, &fp).unwrap(); }
    println!("read_output       {:>8.1} us", t0.elapsed().as_secs_f64()*1e6/iters as f64);

    let t0 = Instant::now();
    for _ in 0..iters { let _ = fft::run_fft(&fp, &cfg, &input).unwrap(); }
    println!("run_fft (total)   {:>8.1} us", t0.elapsed().as_secs_f64()*1e6/iters as f64);
}
