//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! Proves all layers compose: the L3 rust coordinator serves a batched
//! mixed-size request stream across a pool of workers from one shared
//! plan cache; when the AOT artifacts exist (`make artifacts`) the
//! L2/L1 JAX+Pallas FFT additionally serves the PJRT fast path and is
//! cross-validated against the cycle-accurate eGPU simulation —
//! reporting latency, throughput, batch occupancy, plan-cache hit rate,
//! simulated eGPU time and aggregate efficiency (the paper's headline
//! metric).
//!
//! ```sh
//! cargo run --release --example fft_service          # simulator phases
//! make artifacts && cargo run --release --example fft_service  # + PJRT
//! ```

use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, ArrivalPattern, AutoscaleController,
    AutoscalePolicy, Backend, FftRequest, FftService, LoadgenConfig, QosClass, ServerConfig,
    ServiceConfig, ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn workload(total: usize) -> Vec<Vec<(f32, f32)>> {
    // a mixed-size stream: mostly 1024-point frames with bursts of 256
    // and occasional 4096 (a realistic radar/SDR channelizer mix)
    (0..total)
        .map(|i| match i % 8 {
            0 | 1 | 2 | 3 => signal(1024, i as u64),
            4 | 5 | 6 => signal(256, i as u64),
            _ => signal(4096, i as u64),
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    // ---- phase 1: batched dispatch through the shared plan cache ----
    let svc = FftService::start(ServiceConfig {
        cores: 4,
        backend: Backend::Simulator,
        ..Default::default()
    })?;
    let n_requests = 128;
    // warm-up batch: pays the one-time program generation per size
    svc.request_all(workload(8).into_iter().map(FftRequest::new).collect())?;
    let inputs = workload(n_requests);
    let expect: Vec<usize> = inputs.iter().map(Vec::len).collect();
    let t0 = Instant::now();
    let results = svc.request_all(inputs.into_iter().map(FftRequest::new).collect())?;
    let wall = t0.elapsed();
    for (r, n) in results.iter().zip(&expect) {
        assert_eq!(r.output.len(), *n);
    }
    let m = svc.metrics();
    println!("== batched dispatch (simulator backend, shared plan cache) ==");
    println!(
        "  {} mixed-size requests in {:.1} ms -> {:.0} req/s",
        n_requests,
        wall.as_secs_f64() * 1e3,
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "  plan cache: hit rate {:.3} ({} builds for {} lookups); \
         batch occupancy mean {:.1}, max {}",
        m.plan_cache.hit_rate(),
        m.plan_cache.misses,
        m.plan_cache.lookups(),
        m.mean_batch_occupancy(),
        m.max_batch_jobs
    );
    print!("{}", m.render());
    svc.shutdown();

    // ---- phase 2: scale-out over simulated cores ----
    println!("\n== scale-out: simulated eGPU cores (paper §8: 'instantiate many') ==");
    for cores in [1usize, 2, 4, 8] {
        let svc = FftService::start(ServiceConfig {
            cores,
            backend: Backend::Simulator,
            ..Default::default()
        })?;
        let t0 = Instant::now();
        svc.run_batch((0..64).map(|i| signal(1024, i)).collect())?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {cores} core(s): 64 fft1024 jobs in {:>7.1} ms ({:>6.0} job/s)",
            wall * 1e3,
            64.0 / wall
        );
        svc.shutdown();
    }

    // ---- phase 3: sharded scheduler (per-shard queues + stealing) ----
    println!("\n== sharded scheduler: size-affinity + work stealing, shared plan cache ==");
    for shards in [1usize, 2, 4, 8] {
        let svc = ShardedFftService::start(ShardPoolConfig {
            shards,
            steal_threshold: 0, // steal on any backlog: maximum balance
            service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
            ..Default::default()
        })?;
        // warm the shared plan cache and *every* shard's resident
        // executor before timing (same 64-job shape as the measured
        // batch, so it chunks across the whole pool)
        svc.request_all((0..64).map(|i| FftRequest::new(signal(1024, i))).collect())?;
        let t0 = Instant::now();
        svc.request_all((0..64).map(|i| FftRequest::new(signal(1024, i))).collect())?;
        let wall = t0.elapsed().as_secs_f64();
        let m = svc.metrics();
        println!(
            "  {shards} shard(s): 64 fft1024 jobs in {:>7.1} ms ({:>6.0} job/s), \
             steals {}, plan-cache hit rate {:.3}",
            wall * 1e3,
            64.0 / wall,
            m.steals,
            m.plan_cache.hit_rate()
        );
        svc.shutdown();
    }

    // ---- phase 4: the traffic frontend under open-loop overload ----
    println!("\n== traffic frontend: admission control + deadlines under open-loop load ==");
    let inner = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
        shards: 4,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })?);
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 4,
            aging: Duration::from_millis(10),
            ..Default::default()
        },
    )?;
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            pattern: ArrivalPattern::Poisson,
            rate_hz: 2000.0,
            duration: Duration::from_millis(1500),
            deadline: Some(Duration::from_millis(25)),
            ..Default::default()
        },
    );
    print!("{}", report.render());
    assert!(report.accounted, "every request must get a result or a typed error");
    server.shutdown();

    // ---- phase 4b: N-class QoS under overload (WFQ + EDF + ladder) ----
    println!("\n== QoS frontend: 3 weighted classes under overload (WFQ shares) ==");
    let inner = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
        shards: 2,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })?);
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: vec![
                QosClass::new("gold", 5).with_capacity(32),
                QosClass::new("silver", 3).with_capacity(32),
                QosClass::new("bronze", 1).with_capacity(32),
            ],
            policy: AdmissionPolicy::Shed,
            dispatchers: 2,
            ..Default::default()
        },
    )?;
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 4000.0,
            duration: Duration::from_millis(1500),
            sizes: vec![1024],
            class_mix: vec![1.0, 1.0, 1.0], // equal arrivals; serve shares follow weights
            deadline: None,
            ..Default::default()
        },
    );
    print!("{}", report.render());
    assert!(report.accounted, "every request must get a result or a typed error");
    server.shutdown();

    // ---- phase 5: elastic serving (SLO-driven shard autoscaling) ----
    println!("\n== autoscaler: capacity follows traffic (1 shard grows under overload) ==");
    let inner = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
        shards: 1,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })?);
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 8,
            ..Default::default()
        },
    )?;
    let controller = AutoscaleController::spawn(
        &server,
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 25.0,
            max_shed_rate: 0.02,
            scale_up_cooldown: Duration::from_millis(100),
            scale_down_cooldown: Duration::from_millis(600),
            interval: Duration::from_millis(25),
            ..Default::default()
        },
    )?;
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 3000.0,
            duration: Duration::from_millis(1500),
            sizes: vec![1024],
            deadline: None,
            ..Default::default()
        },
    );
    print!("{}", report.render());
    let log = controller.stop();
    print!("{}", log.render());
    assert!(report.accounted, "every request must get a result or a typed error");
    server.shutdown();

    // ---- PJRT phases need the AOT artifacts and the pjrt feature ----
    let have_artifacts = std::path::Path::new("artifacts/fft256.hlo.txt").exists();
    if !have_artifacts {
        println!("\nartifacts/ missing — PJRT phases skipped (run `make artifacts`)");
        println!("\nE2E OK (simulator phases)");
        return Ok(());
    }

    // ---- phase 6: PJRT fast path (the serving configuration) ----
    let svc = match FftService::start(ServiceConfig {
        cores: 4,
        backend: Backend::Pjrt,
        ..Default::default()
    }) {
        Ok(svc) => svc,
        Err(e) => {
            println!("\nPJRT unavailable ({e}) — phases skipped");
            println!("\nE2E OK (simulator phases)");
            return Ok(());
        }
    };
    // warm up: compile the three artifact sizes once (the paid-once
    // startup cost; EXPERIMENTS.md §Perf) so the measurement below is
    // steady-state serving
    svc.run_batch(vec![signal(256, 0), signal(1024, 0), signal(4096, 0)])?;
    let n_requests = 256;
    let inputs = workload(n_requests);
    let expect: Vec<usize> = inputs.iter().map(Vec::len).collect();
    let t0 = Instant::now();
    let results = svc.run_batch(inputs)?;
    let wall = t0.elapsed();
    for (r, n) in results.iter().zip(&expect) {
        assert_eq!(r.output.len(), *n);
    }
    let m = svc.metrics();
    println!("\n== PJRT fast path ==");
    println!(
        "  {} mixed-size requests in {:.1} ms -> {:.0} req/s",
        n_requests,
        wall.as_secs_f64() * 1e3,
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "  latency: p50 <= {:.0} us (cumulative metrics include the three \
         one-time artifact compiles)",
        m.latency_percentile_us(0.50),
    );
    print!("{}", m.render());
    svc.shutdown();

    // ---- phase 7: cross-validated run (sim numerics == PJRT) ----
    let svc = FftService::start(ServiceConfig {
        cores: 4,
        backend: Backend::Validate,
        ..Default::default()
    })?;
    let n_val = 32;
    let t0 = Instant::now();
    let results = svc.run_batch(workload(n_val))?;
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!("\n== cross-validated (PJRT vs cycle-accurate eGPU sim) ==");
    println!(
        "  {} requests validated in {:.1} ms (every output matched within 1e-4 rms)",
        n_val,
        wall.as_secs_f64() * 1e3
    );
    println!(
        "  simulated eGPU time: {:.1} us across {} jobs on {}",
        m.virtual_us,
        results.len(),
        svc.config().variant
    );
    println!(
        "  aggregate eGPU efficiency: {:.2}%  (the paper's headline metric; \
         Table 3 best ~27-36%)",
        m.efficiency_pct()
    );
    svc.shutdown();

    println!("\nE2E OK");
    Ok(())
}
