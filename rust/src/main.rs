//! egpu-fft CLI: regenerate the paper's tables and figures, run single
//! design points, validate numerics, or serve FFTs through the
//! coordinator. (clap is not available in this offline image; the
//! argument parsing is deliberately simple.)

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};
use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::coordinator::{
    loadgen, AdmissionPolicy, ArrivalPattern, AutoscaleController, AutoscalePolicy, Backend,
    BackendSet, BackendSetConfig, DegradeLevel, FftRequest, FftService, LoadgenConfig, QosClass,
    ServerConfig, ServiceConfig, ServiceError, ServiceHandle, ShardPoolConfig, ShardedFftService,
    TenantSpec, TrafficServer,
};
use egpu_fft::fft::{self, reference};
use egpu_fft::runtime::spawn_pjrt_server;
use egpu_fft::report;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "\
egpu-fft — soft GPGPU vs IP cores, reproduced

USAGE:
  egpu-fft table <1|2|3|4|5|6>       regenerate a paper table
  egpu-fft figure <2|4>              regenerate a paper figure
  egpu-fft tables                    regenerate everything (tables 1-6)
  egpu-fft run [--points N] [--radix R] [--variant V] [--listing]
                                     simulate one design point
  egpu-fft validate                  numerics across the design space
  egpu-fft batch [--points N] [--radix R] [--batch B]
                                     multi-batch amortization demo (§6)
  egpu-fft reduce [--n N] [--variant V]
                                     sum-reduction workload (§4)
  egpu-fft serve [--cores K] [--requests N] [--points P]
                 [--backend sim|pjrt|validate] [--batched]
                 [--shards N] [--steal-threshold T]
                                     run the FFT service demo
                                     (--batched: coalesced request_all
                                      dispatch through the plan cache;
                                      --shards: per-shard queues with
                                      size-affinity + work stealing,
                                      0 = one shard per hardware thread;
                                      --shards replaces --cores — each
                                      shard runs one resident-SM worker)
  egpu-fft serve --backends sim,pjrt [--validate-fraction F]
                 [--cores K | --shards N] [--requests N] [--points P]
                 [--workers W]
                                     multi-backend routing demo: a
                                     calibration pass seeds a measured
                                     per-lane cost model, the router
                                     picks a lane per request, and a
                                     sampled fraction F of fast-path
                                     results is cross-checked against
                                     the simulator (when the pjrt lane
                                     is unavailable the set degrades to
                                     sim-only routing)
  egpu-fft serve --qos-classes NAME:W[:CAP[:DL_MS]],...
                 [--requests N] [--points P] [--shards N]
                 [--policy block|shed|degrade]
                                     multi-class QoS frontend demo:
                                     submit N requests round-robin over
                                     the configured classes through the
                                     WFQ/EDF scheduler and print the
                                     per-class serve shares (weight 0 =
                                     background class, aging-protected)
  egpu-fft serve --tenants NAME:RATE[:BURST[:QUOTA[:prio]]],...
                 [--qos-classes ...] [--requests N] [--points P]
                 [--shards N] [--policy block|shed|degrade]
                                     multi-tenant frontend demo: each
                                     request carries a tenant id and is
                                     throttled by that tenant's token
                                     bucket (RATE req/s sustained, BURST
                                     capacity) and in-flight job-unit
                                     QUOTA before it can occupy a class
                                     queue; `prio` tenants preempt
                                     background multi-pass work at the
                                     between-pass checkpoint; prints the
                                     per-tenant admitted/throttled/
                                     billed breakdown
  egpu-fft serve --autoscale [--min-shards A] [--max-shards B]
                 [--target-p99-ms X] [--max-shed-rate F]
                 [--degrade half|quarter]
                 [--swap-p99-ms X --backends sim,pjrt]
                 [--rate R] [--duration S] [--queue-capacity N]
                                     elastic serving demo: an SLO-driven
                                     controller grows/shrinks the shard
                                     pool from the traffic frontend's
                                     pressure feed while an open-loop
                                     load step (rate R, then 2R) runs;
                                     prints scale events, shards over
                                     time, and before/after shed rates
                                     (--degrade arms the resolution
                                      ladder: bursts are served coarser
                                      before any shard is added;
                                      --swap-p99-ms arms the backend
                                      swap: when service p99 exceeds X
                                      ms the controller pins the
                                      measured-fastest lane before
                                      scaling — requires --backends)
  egpu-fft loadtest [--mix fft|large-n|ntt]
                 [--pattern poisson|burst] [--rate R] [--duration S]
                 [--policy block|shed|degrade] [--queue-capacity N]
                 [--qos-classes NAME:W[:CAP[:DL_MS]],...]
                 [--class-mix F0,F1,...]
                 [--tenants NAME:RATE[:BURST[:QUOTA[:prio]]],...]
                 [--tenant-mix F0,F1,...]
                 [--shards N] [--dispatchers N] [--sizes 256,1024,...]
                 [--deadline-ms D] [--aging-ms A] [--high-frac F]
                 [--burst N] [--seed S] [--json [PATH]]
                                     open-loop load test through the
                                     admission-controlled QoS frontend:
                                     offered vs achieved throughput,
                                     shed rate, deadline miss rate,
                                     queue-wait / service-time tails,
                                     and per-class + per-tenant
                                     breakdowns (--mix picks the request
                                      mix: `fft` is the default 256-4096
                                      complex pool, `large-n` reaches
                                      past the single-pass ceiling, and
                                      `ntt` submits Goldilocks
                                      prime-field payloads through the
                                      same frontend;
                                      --tenants arms the
                                      tenancy layer; --tenant-mix splits
                                      arrivals across tenant indices,
                                      defaulting to a uniform split —
                                      offer one tenant far more than its
                                      bucket admits to reproduce the
                                      adversarial isolation run;
                                      --json alone prints the JSON
                                      report to stdout; --json PATH
                                      writes it to a file)
  egpu-fft help

Variants: DP, DP-VM, DP-Complex, DP-VM-Complex, QP, QP-Complex";

fn parse_variant(s: &str) -> Result<Variant> {
    let v = match s.to_uppercase().as_str() {
        "DP" => Variant::DP,
        "DP-VM" => Variant::DP_VM,
        "DP-COMPLEX" => Variant::DP_COMPLEX,
        "DP-VM-COMPLEX" => Variant::DP_VM_COMPLEX,
        "QP" => Variant::QP,
        "QP-COMPLEX" => Variant::QP_COMPLEX,
        _ => bail!("unknown variant `{s}`"),
    };
    Ok(v)
}

fn parse_sizes(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|p| p.trim().parse::<usize>().map_err(|e| anyhow!("bad size `{p}`: {e}")))
        .collect()
}

/// `NAME:WEIGHT[:CAPACITY[:DEADLINE_MS]],...` — e.g.
/// `gold:5:64:25,silver:3:64,bg:0`.
fn parse_qos_classes(s: &str) -> Result<Vec<QosClass>> {
    s.split(',')
        .map(|spec| {
            let parts: Vec<&str> = spec.trim().split(':').collect();
            if parts.len() < 2 || parts.len() > 4 || parts[0].is_empty() {
                bail!("bad class spec `{spec}` (NAME:WEIGHT[:CAPACITY[:DEADLINE_MS]])");
            }
            if !parts[0].chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                bail!("class name `{}` must be alphanumeric/_/- only", parts[0]);
            }
            let weight: u32 = parts[1].parse().map_err(|e| anyhow!("bad weight in `{spec}`: {e}"))?;
            let mut class = QosClass::new(parts[0], weight);
            if let Some(cap) = parts.get(2) {
                class.capacity = cap.parse().map_err(|e| anyhow!("bad capacity in `{spec}`: {e}"))?;
            }
            if let Some(dl) = parts.get(3) {
                let ms: f64 = dl.parse().map_err(|e| anyhow!("bad deadline in `{spec}`: {e}"))?;
                if ms > 0.0 {
                    class.deadline_default = Some(Duration::from_secs_f64(ms / 1e3));
                }
            }
            Ok(class)
        })
        .collect()
}

/// `NAME:RATE_HZ[:BURST[:QUOTA[:prio]]],...` — e.g.
/// `victim:50:10:-:prio,abuser:200:40:512`. RATE_HZ is the token
/// bucket's sustained refill rate; BURST its capacity (defaults to the
/// rate rounded up, min 1); QUOTA the in-flight job-unit cap (`-` = no
/// cap); a trailing `prio` marks the tenant as preempting background
/// multi-pass work at the between-pass checkpoint.
fn parse_tenants(s: &str) -> Result<Vec<TenantSpec>> {
    s.split(',')
        .map(|spec| {
            let parts: Vec<&str> = spec.trim().split(':').collect();
            if parts.len() < 2 || parts.len() > 5 || parts[0].is_empty() {
                bail!("bad tenant spec `{spec}` (NAME:RATE_HZ[:BURST[:QUOTA[:prio]]])");
            }
            if !parts[0].chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                bail!("tenant name `{}` must be alphanumeric/_/- only", parts[0]);
            }
            let rate: f64 =
                parts[1].parse().map_err(|e| anyhow!("bad rate in `{spec}`: {e}"))?;
            if !rate.is_finite() || rate < 0.0 {
                bail!("tenant rate in `{spec}` must be finite and >= 0");
            }
            let burst: u64 = match parts.get(2) {
                Some(b) => b.parse().map_err(|e| anyhow!("bad burst in `{spec}`: {e}"))?,
                None => (rate.ceil() as u64).max(1),
            };
            let mut t = TenantSpec::new(parts[0], rate, burst);
            if let Some(&q) = parts.get(3) {
                if q != "-" {
                    let units: u64 =
                        q.parse().map_err(|e| anyhow!("bad quota in `{spec}`: {e}"))?;
                    if units == 0 {
                        bail!("tenant quota in `{spec}` must be > 0 (use `-` for no cap)");
                    }
                    t = t.with_quota(units);
                }
            }
            if let Some(&p) = parts.get(4) {
                match p {
                    "prio" => t = t.with_priority(),
                    "-" => {}
                    other => bail!("bad priority marker `{other}` in `{spec}` (use `prio`)"),
                }
            }
            Ok(t)
        })
        .collect()
}

fn parse_mix(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|p| p.trim().parse::<f64>().map_err(|e| anyhow!("bad mix fraction `{p}`: {e}")))
        .collect()
}

fn flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    out.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    out.insert(key.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    out
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("table") => {
            let n: u32 = args
                .get(1)
                .ok_or_else(|| anyhow!("table number required"))?
                .parse()?;
            print_table(n)
        }
        Some("figure") => {
            let n: u32 = args
                .get(1)
                .ok_or_else(|| anyhow!("figure number required"))?
                .parse()?;
            match n {
                2 => print!("{}", report::figure2(32, 3)?),
                4 => print!("{}", report::figure4()),
                _ => bail!("only figures 2 and 4 exist"),
            }
            Ok(())
        }
        Some("tables") => {
            for n in 1..=6 {
                print_table(n)?;
                println!();
            }
            Ok(())
        }
        Some("run") => {
            let f = flags(&args[1..]);
            let points: usize = f.get("points").map(|s| s.parse()).transpose()?.unwrap_or(4096);
            let radix: usize = f.get("radix").map(|s| s.parse()).transpose()?.unwrap_or(16);
            let variant = parse_variant(f.get("variant").map(String::as_str).unwrap_or("DP"))?;
            let cfg = SmConfig::for_radix(variant, radix);
            let fp = fft::generate(&cfg, points, radix)?;
            if f.contains_key("listing") {
                print!("{}", fp.program.listing());
            }
            let (profile, err) = fft::validate(&cfg, points, radix, 2024)?;
            println!(
                "{points}-point radix-{radix} on {variant} ({} instructions)",
                fp.program.len()
            );
            println!("{profile}");
            println!("numerics vs reference FFT: rms {err:.2e}");
            Ok(())
        }
        Some("batch") => {
            let f = flags(&args[1..]);
            let points: usize = f.get("points").map(|s| s.parse()).transpose()?.unwrap_or(1024);
            let radix: usize = f.get("radix").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let batch: usize = f.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let cfg = SmConfig::for_radix(Variant::DP, radix);
            let fp = fft::generate_batched(&cfg, points, radix, batch)?;
            let inputs: Vec<Vec<(f32, f32)>> = (0..batch)
                .map(|b| {
                    reference::test_signal(points, b as u64)
                        .iter()
                        .map(|c| c.to_f32_pair())
                        .collect()
                })
                .collect();
            let (_, prof) = fft::run_fft_batch(&fp, &cfg, &inputs)?;
            let (single, _) = fft::validate(&cfg, points, radix, 0)?;
            let per = prof.total() as f64 / batch as f64;
            println!(
                "fft{points} radix-{radix} x{batch}: {per:.0} cycles/FFT vs {} single \
                 ({:+.1}%), efficiency {:.2}% vs {:.2}%",
                single.total(),
                100.0 * (per / single.total() as f64 - 1.0),
                prof.efficiency_pct(),
                single.efficiency_pct()
            );
            Ok(())
        }
        Some("reduce") => {
            let f = flags(&args[1..]);
            let n: usize = f.get("n").map(|s| s.parse()).transpose()?.unwrap_or(8192);
            let variant = parse_variant(f.get("variant").map(String::as_str).unwrap_or("DP-VM"))?;
            let cfg = SmConfig::for_radix(variant, 4);
            let rp = egpu_fft::apps::reduction::generate(&cfg, n)?;
            let input: Vec<f32> =
                reference::test_signal(n, 3).iter().map(|c| c.re as f32).collect();
            let want: f64 = input.iter().map(|&v| v as f64).sum();
            let (sum, prof) = egpu_fft::apps::reduction::run(&rp, &cfg, &input)?;
            println!("reduce {n} on {variant}: sum {sum:.4} (reference {want:.4})");
            println!("{prof}");
            Ok(())
        }
        Some("validate") => {
            let mut checked = 0;
            for radix in [2usize, 4, 8, 16] {
                for points in [256usize, 512, 1024, 4096] {
                    for v in Variant::ALL6 {
                        let cfg = SmConfig::for_radix(v, radix);
                        let (_, err) = fft::validate(&cfg, points, radix, 7)?;
                        if err > fft::F32_TOL {
                            bail!("FAIL {points}/{radix}/{v}: rms {err:e}");
                        }
                        checked += 1;
                    }
                }
            }
            println!("numerics OK across {checked} design points");
            Ok(())
        }
        Some("serve") => {
            let f = flags(&args[1..]);
            if f.contains_key("autoscale") {
                return serve_autoscale(&f);
            }
            if f.contains_key("qos-classes") || f.contains_key("tenants") {
                return serve_qos(&f);
            }
            if f.contains_key("backends") {
                return serve_routed(&f);
            }
            let cores: usize = f.get("cores").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let requests: usize =
                f.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(64);
            let points: usize = f.get("points").map(|s| s.parse()).transpose()?.unwrap_or(1024);
            let backend = match f.get("backend").map(String::as_str).unwrap_or("sim") {
                "sim" => Backend::Simulator,
                "pjrt" => Backend::Pjrt,
                "validate" => Backend::Validate,
                b => bail!("unknown backend `{b}`"),
            };
            let inputs: Vec<Vec<(f32, f32)>> = (0..requests)
                .map(|i| {
                    reference::test_signal(points, i as u64)
                        .iter()
                        .map(|c| c.to_f32_pair())
                        .collect()
                })
                .collect();
            let batched = f.contains_key("batched");
            let mode = if batched { "batched dispatch" } else { "per-request dispatch" };
            if let Some(shards) = f.get("shards") {
                let shards: usize = shards.parse()?;
                if f.contains_key("cores") {
                    eprintln!(
                        "note: --cores is ignored with --shards \
                         (each shard runs one resident-SM worker)"
                    );
                }
                let steal_threshold: usize =
                    f.get("steal-threshold").map(|s| s.parse()).transpose()?.unwrap_or(2);
                let svc = ShardedFftService::start(ShardPoolConfig {
                    shards,
                    steal_threshold,
                    service: ServiceConfig { backend, ..Default::default() },
                    ..Default::default()
                })?;
                let t0 = std::time::Instant::now();
                let results = if batched {
                    svc.request_all(inputs.into_iter().map(FftRequest::new).collect())?
                } else {
                    svc.run_batch(inputs)?
                };
                let wall = t0.elapsed();
                println!(
                    "served {} fft{points} requests ({mode}) on {} shards in {:.1} ms \
                     ({:.0} req/s)",
                    results.len(),
                    svc.shards(),
                    wall.as_secs_f64() * 1e3,
                    results.len() as f64 / wall.as_secs_f64()
                );
                print!("{}", svc.metrics().render());
                svc.shutdown();
                return Ok(());
            }
            let svc = FftService::start(ServiceConfig {
                cores,
                backend,
                ..Default::default()
            })?;
            let t0 = std::time::Instant::now();
            let results = if batched {
                svc.request_all(inputs.into_iter().map(FftRequest::new).collect())?
            } else {
                svc.run_batch(inputs)?
            };
            let wall = t0.elapsed();
            println!(
                "served {} fft{points} requests ({mode}) on {cores} cores in {:.1} ms \
                 ({:.0} req/s)",
                results.len(),
                wall.as_secs_f64() * 1e3,
                results.len() as f64 / wall.as_secs_f64()
            );
            print!("{}", svc.metrics().render());
            svc.shutdown();
            Ok(())
        }
        Some("loadtest") => {
            let f = flags(&args[1..]);
            // The preset supplies the workload and the defaults the
            // explicit flags below override.
            let base = match f.get("mix").map(String::as_str).unwrap_or("fft") {
                "fft" => LoadgenConfig::default(),
                "large-n" | "large_n" => LoadgenConfig::large_n(),
                "ntt" => LoadgenConfig::ntt(),
                m => bail!("unknown mix `{m}` (fft|large-n|ntt)"),
            };
            let pattern: ArrivalPattern =
                f.get("pattern").map(String::as_str).unwrap_or("poisson").parse()?;
            let rate: f64 =
                f.get("rate").map(|s| s.parse()).transpose()?.unwrap_or(base.rate_hz);
            if rate <= 0.0 {
                bail!("--rate must be positive");
            }
            let duration: f64 =
                f.get("duration").map(|s| s.parse()).transpose()?.unwrap_or(2.0);
            if duration <= 0.0 {
                bail!("--duration must be positive");
            }
            let burst: usize = f.get("burst").map(|s| s.parse()).transpose()?.unwrap_or(32);
            let sizes: Vec<usize> = f
                .get("sizes")
                .map(|s| parse_sizes(s))
                .transpose()?
                .unwrap_or_else(|| base.sizes.clone());
            let high_frac: f64 =
                f.get("high-frac").map(|s| s.parse()).transpose()?.unwrap_or(0.5);
            let deadline = match f.get("deadline-ms") {
                Some(s) => {
                    let ms: f64 = s.parse()?;
                    if ms < 0.0 {
                        bail!("--deadline-ms must be >= 0 (0 disables deadlines)");
                    }
                    (ms > 0.0).then(|| Duration::from_secs_f64(ms / 1e3))
                }
                None => base.deadline,
            };
            let aging_ms: f64 =
                f.get("aging-ms").map(|s| s.parse()).transpose()?.unwrap_or(10.0);
            if aging_ms < 0.0 {
                bail!("--aging-ms must be >= 0");
            }
            let seed: u64 = f.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
            let policy = match f.get("policy").map(String::as_str).unwrap_or("shed") {
                "block" => AdmissionPolicy::Block,
                "shed" => AdmissionPolicy::Shed,
                "degrade" => AdmissionPolicy::Degrade,
                p => bail!("unknown policy `{p}` (block|shed|degrade)"),
            };
            let queue_capacity: usize =
                f.get("queue-capacity").map(|s| s.parse()).transpose()?.unwrap_or(256);
            let dispatchers: usize =
                f.get("dispatchers").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let shards: usize = f.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let classes = f.get("qos-classes").map(|s| parse_qos_classes(s)).transpose()?;
            let class_mix = f
                .get("class-mix")
                .map(|s| parse_mix(s))
                .transpose()?
                .unwrap_or_default();
            let tenants =
                f.get("tenants").map(|s| parse_tenants(s)).transpose()?.unwrap_or_default();
            let tenant_mix = f
                .get("tenant-mix")
                .map(|s| parse_mix(s))
                .transpose()?
                .unwrap_or_default();
            if !tenant_mix.is_empty() && tenants.is_empty() {
                bail!("--tenant-mix requires --tenants");
            }
            // a tenant roster without an explicit mix splits arrivals
            // uniformly, so every configured tenant receives traffic
            let tenant_mix = if !tenants.is_empty() && tenant_mix.is_empty() {
                vec![1.0; tenants.len()]
            } else {
                tenant_mix
            };
            // an explicit mix without explicit classes gets one
            // equal-weight class per fraction; --queue-capacity sets the
            // per-class cap on derived classes (an explicit --qos-classes
            // spec carries its own capacities)
            let classes = match classes {
                Some(c) => Some(c),
                None if !class_mix.is_empty() => Some(
                    (0..class_mix.len())
                        .map(|i| {
                            QosClass::new(&format!("class{i}"), 1).with_capacity(queue_capacity)
                        })
                        .collect(),
                ),
                None => None,
            };

            let inner = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
                shards,
                service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
                ..Default::default()
            })?);
            let mut server_cfg = ServerConfig {
                policy,
                dispatchers,
                aging: Duration::from_secs_f64(aging_ms / 1e3),
                tenants,
                ..Default::default()
            };
            server_cfg.classes = match classes {
                Some(c) => c,
                None => server_cfg
                    .classes
                    .into_iter()
                    .map(|c| c.with_capacity(queue_capacity))
                    .collect(),
            };
            let server = TrafficServer::start(inner, server_cfg)?;
            let cfg = LoadgenConfig {
                pattern,
                rate_hz: rate,
                duration: Duration::from_secs_f64(duration),
                burst_size: burst,
                sizes,
                high_fraction: high_frac,
                class_mix,
                tenant_mix,
                deadline,
                workload: base.workload,
                seed,
            };
            let report = loadgen::run(&server, &cfg);
            match f.get("json").map(String::as_str) {
                Some("true") => println!("{}", report.to_json()),
                Some(path) => {
                    std::fs::write(path, report.to_json() + "\n")?;
                    eprintln!("wrote {path}");
                }
                None => {
                    print!("{}", report.render());
                    print!("{}", server.metrics().render());
                }
            }
            server.shutdown();
            Ok(())
        }
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

/// `serve --qos-classes` / `serve --tenants`: a multi-class QoS
/// frontend demo. Submits `--requests` FFTs round-robin across the
/// configured classes (and, with `--tenants`, round-robin across the
/// tenant roster so each tenant's token bucket and quota are exercised)
/// through the WFQ/EDF scheduler, then prints the per-class serve
/// shares and the per-tenant admitted/throttled breakdown.
fn serve_qos(f: &HashMap<String, String>) -> Result<()> {
    let classes = f.get("qos-classes").map(|s| parse_qos_classes(s)).transpose()?;
    let tenants =
        f.get("tenants").map(|s| parse_tenants(s)).transpose()?.unwrap_or_default();
    let requests: usize = f.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(96);
    let points: usize = f.get("points").map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let shards: usize = f.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let policy = match f.get("policy").map(String::as_str).unwrap_or("shed") {
        "block" => AdmissionPolicy::Block,
        "shed" => AdmissionPolicy::Shed,
        "degrade" => AdmissionPolicy::Degrade,
        p => bail!("unknown policy `{p}` (block|shed|degrade)"),
    };
    let inner = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
        shards,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })?);
    let mut server_cfg = ServerConfig { policy, tenants, ..Default::default() };
    if let Some(c) = classes {
        server_cfg.classes = c;
    }
    let n_classes = server_cfg.classes.len();
    let n_tenants = server_cfg.tenants.len();
    let server = TrafficServer::start(inner, server_cfg)?;
    let input: Vec<(f32, f32)> =
        reference::test_signal(points, 11).iter().map(|c| c.to_f32_pair()).collect();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..requests)
        .filter_map(|i| {
            let mut req = FftRequest::new(input.clone()).with_class(i % n_classes);
            if n_tenants > 0 {
                req = req.with_tenant(i % n_tenants);
            }
            server.request(req).ok()
        })
        .collect();
    let served = handles.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    let wall = t0.elapsed();
    println!(
        "qos serve: {served}/{requests} fft{points} requests over {n_classes} classes{} \
         in {:.1} ms ({:.0} req/s)",
        if n_tenants > 0 { format!(" and {n_tenants} tenants") } else { String::new() },
        wall.as_secs_f64() * 1e3,
        served as f64 / wall.as_secs_f64()
    );
    print!("{}", server.metrics().render());
    server.shutdown();
    Ok(())
}

/// Register each lane from a `--backends` comma list. `sim` is the
/// always-present reference lane; a `pjrt` lane that cannot spawn (no
/// `pjrt` feature, or missing artifacts) degrades to sim-only routing
/// with a note, so the command runs in any build.
fn register_backends(set: &mut BackendSet, spec: &str) -> Result<()> {
    for name in spec.split(',').map(str::trim).filter(|n| !n.is_empty()) {
        match name {
            "sim" => {}
            "pjrt" => match spawn_pjrt_server("artifacts") {
                Ok((handle, _server)) => set.register("pjrt", Box::new(handle), 1)?,
                Err(e) => {
                    eprintln!("note: pjrt lane unavailable ({e:#}); routing sim-only");
                }
            },
            other => bail!("unknown backend `{other}` in --backends (sim|pjrt)"),
        }
    }
    Ok(())
}

/// `serve --backends`: the multi-backend routing demo. Builds the
/// simulator service (pool, or sharded with `--shards`), registers the
/// requested alternate lanes, seeds the measured cost model with a
/// calibration pass, then drives `--requests` transforms through the
/// router and prints the per-lane serve counters.
fn serve_routed(f: &HashMap<String, String>) -> Result<()> {
    let spec = f.get("backends").expect("dispatched on the flag's presence");
    let requests: usize = f.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let points: usize = f.get("points").map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let validate_fraction: f64 =
        f.get("validate-fraction").map(|s| s.parse()).transpose()?.unwrap_or(0.0);
    let workers: usize = f.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let sim = if let Some(shards) = f.get("shards") {
        ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
            shards: shards.parse()?,
            service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
            ..Default::default()
        })?)
    } else {
        let cores: usize = f.get("cores").map(|s| s.parse()).transpose()?.unwrap_or(4);
        ServiceHandle::Pool(FftService::start(ServiceConfig { cores, ..Default::default() })?)
    };
    let mut set = BackendSet::new(
        sim,
        BackendSetConfig {
            validate_fraction,
            calibrate_sizes: vec![points],
            ..Default::default()
        },
    )?;
    register_backends(&mut set, spec)?;
    set.calibrate()?;
    let handle = ServiceHandle::Routed(set);
    let inputs: Vec<Vec<(f32, f32)>> = (0..requests)
        .map(|i| {
            reference::test_signal(points, i as u64)
                .iter()
                .map(|c| c.to_f32_pair())
                .collect()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = handle
        .as_routed()
        .expect("just wrapped")
        .run_batch(inputs, workers)?;
    let wall = t0.elapsed();
    println!(
        "served {} fft{points} requests routed over [{spec}] in {:.1} ms ({:.0} req/s)",
        results.len(),
        wall.as_secs_f64() * 1e3,
        results.len() as f64 / wall.as_secs_f64()
    );
    print!("{}", handle.metrics().render());
    handle.shutdown();
    Ok(())
}

/// Validate the `serve --autoscale` flag combination before any
/// service threads start: the controller resizes the *sharded*
/// service, so the fixed-size pool (`--cores`) cannot be its scaling
/// actuator, and the backend-swap threshold needs a routed backend set
/// to act on.
fn validate_autoscale_flags(
    f: &HashMap<String, String>,
) -> std::result::Result<(), ServiceError> {
    if f.contains_key("cores") {
        return Err(ServiceError::ActuatorMismatch(
            "--cores selects the fixed-size pool service, but --autoscale resizes the \
             sharded service; use --min-shards/--max-shards instead"
                .into(),
        ));
    }
    if f.contains_key("swap-p99-ms") && !f.contains_key("backends") {
        return Err(ServiceError::ActuatorMismatch(
            "--swap-p99-ms drives the backend-swap actuator, which needs --backends \
             sim,pjrt to build a routed backend set"
                .into(),
        ));
    }
    Ok(())
}

/// `serve --autoscale`: an elastic-serving demo. Starts the sharded
/// service at `--min-shards`, wraps it in the admission-controlled
/// frontend, and lets the SLO-driven controller resize the pool while
/// an open-loop load step runs (`--rate` for the first half of
/// `--duration`, doubled for the second half). `--degrade half|quarter`
/// arms the resolution ladder: the controller serves bursts coarser
/// before reaching for a shard.
fn serve_autoscale(f: &HashMap<String, String>) -> Result<()> {
    validate_autoscale_flags(f)?;
    let min_shards: usize = f.get("min-shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let max_shards: usize = f.get("max-shards").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let target_p99_ms: f64 =
        f.get("target-p99-ms").map(|s| s.parse()).transpose()?.unwrap_or(10.0);
    let max_shed_rate: f64 =
        f.get("max-shed-rate").map(|s| s.parse()).transpose()?.unwrap_or(0.01);
    let rate: f64 = f.get("rate").map(|s| s.parse()).transpose()?.unwrap_or(2000.0);
    if rate <= 0.0 {
        bail!("--rate must be positive");
    }
    let duration: f64 = f.get("duration").map(|s| s.parse()).transpose()?.unwrap_or(4.0);
    if duration <= 0.0 {
        bail!("--duration must be positive");
    }
    let queue_capacity: usize =
        f.get("queue-capacity").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let swap_p99_ms: f64 =
        f.get("swap-p99-ms").map(|s| s.parse()).transpose()?.unwrap_or(0.0);

    let sharded = ServiceHandle::Sharded(ShardedFftService::start(ShardPoolConfig {
        shards: min_shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })?);
    let inner = match f.get("backends") {
        Some(spec) => {
            let mut set = BackendSet::new(sharded, BackendSetConfig::default())?;
            register_backends(&mut set, spec)?;
            set.calibrate()?;
            ServiceHandle::Routed(set)
        }
        None => sharded,
    };
    let mut server_cfg = ServerConfig {
        policy: AdmissionPolicy::Shed,
        dispatchers: (2 * max_shards).max(4),
        ..Default::default()
    };
    server_cfg.classes =
        server_cfg.classes.into_iter().map(|c| c.with_capacity(queue_capacity)).collect();
    let server = TrafficServer::start(inner, server_cfg)?;
    let max_degrade: DegradeLevel = f
        .get("degrade")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(DegradeLevel::Full);
    let policy = AutoscalePolicy {
        min_shards,
        max_shards,
        target_p99_ms,
        max_shed_rate,
        max_degrade,
        swap_service_p99_ms: swap_p99_ms,
        ..Default::default()
    };
    let controller = AutoscaleController::spawn(&server, policy)?;

    let phase = Duration::from_secs_f64(duration / 2.0);
    println!(
        "autoscale serve: {min_shards}..{max_shards} shards, SLO queue p99 \
         {target_p99_ms:.1}ms / shed {max_shed_rate:.3}; offered {rate:.0} rps then \
         {:.0} rps ({:.1}s each)",
        2.0 * rate,
        phase.as_secs_f64()
    );
    for (label, phase_rate) in [("baseline", rate), ("step (2x offered)", 2.0 * rate)] {
        let report = loadgen::run(
            &server,
            &LoadgenConfig { rate_hz: phase_rate, duration: phase, ..Default::default() },
        );
        println!("-- {label} --");
        print!("{}", report.render());
    }
    let log = controller.stop();
    print!("{}", log.render());
    print!("{}", server.metrics().render());
    server.shutdown();
    Ok(())
}

fn print_table(n: u32) -> Result<()> {
    match n {
        1 => print!("{}", report::profile_table(4)?.render_markdown()),
        2 => print!("{}", report::profile_table(8)?.render_markdown()),
        3 => print!("{}", report::profile_table(16)?.render_markdown()),
        4 => print!("{}", report::render_table4()),
        5 => print!("{}", report::render_table5(&report::table5()?)),
        6 => print!("{}", report::render_table6(&report::table6()?)),
        _ => bail!("tables 1-6 exist"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fl(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn autoscale_rejects_the_fixed_size_pool_up_front() {
        let err = validate_autoscale_flags(&fl(&[("autoscale", "true"), ("cores", "4")]))
            .expect_err("--cores selects the pool service");
        assert!(err.to_string().contains("actuator/service mismatch"), "{err}");
        assert!(err.to_string().contains("--min-shards"), "{err}");
    }

    #[test]
    fn swap_threshold_requires_a_routed_backend_set() {
        let err = validate_autoscale_flags(&fl(&[("autoscale", "true"), ("swap-p99-ms", "5")]))
            .expect_err("swap needs a routed set to act on");
        assert!(err.to_string().contains("--backends"), "{err}");
        let armed =
            fl(&[("autoscale", "true"), ("swap-p99-ms", "5"), ("backends", "sim,pjrt")]);
        assert!(validate_autoscale_flags(&armed).is_ok());
        assert!(validate_autoscale_flags(&fl(&[("autoscale", "true")])).is_ok());
    }

    #[test]
    fn tenant_spec_parsing_covers_every_field_and_rejects_garbage() {
        let ts = parse_tenants("victim:50:10:-:prio,abuser:200:40:512,bg:5").unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].name, "victim");
        assert_eq!(ts[0].rate_hz, 50.0);
        assert_eq!(ts[0].burst, 10);
        assert_eq!(ts[0].quota_units, None);
        assert!(ts[0].priority);
        assert_eq!(ts[1].name, "abuser");
        assert_eq!(ts[1].quota_units, Some(512));
        assert!(!ts[1].priority);
        // burst defaults to the rate rounded up
        assert_eq!(ts[2].burst, 5);
        assert_eq!(ts[2].quota_units, None);

        assert!(parse_tenants("noname").is_err(), "rate is required");
        assert!(parse_tenants(":5:1").is_err(), "name is required");
        assert!(parse_tenants("t:abc").is_err(), "rate must parse");
        assert!(parse_tenants("t:-1").is_err(), "rate must be >= 0");
        assert!(parse_tenants("t:5:1:0").is_err(), "quota 0 is not `no cap`");
        assert!(parse_tenants("t:5:1:-:wat").is_err(), "only `prio` marks priority");
        assert!(parse_tenants("we ird:5").is_err(), "names are alnum/_/-");
    }

    #[test]
    fn flag_parsing_splits_values_and_presence_flags() {
        let args: Vec<String> = ["--cores", "8", "--batched", "--points", "512"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = flags(&args);
        assert_eq!(f.get("cores").map(String::as_str), Some("8"));
        assert_eq!(f.get("batched").map(String::as_str), Some("true"));
        assert_eq!(f.get("points").map(String::as_str), Some("512"));
    }
}
