//! eGPU assembly generators for the paper's FFT programs.
//!
//! One kernel per thread per pass (§3): the thread loads its
//! `radix` complex points from shared memory, computes the radix-R DIF
//! kernel built from radix-2 butterflies with §3.1's reduced-cost
//! internal rotations, applies per-thread twiddles from the shared-
//! memory tables, and stores back in place (digit-reversed natural-
//! order addressing on the final pass, §3.2).
//!
//! Variant lowering:
//! * **Complex FU** — twiddle multiplies become the §5 three-op
//!   sequence `lod_coeff; mul_real; mul_imag` (kernel-internal constant
//!   rotations stay on the real FP path, matching the paper's radix-8
//!   cycle counts);
//! * **VM** — the writeback of bank-eligible passes (exact §4 check in
//!   [`plan`]) uses `save_bank`;
//! * register *renaming* replaces the paper's physical `mov`s for
//!   trivial rotations and kernel-internal reordering (Table 4 lists
//!   those moves; ours fold into addressing — noted in EXPERIMENTS.md).

use std::sync::Arc;

use super::plan::{FftPlan, Layout, Pass, PlanError};
use super::twiddle::{classify, twiddle, TwiddleKind};
use crate::arch::{SmConfig, Variant};
use crate::isa::{Inst, Program, Reg};

/// A generated FFT program plus the metadata needed to run it.
#[derive(Clone, Debug)]
pub struct FftProgram {
    pub program: Program,
    pub plan: FftPlan,
    pub layout: Layout,
    pub variant: Variant,
    /// Precomputed twiddle-table memory image: (base word address,
    /// words). Computed once at generate time so the serving path never
    /// re-evaluates sin/cos (§Perf); each table sits behind an `Arc` so
    /// cloning a program shares the images instead of copying them.
    pub twiddle_image: Vec<(usize, Arc<[u32]>)>,
}

/// Generate the FFT program for one design point under `cfg`.
pub fn generate(cfg: &SmConfig, points: usize, radix: usize) -> Result<FftProgram, PlanError> {
    generate_opt(cfg, points, radix, true)
}

/// Multi-batch program (§6): `batch` resident datasets transformed by
/// one thread initialization; per-pass addressing and twiddle loads are
/// paid once and amortized across the batch ("these would be amortized
/// away for multi-batch FFTs"). Twiddles stay in registers for the
/// whole pass, so the mode needs `2(radix-1)` spare registers — radix
/// ≤ 8 in the paper's register budgets — and a single-block plan.
pub fn generate_batched(
    cfg: &SmConfig,
    points: usize,
    radix: usize,
    batch: usize,
) -> Result<FftProgram, PlanError> {
    if batch <= 1 {
        return generate(cfg, points, radix);
    }
    let plan = FftPlan::new(points, radix, cfg.threads)?;
    if radix > 8 || !plan.single_radix() || plan.passes.iter().any(|p| p.blocks > 1) {
        return Err(PlanError::BatchUnsupported { points, radix });
    }
    let layout = Layout::new_batched(&plan, cfg.smem_words, batch)?;
    let mut g = Gen::new(cfg, &plan, &layout);
    g.emit_program();
    let name = format!(
        "fft{points}x{batch}-r{radix}-{}",
        cfg.variant.name()
    );
    let mut program = Program::new(name, g.code);
    program = super::sched::schedule(&program, cfg.pipeline_depth);
    debug_assert!((program.max_reg() as usize) < cfg.regs_per_thread);
    let twiddle_image = twiddle_image_for(&plan, &layout);
    Ok(FftProgram {
        program,
        plan,
        layout: layout.clone(),
        variant: cfg.variant,
        twiddle_image,
    })
}

fn twiddle_image_for(plan: &FftPlan, layout: &Layout) -> Vec<(usize, Arc<[u32]>)> {
    plan.passes
        .iter()
        .zip(&layout.twiddle_bases)
        .filter_map(|(pass, base)| {
            base.map(|b| {
                let words: Vec<u32> = super::twiddle::pass_table(pass.radix, pass.stride)
                    .into_iter()
                    .flat_map(|(re, im)| [re.to_bits(), im.to_bits()])
                    .collect();
                (b, words.into())
            })
        })
        .collect()
}

/// As [`generate`], optionally skipping the list scheduler (used by the
/// scheduling-ablation benchmark).
pub fn generate_opt(
    cfg: &SmConfig,
    points: usize,
    radix: usize,
    schedule: bool,
) -> Result<FftProgram, PlanError> {
    let plan = FftPlan::new(points, radix, cfg.threads)?;
    let layout = Layout::new(&plan, cfg.smem_words)?;
    let mut g = Gen::new(cfg, &plan, &layout);
    g.emit_program();
    let name = format!("fft{points}-r{radix}-{}", cfg.variant.name());
    let mut program = Program::new(name, g.code);
    if schedule {
        program = super::sched::schedule(&program, cfg.pipeline_depth);
    }
    debug_assert!((program.max_reg() as usize) < cfg.regs_per_thread);
    let twiddle_image = twiddle_image_for(&plan, &layout);
    Ok(FftProgram {
        program,
        plan,
        layout: layout.clone(),
        variant: cfg.variant,
        twiddle_image,
    })
}

// ---------------------------------------------------------------------
// management registers (fixed)
const R_TID: Reg = 0; // thread id, preloaded
const R_A0: Reg = 1; // data base word address (2j)
const R_RIDX: Reg = 2; // twiddle row / reversed base address
const R_TEFF: Reg = 3; // effective thread id for blocked passes
const R_S0: Reg = 4; // scratch
const R_S1: Reg = 5; // scratch
const FIRST_FREE: Reg = 6;

/// One complex value: the registers currently holding (re, im).
#[derive(Clone, Copy, Debug)]
struct Val {
    re: Reg,
    im: Reg,
}

/// Tiny free-list register pool; renaming returns freed registers.
struct Pool {
    free: Vec<Reg>,
    high_water: Reg,
}

impl Pool {
    fn new(first: Reg, last: Reg) -> Self {
        Pool { free: (first..=last).rev().collect(), high_water: 0 }
    }
    fn alloc(&mut self) -> Reg {
        let r = self.free.pop().expect("register pool exhausted");
        self.high_water = self.high_water.max(r);
        r
    }
    fn alloc_val(&mut self) -> Val {
        Val { re: self.alloc(), im: self.alloc() }
    }
    fn release(&mut self, r: Reg) {
        debug_assert!(!self.free.contains(&r));
        self.free.push(r);
    }
    fn release_val(&mut self, v: Val) {
        self.release(v.re);
        self.release(v.im);
    }
}

struct Consts {
    c707: Reg,
    mc707: Reg,
    c16_1: Reg,  // cos(π/8)
    s16_1: Reg,  // sin(π/8)
    mc16_1: Reg, // -cos(π/8)
    ms16_1: Reg, // -sin(π/8)
}

struct Gen<'a> {
    cfg: &'a SmConfig,
    plan: &'a FftPlan,
    layout: &'a Layout,
    code: Vec<Inst>,
    pool: Pool,
    consts: Consts,
}

const SIGN_BIT: u32 = 0x8000_0000;

impl<'a> Gen<'a> {
    fn new(cfg: &'a SmConfig, plan: &'a FftPlan, layout: &'a Layout) -> Self {
        let max_radix = plan.passes.iter().map(|p| p.radix).max().unwrap();
        // const registers depend on the largest kernel radix
        let n_consts: Reg = match max_radix {
            16 => 6,
            8 => 2,
            _ => 0,
        };
        let consts = Consts {
            c707: FIRST_FREE,
            mc707: FIRST_FREE + 1,
            c16_1: FIRST_FREE + 2,
            s16_1: FIRST_FREE + 3,
            mc16_1: FIRST_FREE + 4,
            ms16_1: FIRST_FREE + 5,
        };
        let pool_first = FIRST_FREE + n_consts;
        let pool = Pool::new(pool_first, (cfg.regs_per_thread - 1) as Reg);
        Gen { cfg, plan, layout, code: Vec::new(), pool, consts }
    }

    fn push(&mut self, i: Inst) {
        self.code.push(i);
    }

    // -- tiny emit helpers -------------------------------------------
    fn fadd(&mut self, d: Reg, a: Reg, b: Reg) {
        self.push(Inst::FAdd { d, a, b });
    }
    fn fsub(&mut self, d: Reg, a: Reg, b: Reg) {
        self.push(Inst::FSub { d, a, b });
    }
    fn fmul(&mut self, d: Reg, a: Reg, b: Reg) {
        self.push(Inst::FMul { d, a, b });
    }
    fn fneg_int(&mut self, d: Reg, a: Reg) {
        // §3.1: FP multiply by -1 as an integer XOR of the sign bit.
        self.push(Inst::IXorI { d, a, imm: SIGN_BIT, fp_work: true });
    }

    fn emit_program(&mut self) {
        let v = self.cfg.variant;
        if v.complex {
            self.push(Inst::CoeffEn);
        }
        self.emit_consts();
        let n_passes = self.plan.n_passes();
        for p in 0..n_passes {
            self.emit_pass(p);
            self.push(Inst::Bar);
        }
        if v.complex {
            self.push(Inst::CoeffDis);
        }
        self.push(Inst::Halt);
    }

    fn emit_consts(&mut self) {
        let max_radix = self.plan.passes.iter().map(|p| p.radix).max().unwrap();
        if max_radix >= 8 {
            let c = std::f32::consts::FRAC_1_SQRT_2;
            self.push(Inst::LdiF { d: self.consts.c707, imm: c });
            self.push(Inst::LdiF { d: self.consts.mc707, imm: -c });
        }
        if max_radix >= 16 {
            let c1 = (std::f64::consts::PI / 8.0).cos() as f32;
            let s1 = (std::f64::consts::PI / 8.0).sin() as f32;
            self.push(Inst::LdiF { d: self.consts.c16_1, imm: c1 });
            self.push(Inst::LdiF { d: self.consts.s16_1, imm: s1 });
            self.push(Inst::LdiF { d: self.consts.mc16_1, imm: -c1 });
            self.push(Inst::LdiF { d: self.consts.ms16_1, imm: -s1 });
        }
    }

    fn emit_pass(&mut self, p: usize) {
        let pass = self.plan.passes[p];
        let is_last = p + 1 == self.plan.n_passes();
        if self.layout.batch > 1 {
            self.emit_pass_batched(p, &pass, is_last);
            return;
        }
        if is_last && pass.blocks > 1 {
            // The digit-reversed writeback scatters across the whole
            // array, so a later block's inputs would be clobbered by an
            // earlier block's stores. Do what §3.2 describes: keep the
            // entire pass in registers — load + compute every block
            // first, then store every block.
            let vals: Vec<Vec<Val>> = (0..pass.blocks)
                .map(|b| self.emit_block_load_compute(p, &pass, b))
                .collect();
            for (b, v) in vals.into_iter().enumerate() {
                self.emit_block_store(p, &pass, b, v);
            }
        } else {
            for block in 0..pass.blocks {
                let v = self.emit_block_load_compute(p, &pass, block);
                self.emit_block_store(p, &pass, block, v);
            }
        }
    }

    /// Effective-thread register for this block (r0 for block 0).
    fn rt(&mut self, block: usize) -> Reg {
        if block == 0 {
            R_TID
        } else {
            let off = (block * self.plan.threads) as i32;
            self.push(Inst::IAddI { d: R_TEFF, a: R_TID, imm: off });
            R_TEFF
        }
    }

    /// Addressing + loads + kernel + twiddles for one block; returns the
    /// logical-order output values (still in registers).
    fn emit_block_load_compute(&mut self, p: usize, pass: &Pass, block: usize) -> Vec<Val> {
        let rt = self.rt(block);
        self.emit_addressing(pass, rt);
        self.emit_loads_kernel(p, pass, 0, None)
    }

    /// The multi-batch pass body (§6): addressing and twiddle loads
    /// once, then the load/kernel/store sequence per resident dataset
    /// with the twiddles held in registers.
    fn emit_pass_batched(&mut self, p: usize, pass: &Pass, is_last: bool) {
        debug_assert_eq!(pass.blocks, 1);
        self.emit_addressing(pass, R_TID);
        let tw: Option<Vec<Val>> = if pass.twiddles {
            let tw_base = self.layout.twiddle_bases[p].expect("twiddled pass") as i32;
            Some(
                (1..pass.radix)
                    .map(|m| {
                        let w = self.pool.alloc_val();
                        let off = tw_base + 2 * (m as i32 - 1);
                        self.push(Inst::Lds { d: w.re, addr: R_RIDX, offset: off });
                        self.push(Inst::Lds { d: w.im, addr: R_RIDX, offset: off + 1 });
                        w
                    })
                    .collect(),
            )
        } else {
            None
        };
        if is_last {
            // natural-order base: same for every dataset (offsets differ)
            self.emit_reversed_base(R_TID);
        }
        for b in 0..self.layout.batch {
            let boff = (b * self.layout.data_words) as i32;
            let x = self.emit_loads_kernel(p, pass, boff, tw.as_deref());
            self.emit_block_store_at(p, pass, x, boff, is_last);
        }
        if let Some(tw) = tw {
            for w in tw {
                self.pool.release_val(w);
            }
        }
    }

    /// Per-thread base addresses: `a0 = 2·j` and (for twiddled passes)
    /// the twiddle-row word offset in `R_RIDX`.
    fn emit_addressing(&mut self, pass: &Pass, rt: Reg) {
        let radix = pass.radix;
        let log2r = radix.trailing_zeros() as u8;
        let s = pass.stride;
        let log2s = s.trailing_zeros() as u8;

        // ---- addressing: a0 = 2·j, ridx = t mod s ----
        if s == 1 {
            // j = radix · teff
            self.push(Inst::IShlI { d: R_A0, a: rt, sh: log2r + 1 });
        } else if pass.kernels(self.plan.points) <= s {
            // pass 1 (j = teff): every thread index is below the stride
            self.push(Inst::IShlI { d: R_A0, a: rt, sh: 1 });
            if pass.twiddles {
                self.push(Inst::IAndI { d: R_RIDX, a: rt, imm: (s - 1) as u32 });
            }
        } else {
            // j = ((t >> log2s) << (log2s + log2r)) | (t & (s-1))
            self.push(Inst::IShrI { d: R_S0, a: rt, sh: log2s });
            self.push(Inst::IShlI { d: R_S0, a: R_S0, sh: log2s + log2r });
            self.push(Inst::IAndI { d: R_RIDX, a: rt, imm: (s - 1) as u32 });
            self.push(Inst::IAdd { d: R_A0, a: R_S0, b: R_RIDX });
            self.push(Inst::IShlI { d: R_A0, a: R_A0, sh: 1 });
        }

        // twiddle-row word offset: ridx · 2(radix-1)
        if pass.twiddles {
            match radix {
                2 => self.push(Inst::IShlI { d: R_RIDX, a: R_RIDX, sh: 1 }),
                4 => {
                    // ×6 = (r<<1) + (r<<2)
                    self.push(Inst::IShlI { d: R_S0, a: R_RIDX, sh: 1 });
                    self.push(Inst::IShlI { d: R_S1, a: R_RIDX, sh: 2 });
                    self.push(Inst::IAdd { d: R_RIDX, a: R_S0, b: R_S1 });
                }
                8 => {
                    // ×14 = (r<<4) - (r<<1)
                    self.push(Inst::IShlI { d: R_S0, a: R_RIDX, sh: 4 });
                    self.push(Inst::IShlI { d: R_S1, a: R_RIDX, sh: 1 });
                    self.push(Inst::ISub { d: R_RIDX, a: R_S0, b: R_S1 });
                }
                _ => {
                    // ×30 = (r<<5) - (r<<1)
                    self.push(Inst::IShlI { d: R_S0, a: R_RIDX, sh: 5 });
                    self.push(Inst::IShlI { d: R_S1, a: R_RIDX, sh: 1 });
                    self.push(Inst::ISub { d: R_RIDX, a: R_S0, b: R_S1 });
                }
            }
        }

    }

    /// Data loads + kernel + twiddle application for one dataset
    /// (`boff` = word offset of the dataset region); twiddles come from
    /// `preloaded` registers in multi-batch mode, or from shared memory.
    fn emit_loads_kernel(
        &mut self,
        p: usize,
        pass: &Pass,
        boff: i32,
        preloaded: Option<&[Val]>,
    ) -> Vec<Val> {
        let radix = pass.radix;
        let s = pass.stride;
        // ---- data loads ----
        let mut x: Vec<Val> = Vec::with_capacity(radix);
        for k in 0..radix {
            let v = self.pool.alloc_val();
            let off = boff + (2 * k * s) as i32;
            self.push(Inst::Lds { d: v.re, addr: R_A0, offset: off });
            self.push(Inst::Lds { d: v.im, addr: R_A0, offset: off + 1 });
            x.push(v);
        }

        // ---- kernel (logical-order outputs) ----
        match radix {
            2 => self.kernel_radix2(&mut x),
            4 => self.kernel_radix4(&mut x),
            8 => self.kernel_radix8(&mut x),
            16 => self.kernel_radix16(&mut x),
            _ => unreachable!(),
        }

        // ---- per-thread twiddles (outputs 1..radix-1) ----
        if pass.twiddles {
            let tw_base = self.layout.twiddle_bases[p].expect("twiddled pass") as i32;
            for (m, xm) in x.iter_mut().enumerate().skip(1) {
                let w = match preloaded {
                    Some(regs) => regs[m - 1],
                    None => {
                        let off = tw_base + 2 * (m as i32 - 1);
                        let w = self.pool.alloc_val();
                        self.push(Inst::Lds { d: w.re, addr: R_RIDX, offset: off });
                        self.push(Inst::Lds { d: w.im, addr: R_RIDX, offset: off + 1 });
                        w
                    }
                };
                if self.cfg.variant.complex {
                    // §5: lod_coeff + mul_real + mul_imag, renaming the
                    // real result into a fresh register.
                    self.push(Inst::LodCoeff { re: w.re, im: w.im });
                    let new_re = self.pool.alloc();
                    self.push(Inst::MulReal { d: new_re, a: xm.re, b: xm.im });
                    self.push(Inst::MulImag { d: xm.im, a: xm.re, b: xm.im });
                    self.pool.release(xm.re);
                    xm.re = new_re;
                } else {
                    let xv = *xm;
                    let out = self.cmul_regs(xv, w.re, w.im);
                    self.pool.release_val(xv);
                    *xm = out;
                }
                if preloaded.is_none() {
                    self.pool.release_val(w);
                }
            }
        }

        x
    }

    /// Writeback for one block's values (in-place, or digit-reversed on
    /// the final pass), then release their registers.
    fn emit_block_store(&mut self, p: usize, pass: &Pass, block: usize, x: Vec<Val>) {
        let is_last = p + 1 == self.plan.n_passes();
        if is_last {
            // rt/A0 may have been clobbered by a later block's
            // load/compute phase; recompute for blocked final passes.
            let rt = if pass.blocks > 1 { self.rt(block) } else { self.rt(0) };
            self.emit_reversed_base(rt);
        }
        self.emit_block_store_at(p, pass, x, 0, is_last);
    }

    /// The store sequence itself; for final passes `R_RIDX` must already
    /// hold the digit-reversed base. `boff` selects the dataset region.
    fn emit_block_store_at(
        &mut self,
        _p: usize,
        pass: &Pass,
        x: Vec<Val>,
        boff: i32,
        is_last: bool,
    ) {
        let radix = pass.radix;
        let s = pass.stride;
        let use_vm = self.cfg.variant.vm && pass.vm_eligible;
        if is_last {
            let sigma = (self.plan.points / radix) as i32; // weight of last digit
            for (m, xm) in x.iter().enumerate() {
                let off = boff + 2 * sigma * m as i32;
                self.push(Inst::Sts { addr: R_RIDX, offset: off, s: xm.re });
                self.push(Inst::Sts { addr: R_RIDX, offset: off + 1, s: xm.im });
            }
        } else {
            for (k, xk) in x.iter().enumerate() {
                let off = boff + (2 * k * s) as i32;
                if use_vm {
                    self.push(Inst::StsBank { addr: R_A0, offset: off, s: xk.re });
                    self.push(Inst::StsBank { addr: R_A0, offset: off + 1, s: xk.im });
                } else {
                    self.push(Inst::Sts { addr: R_A0, offset: off, s: xk.re });
                    self.push(Inst::Sts { addr: R_A0, offset: off + 1, s: xk.im });
                }
            }
        }
        for v in x {
            self.pool.release_val(v);
        }
    }

    /// Natural-order base address for the final pass (§3.2): the mixed-
    /// radix digit reversal of the thread's kernel base, as a word
    /// address, left in `R_RIDX`.
    fn emit_reversed_base(&mut self, rt: Reg) {
        let last = self.plan.n_passes() - 1;
        let r_last = self.plan.passes[last].radix;
        let mut sigma = 1usize;
        let mut first = true;
        for p in 0..last {
            let pass = &self.plan.passes[p];
            // digit_p(teff) = (teff >> log2(s_p / r_last)) & (R_p - 1)
            let shift = (pass.stride / r_last).trailing_zeros() as u8;
            let wordshift = (sigma.trailing_zeros() + 1) as u8;
            if first {
                self.push(Inst::IShrI { d: R_RIDX, a: rt, sh: shift });
                self.push(Inst::IAndI { d: R_RIDX, a: R_RIDX, imm: (pass.radix - 1) as u32 });
                self.push(Inst::IShlI { d: R_RIDX, a: R_RIDX, sh: wordshift });
                first = false;
            } else {
                self.push(Inst::IShrI { d: R_S0, a: rt, sh: shift });
                self.push(Inst::IAndI { d: R_S0, a: R_S0, imm: (pass.radix - 1) as u32 });
                self.push(Inst::IShlI { d: R_S0, a: R_S0, sh: wordshift });
                self.push(Inst::IAdd { d: R_RIDX, a: R_RIDX, b: R_S0 });
            }
            sigma *= pass.radix;
        }
        if first {
            // single-pass FFT: base is 0
            self.push(Inst::Ldi { d: R_RIDX, imm: 0 });
        }
    }

    // -- complex building blocks --------------------------------------

    /// d = a + b into fresh registers.
    fn cadd_new(&mut self, a: Val, b: Val) -> Val {
        let d = self.pool.alloc_val();
        self.fadd(d.re, a.re, b.re);
        self.fadd(d.im, a.im, b.im);
        d
    }

    /// d = a - b into fresh registers.
    fn csub_new(&mut self, a: Val, b: Val) -> Val {
        let d = self.pool.alloc_val();
        self.fsub(d.re, a.re, b.re);
        self.fsub(d.im, a.im, b.im);
        d
    }

    /// Full 6-op complex multiply `x · (wre, wim)` from register
    /// operands, producing fresh result registers.
    fn cmul_regs(&mut self, x: Val, wre: Reg, wim: Reg) -> Val {
        let t0 = self.pool.alloc();
        let t1 = self.pool.alloc();
        let d = self.pool.alloc_val();
        self.fmul(t0, x.re, wre);
        self.fmul(t1, x.im, wim);
        self.fsub(d.re, t0, t1);
        self.fmul(t0, x.re, wim);
        self.fmul(t1, x.im, wre);
        self.fadd(d.im, t0, t1);
        self.pool.release(t0);
        self.pool.release(t1);
        d
    }

    /// Apply a compile-time constant rotation `w` to `x` using the
    /// §3.1 reduced-cost forms; returns the (possibly renamed) value.
    fn rotate_const(&mut self, x: Val, n: usize, k: usize) -> Val {
        let w = twiddle(n, k);
        match classify(w) {
            TwiddleKind::One => x,
            TwiddleKind::MinusOne => {
                // two INT sign flips
                let d = self.pool.alloc_val();
                self.fneg_int(d.re, x.re);
                self.fneg_int(d.im, x.im);
                self.pool.release_val(x);
                d
            }
            TwiddleKind::MinusJ => {
                // (re,im) -> (im, -re): rename + one INT sign flip
                let nim = self.pool.alloc();
                self.fneg_int(nim, x.re);
                self.pool.release(x.re);
                Val { re: x.im, im: nim }
            }
            TwiddleKind::PlusJ => {
                let nre = self.pool.alloc();
                self.fneg_int(nre, x.im);
                self.pool.release(x.im);
                Val { re: nre, im: x.re }
            }
            TwiddleKind::EqualCoeff { mag, re_neg, im_neg } => {
                // w = m(σr + σi j): 2 add/sub + 2 multiplies (§3.1)
                debug_assert!((mag - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-9);
                let (cpos, cneg) = (self.consts.c707, self.consts.mc707);
                let d = self.pool.alloc_val();
                let t = self.pool.alloc();
                // re' = m(σr·xr − σi·xi); im' = m(σi·xr + σr·xi)
                match (re_neg, im_neg) {
                    (false, true) => {
                        // m(1 - j): re' = m(xr + xi), im' = m(xi − xr)
                        self.fadd(t, x.re, x.im);
                        self.fmul(d.re, t, cpos);
                        self.fsub(t, x.im, x.re);
                        self.fmul(d.im, t, cpos);
                    }
                    (true, true) => {
                        // m(-1 - j): re' = m(xi − xr), im' = −m(xr + xi)
                        self.fsub(t, x.im, x.re);
                        self.fmul(d.re, t, cpos);
                        self.fadd(t, x.re, x.im);
                        self.fmul(d.im, t, cneg);
                    }
                    (true, false) => {
                        // m(-1 + j): re' = −m(xr + xi), im' = m(xr − xi)
                        self.fadd(t, x.re, x.im);
                        self.fmul(d.re, t, cneg);
                        self.fsub(t, x.re, x.im);
                        self.fmul(d.im, t, cpos);
                    }
                    (false, false) => {
                        // m(1 + j): re' = m(xr − xi), im' = m(xr + xi)
                        self.fsub(t, x.re, x.im);
                        self.fmul(d.re, t, cpos);
                        self.fadd(t, x.re, x.im);
                        self.fmul(d.im, t, cpos);
                    }
                }
                self.pool.release(t);
                self.pool.release_val(x);
                d
            }
            TwiddleKind::Full(w) => {
                // constant full rotation from pre-loaded const registers
                // (only the W16 family appears in our kernels)
                let (wre, wim) = self.const_regs_for(w);
                let d = self.cmul_regs(x, wre, wim);
                self.pool.release_val(x);
                d
            }
        }
    }

    /// Map a full-rotation constant onto the pre-loaded W16 registers.
    fn const_regs_for(&self, w: super::twiddle::Cpx) -> (Reg, Reg) {
        let c1 = (std::f64::consts::PI / 8.0).cos();
        let s1 = (std::f64::consts::PI / 8.0).sin();
        let pick = |v: f64| -> Reg {
            if (v - c1).abs() < 1e-9 {
                self.consts.c16_1
            } else if (v + c1).abs() < 1e-9 {
                self.consts.mc16_1
            } else if (v - s1).abs() < 1e-9 {
                self.consts.s16_1
            } else if (v + s1).abs() < 1e-9 {
                self.consts.ms16_1
            } else {
                panic!("unsupported kernel rotation constant {v}");
            }
        };
        (pick(w.re), pick(w.im))
    }

    // -- kernels (in logical output order) -----------------------------

    fn kernel_radix2(&mut self, x: &mut [Val]) {
        let (a, b) = (x[0], x[1]);
        let v = self.csub_new(a, b); // Y1
        let u = self.cadd_new(a, b); // Y0
        self.pool.release_val(a);
        self.pool.release_val(b);
        x[0] = u;
        x[1] = v;
    }

    /// Radix-4 DIF dragonfly: 8 complex add/sub, the ±j rotation folded
    /// into operand routing (16 real FP ops).
    fn kernel_radix4(&mut self, x: &mut [Val]) {
        let (a, b, c, d) = (x[0], x[1], x[2], x[3]);
        let t0 = self.cadd_new(a, c);
        let t1 = self.csub_new(a, c);
        let t2 = self.cadd_new(b, d);
        let t3 = self.csub_new(b, d);
        self.pool.release_val(a);
        self.pool.release_val(b);
        self.pool.release_val(c);
        self.pool.release_val(d);
        let y0 = self.cadd_new(t0, t2);
        let y2 = self.csub_new(t0, t2);
        // Y1 = t1 − j·t3 ; Y3 = t1 + j·t3 (pure add/sub on components)
        let y1 = self.pool.alloc_val();
        self.fadd(y1.re, t1.re, t3.im);
        self.fsub(y1.im, t1.im, t3.re);
        let y3 = self.pool.alloc_val();
        self.fsub(y3.re, t1.re, t3.im);
        self.fadd(y3.im, t1.im, t3.re);
        self.pool.release_val(t0);
        self.pool.release_val(t1);
        self.pool.release_val(t2);
        self.pool.release_val(t3);
        x[0] = y0;
        x[1] = y1;
        x[2] = y2;
        x[3] = y3;
    }

    /// Radix-8 DIF kernel per Table 4: one radix-2 stage with W8
    /// rotations, then two radix-4 kernels on the halves.
    fn kernel_radix8(&mut self, x: &mut [Val]) {
        // stage: u_k = x_k + x_{k+4}; v_k = (x_k − x_{k+4})·W8^k
        let mut u = Vec::with_capacity(4);
        let mut v = Vec::with_capacity(4);
        for k in 0..4 {
            let (a, b) = (x[k], x[k + 4]);
            let vk = self.csub_new(a, b);
            let uk = self.cadd_new(a, b);
            self.pool.release_val(a);
            self.pool.release_val(b);
            u.push(uk);
            v.push(self.rotate_const(vk, 8, k));
        }
        // even outputs from DFT4(u), odd from DFT4(v)
        let mut ue: Vec<Val> = u;
        self.kernel_radix4(&mut ue);
        let mut vo: Vec<Val> = v;
        self.kernel_radix4(&mut vo);
        for m in 0..4 {
            x[2 * m] = ue[m];
            x[2 * m + 1] = vo[m];
        }
    }

    /// Radix-16 DIF kernel: 4 column DFT4s, the 9 internal W16^{kρ}
    /// rotations in §3.1 reduced form (4 full multiplies, 4
    /// equal-coefficient, 1 integer −j), then 4 row DFT4s.
    fn kernel_radix16(&mut self, x: &mut [Val]) {
        // columns: g_ρ(k) = DFT4 over δ of x_{k+4δ}, then ·W16^{kρ}
        let mut g = vec![[None::<Val>; 4]; 4]; // g[ρ][k]
        for k in 0..4 {
            let mut col = vec![x[k], x[k + 4], x[k + 8], x[k + 12]];
            self.kernel_radix4(&mut col);
            for (rho, val) in col.into_iter().enumerate() {
                let rotated = self.rotate_const(val, 16, k * rho);
                g[rho][k] = Some(rotated);
            }
        }
        // rows: Y_{4μ+ρ} = DFT4 over k of g_ρ(k)
        for rho in 0..4 {
            let mut row: Vec<Val> = (0..4).map(|k| g[rho][k].take().unwrap()).collect();
            self.kernel_radix4(&mut row);
            for (mu, val) in row.into_iter().enumerate() {
                x[4 * mu + rho] = val;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    fn gen(points: usize, radix: usize, variant: Variant) -> FftProgram {
        let cfg = SmConfig::for_radix(variant, radix);
        generate(&cfg, points, radix).unwrap()
    }

    /// Static instruction counts for the radix-4 / 4096 program, checked
    /// against the counts derivable from Table 1 (see DESIGN.md §3):
    /// 78 load instructions, 48 stores, and 34 FP ops per twiddled pass.
    #[test]
    fn radix4_4096_static_counts() {
        let f = gen(4096, 4, Variant::DP);
        let h = f.program.class_histogram();
        assert_eq!(h[OpClass::Load.index()], 78, "loads: 5×14 + 8");
        assert_eq!(h[OpClass::Store.index()], 48, "stores: 6 passes × 8");
        // FP: 5 passes × (16 kernel + 18 cmul) + 16 final = 186
        assert_eq!(h[OpClass::Fp.index()], 5 * 34 + 16);
        assert_eq!(h[OpClass::StoreVm.index()], 0);
    }

    #[test]
    fn radix4_4096_vm_splits_stores() {
        let f = gen(4096, 4, Variant::DP_VM);
        let h = f.program.class_histogram();
        // 4 eligible passes bank-write, 2 (incl. final) store coherently
        assert_eq!(h[OpClass::StoreVm.index()], 4 * 8);
        assert_eq!(h[OpClass::Store.index()], 2 * 8);
    }

    #[test]
    fn radix4_4096_complex_variant_counts() {
        let f = gen(4096, 4, Variant::DP_COMPLEX);
        let h = f.program.class_histogram();
        // per twiddled pass: 3 cmuls × (lod_coeff + mul_real + mul_imag)
        // plus the program-level coeff_en/dis pair
        assert_eq!(h[OpClass::Complex.index()], 5 * 9 + 2);
        // FP falls to the 16-op kernel per pass
        assert_eq!(h[OpClass::Fp.index()], 6 * 16);
        // loads unchanged (tw values still fetched into registers)
        assert_eq!(h[OpClass::Load.index()], 78);
    }

    #[test]
    fn radix8_kernel_cost_matches_table4_structure() {
        let f = gen(512, 8, Variant::DP);
        let h = f.program.class_histogram();
        // kernel: 16 stage FP + W8 rotations (0 + 4 + 1 + 4, with W8^3
        // in §3.1 equal-coefficient form where Table 4 spends a full
        // 6-op multiply) + 2×16 DFT4 = 56 FP + the −j integer flip.
        // Twiddled passes add 7 × 6 = 42 -> 98; final pass 56.
        assert_eq!(h[OpClass::Fp.index()], 2 * 98 + 56);
        let f4096 = gen(4096, 8, Variant::DP);
        let h2 = f4096.program.class_histogram();
        assert_eq!(h2[OpClass::Fp.index()], 3 * 98 + 56);
        assert_eq!(h2[OpClass::Load.index()], 3 * (16 + 14) + 16, "paper: 106");
        assert_eq!(h2[OpClass::Store.index()], 4 * 16);
    }

    #[test]
    fn radix16_kernel_cost() {
        let f = gen(4096, 16, Variant::DP);
        let h = f.program.class_histogram();
        // kernel 168 FP; twiddled passes add 15×6 = 90
        assert_eq!(h[OpClass::Fp.index()], 2 * (168 + 90) + 168);
        assert_eq!(h[OpClass::Load.index()], 2 * (32 + 30) + 32, "paper: 156");
    }

    #[test]
    fn register_budget_respected() {
        for (points, radix) in [
            (256, 2),
            (256, 4),
            (1024, 4),
            (4096, 4),
            (512, 8),
            (4096, 8),
            (256, 16),
            (1024, 16),
            (4096, 16),
        ] {
            for v in Variant::ALL6 {
                let cfg = SmConfig::for_radix(v, radix);
                let f = generate(&cfg, points, radix).unwrap();
                assert!(
                    (f.program.max_reg() as usize) < cfg.regs_per_thread,
                    "{points}/{radix}/{v}: r{} vs {}",
                    f.program.max_reg(),
                    cfg.regs_per_thread
                );
            }
        }
    }

    #[test]
    fn mixed_radix_1024_blocks_unrolled() {
        let f = gen(1024, 16, Variant::DP);
        // final radix-4 pass runs as 4 blocks: 4 iaddi teff offsets
        let teff_offsets: Vec<i32> = f
            .program
            .insts
            .iter()
            .filter_map(|i| match i {
                Inst::IAddI { d: 3, a: 0, imm } => Some(*imm),
                _ => None,
            })
            .collect();
        // once in the load/compute phase and once in the store phase
        // (the blocked final pass runs entirely in registers, §3.2)
        assert_eq!(teff_offsets, vec![64, 128, 192, 64, 128, 192]);
        let h = f.program.class_histogram();
        // stores: 2 radix-16 passes ×32 + 4 blocks × 8
        assert_eq!(
            h[OpClass::Store.index()] + h[OpClass::StoreVm.index()],
            2 * 32 + 4 * 8
        );
    }

    #[test]
    fn programs_assemble_round_trip() {
        let f = gen(256, 4, Variant::DP);
        let listing: String = f
            .program
            .insts
            .iter()
            .map(|i| format!("{i}\n"))
            .collect();
        let p2 = crate::isa::asm::assemble("rt", &listing).unwrap();
        // fp_work flags are comments in the listing, so compare by class
        assert_eq!(p2.class_histogram(), f.program.class_histogram());
    }
}
