//! Twiddle factors and their cost classification (§3.1 of the paper).
//!
//! A twiddle `W_N^k = e^{-2πik/N}` multiplying a complex operand costs
//! 6 real FP ops in the pedantic implementation (4 mul + add + sub).
//! §3.1 observes that many of the *compile-time constant* rotations
//! inside an FFT kernel are computationally simple:
//!
//! * `±1`, `±j` — pure sign/swap games, implementable with INT moves
//!   and an XOR of the FP sign bit (`x ^ 0x8000_0000`);
//! * equal-coefficient rotations (odd multiples of π/4, e.g.
//!   `0.707 − 0.707j`) — two real multiplies plus two add/subs.
//!
//! Per-thread twiddles loaded from the shared-memory tables are *data*,
//! so SIMT execution must treat them as full complex multiplies.

use std::f64::consts::PI;
use std::ops::{Add, Mul, Neg, Sub};

use super::field::{ButterflyField, Workload};

/// Double-precision complex scalar used by the planner and reference.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };
    pub const ONE: Cpx = Cpx { re: 1.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Self {
        Cpx { re, im }
    }

    pub fn conj(self) -> Self {
        Cpx::new(self.re, -self.im)
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// `e^{iθ}`.
    pub fn cis(theta: f64) -> Self {
        Cpx::new(theta.cos(), theta.sin())
    }

    pub fn to_f32_pair(self) -> (f32, f32) {
        (self.re as f32, self.im as f32)
    }
}

impl Add for Cpx {
    type Output = Cpx;
    fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for Cpx {
    type Output = Cpx;
    fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for Cpx {
    type Output = Cpx;
    fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Neg for Cpx {
    type Output = Cpx;
    fn neg(self) -> Cpx {
        Cpx::new(-self.re, -self.im)
    }
}

/// Forward-DFT twiddle `W_n^k = e^{-2πik/n}`, computed with exact
/// handling of the quadrant boundaries so classification is robust.
pub fn twiddle(n: usize, k: usize) -> Cpx {
    let k = k % n;
    // Exact values on the axes avoid -0.0 / 1e-17 noise.
    let (num, den) = (4 * k, n); // angle = 2π k/n = (π/2)·(4k/n)
    if num % den == 0 {
        return match (num / den) % 4 {
            0 => Cpx::new(1.0, 0.0),
            1 => Cpx::new(0.0, -1.0),
            2 => Cpx::new(-1.0, 0.0),
            _ => Cpx::new(0.0, 1.0),
        };
    }
    Cpx::cis(-2.0 * PI * k as f64 / n as f64)
}

/// The complex f32 butterfly field: the paper's FFT workload, as one
/// instance of the [`ButterflyField`] boundary. Twiddles are computed
/// in f64 (with [`twiddle`]'s exact axis values) and rounded once to
/// f32 — the precision the executors serve — so every table derived
/// through this impl is bitwise identical to the pre-trait tables.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Complex32;

impl ButterflyField for Complex32 {
    type Elem = (f32, f32);
    const NAME: &'static str = "complex-f32";
    const WORKLOAD: Workload = Workload::Fft;

    fn twiddle(n: usize, k: usize) -> (f32, f32) {
        twiddle(n, k).to_f32_pair()
    }

    fn add(a: (f32, f32), b: (f32, f32)) -> (f32, f32) {
        (a.0 + b.0, a.1 + b.1)
    }

    fn mul(a: (f32, f32), b: (f32, f32)) -> (f32, f32) {
        (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
    }

    // The wire format *is* the element type: both directions move the
    // vector without touching it, keeping the FFT hot path copy-free.
    fn pack_vec(v: Vec<(f32, f32)>) -> Vec<(f32, f32)> {
        v
    }

    fn unpack_vec(v: Vec<(f32, f32)>) -> Vec<(f32, f32)> {
        v
    }
}

/// §3.1 cost classes for a compile-time rotation constant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TwiddleKind {
    /// ×1 — no work at all.
    One,
    /// ×(−1) — two sign flips (INT).
    MinusOne,
    /// ×(−j) — swap + one sign flip (INT, or one INT + one FP).
    MinusJ,
    /// ×(+j) — swap + one sign flip (INT).
    PlusJ,
    /// `m·(σ_r + σ_i·j)` with `|re| == |im|`: two real multiplies and
    /// two add/subs (4 FP ops).
    EqualCoeff {
        /// magnitude of each coefficient (e.g. `0.70710678`)
        mag: f64,
        re_neg: bool,
        im_neg: bool,
    },
    /// General rotation: full 6-op complex multiply.
    Full(Cpx),
}

const EPS: f64 = 1e-12;

pub fn classify(w: Cpx) -> TwiddleKind {
    let close = |a: f64, b: f64| (a - b).abs() < EPS;
    if close(w.re, 1.0) && close(w.im, 0.0) {
        TwiddleKind::One
    } else if close(w.re, -1.0) && close(w.im, 0.0) {
        TwiddleKind::MinusOne
    } else if close(w.re, 0.0) && close(w.im, -1.0) {
        TwiddleKind::MinusJ
    } else if close(w.re, 0.0) && close(w.im, 1.0) {
        TwiddleKind::PlusJ
    } else if close(w.re.abs(), w.im.abs()) {
        TwiddleKind::EqualCoeff {
            mag: w.re.abs(),
            re_neg: w.re < 0.0,
            im_neg: w.im < 0.0,
        }
    } else {
        TwiddleKind::Full(w)
    }
}

impl TwiddleKind {
    /// Real-FP operation count of this rotation (§3.1's accounting).
    pub fn fp_ops(&self) -> usize {
        match self {
            TwiddleKind::One | TwiddleKind::MinusOne | TwiddleKind::MinusJ
            | TwiddleKind::PlusJ => 0,
            TwiddleKind::EqualCoeff { .. } => 4,
            TwiddleKind::Full(_) => 6,
        }
    }
}

/// The per-pass twiddle table stored in shared memory: for each
/// `r ∈ 0..stride`, the `radix−1` factors `W_L^{r·m}` (`m = 1..radix`),
/// with `L = radix·stride`, laid out interleaved re/im.
pub fn pass_table(radix: usize, stride: usize) -> Vec<(f32, f32)> {
    let l = radix * stride;
    let mut out = Vec::with_capacity(stride * (radix - 1));
    for r in 0..stride {
        for m in 1..radix {
            out.push(twiddle(l, r * m).to_f32_pair());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_axis_values() {
        assert_eq!(twiddle(4, 0), Cpx::new(1.0, 0.0));
        assert_eq!(twiddle(4, 1), Cpx::new(0.0, -1.0));
        assert_eq!(twiddle(4, 2), Cpx::new(-1.0, 0.0));
        assert_eq!(twiddle(4, 3), Cpx::new(0.0, 1.0));
        assert_eq!(twiddle(8, 2), Cpx::new(0.0, -1.0));
        assert_eq!(twiddle(16, 8), Cpx::new(-1.0, 0.0));
    }

    #[test]
    fn classification_section_3_1() {
        assert_eq!(classify(twiddle(4, 0)), TwiddleKind::One);
        assert_eq!(classify(twiddle(4, 1)), TwiddleKind::MinusJ);
        assert_eq!(classify(twiddle(4, 2)), TwiddleKind::MinusOne);
        assert_eq!(classify(twiddle(4, 3)), TwiddleKind::PlusJ);
        // W8^1 = 0.707 - 0.707j
        match classify(twiddle(8, 1)) {
            TwiddleKind::EqualCoeff { mag, re_neg, im_neg } => {
                assert!((mag - 0.70710678).abs() < 1e-6);
                assert!(!re_neg && im_neg);
            }
            k => panic!("wrong kind {k:?}"),
        }
        // W8^3 = -0.707 - 0.707j (paper Table 4 treats it as a full
        // complex multiply; classification still sees the symmetry)
        assert!(matches!(classify(twiddle(8, 3)), TwiddleKind::EqualCoeff { .. }));
        assert!(matches!(classify(twiddle(16, 1)), TwiddleKind::Full(_)));
    }

    /// §3.1: in the 16 distinct W values of a radix-2 16-point DFT, the
    /// reduced implementation needs only 4 full complex multiplies.
    #[test]
    fn sixteen_point_reduction() {
        let mut full = 0;
        let mut eq = 0;
        let mut trivial = 0;
        for k in 0..16 {
            match classify(twiddle(16, k)) {
                TwiddleKind::Full(_) => full += 1,
                TwiddleKind::EqualCoeff { .. } => eq += 1,
                _ => trivial += 1,
            }
        }
        // k ∈ {1,3,5,7,9,11,13,15} are full in a naive count, but the
        // kernel only *instantiates* 4 of them (the rest are negations);
        // classification of raw values: 8 full, 4 equal-coeff, 4 trivial.
        assert_eq!((full, eq, trivial), (8, 4, 4));
    }

    #[test]
    fn pass_table_layout() {
        let t = pass_table(4, 4); // radix-4, stride 4, L = 16
        assert_eq!(t.len(), 4 * 3);
        // r=1, m=2 -> W_16^2 at index r*(radix-1) + (m-1) = 1*3 + 1
        let w = twiddle(16, 2).to_f32_pair();
        assert_eq!(t[4], w);
        // r=0 row is all ones
        assert_eq!(t[0], (1.0, 0.0));
        assert_eq!(t[1], (1.0, 0.0));
        assert_eq!(t[2], (1.0, 0.0));
    }

    #[test]
    fn fp_op_costs() {
        assert_eq!(classify(twiddle(4, 1)).fp_ops(), 0);
        assert_eq!(classify(twiddle(8, 1)).fp_ops(), 4);
        assert_eq!(classify(twiddle(16, 1)).fp_ops(), 6);
    }

    #[test]
    fn twiddle_unit_circle_and_group() {
        for (n, k) in [(16usize, 3usize), (64, 17), (4096, 1234)] {
            let w = twiddle(n, k);
            assert!((w.abs() - 1.0).abs() < 1e-12);
            // W_n^k * W_n^{n-k} = 1
            let prod = w * twiddle(n, n - k);
            assert!((prod.re - 1.0).abs() < 1e-12 && prod.im.abs() < 1e-12);
        }
    }
}
