//! Static instruction scheduler for generated FFT passes.
//!
//! The paper's FFT programs are hand-scheduled assembly; at shallow
//! wavefronts (< pipeline depth 8) naive instruction order would stall
//! on every RAW edge. This list scheduler reorders instructions inside
//! each control-free region to maximize dependency distance, mimicking
//! what the paper's authors did by hand (their 256-point runs still
//! show residual NOPs — so does ours).
//!
//! Correctness edges:
//! * register RAW / WAR / WAW;
//! * coefficient cache: `lod_coeff` defines it, `mul_real`/`mul_imag`
//!   read it (and a later `lod_coeff` must not overtake them);
//! * memory: loads never cross stores in either direction (passes are
//!   in-place — another thread's store may alias this thread's load);
//! * control ops (`bar`, `bnz`, `halt`, `coeff_en/dis`) are region
//!   boundaries and never move.

use crate::isa::{Inst, Program};

/// Schedule a whole program, region by region.
pub fn schedule(program: &Program, latency: usize) -> Program {
    let mut out = Vec::with_capacity(program.insts.len());
    let mut region = Vec::new();
    for &inst in &program.insts {
        if is_boundary(&inst) {
            schedule_region(&mut out, &region, latency);
            region.clear();
            out.push(inst);
        } else {
            region.push(inst);
        }
    }
    schedule_region(&mut out, &region, latency);
    Program::new(program.name.clone(), out)
}

fn is_boundary(inst: &Inst) -> bool {
    matches!(
        inst,
        Inst::Bar | Inst::Bnz { .. } | Inst::Halt | Inst::CoeffEn | Inst::CoeffDis | Inst::Nop
    )
}

/// Virtual coefficient-cache "register" id used for dependence tracking.
const COEFF: usize = usize::MAX;

fn defs(inst: &Inst) -> Option<usize> {
    if matches!(inst, Inst::LodCoeff { .. }) {
        return Some(COEFF);
    }
    inst.dst().map(|r| r as usize)
}

fn uses(inst: &Inst) -> Vec<usize> {
    let mut v: Vec<usize> = inst.srcs().map(|r| r as usize).collect();
    if matches!(inst, Inst::MulReal { .. } | Inst::MulImag { .. }) {
        v.push(COEFF);
    }
    v
}

fn is_load(inst: &Inst) -> bool {
    matches!(inst, Inst::Lds { .. })
}

fn is_store(inst: &Inst) -> bool {
    matches!(inst, Inst::Sts { .. } | Inst::StsBank { .. })
}

fn schedule_region(out: &mut Vec<Inst>, region: &[Inst], latency: usize) {
    let n = region.len();
    if n <= 2 {
        out.extend_from_slice(region);
        return;
    }

    // Build the dependence DAG.
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let edge = |from: usize, to: usize, preds: &mut Vec<Vec<usize>>,
                    succs: &mut Vec<Vec<usize>>| {
        if from != to && !succs[from].contains(&to) {
            succs[from].push(to);
            preds[to].push(from);
        }
    };

    use std::collections::HashMap;
    let mut last_def: HashMap<usize, usize> = HashMap::new();
    let mut last_uses: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut loads_seen: Vec<usize> = Vec::new();
    let mut stores_seen: Vec<usize> = Vec::new();

    for (i, inst) in region.iter().enumerate() {
        for u in uses(inst) {
            if let Some(&d) = last_def.get(&u) {
                edge(d, i, &mut preds, &mut succs); // RAW
            }
            last_uses.entry(u).or_default().push(i);
        }
        if let Some(d) = defs(inst) {
            if let Some(&dd) = last_def.get(&d) {
                edge(dd, i, &mut preds, &mut succs); // WAW
            }
            if let Some(us) = last_uses.get(&d) {
                for &u in us {
                    edge(u, i, &mut preds, &mut succs); // WAR
                }
            }
            last_def.insert(d, i);
            last_uses.insert(d, Vec::new());
        }
        if is_load(inst) {
            for &s in &stores_seen {
                edge(s, i, &mut preds, &mut succs); // store -> later load
            }
            loads_seen.push(i);
        }
        if is_store(inst) {
            for &l in &loads_seen {
                edge(l, i, &mut preds, &mut succs); // load -> later store
            }
            // stores keep their mutual order: two stores may alias (the
            // scheduler has no address information), and a save_bank
            // followed by a coherent sts to the same word must not swap
            if let Some(&prev) = stores_seen.last() {
                edge(prev, i, &mut preds, &mut succs);
            }
            stores_seen.push(i);
        }
    }

    // Height (latency-weighted longest path to a sink): classic list-
    // scheduling priority.
    let mut height = vec![0usize; n];
    for i in (0..n).rev() {
        for &s in &succs[i] {
            height[i] = height[i].max(height[s] + latency);
        }
    }

    // Greedy list schedule: among ready nodes pick max height, breaking
    // ties by original order (stability keeps loads early).
    let mut remaining_preds: Vec<usize> = preds.iter().map(Vec::len).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| remaining_preds[i] == 0).collect();
    let mut scheduled = Vec::with_capacity(n);
    while let Some(pos) = ready
        .iter()
        .enumerate()
        .max_by_key(|(_, &i)| (height[i], std::cmp::Reverse(i)))
        .map(|(p, _)| p)
    {
        let i = ready.swap_remove(pos);
        scheduled.push(region[i]);
        for &s in &succs[i] {
            remaining_preds[s] -= 1;
            if remaining_preds[s] == 0 {
                ready.push(s);
            }
        }
    }
    debug_assert_eq!(scheduled.len(), n, "scheduler dropped instructions");
    out.extend(scheduled);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn classes(p: &Program) -> Vec<crate::isa::OpClass> {
        p.insts.iter().map(|i| i.class()).collect()
    }

    #[test]
    fn preserves_instruction_multiset() {
        let p = assemble(
            "t",
            "ldif r1, 1.0\nldif r2, 2.0\nfadd r3, r1, r2\nfmul r4, r3, r3\n\
             lds r5, [r1+0]\nsts [r1+1], r5\nbar\nfadd r6, r4, r4\nhalt",
        )
        .unwrap();
        let s = schedule(&p, 8);
        assert_eq!(s.insts.len(), p.insts.len());
        let mut a = p.insts.iter().map(|i| format!("{i}")).collect::<Vec<_>>();
        let mut b = s.insts.iter().map(|i| format!("{i}")).collect::<Vec<_>>();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn interleaves_independent_chains() {
        // two independent dependent-pairs: scheduler should alternate
        let p = assemble(
            "t",
            "ldif r1, 1.0\nfadd r2, r1, r1\nldif r3, 2.0\nfadd r4, r3, r3\nhalt",
        )
        .unwrap();
        let s = schedule(&p, 8);
        // dependent pair must not be adjacent after scheduling
        let txt: Vec<String> = s.insts.iter().map(|i| format!("{i}")).collect();
        let pos = |needle: &str| txt.iter().position(|t| t == needle).unwrap();
        assert!(pos("fadd r2, r1, r1") > pos("ldif r1, 1.0"));
        assert!(pos("fadd r4, r3, r3") > pos("ldif r3, 2.0"));
        let gap = pos("fadd r2, r1, r1").abs_diff(pos("ldif r1, 1.0"));
        assert!(gap >= 2, "scheduler should interleave: {txt:?}");
    }

    #[test]
    fn loads_never_cross_stores() {
        let p = assemble(
            "t",
            "ldi r1, 0\nlds r2, [r1+0]\nsts [r1+4], r2\nlds r3, [r1+8]\nhalt",
        )
        .unwrap();
        let s = schedule(&p, 8);
        let order: Vec<&Inst> = s.insts.iter().collect();
        let load8 = order
            .iter()
            .position(|i| matches!(i, Inst::Lds { offset: 8, .. }))
            .unwrap();
        let store = order
            .iter()
            .position(|i| matches!(i, Inst::Sts { .. }))
            .unwrap();
        assert!(load8 > store, "load after store must stay after");
    }

    #[test]
    fn war_respected() {
        // r1 is read then rewritten: the rewrite must not move above the read
        let p = assemble(
            "t",
            "ldif r1, 1.0\nfadd r2, r1, r1\nldif r1, 3.0\nfadd r3, r1, r1\nhalt",
        )
        .unwrap();
        let s = schedule(&p, 8);
        let txt: Vec<String> = s.insts.iter().map(|i| format!("{i}")).collect();
        let pos = |needle: &str| txt.iter().position(|t| t == needle).unwrap();
        assert!(pos("ldif r1, 3.0") > pos("fadd r2, r1, r1"));
        assert!(pos("fadd r3, r1, r1") > pos("ldif r1, 3.0"));
    }

    #[test]
    fn coeff_cache_ordering() {
        let p = assemble(
            "t",
            "ldif r1, 1.0\nldif r2, 2.0\nlod_coeff r1, r2\nmul_real r3, r1, r2\n\
             lod_coeff r2, r1\nmul_imag r4, r1, r2\nhalt",
        )
        .unwrap();
        let s = schedule(&p, 8);
        let txt: Vec<String> = s.insts.iter().map(|i| format!("{i}")).collect();
        let pos = |needle: &str| txt.iter().position(|t| t == needle).unwrap();
        // first mul_real must stay between the two lod_coeffs
        assert!(pos("mul_real r3, r1, r2") > pos("lod_coeff r1, r2"));
        assert!(pos("mul_real r3, r1, r2") < pos("lod_coeff r2, r1"));
        assert!(pos("mul_imag r4, r1, r2") > pos("lod_coeff r2, r1"));
    }

    #[test]
    fn boundaries_pin_regions() {
        let p = assemble("t", "ldif r1, 1.0\nbar\nfadd r2, r1, r1\nhalt").unwrap();
        let s = schedule(&p, 8);
        assert_eq!(classes(&s), classes(&p)); // nothing crossed the bar
    }
}
