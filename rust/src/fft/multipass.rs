//! Four-step (Bailey) decomposition: break an N-point transform past
//! the single-pass shared-memory ceiling into two stages of sub-FFTs
//! that each fit the existing ≤4096-point executor.
//!
//! With N = n1·n2, index the input as j = j1 + n1·j2 and the output as
//! k = k2 + n2·k1 (j1, k1 < n1; j2, k2 < n2). Then
//!
//! ```text
//! X[k2 + n2·k1] = Σ_{j1} W_N^{j1·k2} · W_{n1}^{j1·k1}
//!                   · [ Σ_{j2} x[j1 + n1·j2] · W_{n2}^{j2·k2} ]
//! ```
//!
//! which is exactly four steps: **row FFTs** (n1 transforms of n2
//! points over the strided input), **twiddle scaling** (multiply row j1
//! element k2 by W_N^{j1·k2}), **transpose**, and **column FFTs** (n2
//! transforms of n1 points), with the final digit interleave folded
//! into the output scatter. Every stage is a batch of ordinary
//! bounded-size jobs, so the scheduler layers (sharding, stealing,
//! QoS) serve a 2^20-point request as they would any other batch —
//! the same strategy the bellman GPU exemplars use to drive a
//! bounded-radix kernel in a `while p < n` multi-round loop.
//!
//! This module owns the pure math: the factorization
//! ([`MultipassPlan`]), the inter-stage twiddle table, the
//! gather/scale/transpose/scatter steps, and a generic driver
//! ([`run_with`]) that threads the stages through any batch-FFT
//! closure. The coordinator supplies the closure (its own batched
//! dispatch) plus the between-pass checkpoint that gives QoS a
//! cooperative preemption point.

use std::fmt;

use thiserror::Error;

use super::field::ButterflyField;
use super::reference;
use super::twiddle::{twiddle, Complex32, Cpx};

/// The largest transform one resident-SM pass serves (radix-4 at 4096
/// points is 16376 of the 16384 shared-memory words — the paper's
/// ceiling, pinned in `fft::plan`).
pub const MAX_SINGLE_PASS_POINTS: usize = 4096;

/// The largest decomposable transform: one four-step level over
/// [`MAX_SINGLE_PASS_POINTS`]-sized stages, i.e. 4096² = 2^24 points.
pub const MAX_POINTS: usize = MAX_SINGLE_PASS_POINTS * MAX_SINGLE_PASS_POINTS;

#[derive(Debug, Error, PartialEq, Eq)]
pub enum MultipassError {
    #[error("unsupported multi-pass FFT size {0}: must be a power of two >= 16")]
    BadSize(usize),
    #[error("invalid pass ceiling {0}: must be a power of two in 16..=4096")]
    BadCeiling(usize),
    #[error(
        "size {points} with pass ceiling {ceiling} needs a sub-FFT larger than \
         the ceiling (one four-step level decomposes at most ceiling^2 points)"
    )]
    TooLarge { points: usize, ceiling: usize },
}

/// Which stage of the decomposition a batch of sub-jobs belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// First stage: `row_jobs` FFTs of `row_points` points each.
    Rows,
    /// Second stage: `col_jobs()` FFTs of `col_points()` points each.
    Cols,
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stage::Rows => write!(f, "rows"),
            Stage::Cols => write!(f, "cols"),
        }
    }
}

/// The balanced N = n1·n2 factorization of one large transform, with
/// both factors at or under the pass ceiling. Balanced (n1 ≤ n2 ≤ 2·n1)
/// keeps both stage batches wide enough to chunk across every shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MultipassPlan {
    /// Total transform size N = `row_jobs · row_points`.
    pub points: usize,
    /// Number of row FFTs in the first stage (n1).
    pub row_jobs: usize,
    /// Size of each row FFT (n2).
    pub row_points: usize,
}

impl MultipassPlan {
    /// Factor `points` for sub-FFTs of at most `ceiling` points.
    /// `ceiling` is normally [`MAX_SINGLE_PASS_POINTS`]; requests may
    /// hint a smaller one to force earlier decomposition. One four-step
    /// level only: `points` must not exceed `ceiling²`.
    pub fn new(points: usize, ceiling: usize) -> Result<Self, MultipassError> {
        if !ceiling.is_power_of_two() || !(16..=MAX_SINGLE_PASS_POINTS).contains(&ceiling) {
            return Err(MultipassError::BadCeiling(ceiling));
        }
        if !points.is_power_of_two() || points < 16 {
            return Err(MultipassError::BadSize(points));
        }
        let log = points.trailing_zeros();
        let row_jobs = 1usize << (log / 2); // n1 = 2^floor(log/2), so n1 <= n2
        let row_points = points / row_jobs; // n2 = 2^ceil(log/2)
        if row_points > ceiling {
            return Err(MultipassError::TooLarge { points, ceiling });
        }
        Ok(MultipassPlan { points, row_jobs, row_points })
    }

    /// Number of column FFTs in the second stage (n2).
    pub fn col_jobs(&self) -> usize {
        self.row_points
    }

    /// Size of each column FFT (n1).
    pub fn col_points(&self) -> usize {
        self.row_jobs
    }

    /// Total sub-FFT jobs across both stages (n1 + n2) — a decomposed
    /// request's true admission cost in single-pass job units.
    pub fn total_jobs(&self) -> usize {
        self.row_jobs + self.row_points
    }
}

/// Admission cost of a `points`-sized request in single-pass job
/// units: 1 when it fits one pass (or cannot decompose at all, in
/// which case it will be rejected downstream), the two-stage sub-job
/// count when it decomposes.
pub fn job_cost(points: usize, ceiling: usize) -> u64 {
    if points <= ceiling {
        return 1;
    }
    match MultipassPlan::new(points, ceiling) {
        Ok(plan) => plan.total_jobs() as u64,
        Err(_) => 1,
    }
}

/// Stage-1 inputs: row `r` (r < n1) is the stride-n1 sequence
/// `x[r + n1·j2]` for j2 in 0..n2. Pure data movement — generic over
/// the element type, like every non-arithmetic step of the pipeline.
pub fn gather_rows<T: Copy>(input: &[T], plan: &MultipassPlan) -> Vec<Vec<T>> {
    let (n1, n2) = (plan.row_jobs, plan.row_points);
    debug_assert_eq!(input.len(), plan.points);
    (0..n1).map(|r| (0..n2).map(|j| input[r + n1 * j]).collect()).collect()
}

/// The inter-stage twiddle table in any butterfly field: entry
/// `[r·n2 + k] = W_N^{r·k}` (N entries total), where `W_N` is the
/// field's primitive N-th root of unity.
pub fn stage_table<F: ButterflyField>(plan: &MultipassPlan) -> Vec<F::Elem> {
    let (n1, n2, n) = (plan.row_jobs, plan.row_points, plan.points);
    let mut out = Vec::with_capacity(n);
    for r in 0..n1 {
        for k in 0..n2 {
            out.push(F::twiddle(n, (r * k) % n));
        }
    }
    out
}

/// The complex-f32 inter-stage twiddle table: [`stage_table`] at
/// [`Complex32`]. Computed in f64 ([`twiddle`]'s exact-axis values)
/// and rounded once to f32 — the precision the executors serve — so
/// the scaling step is deterministic bit-for-bit.
pub fn stage_twiddles(plan: &MultipassPlan) -> Vec<(f32, f32)> {
    stage_table::<Complex32>(plan)
}

/// Scale row `r` element `k` by `W_N^{r·k}` in the field's arithmetic.
pub fn apply_twiddles<F: ButterflyField>(
    rows: &mut [Vec<F::Elem>],
    twiddles: &[F::Elem],
    plan: &MultipassPlan,
) {
    let n2 = plan.row_points;
    debug_assert_eq!(twiddles.len(), plan.points);
    for (r, row) in rows.iter_mut().enumerate() {
        for (k, v) in row.iter_mut().enumerate() {
            *v = F::mul(*v, twiddles[r * n2 + k]);
        }
    }
}

/// Stage-2 inputs: column `k` (k < n2) gathers element `k` of every
/// scaled row.
pub fn transpose<T: Copy>(rows: &[Vec<T>], plan: &MultipassPlan) -> Vec<Vec<T>> {
    let (n1, n2) = (plan.row_jobs, plan.row_points);
    (0..n2).map(|k| (0..n1).map(|r| rows[r][k]).collect()).collect()
}

/// [`transpose`] without the second grid copy: the stage-1 output
/// buffers are reused as stage-2 input buffers. The leading m×m square
/// block (m = min(n1, n2)) is swap-transposed element by element; the
/// columns past the block of a wide grid (n2 > n1) are gathered into
/// fresh rows and appended, while the rows past the block of a tall
/// grid (n1 > n2) are drained whole and re-dealt one element onto the
/// end of each surviving row. Balanced plans from
/// [`MultipassPlan::new`] are square or wide with n2/n1 = 2, but the
/// plan fields are public, so the tall orientation is handled (and
/// property-tested) rather than assumed away — it used to
/// index out of bounds. On return `rows` holds the n2 column vectors
/// in column order.
pub fn transpose_in_place<T: Copy>(rows: &mut Vec<Vec<T>>, plan: &MultipassPlan) {
    let (n1, n2) = (plan.row_jobs, plan.row_points);
    debug_assert_eq!(rows.len(), n1);
    let m = n1.min(n2);
    // Columns m..n2 have no destination row inside the square block;
    // gather them before truncation discards their elements. The block
    // swap below never touches column indices >= m, so order is safe.
    let extras: Vec<Vec<T>> = (m..n2).map(|k| (0..n1).map(|r| rows[r][k]).collect()).collect();
    // Rows m..n1 have no source column inside the block: take them out
    // whole; element k of each lands at the tail of output row k.
    let tail: Vec<Vec<T>> = rows.drain(m..).collect();
    for r in 0..m {
        for c in (r + 1)..m {
            let (a, b) = rows.split_at_mut(c);
            std::mem::swap(&mut a[r][c], &mut b[0][r]);
        }
    }
    for (k, row) in rows.iter_mut().enumerate() {
        row.truncate(m);
        row.extend(tail.iter().map(|t| t[k]));
    }
    rows.extend(extras);
}

/// Recompose the output: element `k1` of column `k2` lands at
/// `k2 + n2·k1` (the four-step output interleave).
pub fn scatter<T: Copy + Default>(cols: &[Vec<T>], plan: &MultipassPlan) -> Vec<T> {
    let n2 = plan.row_points;
    let mut out = vec![T::default(); plan.points];
    for (k2, col) in cols.iter().enumerate() {
        for (k1, &v) in col.iter().enumerate() {
            out[k2 + n2 * k1] = v;
        }
    }
    out
}

/// Drive the four steps through `batch_fft`, which serves one stage's
/// sub-FFT batch (inputs in order; outputs must come back in the same
/// order, transformed, sizes preserved — the contract every service
/// batch path already keeps). `between_passes` runs after stage 1 is
/// scaled and before stage 2 is submitted: the cooperative preemption
/// point, where a scheduler may abandon the request (deadline passed)
/// by returning an error, or *pause* — blocking inside the closure —
/// to let a higher-priority tenant's waiting work reach the pool
/// before this request's stage-2 batch re-occupies it (the
/// coordinator's bounded between-pass yield).
///
/// Generic over the butterfly field: the same driver serves the f32
/// FFT ([`Complex32`]) and the Goldilocks NTT — only the twiddle
/// table and the sub-transform closure change. The driver itself is
/// deterministic: given the same sub-transform results it produces
/// bitwise-identical output regardless of how the closure scheduled
/// the jobs.
pub fn run_with<F: ButterflyField, E>(
    plan: &MultipassPlan,
    input: &[F::Elem],
    twiddles: &[F::Elem],
    mut batch_fft: impl FnMut(Vec<Vec<F::Elem>>, Stage) -> Result<Vec<Vec<F::Elem>>, E>,
    mut between_passes: impl FnMut() -> Result<(), E>,
) -> Result<Vec<F::Elem>, E> {
    assert_eq!(input.len(), plan.points, "input length must match the plan");
    assert_eq!(twiddles.len(), plan.points, "twiddle table must have N entries");
    let mut rows = batch_fft(gather_rows(input, plan), Stage::Rows)?;
    assert_eq!(rows.len(), plan.row_jobs, "stage 1 must return one output per row job");
    for row in &rows {
        assert_eq!(row.len(), plan.row_points, "stage 1 outputs must keep their size");
    }
    apply_twiddles::<F>(&mut rows, twiddles, plan);
    between_passes()?;
    // The scaled stage-1 buffers become the stage-2 inputs in place —
    // no second grid copy between the passes.
    transpose_in_place(&mut rows, plan);
    let cols = batch_fft(rows, Stage::Cols)?;
    assert_eq!(cols.len(), plan.col_jobs(), "stage 2 must return one output per column job");
    for col in &cols {
        assert_eq!(col.len(), plan.col_points(), "stage 2 outputs must keep their size");
    }
    Ok(scatter(&cols, plan))
}

/// The decomposition algebra in f64 end to end: [`reference::fft`]
/// sub-transforms and exact twiddles. Tests use this as the scaled
/// oracle at sizes the f64 reference can verify directly — it must
/// agree with the full-size direct transform to f64 accuracy, which
/// pins the index algebra (gather stride, twiddle exponent, output
/// interleave) independently of f32 executor noise.
pub fn four_step_reference(input: &[Cpx], plan: &MultipassPlan) -> Vec<Cpx> {
    let (n1, n2, n) = (plan.row_jobs, plan.row_points, plan.points);
    assert_eq!(input.len(), n);
    let mut rows: Vec<Vec<Cpx>> = (0..n1)
        .map(|r| {
            let row: Vec<Cpx> = (0..n2).map(|j| input[r + n1 * j]).collect();
            reference::fft(&row)
        })
        .collect();
    for (r, row) in rows.iter_mut().enumerate() {
        for (k, v) in row.iter_mut().enumerate() {
            *v = *v * twiddle(n, (r * k) % n);
        }
    }
    let mut out = vec![Cpx::ZERO; n];
    for k2 in 0..n2 {
        let col: Vec<Cpx> = (0..n1).map(|r| rows[r][k2]).collect();
        let col = reference::fft(&col);
        for (k1, &v) in col.iter().enumerate() {
            out[k2 + n2 * k1] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::{fft, rms_rel_error, test_signal};

    #[test]
    fn balanced_factorizations() {
        for (points, n1, n2) in [
            (8192usize, 64usize, 128usize),
            (1 << 16, 256, 256),
            (1 << 17, 256, 512),
            (1 << 20, 1024, 1024),
            (1 << 24, 4096, 4096),
        ] {
            let p = MultipassPlan::new(points, MAX_SINGLE_PASS_POINTS).unwrap();
            assert_eq!((p.row_jobs, p.row_points), (n1, n2), "{points}");
            assert_eq!(p.row_jobs * p.row_points, points);
            assert_eq!(p.col_jobs(), n2);
            assert_eq!(p.col_points(), n1);
            assert_eq!(p.total_jobs(), n1 + n2);
        }
        // a smaller ceiling hint forces the same balanced split as long
        // as it fits
        let p = MultipassPlan::new(1 << 20, 1024).unwrap();
        assert_eq!((p.row_jobs, p.row_points), (1024, 1024));
    }

    #[test]
    fn bad_inputs_rejected() {
        assert_eq!(
            MultipassPlan::new(3 * 4096, 4096),
            Err(MultipassError::BadSize(3 * 4096))
        );
        assert_eq!(MultipassPlan::new(8, 4096), Err(MultipassError::BadSize(8)));
        assert_eq!(MultipassPlan::new(8192, 8192), Err(MultipassError::BadCeiling(8192)));
        assert_eq!(MultipassPlan::new(8192, 8), Err(MultipassError::BadCeiling(8)));
        assert_eq!(
            MultipassPlan::new(1 << 25, 4096),
            Err(MultipassError::TooLarge { points: 1 << 25, ceiling: 4096 })
        );
        // 2^13 over a 64-point ceiling needs n2 = 128 > 64
        assert_eq!(
            MultipassPlan::new(8192, 64),
            Err(MultipassError::TooLarge { points: 8192, ceiling: 64 })
        );
    }

    #[test]
    fn job_cost_is_the_two_stage_job_count() {
        assert_eq!(job_cost(1024, 4096), 1);
        assert_eq!(job_cost(4096, 4096), 1);
        assert_eq!(job_cost(8192, 4096), 64 + 128);
        assert_eq!(job_cost(1 << 20, 4096), 2048);
        // an undecomposable size falls back to unit cost (rejected later)
        assert_eq!(job_cost(1 << 25, 4096), 1);
    }

    /// The f64 four-step recomposition must match the direct reference
    /// transform to f64 accuracy: this pins the index algebra.
    #[test]
    fn four_step_reference_matches_direct_fft() {
        for points in [1024usize, 4096] {
            let plan = MultipassPlan::new(points, 256).unwrap();
            let x = test_signal(points, 11);
            let got = four_step_reference(&x, &plan);
            let want = fft(&x);
            let err = rms_rel_error(&got, &want);
            assert!(err < 1e-12, "{points}: four-step algebra error {err}");
        }
    }

    /// The f32 driver over f64-reference sub-transforms (rounded to f32
    /// per stage, as a real executor would) stays within f32 tolerance
    /// of the direct transform.
    #[test]
    fn run_with_reference_stages_matches_direct_fft() {
        let points = 4096;
        let plan = MultipassPlan::new(points, 256).unwrap();
        let x = test_signal(points, 5);
        let input: Vec<(f32, f32)> = x.iter().map(|c| c.to_f32_pair()).collect();
        let tw = stage_twiddles(&plan);
        let got = run_with::<Complex32, ()>(
            &plan,
            &input,
            &tw,
            |jobs, _stage| {
                Ok(jobs
                    .into_iter()
                    .map(|j| {
                        let cpx: Vec<Cpx> =
                            j.iter().map(|&(re, im)| Cpx::new(re as f64, im as f64)).collect();
                        fft(&cpx).iter().map(|c| c.to_f32_pair()).collect()
                    })
                    .collect())
            },
            || Ok(()),
        )
        .unwrap();
        let got_cpx: Vec<Cpx> =
            got.iter().map(|&(re, im)| Cpx::new(re as f64, im as f64)).collect();
        let err = rms_rel_error(&got_cpx, &fft(&x));
        assert!(err < 5.0 * crate::fft::F32_TOL, "multi-pass rms error {err}");
    }

    /// The between-pass checkpoint aborts the request before stage 2 is
    /// ever submitted — the cooperative preemption contract.
    #[test]
    fn between_passes_short_circuits_stage_two() {
        let plan = MultipassPlan::new(1024, 32).unwrap();
        let input: Vec<(f32, f32)> =
            test_signal(1024, 3).iter().map(|c| c.to_f32_pair()).collect();
        let tw = stage_twiddles(&plan);
        let mut stage2 = false;
        let got = run_with::<Complex32, _>(
            &plan,
            &input,
            &tw,
            |jobs, stage| {
                if stage == Stage::Cols {
                    stage2 = true;
                }
                Ok::<_, &str>(jobs)
            },
            || Err("preempted"),
        );
        assert_eq!(got, Err("preempted"));
        assert!(!stage2, "stage 2 must not run after a failed checkpoint");
    }

    /// The buffer-reusing transpose must agree element-for-element with
    /// the copying transpose, for square and rectangular (1:2) plans —
    /// including the odd-log2 sizes (2^13, 2^15) whose balanced splits
    /// are rectangular.
    #[test]
    fn in_place_transpose_matches_the_copying_transpose() {
        for (points, ceiling) in [(1024usize, 64usize), (8192, 4096), (1 << 15, 4096)] {
            // 1024/64: 32 x 32 (square); 8192: 64 x 128; 2^15: 128 x 256
            let plan = MultipassPlan::new(points, ceiling).unwrap();
            let input: Vec<(f32, f32)> =
                test_signal(points, 9).iter().map(|c| c.to_f32_pair()).collect();
            let rows = gather_rows(&input, &plan);
            let want = transpose(&rows, &plan);
            let mut got = rows;
            transpose_in_place(&mut got, &plan);
            assert_eq!(got, want);
        }
    }

    /// Property test over *random* power-of-two splits, not just the
    /// balanced ones [`MultipassPlan::new`] produces: the plan fields
    /// are public, so square, wide (n2 > n1) and tall (n1 > n2) grids
    /// are all representable — and the tall orientation made the old
    /// swap/extras path index out of bounds. Elements are tagged with
    /// their (row, column) coordinates so any misplacement, not just a
    /// wrong value, fails the comparison.
    #[test]
    fn in_place_transpose_matches_transpose_on_random_power_of_two_splits() {
        let mut state: u64 = 0x51ED_5EED_0DD5_EED5;
        let mut next = move || {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            state.wrapping_mul(0x2545F4914F6CDD1D)
        };
        for case in 0..128 {
            let log = 2 + (next() % 13) as u32; // N = 4 .. 2^14
            let split = (next() % (log as u64 + 1)) as u32; // n1 = 2^split
            let plan = MultipassPlan {
                points: 1usize << log,
                row_jobs: 1usize << split,
                row_points: 1usize << (log - split),
            };
            let rows: Vec<Vec<(f32, f32)>> = (0..plan.row_jobs)
                .map(|r| (0..plan.row_points).map(|c| (r as f32, c as f32)).collect())
                .collect();
            let want = transpose(&rows, &plan);
            let mut got = rows;
            transpose_in_place(&mut got, &plan);
            assert_eq!(
                got, want,
                "case {case}: {} x {} split diverged",
                plan.row_jobs, plan.row_points
            );
        }
    }

    /// The four-step driver over exact Goldilocks stages must equal
    /// the direct NTT *exactly* — integer algebra has no rounding to
    /// hide an index or twiddle-exponent mistake, so this pins the
    /// generic decomposition for the second field.
    #[test]
    fn run_with_goldilocks_stages_equals_direct_ntt_exactly() {
        use crate::fft::field::{self, Goldilocks};
        for (points, ceiling) in [(1024usize, 64usize), (8192, 4096)] {
            let plan = MultipassPlan::new(points, ceiling).unwrap();
            let input = field::test_elements(points, 17);
            let table = stage_table::<Goldilocks>(&plan);
            let got = run_with::<Goldilocks, ()>(
                &plan,
                &input,
                &table,
                |jobs, _stage| Ok(jobs.iter().map(|j| field::ntt(j)).collect()),
                || Ok(()),
            )
            .unwrap();
            assert_eq!(got, field::ntt(&input), "{points}-point NTT four-step");
        }
    }

    #[test]
    fn twiddle_table_layout() {
        let plan = MultipassPlan::new(1024, 64).unwrap();
        let tw = stage_twiddles(&plan);
        assert_eq!(tw.len(), 1024);
        // row 0 is all W^0 = 1
        for k in 0..plan.row_points {
            assert_eq!(tw[k], (1.0, 0.0));
        }
        // row 1 element k is W_N^k
        for k in [1usize, 7, 31] {
            assert_eq!(tw[plan.row_points + k], twiddle(1024, k).to_f32_pair());
        }
    }

    #[test]
    fn stage_display_names() {
        assert_eq!(Stage::Rows.to_string(), "rows");
        assert_eq!(Stage::Cols.to_string(), "cols");
    }
}
