//! FFT execution planning: pass decomposition, thread→index mapping,
//! digit-reversed output addressing, virtual-bank eligibility, and the
//! shared-memory layout.
//!
//! The decomposition follows the paper: a size-N FFT at radix R is
//! `log_R(N)` in-place decimation-in-frequency passes; pass `p` works at
//! stride `s_p = N / R^p` (Figure 2: pass 1 of the radix-4 256-point FFT
//! touches {t, t+64, t+128, t+192}). When N is not a pure power of R the
//! trailing pass(es) drop to a smaller radix (§6.2: the 1024-point
//! radix-16 FFT is 16·16·4, with the radix-4 pass run as four blocks
//! reusing the radix-16 thread initialization).
//!
//! Planning and code generation target the simulated SM's f32 SIMT
//! datapath and therefore serve only [`Workload::Fft`]
//! ([`crate::fft::field::Workload`]): the Goldilocks NTT butterfly
//! needs 64-bit modular arithmetic the f32 lanes cannot express, so
//! that workload runs on the host integer datapath
//! ([`crate::fft::field::ntt_with_roots`]) and shares everything
//! *above* this layer — factorization, stage tables, caching,
//! scheduling — rather than the generated programs.

use std::sync::Arc;

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum PlanError {
    #[error("unsupported FFT size {0}: must be a power of two ≥ 4")]
    BadSize(usize),
    #[error("unsupported radix {0}: must be 2, 4, 8 or 16")]
    BadRadix(usize),
    #[error("size {points} with radix {radix} leaves no valid decomposition")]
    NoDecomposition { points: usize, radix: usize },
    #[error("FFT working set ({need} words) exceeds shared memory ({have} words)")]
    TooLarge { need: usize, have: usize },
    #[error(
        "multi-batch mode unsupported for {points}-pt radix-{radix}: needs \
         a single-block, single-radix plan with radix ≤ 8 (register budget)"
    )]
    BatchUnsupported { points: usize, radix: usize },
}

/// One in-place DIF pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pass {
    /// Kernel radix of this pass.
    pub radix: usize,
    /// Butterfly stride `s_p`; the kernel of effective thread `t`
    /// touches `j + k·s_p` for `k = 0..radix`.
    pub stride: usize,
    /// Sequential blocks: `kernels / threads` (≥ 2 only for mixed-radix
    /// or capacity-limited passes).
    pub blocks: usize,
    /// Whether the pass applies non-trivial twiddles (stride > 1).
    pub twiddles: bool,
    /// Whether this pass's writeback may use `save_bank` (filled in by
    /// the exact eligibility check; only meaningful on VM variants).
    pub vm_eligible: bool,
}

impl Pass {
    /// Total butterfly kernels in this pass.
    pub fn kernels(&self, points: usize) -> usize {
        points / self.radix
    }

    /// Base in-place index of the kernel run by effective thread `t`.
    pub fn kernel_base(&self, t: usize) -> usize {
        (t / self.stride) * self.radix * self.stride + (t % self.stride)
    }

    /// Twiddle row for effective thread `t` (`r = t mod stride`).
    pub fn twiddle_row(&self, t: usize) -> usize {
        t % self.stride
    }
}

/// A complete FFT plan for one (points, radix) design point.
///
/// The pass list sits behind an `Arc` so a plan (and therefore an
/// [`super::FftProgram`]) clones in O(1): the shared plan cache and
/// every per-core executor hold the same pass array.
#[derive(Clone, Debug)]
pub struct FftPlan {
    pub points: usize,
    /// Nominal radix of the design point (the paper's table row).
    pub radix: usize,
    pub passes: Arc<[Pass]>,
    /// Threads launched (= kernels of the first pass, the paper's
    /// "thread initialization", capped at the SM capacity).
    pub threads: usize,
}

impl FftPlan {
    /// Build a plan. `max_threads` is the SM thread capacity for the
    /// launch configuration (1024 for radix ≤ 4, 512 above, per §6).
    pub fn new(points: usize, radix: usize, max_threads: usize) -> Result<Self, PlanError> {
        if !points.is_power_of_two() || points < 4 {
            return Err(PlanError::BadSize(points));
        }
        if !matches!(radix, 2 | 4 | 8 | 16) {
            return Err(PlanError::BadRadix(radix));
        }

        // Greedy digit decomposition: use the nominal radix while it
        // divides what remains, then fall to the largest power of two
        // that fits (1024 @ radix-16 -> 16·16·4, §6.2).
        let mut radices = Vec::new();
        let mut rem = points;
        while rem > 1 {
            let mut r = radix.min(rem);
            while rem % r != 0 || (rem / r > 1 && !(rem / r).is_power_of_two()) {
                r /= 2;
                if r < 2 {
                    return Err(PlanError::NoDecomposition { points, radix });
                }
            }
            radices.push(r);
            rem /= r;
        }

        // Strides: s_p = product of the radices of the following passes.
        let n_passes = radices.len();
        let mut strides = vec![1usize; n_passes];
        for p in (0..n_passes - 1).rev() {
            strides[p] = strides[p + 1] * radices[p + 1];
        }

        let threads = (points / radices[0]).min(max_threads);
        let mut passes: Vec<Pass> = radices
            .iter()
            .zip(&strides)
            .map(|(&radix, &stride)| Pass {
                radix,
                stride,
                blocks: (points / radix).div_ceil(threads),
                twiddles: stride > 1,
                vm_eligible: false,
            })
            .collect();

        // Exact virtual-bank eligibility (§4): pass p's writeback may use
        // save_bank iff every word read in pass p+1 comes from an SP
        // congruent (mod 4) with the SP that wrote it in pass p. The
        // final pass always stores coherently (host readback).
        for p in 0..n_passes - 1 {
            let eligible = vm_check(points, threads, &passes[p], &passes[p + 1]);
            passes[p].vm_eligible = eligible;
        }

        Ok(FftPlan { points, radix, passes: passes.into(), threads })
    }

    /// Natural (frequency-domain) index of in-place position `i` after
    /// all DIF passes: the mixed-radix digit reversal.
    pub fn natural_of_inplace(&self, i: usize) -> usize {
        let mut weight = 1usize; // σ_p: product of radices of passes < p
        let mut out = 0usize;
        for pass in &self.passes {
            let digit = (i / pass.stride) % pass.radix;
            out += digit * weight;
            weight *= pass.radix;
        }
        out
    }

    /// Number of passes.
    pub fn n_passes(&self) -> usize {
        self.passes.len()
    }

    /// Is this a single-radix plan (every pass at the nominal radix)?
    pub fn single_radix(&self) -> bool {
        self.passes.iter().all(|p| p.radix == self.radix)
    }
}

/// Exhaustive mod-4 congruence check between the writers of pass `p`
/// and the readers of pass `q = p+1` (both possibly blocked).
fn vm_check(points: usize, threads: usize, wp: &Pass, rp: &Pass) -> bool {
    // writer_of[i]: physical thread that wrote in-place index i in pass p
    let mut writer_sp = vec![0u8; points];
    for block in 0..wp.blocks {
        for t in 0..threads.min(wp.kernels(points)) {
            let teff = block * threads + t;
            if teff >= wp.kernels(points) {
                break;
            }
            let base = wp.kernel_base(teff);
            for k in 0..wp.radix {
                writer_sp[base + k * wp.stride] = (t % 16) as u8;
            }
        }
    }
    for block in 0..rp.blocks {
        for t in 0..threads.min(rp.kernels(points)) {
            let teff = block * threads + t;
            if teff >= rp.kernels(points) {
                break;
            }
            let base = rp.kernel_base(teff);
            for k in 0..rp.radix {
                let w = writer_sp[base + k * rp.stride] % 4;
                if w != (t % 4) as u8 {
                    return false;
                }
            }
        }
    }
    true
}

/// Shared-memory layout for an FFT run: `batch` interleaved-complex
/// datasets at the bottom, one twiddle table per twiddled pass above.
/// Multi-batch (§6: twiddle loads "would be amortized away for
/// multi-batch FFTs") packs B datasets so one resident thread set
/// processes all of them per pass while the twiddles sit in registers.
#[derive(Clone, Debug)]
pub struct Layout {
    pub data_base: usize,
    /// Words per dataset (2·points).
    pub data_words: usize,
    /// Number of resident datasets.
    pub batch: usize,
    /// Per-pass twiddle table base (word address); `None` for passes
    /// without twiddles.
    pub twiddle_bases: Vec<Option<usize>>,
    pub words_used: usize,
}

impl Layout {
    pub fn new(plan: &FftPlan, smem_words: usize) -> Result<Self, PlanError> {
        Self::new_batched(plan, smem_words, 1)
    }

    pub fn new_batched(
        plan: &FftPlan,
        smem_words: usize,
        batch: usize,
    ) -> Result<Self, PlanError> {
        assert!(batch >= 1);
        let data_words = 2 * plan.points;
        let mut cursor = data_words * batch;
        let mut twiddle_bases = Vec::with_capacity(plan.n_passes());
        for pass in &plan.passes {
            if pass.twiddles {
                twiddle_bases.push(Some(cursor));
                cursor += pass.stride * (pass.radix - 1) * 2;
            } else {
                twiddle_bases.push(None);
            }
        }
        if cursor > smem_words {
            return Err(PlanError::TooLarge { need: cursor, have: smem_words });
        }
        Ok(Layout { data_base: 0, data_words, batch, twiddle_bases, words_used: cursor })
    }

    /// Word address of the real part of data element `i` of dataset `b`.
    pub fn data_addr(&self, b: usize, i: usize) -> usize {
        self.data_base + b * self.data_words + 2 * i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_radix_decompositions() {
        for (points, radix, expect_passes) in [
            (256usize, 4usize, 4usize),
            (1024, 4, 5),
            (4096, 4, 6),
            (512, 8, 3),
            (4096, 8, 4),
            (256, 16, 2),
            (4096, 16, 3),
            (256, 2, 8),
        ] {
            let plan = FftPlan::new(points, radix, 1024).unwrap();
            assert_eq!(plan.n_passes(), expect_passes, "{points}/{radix}");
            assert!(plan.single_radix());
            // strides decrease by the radix each pass, ending at 1
            assert_eq!(plan.passes.last().unwrap().stride, 1);
            assert_eq!(plan.passes[0].stride, points / radix);
        }
    }

    /// §6.2: 1024-point radix-16 = 16 · 16 · 4, radix-4 pass in 4 blocks
    /// reusing the 64-thread initialization.
    #[test]
    fn mixed_radix_1024() {
        let plan = FftPlan::new(1024, 16, 512).unwrap();
        let radices: Vec<usize> = plan.passes.iter().map(|p| p.radix).collect();
        assert_eq!(radices, vec![16, 16, 4]);
        assert_eq!(plan.threads, 64);
        assert_eq!(plan.passes[2].blocks, 4);
        assert_eq!(plan.passes[0].blocks, 1);
        let strides: Vec<usize> = plan.passes.iter().map(|p| p.stride).collect();
        assert_eq!(strides, vec![64, 4, 1]);
    }

    /// Figure 2 of the paper: radix-4, 256 points. Pass 1 T0 reads
    /// {0,64,128,192}; pass 2 T0 reads {0,16,32,48}; pass 3 T0 reads
    /// {0,4,8,12}; pass 3 T4 reads {16,20,24,28}.
    #[test]
    fn figure2_index_mapping() {
        let plan = FftPlan::new(256, 4, 1024).unwrap();
        let p1 = &plan.passes[0];
        assert_eq!(p1.kernel_base(0), 0);
        assert_eq!(p1.stride, 64);
        let p2 = &plan.passes[1];
        assert_eq!(p2.kernel_base(0), 0);
        assert_eq!(p2.stride, 16);
        let p3 = &plan.passes[2];
        assert_eq!(p3.stride, 4);
        assert_eq!(p3.kernel_base(4), 16);
        // Pass 2 T17: base 65 (Figure 2 shows i065..i113 in that column)
        assert_eq!(p2.kernel_base(17), 65);
    }

    /// VM eligibility must match the paper's §4 narrative: for radix-4,
    /// every pass except the last two can bank-write.
    #[test]
    fn vm_eligibility_radix4() {
        for (points, expect_vm) in [(256usize, 2usize), (1024, 3), (4096, 4)] {
            let plan = FftPlan::new(points, 4, 1024).unwrap();
            let n = plan.n_passes();
            let got: Vec<bool> = plan.passes.iter().map(|p| p.vm_eligible).collect();
            let count = got.iter().filter(|&&b| b).count();
            assert_eq!(count, expect_vm, "{points}: {got:?}");
            // the eligible ones are exactly the first n-2
            for (i, &b) in got.iter().enumerate() {
                assert_eq!(b, i + 2 < n, "{points} pass {i}");
            }
        }
    }

    #[test]
    fn vm_eligibility_radix8_and_16() {
        // radix-8 4096: paper derivation -> passes 1,2 eligible of 4
        let plan = FftPlan::new(4096, 8, 512).unwrap();
        let got: Vec<bool> = plan.passes.iter().map(|p| p.vm_eligible).collect();
        assert_eq!(got, vec![true, true, false, false]);
        // radix-16 4096: only pass 1 of 3
        let plan = FftPlan::new(4096, 16, 512).unwrap();
        let got: Vec<bool> = plan.passes.iter().map(|p| p.vm_eligible).collect();
        assert_eq!(got, vec![true, false, false]);
        // radix-16 256: two passes, none eligible (paper shows "-")
        let plan = FftPlan::new(256, 16, 512).unwrap();
        assert!(plan.passes.iter().all(|p| !p.vm_eligible));
        // mixed 1024: pass 1 eligible only
        let plan = FftPlan::new(1024, 16, 512).unwrap();
        let got: Vec<bool> = plan.passes.iter().map(|p| p.vm_eligible).collect();
        assert_eq!(got, vec![true, false, false]);
    }

    /// Digit reversal sanity: it is an involution-like permutation and
    /// matches bit reversal for radix 2.
    #[test]
    fn digit_reversal_permutation() {
        let plan = FftPlan::new(256, 4, 1024).unwrap();
        let mut seen = vec![false; 256];
        for i in 0..256 {
            let r = plan.natural_of_inplace(i);
            assert!(!seen[r]);
            seen[r] = true;
        }
        let plan2 = FftPlan::new(16, 2, 1024).unwrap();
        for i in 0..16usize {
            let r = plan2.natural_of_inplace(i);
            let bitrev = (i.reverse_bits() >> (usize::BITS - 4)) as usize;
            assert_eq!(r, bitrev);
        }
    }

    #[test]
    fn layout_fits_paper_configs() {
        // the 64 KB shared memory of §6 holds data + twiddles for every
        // reported design point
        let smem = 16384;
        for (points, radix, max_t) in [
            (4096usize, 4usize, 1024usize),
            (4096, 8, 512),
            (4096, 16, 512),
            (1024, 4, 1024),
            (1024, 16, 512),
            (512, 8, 512),
            (256, 4, 1024),
            (256, 16, 512),
        ] {
            let plan = FftPlan::new(points, radix, max_t).unwrap();
            let layout = Layout::new(&plan, smem).unwrap();
            assert!(layout.words_used <= smem, "{points}/{radix}");
        }
        // radix-4/4096 is the tight one: 16376 of 16384 words
        let plan = FftPlan::new(4096, 4, 1024).unwrap();
        let layout = Layout::new(&plan, smem).unwrap();
        assert_eq!(layout.words_used, 16376);
    }

    /// Plans clone in O(1): the pass array is shared, not copied.
    #[test]
    fn plans_share_passes_on_clone() {
        let plan = FftPlan::new(1024, 4, 1024).unwrap();
        let clone = plan.clone();
        assert!(Arc::ptr_eq(&plan.passes, &clone.passes));
    }

    #[test]
    fn layout_overflow_detected() {
        let plan = FftPlan::new(4096, 4, 1024).unwrap();
        assert!(matches!(
            Layout::new(&plan, 8192),
            Err(PlanError::TooLarge { .. })
        ));
    }

    #[test]
    fn bad_inputs_rejected() {
        assert!(FftPlan::new(100, 4, 1024).is_err());
        assert!(FftPlan::new(256, 5, 1024).is_err());
        assert!(FftPlan::new(2, 2, 1024).is_err());
    }
}
