//! Shared FFT plan cache: one fully-generated program per design point.
//!
//! Generating an FFT program is the expensive part of serving a request
//! — planning, code generation, list scheduling and twiddle-table
//! synthesis cost ~0.5 ms for a 4096-point program, against a few µs of
//! per-request data movement. The related bellman GPU FFT kernels
//! precompute their `pq`/`omega` tables once per size and reuse them
//! across rounds; [`PlanCache`] is the same idea for the coordinator: a
//! process-wide memo of `(points, radix, variant) → Arc<FftProgram>`
//! (program + schedule + twiddle image) behind a mutex, shared by every
//! worker thread, with LRU eviction and hit/miss/eviction counters that
//! surface in the service metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::codegen::{generate, FftProgram};
use super::field::{self, Goldilocks, Workload};
use super::multipass::{self, MultipassPlan};
use super::plan::PlanError;
use crate::arch::{SmConfig, Variant};

/// Default number of resident design points (far above the paper's
/// 8-size × 4-radix sweep touching a handful of sizes at a time).
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 16;

/// Number of resident inter-stage twiddle tables for multi-pass
/// requests. Bounded separately from the program cache because the
/// tables are big — a 2^20-point table is one million `(f32, f32)`
/// entries, ~8 MB — while a serving mix rarely touches more than a
/// couple of large sizes at once.
pub const STAGE_TWIDDLE_CAPACITY: usize = 4;

/// Cache key: one scheduled program per design point. Besides the
/// `(points, radix, variant)` triple, the key covers every `SmConfig`
/// field code generation reads (launch geometry, memory size, register
/// budget, scheduler pipeline depth), so a custom configuration can
/// never be handed a program generated under a different one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub points: usize,
    pub radix: usize,
    pub variant: Variant,
    pub threads: usize,
    pub smem_words: usize,
    pub regs_per_thread: usize,
    pub pipeline_depth: usize,
}

impl PlanKey {
    pub fn for_config(cfg: &SmConfig, points: usize, radix: usize) -> Self {
        PlanKey {
            points,
            radix,
            variant: cfg.variant,
            threads: cfg.threads,
            smem_words: cfg.smem_words,
            regs_per_thread: cfg.regs_per_thread,
            pipeline_depth: cfg.pipeline_depth,
        }
    }
}

/// Counter snapshot, exposed through `MetricsSnapshot::plan_cache`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
    /// Times a caller found the cache mutex held by another thread and
    /// had to block — the observable cost of sharing one cache across
    /// many workers/shards.
    pub lock_contentions: u64,
}

impl CacheStats {
    /// Total lookups (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

struct Slot {
    program: Arc<FftProgram>,
    /// Logical timestamp of the last lookup that returned this slot.
    last_used: u64,
}

struct Inner {
    map: HashMap<PlanKey, Slot>,
    tick: u64,
}

/// Key for one memoized inter-stage table: the workload discriminator
/// keeps an NTT root table from ever colliding with an FFT twiddle
/// table for the same factorization — both workloads share the one
/// [`STAGE_TWIDDLE_CAPACITY`]-bounded pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct StageKey {
    workload: Workload,
    plan: MultipassPlan,
}

/// One memoized inter-stage table, in its field's native element type.
#[derive(Clone)]
enum StageTable {
    Fft(Arc<Vec<(f32, f32)>>),
    Ntt(Arc<Vec<u64>>),
}

struct TwiddleSlot {
    table: StageTable,
    last_used: u64,
}

struct TwiddleInner {
    map: HashMap<StageKey, TwiddleSlot>,
    tick: u64,
}

/// Thread-safe LRU memo of generated FFT programs.
///
/// Programs are built *outside* the lock (other design points stay
/// servable during a ~ms generation) with a double-checked insert, so
/// concurrent first requests for the same key may generate twice; the
/// first insert wins and the duplicate is dropped.
pub struct PlanCache {
    capacity: usize,
    inner: Mutex<Inner>,
    twiddles: Mutex<TwiddleInner>,
    /// Single-pass NTT root tables by size. Unbounded by design: the
    /// legal single-pass sizes are the powers of two up to 4096, a
    /// dozen small tables totalling well under one stage table.
    roots: Mutex<HashMap<usize, Arc<Vec<u64>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    contentions: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    /// A cache holding at most `capacity` design points (clamped to 1).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            twiddles: Mutex::new(TwiddleInner { map: HashMap::new(), tick: 0 }),
            roots: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            contentions: AtomicU64::new(0),
        }
    }

    /// Take the cache lock, counting the acquisitions that found it
    /// already held (sharing cost surfaced in [`CacheStats`]).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        if let Ok(guard) = self.inner.try_lock() {
            return guard;
        }
        self.contentions.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, key: &PlanKey) -> bool {
        self.lock().map.contains_key(key)
    }

    /// Fetch the shared program for one design point, generating (and
    /// scheduling) it on a miss. Failed generations cache nothing.
    pub fn get_or_build(
        &self,
        cfg: &SmConfig,
        points: usize,
        radix: usize,
    ) -> Result<Arc<FftProgram>, PlanError> {
        let key = PlanKey::for_config(cfg, points, radix);
        if let Some(program) = self.lookup(&key) {
            return Ok(program);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(generate(cfg, points, radix)?);
        Ok(self.insert(key, built))
    }

    fn lookup(&self, key: &PlanKey) -> Option<Arc<FftProgram>> {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner.map.get_mut(key)?;
        slot.last_used = tick;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(&slot.program))
    }

    /// Insert (or adopt a concurrently-inserted duplicate of) `program`,
    /// evicting the least-recently-used entry when over capacity.
    fn insert(&self, key: PlanKey, program: Arc<FftProgram>) -> Arc<FftProgram> {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(slot) = inner.map.get_mut(&key) {
            // another worker built the same plan first: share theirs
            slot.last_used = tick;
            return Arc::clone(&slot.program);
        }
        inner.map.insert(key, Slot { program: Arc::clone(&program), last_used: tick });
        while inner.map.len() > self.capacity {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(k, _)| *k)
                .expect("over-capacity cache is non-empty");
            inner.map.remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        program
    }

    /// Fetch the shared complex inter-stage twiddle table for one
    /// multi-pass FFT factorization, computing it on a miss. Like
    /// programs, tables are built outside the lock with a
    /// double-checked insert (a 2^20-point table costs tens of ms to
    /// synthesize); eviction is LRU over a separate
    /// [`STAGE_TWIDDLE_CAPACITY`]-sized pool shared with the NTT root
    /// tables (the key carries the workload, so same-plan tables of
    /// the two fields never collide).
    pub fn stage_twiddles(&self, plan: &MultipassPlan) -> Arc<Vec<(f32, f32)>> {
        let key = StageKey { workload: Workload::Fft, plan: *plan };
        if let Some(StageTable::Fft(t)) = self.stage_lookup(&key) {
            return t;
        }
        let table = Arc::new(multipass::stage_twiddles(plan));
        match self.stage_insert(key, StageTable::Fft(table)) {
            StageTable::Fft(t) => t,
            StageTable::Ntt(_) => unreachable!("an Fft key always holds an Fft table"),
        }
    }

    /// Fetch the shared Goldilocks inter-stage root table for one
    /// multi-pass NTT factorization — the [`stage_twiddles`] analogue
    /// for [`Workload::Ntt`], living in the same LRU pool under its
    /// own workload key.
    ///
    /// [`stage_twiddles`]: PlanCache::stage_twiddles
    pub fn ntt_stage_roots(&self, plan: &MultipassPlan) -> Arc<Vec<u64>> {
        let key = StageKey { workload: Workload::Ntt, plan: *plan };
        if let Some(StageTable::Ntt(t)) = self.stage_lookup(&key) {
            return t;
        }
        let table = Arc::new(multipass::stage_table::<Goldilocks>(plan));
        match self.stage_insert(key, StageTable::Ntt(table)) {
            StageTable::Ntt(t) => t,
            StageTable::Fft(_) => unreachable!("an Ntt key always holds an Ntt table"),
        }
    }

    /// Fetch the shared forward root table for one single-pass NTT
    /// size — the executor-side analogue of a program's twiddle image.
    pub fn ntt_roots(&self, points: usize) -> Arc<Vec<u64>> {
        {
            let roots = self.roots.lock().unwrap();
            if let Some(t) = roots.get(&points) {
                return Arc::clone(t);
            }
        }
        let table = Arc::new(field::root_table(points));
        let mut roots = self.roots.lock().unwrap();
        Arc::clone(roots.entry(points).or_insert(table))
    }

    fn stage_lookup(&self, key: &StageKey) -> Option<StageTable> {
        let mut inner = self.twiddles.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner.map.get_mut(key)?;
        slot.last_used = tick;
        Some(slot.table.clone())
    }

    fn stage_insert(&self, key: StageKey, table: StageTable) -> StageTable {
        let mut inner = self.twiddles.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(slot) = inner.map.get_mut(&key) {
            // another worker synthesized the same table first: share theirs
            slot.last_used = tick;
            return slot.table.clone();
        }
        inner.map.insert(key, TwiddleSlot { table: table.clone(), last_used: tick });
        while inner.map.len() > STAGE_TWIDDLE_CAPACITY {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(k, _)| *k)
                .expect("over-capacity cache is non-empty");
            inner.map.remove(&victim);
        }
        table
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
            capacity: self.capacity,
            lock_contentions: self.contentions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(radix: usize) -> SmConfig {
        SmConfig::for_radix(Variant::DP, radix)
    }

    #[test]
    fn hit_and_miss_counters() {
        let cache = PlanCache::new(4);
        let c = cfg(4);
        let a = cache.get_or_build(&c, 256, 4).unwrap();
        let b = cache.get_or_build(&c, 256, 4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first program");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.lookups(), 2);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_design_points_get_distinct_programs() {
        let cache = PlanCache::new(8);
        let p4 = cache.get_or_build(&cfg(4), 256, 4).unwrap();
        let p16 = cache.get_or_build(&cfg(16), 256, 16).unwrap();
        let vmc = SmConfig::for_radix(Variant::DP_VM_COMPLEX, 4);
        let pv = cache.get_or_build(&vmc, 256, 4).unwrap();
        assert!(!Arc::ptr_eq(&p4, &p16));
        assert!(!Arc::ptr_eq(&p4, &pv), "variant is part of the key");
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.stats().misses, 3);
    }

    /// A custom launch geometry must never be served a program that was
    /// generated under a different SmConfig for the same triple.
    #[test]
    fn custom_launch_geometry_is_a_distinct_key() {
        let cache = PlanCache::new(8);
        let stock = cfg(4); // threads = 1024
        let narrow = SmConfig { threads: 64, ..stock };
        let a = cache.get_or_build(&stock, 1024, 4).unwrap();
        let b = cache.get_or_build(&narrow, 1024, 4).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(a.plan.threads, 256); // min(1024/4, 1024)
        assert_eq!(b.plan.threads, 64);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cache = PlanCache::new(2);
        let c = cfg(4);
        cache.get_or_build(&c, 256, 4).unwrap(); // A
        cache.get_or_build(&c, 1024, 4).unwrap(); // B
        cache.get_or_build(&c, 256, 4).unwrap(); // touch A -> B is LRU
        cache.get_or_build(&c, 4096, 4).unwrap(); // C evicts B
        assert!(cache.contains(&PlanKey::for_config(&c, 256, 4)));
        assert!(!cache.contains(&PlanKey::for_config(&c, 1024, 4)));
        assert!(cache.contains(&PlanKey::for_config(&c, 4096, 4)));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        // the evicted size rebuilds on next access (a fresh miss)
        cache.get_or_build(&c, 1024, 4).unwrap();
        assert_eq!(cache.stats().misses, 4);
    }

    #[test]
    fn plan_errors_propagate_and_cache_nothing() {
        let cache = PlanCache::new(2);
        assert!(cache.get_or_build(&cfg(4), 100, 4).is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.stats().hits, 0);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let cache = PlanCache::new(0);
        assert_eq!(cache.capacity(), 1);
        let c = cfg(4);
        cache.get_or_build(&c, 256, 4).unwrap();
        cache.get_or_build(&c, 1024, 4).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn serial_access_never_contends() {
        let cache = PlanCache::new(4);
        let c = cfg(4);
        cache.get_or_build(&c, 256, 4).unwrap();
        cache.get_or_build(&c, 256, 4).unwrap();
        cache.get_or_build(&c, 1024, 4).unwrap();
        assert_eq!(cache.stats().lock_contentions, 0, "single thread never blocks");
    }

    #[test]
    fn concurrent_access_shares_one_program() {
        let cache = Arc::new(PlanCache::new(4));
        let c = cfg(4);
        let mut joins = Vec::new();
        for _ in 0..4 {
            let cache = Arc::clone(&cache);
            joins.push(std::thread::spawn(move || cache.get_or_build(&c, 256, 4).unwrap()));
        }
        let programs: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for p in &programs[1..] {
            assert!(Arc::ptr_eq(&programs[0], p));
        }
        assert_eq!(cache.len(), 1);
        let s = cache.stats();
        assert_eq!(s.lookups(), 4);
        assert!(s.misses >= 1, "at least the first access generates");
    }

    #[test]
    fn stage_twiddles_are_shared_and_correct() {
        let cache = PlanCache::new(4);
        let plan = MultipassPlan::new(1024, 64).unwrap();
        let a = cache.stage_twiddles(&plan);
        let b = cache.stage_twiddles(&plan);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first table");
        assert_eq!(*a, multipass::stage_twiddles(&plan));
    }

    #[test]
    fn stage_twiddles_evict_lru_beyond_capacity() {
        let cache = PlanCache::new(4);
        let plans: Vec<MultipassPlan> = [1024usize, 2048, 4096, 8192, 16384]
            .iter()
            .map(|&n| MultipassPlan::new(n, 4096).unwrap())
            .collect();
        let first = cache.stage_twiddles(&plans[0]);
        for p in &plans[1..] {
            cache.stage_twiddles(p);
        }
        // five distinct tables through a 4-slot pool: the oldest was
        // evicted, so a re-fetch synthesizes a fresh allocation
        let again = cache.stage_twiddles(&plans[0]);
        assert!(!Arc::ptr_eq(&first, &again), "evicted table must rebuild");
        assert_eq!(*first, *again, "rebuilt table is identical");
    }

    /// Same factorization, two workloads: the workload in the stage
    /// key must keep the tables apart — an NTT request served an FFT
    /// twiddle table (or vice versa) would be silently wrong data.
    #[test]
    fn stage_tables_never_collide_across_workloads() {
        let cache = PlanCache::new(4);
        let plan = MultipassPlan::new(1024, 64).unwrap();
        let fft = cache.stage_twiddles(&plan);
        let ntt = cache.ntt_stage_roots(&plan);
        assert_eq!(fft.len(), 1024);
        assert_eq!(ntt.len(), 1024);
        assert_eq!(*ntt, multipass::stage_table::<Goldilocks>(&plan));
        // both stay resident and re-fetches share, despite equal plans
        assert!(Arc::ptr_eq(&fft, &cache.stage_twiddles(&plan)));
        assert!(Arc::ptr_eq(&ntt, &cache.ntt_stage_roots(&plan)));
    }

    #[test]
    fn ntt_roots_are_shared_and_correct() {
        let cache = PlanCache::new(4);
        let a = cache.ntt_roots(256);
        let b = cache.ntt_roots(256);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first table");
        assert_eq!(*a, field::root_table(256));
        assert_eq!(a[0], 1);
    }

    #[test]
    fn stage_twiddles_do_not_touch_program_counters() {
        let cache = PlanCache::new(4);
        let plan = MultipassPlan::new(8192, 4096).unwrap();
        cache.stage_twiddles(&plan);
        cache.stage_twiddles(&plan);
        assert_eq!(cache.stats().lookups(), 0);
        assert!(cache.is_empty());
    }
}
