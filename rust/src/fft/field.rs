//! Butterfly fields: the element-type boundary that makes the engine
//! workload-agnostic.
//!
//! Every transform the engine serves is the same algebra — butterflies
//! over an N-th root of unity — instantiated in some field. The
//! [`ButterflyField`] trait names exactly the operations the shared
//! machinery needs (root powers, add, mul, and a packed wire format),
//! and two fields implement it:
//!
//! * [`Complex32`](super::twiddle::Complex32) — the paper's f32 complex
//!   FFT, computed on the simulated SM;
//! * [`Goldilocks`] — the 64-bit prime field `p = 2^64 − 2^32 + 1`,
//!   whose number-theoretic transform (NTT) is the butterfly workload
//!   of the ZK-prover repos in the paper's lineage (`bellman`'s
//!   GPU FFT kernels run the identical four-step strategy over a prime
//!   field). Goldilocks is the field where `mulmod` is nearly free: the
//!   128-bit product reduces with two shifts and two adds because
//!   `2^64 ≡ 2^32 − 1 (mod p)` and `2^96 ≡ −1 (mod p)`.
//!
//! What is shared across fields: the four-step multipass decomposition
//! and its index algebra ([`super::multipass`]), the stage-table memo
//! in the [`super::cache::PlanCache`], job slots / arena buffers,
//! sharding, QoS, tenancy, and every metrics surface. What is per
//! field: the butterfly arithmetic itself and the executor datapath —
//! the f32 SIMT SM for [`Workload::Fft`], a host 64-bit-ALU loop for
//! [`Workload::Ntt`] (the simulated SM's f32 lanes cannot carry 64-bit
//! modular arithmetic; the follow-up eGPU papers add exactly such an
//! integer datapath variant). Plan generation and code generation
//! ([`super::plan`], [`super::codegen`]) therefore stay FFT-only.
//!
//! Elements travel through the (f32, f32)-typed slots and rings
//! bit-packed ([`ButterflyField::pack_vec`]): one `u64` field element
//! is carried as the raw bit halves of a pair. This is lossless because
//! the serving layers only *move* payloads — lease, copy, truncate,
//! transpose — and never apply floating-point arithmetic to them; the
//! unpack at the executor restores the exact integer.

use std::fmt;

/// Which transform algebra a request runs under — threaded from
/// [`FftRequest`](crate::coordinator::FftRequest) through jobs, plan
/// cache keys and metrics so the two workloads share every serving
/// layer without ever sharing a table or an executor compute path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Complex f32 FFT on the simulated SM (the default).
    #[default]
    Fft,
    /// Goldilocks number-theoretic transform on the host 64-bit ALU.
    Ntt,
}

impl Workload {
    /// Lower-case name, as used by CLI flags and metrics labels.
    pub fn name(self) -> &'static str {
        match self {
            Workload::Fft => "fft",
            Workload::Ntt => "ntt",
        }
    }

    /// Parse a CLI name (`"fft"` / `"ntt"`).
    pub fn parse(s: &str) -> Option<Workload> {
        match s {
            "fft" => Some(Workload::Fft),
            "ntt" => Some(Workload::Ntt),
            _ => None,
        }
    }
}

impl fmt::Display for Workload {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The operations the shared transform machinery needs from a field.
///
/// `Elem::default()` must be the additive zero, `twiddle(n, 0)` the
/// multiplicative one, and `twiddle(n, k)` the k-th power of a
/// primitive n-th root of unity with the *consistency law*
/// `twiddle(m, k) == twiddle(n, k·n/m)` for `m | n` — the property the
/// four-step decomposition's index algebra relies on. Both provided
/// fields derive their roots from one generator, so the law holds by
/// construction.
pub trait ButterflyField {
    /// Field element (native representation, not the wire format).
    type Elem: Copy + PartialEq + fmt::Debug + Default + Send + Sync + 'static;
    /// Human-readable field name (metrics / assertions).
    const NAME: &'static str;
    /// The workload discriminator requests in this field carry.
    const WORKLOAD: Workload;
    /// k-th power of the primitive n-th root of unity.
    fn twiddle(n: usize, k: usize) -> Self::Elem;
    /// Field addition.
    fn add(a: Self::Elem, b: Self::Elem) -> Self::Elem;
    /// Field multiplication.
    fn mul(a: Self::Elem, b: Self::Elem) -> Self::Elem;
    /// Move a native vector into the packed `(f32, f32)` wire format
    /// the job slots carry (bit-preserving; identity for complex f32).
    fn pack_vec(v: Vec<Self::Elem>) -> Vec<(f32, f32)>;
    /// Inverse of [`ButterflyField::pack_vec`].
    fn unpack_vec(v: Vec<(f32, f32)>) -> Vec<Self::Elem>;
}

/// The Goldilocks prime `p = 2^64 − 2^32 + 1`.
pub const P: u64 = 0xFFFF_FFFF_0000_0001;

/// `2^64 mod p = 2^32 − 1` — the constant both reduction steps use.
const EPSILON: u64 = 0xFFFF_FFFF;

/// Multiplicative generator of the full group `F_p*` (order `p − 1`).
pub const GENERATOR: u64 = 7;

/// `p − 1 = 2^32 · (2^32 − 1)`: roots of unity exist for every
/// power-of-two order up to `2^32` — far past the engine's largest
/// decomposable transform.
pub const TWO_ADICITY: u32 = 32;

/// Marker type for the Goldilocks field (see [`ButterflyField`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Goldilocks;

impl ButterflyField for Goldilocks {
    type Elem = u64;
    const NAME: &'static str = "goldilocks";
    const WORKLOAD: Workload = Workload::Ntt;

    fn twiddle(n: usize, k: usize) -> u64 {
        debug_assert!(n.is_power_of_two());
        powmod(root_of_unity(n.trailing_zeros()), (k % n) as u64)
    }

    fn add(a: u64, b: u64) -> u64 {
        addmod(a, b)
    }

    fn mul(a: u64, b: u64) -> u64 {
        mulmod(a, b)
    }

    fn pack_vec(v: Vec<u64>) -> Vec<(f32, f32)> {
        v.into_iter().map(pack).collect()
    }

    fn unpack_vec(v: Vec<(f32, f32)>) -> Vec<u64> {
        v.into_iter().map(unpack).collect()
    }
}

/// Bit-pack one field element into the `(f32, f32)` wire format: the
/// high and low 32-bit halves travel as raw f32 bit patterns.
/// `f32::from_bits`/`to_bits` are bit-preserving in Rust, and no
/// serving layer performs FP arithmetic on payload words, so
/// `unpack(pack(x)) == x` for every `u64`.
#[inline]
pub fn pack(x: u64) -> (f32, f32) {
    (f32::from_bits((x >> 32) as u32), f32::from_bits(x as u32))
}

/// Inverse of [`pack`].
#[inline]
pub fn unpack(w: (f32, f32)) -> u64 {
    ((w.0.to_bits() as u64) << 32) | w.1.to_bits() as u64
}

/// Canonicalizing addition mod p. Accepts any canonical inputs
/// (`< p`); the overflowed top bit folds back via `2^64 ≡ ε`.
#[inline]
pub fn addmod(a: u64, b: u64) -> u64 {
    debug_assert!(a < P && b < P);
    let (mut sum, overflow) = a.overflowing_add(b);
    if overflow {
        // a + b − 2^64 + ε: cannot overflow again (a + b < 2p) and the
        // result is already < p.
        sum = sum.wrapping_add(EPSILON);
    }
    if sum >= P {
        sum -= P;
    }
    sum
}

/// Canonicalizing subtraction mod p: a borrow folds back via
/// `−2^64 ≡ −ε`.
#[inline]
pub fn submod(a: u64, b: u64) -> u64 {
    debug_assert!(a < P && b < P);
    let (diff, borrow) = a.overflowing_sub(b);
    if borrow {
        diff.wrapping_sub(EPSILON)
    } else {
        diff
    }
}

/// Reduce a 128-bit product to a canonical Goldilocks element — the
/// two-shifts-and-adds reduction that makes this field cheap. With
/// `x = lo + 2^64·hi` and `hi = hi_lo + 2^32·hi_hi`:
///
/// ```text
/// 2^64 ≡ ε = 2^32 − 1,   2^96 ≡ −1   (mod p)
/// x ≡ lo − hi_hi + ε·hi_lo
/// ```
#[inline]
pub fn reduce128(x: u128) -> u64 {
    let lo = x as u64;
    let hi = (x >> 64) as u64;
    let hi_hi = hi >> 32;
    let hi_lo = hi & EPSILON;
    let (mut t0, borrow) = lo.overflowing_sub(hi_hi);
    if borrow {
        // borrowed 2^64 ≡ ε; t0 > 2^64 − 2^32 here, so no underflow
        t0 = t0.wrapping_sub(EPSILON);
    }
    let t1 = hi_lo * EPSILON; // ≤ (2^32 − 1)^2, fits u64
    let (mut res, carry) = t0.overflowing_add(t1);
    if carry {
        // dropped 2^64 ≡ ε; res < 2^64 − 2^32 here, so no overflow
        res = res.wrapping_add(EPSILON);
    }
    if res >= P {
        res -= P;
    }
    res
}

/// Multiplication mod p via [`reduce128`].
#[inline]
pub fn mulmod(a: u64, b: u64) -> u64 {
    reduce128((a as u128) * (b as u128))
}

/// Reduce an arbitrary `u64` to its canonical residue. One conditional
/// subtract suffices because `2^64 − 1 < 2p`. The NTT executor applies
/// this while unpacking request payloads, so a client submitting raw
/// (unreduced) words still gets the transform of their residues.
#[inline]
pub fn canonicalize(x: u64) -> u64 {
    if x >= P {
        x - P
    } else {
        x
    }
}

/// `base^exp mod p` by square-and-multiply.
pub fn powmod(mut base: u64, mut exp: u64) -> u64 {
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mulmod(acc, base);
        }
        base = mulmod(base, base);
        exp >>= 1;
    }
    acc
}

/// Multiplicative inverse of a nonzero element (Fermat: `a^(p−2)`).
pub fn invmod(a: u64) -> u64 {
    debug_assert!(a != 0 && a < P, "zero has no inverse");
    powmod(a, P - 2)
}

/// The canonical primitive `2^log_n`-th root of unity,
/// `g^((p−1) >> log_n)`. Deriving every order's root from the one
/// generator gives the tower consistency the four-step algebra needs:
/// `ω_m = ω_n^(n/m)` whenever `m | n`.
pub fn root_of_unity(log_n: u32) -> u64 {
    assert!(log_n <= TWO_ADICITY, "no 2^{log_n}-th root of unity in Goldilocks");
    powmod(GENERATOR, (P - 1) >> log_n)
}

/// The forward root table for an n-point NTT: `ω_n^0 .. ω_n^(n−1)` —
/// the NTT analogue of the complex twiddle table, memoized per size by
/// the plan cache on the serving path.
pub fn root_table(n: usize) -> Vec<u64> {
    assert!(n.is_power_of_two(), "NTT size must be a power of two");
    powers(root_of_unity(n.trailing_zeros()), n)
}

/// The inverse root table `ω_n^0, ω_n^{−1}, .., ω_n^{−(n−1)}`.
pub fn inverse_root_table(n: usize) -> Vec<u64> {
    assert!(n.is_power_of_two(), "NTT size must be a power of two");
    powers(invmod(root_of_unity(n.trailing_zeros())), n)
}

fn powers(base: u64, n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    let mut acc = 1u64;
    for _ in 0..n {
        out.push(acc);
        acc = mulmod(acc, base);
    }
    out
}

/// In-place iterative radix-2 NTT over a precomputed root table
/// (`roots[i] = ω_n^i`, forward or inverse) — the executor compute
/// loop for [`Workload::Ntt`], structurally the same
/// decimation-in-time loop as [`super::reference::fft_radix2`] with
/// the complex butterfly swapped for modular arithmetic.
pub fn ntt_with_roots(a: &mut [u64], roots: &[u64]) {
    let n = a.len();
    assert!(n.is_power_of_two(), "NTT size must be a power of two");
    assert_eq!(roots.len(), n, "root table must have n entries");
    if n == 1 {
        return;
    }
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() as usize >> (32 - bits);
        if i < j {
            a.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let step = n / len;
        for start in (0..n).step_by(len) {
            for k in 0..len / 2 {
                let w = roots[k * step];
                let u = a[start + k];
                let v = mulmod(a[start + k + len / 2], w);
                a[start + k] = addmod(u, v);
                a[start + k + len / 2] = submod(u, v);
            }
        }
        len <<= 1;
    }
}

/// Forward NTT: `X[k] = Σ_j x[j]·ω_n^{jk}` (fresh output vector).
pub fn ntt(input: &[u64]) -> Vec<u64> {
    let mut a = input.to_vec();
    ntt_with_roots(&mut a, &root_table(input.len()));
    a
}

/// Inverse NTT: runs the same loop over the inverse roots, then scales
/// by `n^{−1}` so that `intt(ntt(x)) == x` exactly.
pub fn intt(input: &[u64]) -> Vec<u64> {
    let n = input.len();
    let mut a = input.to_vec();
    ntt_with_roots(&mut a, &inverse_root_table(n));
    let n_inv = invmod(n as u64);
    for x in &mut a {
        *x = mulmod(*x, n_inv);
    }
    a
}

/// Naive O(n²) modular DFT — the definitionally-correct oracle every
/// NTT path is checked against with *exact* integer equality (this is
/// [`super::reference::dft_naive_in`] instantiated at [`Goldilocks`]).
pub fn dft_naive(input: &[u64]) -> Vec<u64> {
    super::reference::dft_naive_in::<Goldilocks>(input)
}

/// Deterministic pseudo-random canonical field elements (xorshift64*,
/// same core as [`super::reference::test_signal`]).
pub fn test_elements(n: usize, seed: u64) -> Vec<u64> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut next = move || {
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545F4914F6CDD1D)
    };
    (0..n)
        .map(|_| loop {
            let v = next();
            if v < P {
                break v;
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mul_ref(a: u64, b: u64) -> u64 {
        ((a as u128 * b as u128) % P as u128) as u64
    }

    fn add_ref(a: u64, b: u64) -> u64 {
        ((a as u128 + b as u128) % P as u128) as u64
    }

    #[test]
    fn constants_are_consistent() {
        assert_eq!(P as u128, (1u128 << 64) - (1u128 << 32) + 1);
        assert_eq!(EPSILON as u128, (1u128 << 64) % P as u128, "2^64 ≡ ε");
        assert_eq!((P - 1) % (1u64 << TWO_ADICITY), 0, "2-adicity of p − 1");
    }

    #[test]
    fn arithmetic_edge_cases_match_u128_reference() {
        let edges = [
            0u64,
            1,
            2,
            EPSILON - 1,
            EPSILON,
            EPSILON + 1,
            1 << 32,
            (1 << 63) - 1,
            1 << 63,
            P - 2,
            P - 1,
        ];
        for &a in &edges {
            for &b in &edges {
                assert_eq!(addmod(a, b), add_ref(a, b), "add {a} {b}");
                assert_eq!(mulmod(a, b), mul_ref(a, b), "mul {a} {b}");
                let want_sub = ((a as i128 - b as i128).rem_euclid(P as i128)) as u64;
                assert_eq!(submod(a, b), want_sub, "sub {a} {b}");
            }
        }
    }

    #[test]
    fn reduce128_extremes() {
        assert_eq!(reduce128(0), 0);
        assert_eq!(reduce128(P as u128), 0);
        assert_eq!(reduce128(1), 1);
        for x in [
            u128::MAX,
            (P as u128 - 1) * (P as u128 - 1), // largest canonical product
            1u128 << 127,
            (1u128 << 96) - 1,
            (1u128 << 96),
        ] {
            assert_eq!(reduce128(x) as u128, x % P as u128, "{x:#x}");
        }
    }

    #[test]
    fn canonicalize_covers_the_whole_u64_range() {
        for &x in &[0u64, 1, P - 1, P, P + 1, u64::MAX] {
            assert_eq!(canonicalize(x) as u128, x as u128 % P as u128, "{x:#x}");
        }
    }

    #[test]
    fn inverse_and_pow_laws() {
        for &a in &[1u64, 2, 7, EPSILON, P - 1, 0xDEAD_BEEF_CAFE_F00D % P] {
            assert_eq!(mulmod(a, invmod(a)), 1, "a·a^-1 = 1 for {a}");
        }
        assert_eq!(powmod(GENERATOR, P - 1), 1, "Fermat");
        assert_eq!(powmod(5, 0), 1);
    }

    #[test]
    fn roots_of_unity_orders_and_tower() {
        for log_n in [0u32, 1, 4, 12, 20] {
            let w = root_of_unity(log_n);
            assert_eq!(powmod(w, 1 << log_n), 1, "order divides 2^{log_n}");
            if log_n > 0 {
                assert_ne!(powmod(w, 1 << (log_n - 1)), 1, "order is exactly 2^{log_n}");
            }
        }
        // tower consistency: ω_m == ω_n^{n/m} for m | n
        assert_eq!(root_of_unity(4), powmod(root_of_unity(8), 16));
        assert_eq!(Goldilocks::twiddle(256, 3), powmod(root_of_unity(8), 3));
    }

    #[test]
    fn pack_roundtrip_is_lossless() {
        for &x in &[0u64, 1, EPSILON, P - 1, u64::MAX, 0x7FC0_0000_7FC0_0000] {
            assert_eq!(unpack(pack(x)), x, "{x:#x}");
        }
        let v = test_elements(64, 3);
        assert_eq!(Goldilocks::unpack_vec(Goldilocks::pack_vec(v.clone())), v);
    }

    #[test]
    fn ntt_of_impulse_is_flat() {
        let mut x = vec![0u64; 16];
        x[0] = 1;
        assert_eq!(ntt(&x), vec![1u64; 16]);
    }

    #[test]
    fn ntt_matches_naive_dft_small() {
        for n in [2usize, 4, 16, 64] {
            let x = test_elements(n, 42);
            assert_eq!(ntt(&x), dft_naive(&x), "n={n}");
        }
    }

    #[test]
    fn intt_round_trip_small() {
        for n in [2usize, 8, 128] {
            let x = test_elements(n, 7);
            assert_eq!(intt(&ntt(&x)), x, "n={n}");
            assert_eq!(ntt(&intt(&x)), x, "n={n} (other order)");
        }
    }

    #[test]
    fn ntt_linearity_exact() {
        let n = 64;
        let a = test_elements(n, 1);
        let b = test_elements(n, 2);
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| addmod(x, y)).collect();
        let fa = ntt(&a);
        let fb = ntt(&b);
        let fsum = ntt(&sum);
        for i in 0..n {
            assert_eq!(fsum[i], addmod(fa[i], fb[i]), "bin {i}");
        }
    }

    #[test]
    fn test_elements_deterministic_and_canonical() {
        let a = test_elements(32, 5);
        assert_eq!(a, test_elements(32, 5));
        assert!(a.iter().all(|&x| x < P));
        assert_ne!(a, test_elements(32, 6));
    }

    #[test]
    fn workload_names_parse_and_display() {
        assert_eq!(Workload::parse("fft"), Some(Workload::Fft));
        assert_eq!(Workload::parse("ntt"), Some(Workload::Ntt));
        assert_eq!(Workload::parse("dct"), None);
        assert_eq!(Workload::Ntt.to_string(), "ntt");
        assert_eq!(Workload::default(), Workload::Fft);
    }
}
