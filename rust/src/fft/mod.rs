//! FFT programs for the eGPU: planning, code generation, execution and
//! validation against reference transforms, plus the shared
//! [`cache::PlanCache`] that memoizes generated programs (program +
//! schedule + twiddle image) across the serving workers.

pub mod cache;
pub mod codegen;
pub mod field;
pub mod multipass;
pub mod plan;
pub mod reference;
pub mod sched;
pub mod twiddle;

pub use cache::{CacheStats, PlanCache, PlanKey};
pub use codegen::{generate, generate_batched, generate_opt, FftProgram};
pub use field::{ButterflyField, Goldilocks, Workload};
pub use multipass::{MultipassError, MultipassPlan, MAX_SINGLE_PASS_POINTS};
pub use plan::{FftPlan, Layout, Pass, PlanError};
pub use twiddle::{Complex32, Cpx};

use crate::arch::SmConfig;
use crate::profile::Profile;
use crate::sim::{SimError, Sm};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum FftError {
    #[error(transparent)]
    Plan(#[from] PlanError),
    #[error(transparent)]
    Sim(#[from] SimError),
    #[error("input length {got} does not match plan points {want}")]
    BadInput { got: usize, want: usize },
}

/// Result of executing an FFT program on the simulated SM.
#[derive(Clone, Debug)]
pub struct FftRun {
    /// Natural-order transform output (f32, as computed by the SM).
    pub output: Vec<(f32, f32)>,
    /// Cycle profile (one paper table column).
    pub profile: Profile,
}

/// Load `input` + twiddle tables into a fresh SM, run the generated
/// program, and read back the natural-order result.
pub fn run_fft(fp: &FftProgram, cfg: &SmConfig, input: &[(f32, f32)]) -> Result<FftRun, FftError> {
    if input.len() != fp.plan.points {
        return Err(FftError::BadInput { got: input.len(), want: fp.plan.points });
    }
    let mut sm = Sm::new(*cfg);
    sm.seed_thread_ids();
    load_workspace(&mut sm, fp, input)?;
    let profile = sm.run(&fp.program, fp.plan.threads)?;
    let output = read_output(&sm, fp)?;
    Ok(FftRun { output, profile })
}

/// Preload input data (interleaved complex) and per-pass twiddle tables.
pub fn load_workspace(sm: &mut Sm, fp: &FftProgram, input: &[(f32, f32)]) -> Result<(), FftError> {
    load_data(sm, fp, input)?;
    load_twiddles(sm, fp)
}

/// Preload only the input data region — the serving path calls this per
/// request, loading the (constant) twiddle tables once per SM (§Perf).
pub fn load_data(sm: &mut Sm, fp: &FftProgram, input: &[(f32, f32)]) -> Result<(), FftError> {
    let mut words: Vec<u32> = Vec::with_capacity(2 * input.len());
    for &(re, im) in input {
        words.push(re.to_bits());
        words.push(im.to_bits());
    }
    sm.smem.host_fill(fp.layout.data_base, &words).map_err(SimError::from)?;
    Ok(())
}

/// Preload the per-pass twiddle tables (precomputed at generate time).
pub fn load_twiddles(sm: &mut Sm, fp: &FftProgram) -> Result<(), FftError> {
    for (base, words) in &fp.twiddle_image {
        sm.smem.host_fill(*base, words).map_err(SimError::from)?;
    }
    Ok(())
}

/// Run a multi-batch program (§6 twiddle-amortization mode) over
/// `inputs.len() == layout.batch` datasets; returns per-dataset outputs
/// and the single shared profile.
pub fn run_fft_batch(
    fp: &FftProgram,
    cfg: &SmConfig,
    inputs: &[Vec<(f32, f32)>],
) -> Result<(Vec<Vec<(f32, f32)>>, Profile), FftError> {
    if inputs.len() != fp.layout.batch {
        return Err(FftError::BadInput { got: inputs.len(), want: fp.layout.batch });
    }
    let mut sm = Sm::new(*cfg);
    sm.seed_thread_ids();
    load_twiddles(&mut sm, fp)?;
    for (b, input) in inputs.iter().enumerate() {
        if input.len() != fp.plan.points {
            return Err(FftError::BadInput { got: input.len(), want: fp.plan.points });
        }
        let mut words: Vec<u32> = Vec::with_capacity(2 * input.len());
        for &(re, im) in input {
            words.push(re.to_bits());
            words.push(im.to_bits());
        }
        sm.smem
            .host_fill(fp.layout.data_addr(b, 0), &words)
            .map_err(SimError::from)?;
    }
    let profile = sm.run(&fp.program, fp.plan.threads)?;
    let mut outputs = Vec::with_capacity(inputs.len());
    for b in 0..inputs.len() {
        let words = sm
            .smem
            .host_read_coherent(fp.layout.data_addr(b, 0), 2 * fp.plan.points)
            .map_err(SimError::from)?;
        outputs.push(
            words
                .chunks_exact(2)
                .map(|w| (f32::from_bits(w[0]), f32::from_bits(w[1])))
                .collect(),
        );
    }
    Ok((outputs, profile))
}

/// Read the natural-order output back; requires bank coherence (the
/// final pass must have stored through the coherent port).
pub fn read_output(sm: &Sm, fp: &FftProgram) -> Result<Vec<(f32, f32)>, FftError> {
    let words = sm
        .smem
        .host_read_coherent(fp.layout.data_base, 2 * fp.plan.points)
        .map_err(SimError::from)?;
    Ok(words
        .chunks_exact(2)
        .map(|w| (f32::from_bits(w[0]), f32::from_bits(w[1])))
        .collect())
}

/// Convenience: simulate one (points, radix, variant) design point on a
/// deterministic test signal and validate against the reference FFT.
/// Returns the profile and the relative RMS error.
pub fn validate(
    cfg: &SmConfig,
    points: usize,
    radix: usize,
    seed: u64,
) -> Result<(Profile, f64), FftError> {
    let fp = generate(cfg, points, radix)?;
    let signal = reference::test_signal(points, seed);
    let input: Vec<(f32, f32)> = signal.iter().map(|c| c.to_f32_pair()).collect();
    let run = run_fft(&fp, cfg, &input)?;
    let got: Vec<Cpx> = run
        .output
        .iter()
        .map(|&(re, im)| Cpx::new(re as f64, im as f64))
        .collect();
    let want = reference::fft(&signal);
    Ok((run.profile, reference::rms_rel_error(&got, &want)))
}

/// f32 FFT numerical tolerance: the simulated SM computes in f32 with
/// log2(N) sequential passes; 1e-4 relative RMS is comfortably above
/// the observed ~1e-6 and far below any real error.
pub const F32_TOL: f64 = 1e-4;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Variant;

    fn check(points: usize, radix: usize, variant: Variant) {
        let cfg = SmConfig::for_radix(variant, radix);
        let (_, err) = validate(&cfg, points, radix, 0xC0FFEE).unwrap();
        assert!(
            err < F32_TOL,
            "{points}-pt radix-{radix} {variant}: rms {err:e}"
        );
    }

    /// The paper's full design space at 256 points (cheap), all radices
    /// × all six variants: numerics must be right everywhere — including
    /// the stale-bank semantics of the VM variants.
    #[test]
    fn numerics_256_all_radices_all_variants() {
        for radix in [2usize, 4, 8, 16] {
            for v in Variant::ALL6 {
                check(256, radix, v);
            }
        }
    }

    #[test]
    fn numerics_512_radix8() {
        for v in Variant::ALL6 {
            check(512, 8, v);
        }
    }

    /// §6.2 mixed radix: 1024 = 16·16·4 with the blocked radix-4 pass.
    #[test]
    fn numerics_1024_mixed_radix16() {
        for v in Variant::ALL6 {
            check(1024, 16, v);
        }
    }

    #[test]
    fn numerics_1024_radix4() {
        check(1024, 4, Variant::DP);
        check(1024, 4, Variant::DP_VM_COMPLEX);
    }

    /// 4096-point spot checks (the expensive corners of Tables 1–3).
    #[test]
    fn numerics_4096_spot() {
        check(4096, 4, Variant::DP);
        check(4096, 16, Variant::DP_VM_COMPLEX);
        check(4096, 8, Variant::QP_COMPLEX);
    }

    /// Impulse input → flat spectrum, amplitude exactly 1.
    #[test]
    fn impulse_response() {
        let cfg = SmConfig::for_radix(Variant::DP, 4);
        let fp = generate(&cfg, 256, 4).unwrap();
        let mut input = vec![(0.0f32, 0.0f32); 256];
        input[0] = (1.0, 0.0);
        let run = run_fft(&fp, &cfg, &input).unwrap();
        for (k, &(re, im)) in run.output.iter().enumerate() {
            assert!((re - 1.0).abs() < 1e-6 && im.abs() < 1e-6, "bin {k}");
        }
    }

    /// Profiles must be invariant to the input data (SIMT: control flow
    /// and cycle counts are data-independent).
    #[test]
    fn profile_data_independent() {
        let cfg = SmConfig::for_radix(Variant::DP_VM, 4);
        let (p1, _) = validate(&cfg, 256, 4, 1).unwrap();
        let (p2, _) = validate(&cfg, 256, 4, 999).unwrap();
        assert_eq!(p1.cycles, p2.cycles);
    }

    /// Multi-batch mode (§6): every dataset transforms correctly, and
    /// the per-FFT cycle cost drops because addressing + twiddle loads
    /// are paid once per pass instead of once per dataset.
    #[test]
    fn multibatch_numerics_and_amortization() {
        for (points, radix, batch) in [(1024usize, 4usize, 4usize), (512, 8, 4), (256, 4, 8)] {
            for variant in [Variant::DP, Variant::DP_VM_COMPLEX, Variant::QP] {
                let cfg = SmConfig::for_radix(variant, radix);
                let fp = generate_batched(&cfg, points, radix, batch).unwrap();
                let signals: Vec<Vec<crate::fft::Cpx>> =
                    (0..batch).map(|b| reference::test_signal(points, b as u64)).collect();
                let inputs: Vec<Vec<(f32, f32)>> = signals
                    .iter()
                    .map(|s| s.iter().map(|c| c.to_f32_pair()).collect())
                    .collect();
                let (outputs, profile) = run_fft_batch(&fp, &cfg, &inputs).unwrap();
                for (b, out) in outputs.iter().enumerate() {
                    let got: Vec<Cpx> = out
                        .iter()
                        .map(|&(re, im)| Cpx::new(re as f64, im as f64))
                        .collect();
                    let err = reference::rms_rel_error(&got, &reference::fft(&signals[b]));
                    assert!(err < F32_TOL, "{points}/{radix}/{variant} batch {b}: {err}");
                }
                // amortization: per-FFT cycles strictly below single-batch
                let (single, _) = validate(&cfg, points, radix, 0).unwrap();
                let per_fft = profile.total() as f64 / batch as f64;
                assert!(
                    per_fft < single.total() as f64,
                    "{points}/{radix}/{variant}: {per_fft} !< {}",
                    single.total()
                );
            }
        }
    }

    /// §6 quantification: "increasing the performance by 8% for the
    /// base case" — our radix-4 4096 twiddle share predicts ~6-7 %
    /// per-FFT improvement at batch 4 on the sizes that fit.
    #[test]
    fn multibatch_improvement_magnitude() {
        let cfg = SmConfig::for_radix(Variant::DP, 4);
        let fp = generate_batched(&cfg, 1024, 4, 4).unwrap();
        let inputs: Vec<Vec<(f32, f32)>> = (0..4)
            .map(|b| {
                reference::test_signal(1024, b as u64)
                    .iter()
                    .map(|c| c.to_f32_pair())
                    .collect()
            })
            .collect();
        let (_, batched) = run_fft_batch(&fp, &cfg, &inputs).unwrap();
        let (single, _) = validate(&cfg, 1024, 4, 0).unwrap();
        let gain = 1.0 - batched.total() as f64 / 4.0 / single.total() as f64;
        assert!(
            (0.03..=0.15).contains(&gain),
            "batch-4 per-FFT improvement {gain:.3} (paper §6: ~8%)"
        );
    }

    #[test]
    fn multibatch_unsupported_cases() {
        let cfg = SmConfig::for_radix(Variant::DP, 16);
        // radix-16: twiddles do not fit in registers
        assert!(matches!(
            generate_batched(&cfg, 4096, 16, 2),
            Err(PlanError::BatchUnsupported { .. })
        ));
        // 4096-pt radix-4 at batch 2: exceeds the 64 KB shared memory
        let cfg4 = SmConfig::for_radix(Variant::DP, 4);
        assert!(matches!(
            generate_batched(&cfg4, 4096, 4, 2),
            Err(PlanError::TooLarge { .. })
        ));
    }

    #[test]
    fn bad_input_length_rejected() {
        let cfg = SmConfig::for_radix(Variant::DP, 4);
        let fp = generate(&cfg, 256, 4).unwrap();
        let input = vec![(0.0f32, 0.0f32); 128];
        assert!(matches!(
            run_fft(&fp, &cfg, &input),
            Err(FftError::BadInput { got: 128, want: 256 })
        ));
    }
}
