//! Reference transforms: the oracle for validating eGPU FFT programs.
//!
//! Two independent implementations (an O(n²) DFT and an iterative
//! radix-2 FFT) cross-check each other, and both check the simulator's
//! output. All math in f64 so the oracle error is negligible against
//! the f32 arithmetic of the simulated SM.

use super::field::ButterflyField;
use super::twiddle::{twiddle, Cpx};

/// Naive O(n²) DFT over any [`ButterflyField`] — the definitionally
/// correct transform in the field's own arithmetic. Instantiated at
/// [`Goldilocks`](super::field::Goldilocks) this is the exact modular
/// oracle every NTT serving path is checked against; the complex-f32
/// instantiation is a lower-precision cousin of [`dft_naive`] (which
/// stays f64 end to end and remains the FFT oracle).
pub fn dft_naive_in<F: ButterflyField>(input: &[F::Elem]) -> Vec<F::Elem> {
    let n = input.len();
    // one root-power table up front: O(n) twiddle evaluations, not O(n²)
    let w: Vec<F::Elem> = (0..n).map(|k| F::twiddle(n, k)).collect();
    (0..n)
        .map(|k| {
            let mut acc = F::Elem::default();
            for (j, &x) in input.iter().enumerate() {
                acc = F::add(acc, F::mul(x, w[(j * k) % n]));
            }
            acc
        })
        .collect()
}

/// Naive O(n²) forward DFT — definitionally correct.
pub fn dft_naive(input: &[Cpx]) -> Vec<Cpx> {
    let n = input.len();
    (0..n)
        .map(|k| {
            let mut acc = Cpx::ZERO;
            for (j, &x) in input.iter().enumerate() {
                acc = acc + x * twiddle(n, (j * k) % n);
            }
            acc
        })
        .collect()
}

/// Iterative radix-2 decimation-in-time FFT (n must be a power of two).
pub fn fft_radix2(input: &[Cpx]) -> Vec<Cpx> {
    let n = input.len();
    assert!(n.is_power_of_two(), "fft_radix2 requires power-of-two length");
    let bits = n.trailing_zeros();
    let mut a: Vec<Cpx> = (0..n)
        .map(|i| input[(i as u32).reverse_bits() as usize >> (32 - bits)])
        .collect();
    let mut len = 2;
    while len <= n {
        let step = n / len;
        for start in (0..n).step_by(len) {
            for k in 0..len / 2 {
                let w = twiddle(n, k * step);
                let u = a[start + k];
                let v = a[start + k + len / 2] * w;
                a[start + k] = u + v;
                a[start + k + len / 2] = u - v;
            }
        }
        len <<= 1;
    }
    a
}

/// Forward FFT for any power-of-two size (radix-2 path).
pub fn fft(input: &[Cpx]) -> Vec<Cpx> {
    fft_radix2(input)
}

/// Root-mean-square error between two complex vectors, normalized by
/// the RMS magnitude of `want` (relative error).
pub fn rms_rel_error(got: &[Cpx], want: &[Cpx]) -> f64 {
    assert_eq!(got.len(), want.len());
    let mut err = 0.0;
    let mut mag = 0.0;
    for (g, w) in got.iter().zip(want) {
        let d = *g - *w;
        err += d.re * d.re + d.im * d.im;
        mag += w.re * w.re + w.im * w.im;
    }
    if mag == 0.0 {
        err.sqrt()
    } else {
        (err / mag).sqrt()
    }
}

/// Deterministic pseudo-random complex test signal (xorshift64*; no
/// external RNG crates are available in this offline image).
pub fn test_signal(n: usize, seed: u64) -> Vec<Cpx> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut next = move || {
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        let v = state.wrapping_mul(0x2545F4914F6CDD1D);
        // map the top 24 bits to [-1, 1)
        ((v >> 40) as f64) / (1u64 << 23) as f64 - 1.0
    };
    (0..n).map(|_| Cpx::new(next(), next())).collect()
}

/// FLOP count convention used throughout the paper's comparisons:
/// `5·N·log2(N)` for a complex N-point FFT.
pub fn fft_flops(n: usize) -> f64 {
    5.0 * n as f64 * (n as f64).log2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dft_of_impulse_is_flat() {
        let mut x = vec![Cpx::ZERO; 8];
        x[0] = Cpx::ONE;
        for y in dft_naive(&x) {
            assert!((y.re - 1.0).abs() < 1e-12 && y.im.abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_single_tone() {
        // x[n] = e^{2πi·3n/16} -> spike at bin 3 (note DFT sign flip)
        let n = 16;
        let x: Vec<Cpx> =
            (0..n).map(|j| twiddle(n, (3 * j) % n).conj()).collect();
        let y = dft_naive(&x);
        for (k, v) in y.iter().enumerate() {
            let expect = if k == 3 { n as f64 } else { 0.0 };
            assert!((v.re - expect).abs() < 1e-9, "bin {k}");
            assert!(v.im.abs() < 1e-9, "bin {k}");
        }
    }

    #[test]
    fn fft_matches_dft_up_to_1024() {
        for n in [2usize, 4, 8, 16, 64, 256, 1024] {
            let x = test_signal(n, 42);
            let err = rms_rel_error(&fft_radix2(&x), &dft_naive(&x));
            assert!(err < 1e-10, "n={n} err={err}");
        }
    }

    #[test]
    fn linearity() {
        let n = 64;
        let a = test_signal(n, 1);
        let b = test_signal(n, 2);
        let sum: Vec<Cpx> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fsum = fft(&sum);
        for i in 0..n {
            let d = fsum[i] - (fa[i] + fb[i]);
            assert!(d.abs() < 1e-9);
        }
    }

    #[test]
    fn parseval() {
        let n = 256;
        let x = test_signal(n, 7);
        let y = fft(&x);
        let tx: f64 = x.iter().map(|c| c.abs().powi(2)).sum();
        let ty: f64 = y.iter().map(|c| c.abs().powi(2)).sum();
        assert!((ty - n as f64 * tx).abs() / (n as f64 * tx) < 1e-12);
    }

    /// The generic naive DFT instantiated at each field: exact
    /// agreement with the Goldilocks NTT, close agreement (f32
    /// accumulation) with the f64 complex oracle.
    #[test]
    fn generic_naive_dft_matches_both_field_oracles() {
        use crate::fft::field::{self, Goldilocks};
        use crate::fft::twiddle::Complex32;
        let x = field::test_elements(32, 9);
        assert_eq!(dft_naive_in::<Goldilocks>(&x), field::ntt(&x));
        let sig = test_signal(64, 4);
        let packed: Vec<(f32, f32)> = sig.iter().map(|c| c.to_f32_pair()).collect();
        let got: Vec<Cpx> = dft_naive_in::<Complex32>(&packed)
            .iter()
            .map(|&(re, im)| Cpx::new(re as f64, im as f64))
            .collect();
        let err = rms_rel_error(&got, &dft_naive(&sig));
        assert!(err < 1e-3, "complex-f32 naive DFT drifted from the f64 oracle: {err}");
    }

    #[test]
    fn test_signal_deterministic_and_bounded() {
        let a = test_signal(32, 5);
        let b = test_signal(32, 5);
        assert_eq!(a, b);
        assert!(a.iter().all(|c| c.re.abs() <= 1.0 && c.im.abs() <= 1.0));
        let c = test_signal(32, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn flops_convention() {
        assert_eq!(fft_flops(4096), 5.0 * 4096.0 * 12.0);
    }
}
