//! Multi-tenant admission: per-tenant token buckets, job-unit quotas,
//! billing counters, and the priority-waiting preemption signal.
//!
//! QoS classes (see [`super::qos`]) decide *which queued request runs
//! next*; they cannot stop one principal from filling every queue slot
//! in the first place. The tenancy layer sits **ahead of** the class
//! queues: a request carrying a tenant id must pass that tenant's
//! token bucket (sustained rate + burst) and its job-unit quota before
//! it may occupy any class-queue capacity. A throttled request is
//! answered immediately with a typed
//! [`super::ServiceError::TenantThrottled`] — it never holds a queue
//! slot, never ages, and never steals a dispatch from a conforming
//! tenant. This is the isolation guarantee the `tenants` bench gates:
//! an abusive tenant offering 10× its rate limit cannot move a
//! well-behaved tenant's queue-wait p99 beyond a bounded ratio.
//!
//! Like the scheduler core, everything here is clock-injected (`now`
//! is a parameter, never read internally), so bucket behaviour is a
//! pure function of the call sequence and the property suite in
//! `rust/tests/proptests.rs` can drive it deterministically.
//!
//! Two levers, two failure modes:
//!
//! * the **token bucket** bounds *request rate*: over any window `W`
//!   a tenant is admitted at most `rate_hz × W + burst` requests,
//!   whatever the arrival pattern;
//! * the **job-unit quota** ([`super::qos::UnitQuota`]) bounds
//!   *in-flight work*: the sum of admitted-but-unfinished job units
//!   (1 for a single-pass request, `n1 + n2` sub-jobs for a
//!   decomposed one — [`crate::fft::multipass::job_cost`]) never
//!   exceeds the configured cap, so a tenant cannot park a handful of
//!   2^20-point requests and monopolize the pool within its request
//!   rate.
//!
//! A tenant marked [`TenantSpec::with_priority`] additionally arms the
//! cross-pass preemption point: while any of its requests sits in a
//! class queue, the registry's [`PreemptWatch`] reads "waiting", and a
//! background tenant's multi-pass request yields at the between-pass
//! checkpoint (see `request::serve_staged`) instead of submitting its
//! stage-2 batch — the cooperative analogue of bellman's
//! `PriorityLock` preempt-me checks, without a global lock.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::{LatencyRecorder, TenantStats};
use super::qos::UnitQuota;

/// A clock-injected token bucket: capacity `burst` tokens, refilled
/// continuously at `rate_hz` tokens/s, starting full. Admitting a
/// request takes one token; an empty bucket throttles.
///
/// Over any window `[t0, t1]` the bucket admits at most
/// `burst + rate_hz × (t1 - t0)` requests — the bound the property
/// suite asserts under random burst interleavings. Time only ever
/// moves the bucket toward full (refill is monotone in `now`), and a
/// `now` earlier than the last refill instant is ignored rather than
/// draining tokens.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_hz: f64,
    burst: f64,
    tokens: f64,
    refilled: Instant,
}

impl TokenBucket {
    /// A bucket holding `burst` tokens at `now`, refilling at
    /// `rate_hz` tokens per second.
    pub fn new(rate_hz: f64, burst: u64, now: Instant) -> TokenBucket {
        let burst = (burst.max(1)) as f64;
        TokenBucket { rate_hz: rate_hz.max(0.0), burst, tokens: burst, refilled: now }
    }

    /// Credit the elapsed time since the last refill, saturating at
    /// the burst capacity. A non-monotone `now` (earlier than the last
    /// refill) is a no-op.
    fn refill(&mut self, now: Instant) {
        if let Some(dt) = now.checked_duration_since(self.refilled) {
            self.tokens = (self.tokens + self.rate_hz * dt.as_secs_f64()).min(self.burst);
            self.refilled = now;
        }
    }

    /// Tokens available at `now`, after refill — monotone in `now`
    /// between takes.
    pub fn available(&mut self, now: Instant) -> f64 {
        self.refill(now);
        self.tokens
    }

    /// Take one token if available: `true` admits, `false` throttles.
    pub fn try_take(&mut self, now: Instant) -> bool {
        self.refill(now);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// One tenant's admission contract: sustained request rate, burst
/// allowance, optional in-flight job-unit quota, and whether the
/// tenant's queued work arms the cross-pass preemption signal.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant name, as reported in metrics and load reports.
    pub name: String,
    /// Sustained admission rate, requests/s (the bucket refill rate).
    pub rate_hz: f64,
    /// Burst allowance, requests (the bucket capacity; min 1).
    pub burst: u64,
    /// Cap on in-flight job units (admitted but not yet finished);
    /// `None` = unlimited. A single-pass request is 1 unit, a
    /// decomposed request costs its sub-job count.
    pub quota_units: Option<u64>,
    /// Priority tenant: its queued requests raise the registry's
    /// [`PreemptWatch`], making background tenants' multi-pass jobs
    /// yield at the between-pass checkpoint.
    pub priority: bool,
}

impl TenantSpec {
    /// A tenant admitting `rate_hz` requests/s sustained with a
    /// `burst`-request allowance, no quota, not priority.
    pub fn new(name: &str, rate_hz: f64, burst: u64) -> TenantSpec {
        TenantSpec {
            name: name.into(),
            rate_hz,
            burst: burst.max(1),
            quota_units: None,
            priority: false,
        }
    }

    /// Builder: cap in-flight job units.
    pub fn with_quota(mut self, units: u64) -> TenantSpec {
        self.quota_units = Some(units);
        self
    }

    /// Builder: mark this tenant as priority (arms the cross-pass
    /// preemption signal while its requests wait in a class queue).
    pub fn with_priority(mut self) -> TenantSpec {
        self.priority = true;
        self
    }
}

/// Why the tenancy layer refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantDenial {
    /// The request named a tenant index the registry was not
    /// configured with.
    Unknown,
    /// The tenant's token bucket is empty or its job-unit quota is
    /// exhausted.
    Throttled,
}

/// A read-only view of the registry's priority-waiting signal, cheap
/// to clone onto a request. `waiting()` is `true` while at least one
/// priority tenant's request sits in a class queue — the condition the
/// between-pass preemption checkpoint yields on.
#[derive(Clone, Debug)]
pub struct PreemptWatch(Arc<AtomicUsize>);

impl PreemptWatch {
    /// A free-standing watch for tests and harnesses (not connected to
    /// any registry); drive it with [`PreemptWatch::set`].
    pub fn manual() -> PreemptWatch {
        PreemptWatch(Arc::new(AtomicUsize::new(0)))
    }

    /// `true` while a priority tenant's request is queued.
    pub fn waiting(&self) -> bool {
        self.0.load(Ordering::Acquire) > 0
    }

    /// Overwrite the waiting count (test/harness support — production
    /// code goes through [`TenantRegistry::enqueued`] /
    /// [`TenantRegistry::dispatched`]).
    pub fn set(&self, waiting: usize) {
        self.0.store(waiting, Ordering::Release);
    }
}

/// Per-tenant billing/health counters (lock-free; the registry owns
/// one block per tenant).
#[derive(Default)]
struct TenantCounters {
    submitted: AtomicU64,
    admitted: AtomicU64,
    throttled: AtomicU64,
    completed: AtomicU64,
    job_units: AtomicU64,
    queue_wait: LatencyRecorder,
}

struct TenantState {
    spec: TenantSpec,
    bucket: Mutex<TokenBucket>,
    quota: UnitQuota,
    counters: TenantCounters,
}

/// The tenant registry: one token bucket + quota + counter block per
/// configured tenant, plus the shared priority-waiting signal. Held in
/// an `Arc` by the traffic frontend; every method is `&self` and
/// thread-safe.
pub struct TenantRegistry {
    tenants: Vec<TenantState>,
    priority_waiting: Arc<AtomicUsize>,
}

impl TenantRegistry {
    /// Build a registry from tenant specs, validated up front: at
    /// least one tenant, non-empty unique names, finite non-negative
    /// rates. `now` seeds every bucket's refill clock.
    pub fn new(specs: Vec<TenantSpec>, now: Instant) -> Result<TenantRegistry> {
        if specs.is_empty() {
            return Err(anyhow!("tenant registry needs at least one tenant"));
        }
        for (i, s) in specs.iter().enumerate() {
            if s.name.is_empty() {
                return Err(anyhow!("tenant {i} has an empty name"));
            }
            if specs[..i].iter().any(|p| p.name == s.name) {
                return Err(anyhow!("duplicate tenant name `{}`", s.name));
            }
            if !s.rate_hz.is_finite() || s.rate_hz < 0.0 {
                return Err(anyhow!("tenant `{}`: rate must be finite and >= 0", s.name));
            }
            if s.quota_units == Some(0) {
                return Err(anyhow!("tenant `{}`: a zero quota can never admit", s.name));
            }
        }
        let tenants = specs
            .into_iter()
            .map(|spec| TenantState {
                bucket: Mutex::new(TokenBucket::new(spec.rate_hz, spec.burst, now)),
                quota: UnitQuota::new(spec.quota_units),
                counters: TenantCounters::default(),
                spec,
            })
            .collect();
        Ok(TenantRegistry { tenants, priority_waiting: Arc::new(AtomicUsize::new(0)) })
    }

    /// Number of configured tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// `true` when no tenants are configured (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// The spec tenant `t` was configured with.
    pub fn spec(&self, t: usize) -> Option<&TenantSpec> {
        self.tenants.get(t).map(|s| &s.spec)
    }

    /// Resolve a tenant name to its index.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|s| s.spec.name == name)
    }

    /// Admission check for one request of `units` job units at `now`:
    /// takes a bucket token and charges the quota, or answers with the
    /// denial reason. A denial charges nothing (bucket and quota are
    /// only consumed together, on success).
    pub fn admit(&self, tenant: usize, units: u64, now: Instant) -> Result<(), TenantDenial> {
        let Some(state) = self.tenants.get(tenant) else {
            return Err(TenantDenial::Unknown);
        };
        state.counters.submitted.fetch_add(1, Ordering::Relaxed);
        // quota first (it can be released on failure; a taken token
        // cannot), so the two levers compose without leaking budget
        if !state.quota.try_charge(units) {
            state.counters.throttled.fetch_add(1, Ordering::Relaxed);
            return Err(TenantDenial::Throttled);
        }
        if !state.bucket.lock().unwrap().try_take(now) {
            state.quota.release(units);
            state.counters.throttled.fetch_add(1, Ordering::Relaxed);
            return Err(TenantDenial::Throttled);
        }
        state.counters.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The admitted request entered a class queue: a priority tenant's
    /// queued request raises the preemption signal.
    pub fn enqueued(&self, tenant: usize) {
        if self.tenants.get(tenant).is_some_and(|s| s.spec.priority) {
            self.priority_waiting.fetch_add(1, Ordering::AcqRel);
        }
    }

    /// The request left its class queue (dispatched or expired):
    /// lowers the priority signal and records the queue wait.
    pub fn dispatched(&self, tenant: usize, queue_wait_us: f64) {
        if let Some(state) = self.tenants.get(tenant) {
            if state.spec.priority {
                self.priority_waiting.fetch_sub(1, Ordering::AcqRel);
            }
            state.counters.queue_wait.record(queue_wait_us);
        }
    }

    /// The request finished successfully: releases its quota units and
    /// bills them to the tenant.
    pub fn completed(&self, tenant: usize, units: u64) {
        if let Some(state) = self.tenants.get(tenant) {
            state.quota.release(units);
            state.counters.completed.fetch_add(1, Ordering::Relaxed);
            state.counters.job_units.fetch_add(units, Ordering::Relaxed);
        }
    }

    /// The admitted request ended without a served result (shed at the
    /// class queue, expired, or failed): releases its quota units
    /// without billing them.
    pub fn aborted(&self, tenant: usize, units: u64) {
        if let Some(state) = self.tenants.get(tenant) {
            state.quota.release(units);
        }
    }

    /// Queued priority-tenant requests right now.
    pub fn priority_waiting(&self) -> usize {
        self.priority_waiting.load(Ordering::Acquire)
    }

    /// A cloneable watch over the priority-waiting signal, for
    /// attaching to background tenants' multi-pass requests.
    pub fn watch(&self) -> PreemptWatch {
        PreemptWatch(Arc::clone(&self.priority_waiting))
    }

    /// Point-in-time per-tenant counters, in configuration order.
    pub fn snapshot(&self) -> Vec<TenantStats> {
        self.tenants
            .iter()
            .map(|s| TenantStats {
                name: s.spec.name.clone(),
                priority: s.spec.priority,
                submitted: s.counters.submitted.load(Ordering::Relaxed),
                admitted: s.counters.admitted.load(Ordering::Relaxed),
                throttled: s.counters.throttled.load(Ordering::Relaxed),
                completed: s.counters.completed.load(Ordering::Relaxed),
                job_units: s.counters.job_units.load(Ordering::Relaxed),
                units_in_flight: s.quota.in_flight(),
                queue_wait: s.counters.queue_wait.snapshot(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn bucket_starts_full_and_admits_the_burst() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 4, now);
        for _ in 0..4 {
            assert!(b.try_take(now));
        }
        assert!(!b.try_take(now), "burst spent, no time passed");
    }

    #[test]
    fn bucket_refills_at_the_rate_and_saturates_at_burst() {
        let now = t0();
        let mut b = TokenBucket::new(10.0, 4, now);
        for _ in 0..4 {
            assert!(b.try_take(now));
        }
        // 250ms at 10/s refills 2.5 tokens: two admits, then throttle
        let later = now + Duration::from_millis(250);
        assert!(b.try_take(later));
        assert!(b.try_take(later));
        assert!(!b.try_take(later));
        // an hour refills far more than 4, but capacity caps at burst
        let much_later = now + Duration::from_secs(3600);
        assert!((b.available(much_later) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bucket_ignores_a_clock_running_backwards() {
        let now = t0() + Duration::from_secs(10);
        let mut b = TokenBucket::new(10.0, 2, now);
        assert!(b.try_take(now));
        let before = now - Duration::from_secs(5);
        assert!((b.available(before) - 1.0).abs() < 1e-9, "no drain, no refill");
        assert!(b.try_take(now), "the remaining token is still there");
    }

    #[test]
    fn zero_rate_bucket_admits_exactly_the_burst_ever() {
        let now = t0();
        let mut b = TokenBucket::new(0.0, 3, now);
        for _ in 0..3 {
            assert!(b.try_take(now));
        }
        assert!(!b.try_take(now + Duration::from_secs(3600)), "never refills");
    }

    fn two_tenants() -> TenantRegistry {
        TenantRegistry::new(
            vec![
                TenantSpec::new("victim", 100.0, 10).with_priority(),
                TenantSpec::new("abuser", 2.0, 2).with_quota(4),
            ],
            t0(),
        )
        .unwrap()
    }

    #[test]
    fn registry_validates_specs() {
        let now = t0();
        assert!(TenantRegistry::new(vec![], now).is_err(), "empty");
        assert!(
            TenantRegistry::new(
                vec![TenantSpec::new("a", 1.0, 1), TenantSpec::new("a", 2.0, 1)],
                now
            )
            .is_err(),
            "duplicate names"
        );
        assert!(
            TenantRegistry::new(vec![TenantSpec::new("", 1.0, 1)], now).is_err(),
            "empty name"
        );
        assert!(
            TenantRegistry::new(vec![TenantSpec::new("a", f64::NAN, 1)], now).is_err(),
            "NaN rate"
        );
        assert!(
            TenantRegistry::new(vec![TenantSpec::new("a", 1.0, 1).with_quota(0)], now).is_err(),
            "zero quota"
        );
    }

    #[test]
    fn admit_throttles_on_bucket_and_counts_both_ways() {
        let reg = two_tenants();
        let now = t0();
        assert!(reg.admit(1, 1, now).is_ok());
        assert!(reg.admit(1, 1, now).is_ok());
        assert_eq!(reg.admit(1, 1, now), Err(TenantDenial::Throttled), "burst 2 spent");
        assert_eq!(reg.admit(99, 1, now), Err(TenantDenial::Unknown));
        let snap = reg.snapshot();
        assert_eq!(snap[1].submitted, 3);
        assert_eq!(snap[1].admitted, 2);
        assert_eq!(snap[1].throttled, 1);
        assert_eq!(snap[1].name, "abuser");
        assert!(!snap[1].priority);
        assert!(snap[0].priority);
    }

    #[test]
    fn quota_throttles_inflight_units_and_releases_on_completion() {
        let reg = two_tenants();
        let now = t0();
        // abuser quota is 4 units; a 3-unit job + a 2-unit job exceed it
        assert!(reg.admit(1, 3, now).is_ok());
        assert_eq!(reg.admit(1, 2, now), Err(TenantDenial::Throttled));
        let snap = reg.snapshot();
        assert_eq!(snap[1].units_in_flight, 3, "denied units are not leaked");
        // completing the first frees the quota (and bills the units)
        reg.completed(1, 3);
        assert!(reg.admit(1, 2, now + Duration::from_secs(1)).is_ok());
        let snap = reg.snapshot();
        assert_eq!(snap[1].job_units, 3);
        assert_eq!(snap[1].units_in_flight, 2);
    }

    #[test]
    fn quota_denial_refunds_before_the_bucket_is_touched() {
        let now = t0();
        let reg = TenantRegistry::new(vec![TenantSpec::new("t", 0.0, 2).with_quota(1)], now)
            .unwrap();
        // quota denial must not consume a bucket token
        assert!(reg.admit(0, 1, now).is_ok());
        assert_eq!(reg.admit(0, 1, now), Err(TenantDenial::Throttled), "quota full");
        reg.completed(0, 1);
        assert!(reg.admit(0, 1, now).is_ok(), "the second (and last) token survived");
    }

    #[test]
    fn aborted_releases_quota_without_billing() {
        let reg = two_tenants();
        let now = t0();
        assert!(reg.admit(1, 4, now).is_ok());
        reg.aborted(1, 4);
        let snap = reg.snapshot();
        assert_eq!(snap[1].units_in_flight, 0);
        assert_eq!(snap[1].job_units, 0, "aborted work is not billed");
    }

    #[test]
    fn priority_signal_tracks_queued_priority_work_only() {
        let reg = two_tenants();
        let watch = reg.watch();
        assert!(!watch.waiting());
        reg.enqueued(1); // non-priority tenant: no signal
        assert!(!watch.waiting());
        reg.enqueued(0);
        reg.enqueued(0);
        assert!(watch.waiting());
        assert_eq!(reg.priority_waiting(), 2);
        reg.dispatched(0, 100.0);
        assert!(watch.waiting());
        reg.dispatched(0, 200.0);
        assert!(!watch.waiting());
        reg.dispatched(1, 50.0); // non-priority dispatch: no underflow
        assert_eq!(reg.priority_waiting(), 0);
        let snap = reg.snapshot();
        assert_eq!(snap[0].queue_wait.count, 2);
        assert_eq!(snap[1].queue_wait.count, 1);
    }

    #[test]
    fn manual_watch_drives_tests() {
        let w = PreemptWatch::manual();
        assert!(!w.waiting());
        w.set(1);
        assert!(w.waiting());
        let w2 = w.clone();
        w.set(0);
        assert!(!w2.waiting(), "clones share the signal");
    }
}
