//! The unified request API: one [`FftRequest`] builder and one
//! [`FftCompute`] trait replace the triplicated `submit` /
//! `submit_degraded` / `submit_batch` method families that had grown on
//! [`super::FftService`], [`super::shard::ShardedFftService`] and
//! [`super::backend::BackendSet`] — and the multi-pass size hint rides
//! the same struct instead of becoming a fourth method variant.
//!
//! This module also owns the large-N orchestration shared by every
//! execution service: [`serve_staged`] decomposes a request above the
//! single-pass ceiling with [`crate::fft::multipass`] and serves each
//! stage as a batch of ordinary sub-jobs through the same `FftCompute`
//! surface, under a reserve-or-spill admission gate
//! ([`MultipassGate`]) so staged continuation passes can never
//! monopolize the pool.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::buffer::{JobArena, JobSlot};
use super::metrics::MultipassSnapshot;
use super::qos::DegradeLevel;
use super::tenant::PreemptWatch;
use super::{FftResult, ServiceError};
use crate::fft::cache::PlanCache;
use crate::fft::field::{self, ButterflyField, Goldilocks, Workload};
use crate::fft::multipass::{self, MultipassPlan, Stage, MAX_SINGLE_PASS_POINTS};
use crate::fft::twiddle::Complex32;

/// One FFT request, as accepted by every service in the stack.
///
/// Built with the `with_*` chain; only the input signal is mandatory.
/// The execution services ([`super::FftService`],
/// [`super::shard::ShardedFftService`], [`super::backend::BackendSet`])
/// honor `level` and `max_pass_points` directly; `class` and `deadline`
/// are read by the traffic frontend at admission, and `deadline` is
/// additionally re-checked at the cooperative preemption point between
/// the passes of a decomposed large request.
#[derive(Clone, Debug)]
pub struct FftRequest {
    /// The signal to transform, interleaved `(re, im)`, held in a
    /// leased [`JobSlot`] that travels by move through every layer
    /// (admission → routing → executor → reply) without cloning.
    pub input: JobSlot,
    /// Which transform the payload asks for: a complex-f32 FFT (the
    /// default) or a Goldilocks NTT whose `u64` elements ride the same
    /// `(f32, f32)` slots bit-packed (see [`crate::fft::field::pack`]).
    /// Every layer above the executor — admission, QoS, tenancy,
    /// sharding, decomposition — treats both identically; only the
    /// compute kernel and the twiddle/root tables differ.
    pub workload: Workload,
    /// QoS degrade level: the request is truncated to
    /// `len >> level.shift()` where it is served — and, for a request
    /// above the pass ceiling, *before* decomposition, so a Half-level
    /// 2^20-point request decomposes as one 2^19-point transform.
    pub level: DegradeLevel,
    /// QoS class index (frontend admission only; execution services
    /// ignore it).
    pub class: usize,
    /// Relative deadline from submission. Enforced while queued at the
    /// frontend and at the between-pass checkpoint of a decomposed
    /// request; a plain small request already dispatched is never
    /// aborted.
    pub deadline: Option<Duration>,
    /// Largest sub-FFT one pass may serve for this request, at most
    /// (and defaulting to)
    /// [`MAX_SINGLE_PASS_POINTS`](crate::fft::multipass::MAX_SINGLE_PASS_POINTS).
    /// Must be a power of two ≥ 16; a smaller hint forces earlier
    /// four-step decomposition (useful for tests and for spreading one
    /// request wider across shards).
    pub max_pass_points: Option<usize>,
    /// Tenant index for the frontend's tenancy layer
    /// ([`super::tenant::TenantRegistry`]); ignored by servers running
    /// without one, and by the execution services.
    pub tenant: Option<usize>,
    /// Preemption signal for a decomposed request: at the between-pass
    /// checkpoint the orchestration pauses (bounded, cooperative —
    /// see [`MULTIPASS_YIELD_CAP`]) while `waiting()` reports a
    /// priority tenant's request queued. The frontend attaches this to
    /// non-priority tenants' large requests; ignored below the pass
    /// ceiling.
    pub preempt: Option<PreemptWatch>,
}

impl FftRequest {
    /// A Full-level, class-0, no-deadline request for `input`. The
    /// payload is moved into a slot leased from [`JobArena::global`]
    /// (pooled when one is free, adopted heap-backed otherwise); use
    /// [`FftRequest::with_input_slot`] to supply a pre-leased slot and
    /// skip even that step.
    pub fn new(input: Vec<(f32, f32)>) -> Self {
        Self::with_input_slot(JobArena::global().adopt_or_lease(input))
    }

    /// The zero-copy constructor: build a request around an
    /// already-leased [`JobSlot`]. Loadgen and the benches pre-lease
    /// and reuse slots so steady-state submission performs no heap
    /// allocation at all.
    pub fn with_input_slot(input: JobSlot) -> Self {
        FftRequest {
            input,
            workload: Workload::Fft,
            level: DegradeLevel::Full,
            class: 0,
            deadline: None,
            max_pass_points: None,
            tenant: None,
            preempt: None,
        }
    }

    /// An NTT request over Goldilocks field elements: the `u64` payload
    /// is bit-packed into the shared `(f32, f32)` wire format (lossless
    /// — see [`crate::fft::field::pack`]) and the request is tagged
    /// [`Workload::Ntt`]. Results unpack with
    /// [`crate::fft::field::unpack`] / `Goldilocks::unpack_vec`.
    pub fn ntt(input: Vec<u64>) -> Self {
        Self::new(Goldilocks::pack_vec(input)).with_workload(Workload::Ntt)
    }

    /// Tag the transform this request asks for (default
    /// [`Workload::Fft`]).
    pub fn with_workload(mut self, workload: Workload) -> Self {
        self.workload = workload;
        self
    }

    /// Set the QoS degrade level.
    pub fn with_level(mut self, level: DegradeLevel) -> Self {
        self.level = level;
        self
    }

    /// Set the QoS class index (frontend admission).
    pub fn with_class(mut self, class: usize) -> Self {
        self.class = class;
        self
    }

    /// Set a relative deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Hint a smaller per-pass size ceiling (see
    /// [`FftRequest::max_pass_points`]).
    pub fn with_max_pass_points(mut self, points: usize) -> Self {
        self.max_pass_points = Some(points);
        self
    }

    /// Name the tenant this request bills to (frontend tenancy layer).
    pub fn with_tenant(mut self, tenant: usize) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// Attach a preemption watch: a decomposed request will pause at
    /// the between-pass checkpoint while the watch reports priority
    /// work waiting (see [`FftRequest::preempt`]).
    pub fn with_preempt_watch(mut self, watch: PreemptWatch) -> Self {
        self.preempt = Some(watch);
        self
    }

    /// The effective (post-degrade) transform size this request serves.
    pub fn effective_points(&self) -> usize {
        self.input.len() >> self.level.shift()
    }

    /// The per-pass ceiling this request runs under, clamped into the
    /// hardware's legal range. (A hint that is not a power of two still
    /// surfaces as a typed [`multipass::MultipassError::BadCeiling`]
    /// when the request actually needs to decompose.)
    pub fn pass_ceiling(&self) -> usize {
        self.max_pass_points
            .unwrap_or(MAX_SINGLE_PASS_POINTS)
            .clamp(16, MAX_SINGLE_PASS_POINTS)
    }

    /// Whether this request exceeds its pass ceiling and therefore
    /// takes the four-step decomposition path.
    pub fn needs_decomposition(&self) -> bool {
        self.effective_points() > self.pass_ceiling()
    }
}

/// The one submission surface every execution service presents.
///
/// `request` is the single-request path (a channel now, the result
/// later); `request_all` is the batch path, absorbing the old
/// `submit_batch` coalescing semantics: same-size Full-level requests
/// within the pass ceiling are coalesced into per-size batch jobs,
/// everything else (degraded, deadline-carrying, or above-ceiling
/// requests) is served individually, and results come back in
/// submission order either way. Numerics never depend on which path a
/// request took.
pub trait FftCompute {
    /// Submit one request; the returned channel yields the result or a
    /// typed error (wrapped in `anyhow::Error`). For a request above
    /// the pass ceiling the four-step orchestration runs on the calling
    /// thread, so the channel is already resolved when this returns —
    /// identical observable behavior, since every serving path `recv`s
    /// promptly.
    fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>>;

    /// Submit a set of requests and wait for every result, in
    /// submission order. Returns the first failure, if any (per-job
    /// metrics still record individual outcomes).
    fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>>;
}

/// Reserve-or-spill admission for decomposed requests: at most
/// `permits` large requests may have their stage batches *pipelined*
/// through the pool concurrently; a request that finds no permit free
/// spills to strictly serialized sub-jobs (one in flight at a time), so
/// staged continuation passes can never deadlock or monopolize the pool
/// no matter how many large requests arrive at once. Both paths are
/// bitwise identical — the gate changes scheduling, never numerics.
pub struct MultipassGate {
    available: AtomicUsize,
}

impl MultipassGate {
    /// A gate with `permits` concurrent pipelined slots (0 = every
    /// large request spills).
    pub fn new(permits: usize) -> Self {
        MultipassGate { available: AtomicUsize::new(permits) }
    }

    /// Try to take a pipelined slot; the permit releases on drop.
    pub fn try_reserve(&self) -> Option<MultipassPermit<'_>> {
        let mut cur = self.available.load(Ordering::Relaxed);
        while cur > 0 {
            match self.available.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(MultipassPermit { gate: self }),
                Err(seen) => cur = seen,
            }
        }
        None
    }

    /// Pipelined slots currently free.
    pub fn available(&self) -> usize {
        self.available.load(Ordering::Relaxed)
    }
}

/// An RAII pipelined-multipass slot (see [`MultipassGate`]).
pub struct MultipassPermit<'a> {
    gate: &'a MultipassGate,
}

impl Drop for MultipassPermit<'_> {
    fn drop(&mut self) {
        self.gate.available.fetch_add(1, Ordering::AcqRel);
    }
}

/// Lock-free multi-pass counters owned by each execution service;
/// snapshots surface as [`MultipassSnapshot`] in the service metrics.
#[derive(Default)]
pub struct MultipassStats {
    requests: AtomicU64,
    completed: AtomicU64,
    reserved: AtomicU64,
    spilled: AtomicU64,
    preempted: AtomicU64,
    yielded: AtomicU64,
    row_jobs: AtomicU64,
    col_jobs: AtomicU64,
}

impl MultipassStats {
    /// A point-in-time copy of the counters.
    pub fn snapshot(&self) -> MultipassSnapshot {
        MultipassSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            reserved: self.reserved.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            preempted: self.preempted.load(Ordering::Relaxed),
            yielded: self.yielded.load(Ordering::Relaxed),
            row_jobs: self.row_jobs.load(Ordering::Relaxed),
            col_jobs: self.col_jobs.load(Ordering::Relaxed),
        }
    }
}

/// Longest a decomposed request will pause at the between-pass
/// checkpoint for waiting priority-tenant work before continuing
/// anyway. The cap keeps the yield cooperative, not a starvation
/// hazard: a stream of priority arrivals can delay a background
/// request's stage 2 by at most this much per checkpoint (there is one
/// checkpoint per decomposed request), and the request's own deadline
/// keeps being enforced while it waits.
pub const MULTIPASS_YIELD_CAP: Duration = Duration::from_millis(250);

/// Serve one above-ceiling request by four-step decomposition over
/// `compute`'s ordinary sub-job paths (the shared large-N orchestration
/// behind both [`super::FftService`] and
/// [`super::shard::ShardedFftService`]):
///
/// 1. apply the degrade level to the *whole* input (truncate before
///    decomposition);
/// 2. factor with [`MultipassPlan`] and fetch the cached inter-stage
///    twiddle table;
/// 3. reserve-or-spill on `gate`: with a permit, each stage batch goes
///    through `request_all` (coalesced, chunked across the pool —
///    passes pipeline across shards); without one, sub-jobs are
///    submitted strictly one at a time;
/// 4. between the passes, run the cooperative preemption point: the
///    deadline is re-checked (a miss aborts with
///    [`ServiceError::DeadlineExceeded`] before stage 2 is submitted),
///    and if the request carries a [`PreemptWatch`] reporting priority
///    work waiting, the orchestration pauses — up to
///    [`MULTIPASS_YIELD_CAP`], deadline still enforced — so a
///    high-priority tenant's request can be dispatched before this
///    request's stage-2 batch re-occupies the pool.
///
/// Orchestration runs on the calling thread; the returned channel is
/// already resolved. The result reports `core: usize::MAX` and no
/// profile (each sub-job's profile was metered individually).
pub(crate) fn serve_staged(
    compute: &dyn FftCompute,
    plans: &PlanCache,
    stats: &MultipassStats,
    gate: &MultipassGate,
    id: u64,
    req: FftRequest,
) -> Receiver<Result<FftResult>> {
    let (tx, rx) = channel();
    let started = Instant::now();
    let ceiling = req.pass_ceiling();
    let workload = req.workload;
    let deadline = req.deadline;
    let preempt = req.preempt;
    let mut input = req.input;
    if req.level != DegradeLevel::Full {
        let keep = input.len() >> req.level.shift();
        input.truncate(keep);
    }
    stats.requests.fetch_add(1, Ordering::Relaxed);
    let plan = match MultipassPlan::new(input.len(), ceiling) {
        Ok(p) => p,
        Err(e) => {
            let _ = tx.send(Err(anyhow::Error::new(e)));
            return rx;
        }
    };
    let permit = gate.try_reserve();
    if permit.is_some() {
        stats.reserved.fetch_add(1, Ordering::Relaxed);
    } else {
        stats.spilled.fetch_add(1, Ordering::Relaxed);
    }
    let staged = StagedRun {
        compute,
        stats,
        pipelined: permit.is_some(),
        deadline,
        preempt,
        started,
    };
    let run: Result<JobSlot> = match workload {
        Workload::Fft => {
            let twiddles = plans.stage_twiddles(&plan);
            staged
                .run::<Complex32>(&plan, &input, &twiddles)
                // pack_vec is the identity for complex-f32: the output
                // moves into the reply slot with no copy
                .map(|out| JobSlot::from(Complex32::pack_vec(out)))
        }
        Workload::Ntt => {
            let roots = plans.ntt_stage_roots(&plan);
            // unpack the bit-packed wire payload; the field kernels
            // require canonical elements in [0, p)
            let elems: Vec<u64> = Goldilocks::unpack_vec(input.into_vec())
                .into_iter()
                .map(field::canonicalize)
                .collect();
            staged
                .run::<Goldilocks>(&plan, &elems, &roots)
                .map(|out| JobSlot::from(Goldilocks::pack_vec(out)))
        }
    };
    drop(permit);
    match run {
        Ok(output) => {
            stats.completed.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Ok(FftResult {
                id,
                output,
                profile: None,
                core: usize::MAX,
                wall_us: started.elapsed().as_secs_f64() * 1e6,
            }));
        }
        Err(e) => {
            let _ = tx.send(Err(e));
        }
    }
    rx
}

/// The field-generic heart of [`serve_staged`]: everything about a
/// decomposed request that does not depend on the element type —
/// pipelined vs spilled sub-job submission, stage-job accounting, and
/// the between-pass deadline/preemption checkpoint — parameterized over
/// a [`ButterflyField`] so the complex-f32 FFT and the Goldilocks NTT
/// share the orchestration verbatim. Sub-jobs travel bit-packed in the
/// common `(f32, f32)` wire format and are tagged `F::WORKLOAD` so the
/// executor picks the matching kernel.
struct StagedRun<'a> {
    compute: &'a dyn FftCompute,
    stats: &'a MultipassStats,
    pipelined: bool,
    deadline: Option<Duration>,
    preempt: Option<PreemptWatch>,
    started: Instant,
}

impl StagedRun<'_> {
    fn run<F: ButterflyField>(
        &self,
        plan: &MultipassPlan,
        input: &[F::Elem],
        twiddles: &[F::Elem],
    ) -> Result<Vec<F::Elem>> {
        multipass::run_with::<F, anyhow::Error>(
            plan,
            input,
            twiddles,
            |jobs, stage| {
                match stage {
                    Stage::Rows => {
                        self.stats.row_jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed)
                    }
                    Stage::Cols => {
                        self.stats.col_jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed)
                    }
                };
                let to_req = |j: Vec<F::Elem>| {
                    FftRequest::with_input_slot(JobSlot::from(F::pack_vec(j)))
                        .with_workload(F::WORKLOAD)
                };
                if self.pipelined {
                    // pipelined: one coalesced stage batch, chunked
                    // across the pool by the service's batch path.
                    // Sub-job grids are adopted as heap-backed slots
                    // (zero copy for FFT, one lossless bit-repack for
                    // NTT; no arena pressure from one request's
                    // fan-out).
                    let results = self
                        .compute
                        .request_all(jobs.into_iter().map(to_req).collect())?;
                    Ok(results
                        .into_iter()
                        .map(|r| F::unpack_vec(r.output.into_vec()))
                        .collect())
                } else {
                    // spilled: strictly one sub-job in flight at a
                    // time — zero pool monopolization, deadlock-free
                    // by construction, bitwise identical output
                    jobs.into_iter()
                        .map(|j| {
                            let r = self
                                .compute
                                .request(to_req(j))
                                .recv()
                                .map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))??;
                            Ok(F::unpack_vec(r.output.into_vec()))
                        })
                        .collect()
                }
            },
            || {
                let check_deadline = || match self.deadline {
                    Some(d) if self.started.elapsed() > d => {
                        self.stats.preempted.fetch_add(1, Ordering::Relaxed);
                        Err(anyhow::Error::new(ServiceError::DeadlineExceeded {
                            waited_us: self.started.elapsed().as_secs_f64() * 1e6,
                        }))
                    }
                    _ => Ok(()),
                };
                check_deadline()?;
                if let Some(watch) = &self.preempt {
                    if watch.waiting() {
                        // priority-tenant work is queued: pause before
                        // submitting stage 2, bounded by the yield cap
                        // and this request's own deadline
                        self.stats.yielded.fetch_add(1, Ordering::Relaxed);
                        let paused = Instant::now();
                        while watch.waiting() && paused.elapsed() < MULTIPASS_YIELD_CAP {
                            std::thread::sleep(Duration::from_millis(1));
                            check_deadline()?;
                        }
                    }
                }
                Ok(())
            },
        )
    }
}

/// The shared `request_all` shape for the pool and sharded services:
/// coalesce what the old `submit_batch` coalesced (same-size Full-level
/// requests within the ceiling, via `batch`, grouped per workload so an
/// FFT and an NTT of the same size never land in one batch job), serve
/// degraded requests individually (via `single`), route above-ceiling
/// requests through `compute.request` (the staged path), and reassemble
/// everything in submission order.
pub(crate) fn serve_request_all(
    compute: &dyn FftCompute,
    mut batch: impl FnMut(Vec<JobSlot>, Workload) -> Result<Vec<FftResult>>,
    single: impl Fn(JobSlot, DegradeLevel, Workload) -> Receiver<Result<FftResult>>,
    reqs: Vec<FftRequest>,
) -> Result<Vec<FftResult>> {
    let n = reqs.len();
    let mut slots: Vec<Option<FftResult>> = (0..n).map(|_| None).collect();
    let mut simple: Vec<(usize, JobSlot, Workload)> = Vec::new();
    let mut staged: Vec<(usize, FftRequest)> = Vec::new();
    let mut pending: Vec<(usize, Receiver<Result<FftResult>>)> = Vec::new();
    for (i, req) in reqs.into_iter().enumerate() {
        if req.needs_decomposition() {
            staged.push((i, req));
        } else if req.level == DegradeLevel::Full {
            simple.push((i, req.input, req.workload));
        } else {
            // degraded requests keep per-request truncation semantics:
            // dispatched individually, in flight while the batch runs
            pending.push((i, single(req.input, req.level, req.workload)));
        }
    }
    for workload in [Workload::Fft, Workload::Ntt] {
        let mut rest = Vec::new();
        let mut idxs = Vec::new();
        let mut inputs = Vec::new();
        for (i, slot, w) in simple {
            if w == workload {
                idxs.push(i);
                inputs.push(slot);
            } else {
                rest.push((i, slot, w));
            }
        }
        simple = rest;
        if inputs.is_empty() {
            continue;
        }
        for (i, r) in idxs.into_iter().zip(batch(inputs, workload)?) {
            slots[i] = Some(r);
        }
    }
    for (i, req) in staged {
        // staged orchestration is synchronous: the receiver is resolved
        pending.push((i, compute.request(req)));
    }
    for (i, rx) in pending {
        slots[i] =
            Some(rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))??);
    }
    Ok(slots.into_iter().map(|s| s.expect("every slot filled")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chain() {
        let req = FftRequest::new(vec![(0.0, 0.0); 1024]);
        assert_eq!(req.workload, Workload::Fft, "FFT is the default workload");
        assert_eq!(req.level, DegradeLevel::Full);
        assert_eq!(req.class, 0);
        assert_eq!(req.deadline, None);
        assert_eq!(req.tenant, None);
        assert!(req.preempt.is_none());
        assert_eq!(req.pass_ceiling(), MAX_SINGLE_PASS_POINTS);
        assert!(!req.needs_decomposition());
        let req = req
            .with_level(DegradeLevel::Half)
            .with_class(2)
            .with_deadline(Duration::from_millis(5))
            .with_max_pass_points(256)
            .with_tenant(1)
            .with_preempt_watch(PreemptWatch::manual());
        assert_eq!(req.effective_points(), 512);
        assert_eq!(req.pass_ceiling(), 256);
        assert!(req.needs_decomposition(), "512 effective > 256 ceiling");
        assert_eq!(req.tenant, Some(1));
        assert!(req.preempt.is_some());
    }

    #[test]
    fn ntt_constructor_tags_and_packs_losslessly() {
        let elems: Vec<u64> = vec![0, 1, field::P - 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D];
        let req = FftRequest::ntt(elems.clone());
        assert_eq!(req.workload, Workload::Ntt);
        assert_eq!(req.level, DegradeLevel::Full);
        let back = Goldilocks::unpack_vec(req.input.into_vec());
        assert_eq!(back, elems, "u64 payloads survive the (f32, f32) wire format");
        let req = FftRequest::new(Vec::new()).with_workload(Workload::Ntt);
        assert_eq!(req.workload, Workload::Ntt);
    }

    #[test]
    fn degrade_can_bring_a_request_under_the_ceiling() {
        let req = FftRequest::new(vec![(0.0, 0.0); 8192]);
        assert!(req.needs_decomposition());
        let req = req.with_level(DegradeLevel::Quarter);
        assert_eq!(req.effective_points(), 2048);
        assert!(!req.needs_decomposition(), "quarter of 8192 fits one pass");
    }

    #[test]
    fn pass_ceiling_clamps_into_legal_range() {
        let base = FftRequest::new(Vec::new());
        assert_eq!(base.clone().with_max_pass_points(1 << 20).pass_ceiling(), 4096);
        assert_eq!(base.clone().with_max_pass_points(4).pass_ceiling(), 16);
        assert_eq!(base.with_max_pass_points(1024).pass_ceiling(), 1024);
    }

    #[test]
    fn gate_reserves_and_releases() {
        let gate = MultipassGate::new(2);
        assert_eq!(gate.available(), 2);
        let a = gate.try_reserve().expect("first permit");
        let b = gate.try_reserve().expect("second permit");
        assert!(gate.try_reserve().is_none(), "gate exhausted");
        assert_eq!(gate.available(), 0);
        drop(a);
        assert_eq!(gate.available(), 1);
        assert!(gate.try_reserve().is_some());
        drop(b);
    }

    #[test]
    fn zero_permit_gate_always_spills() {
        let gate = MultipassGate::new(0);
        assert!(gate.try_reserve().is_none());
        assert_eq!(gate.available(), 0);
    }

    #[test]
    fn stats_snapshot_copies_counters() {
        let stats = MultipassStats::default();
        stats.requests.fetch_add(2, Ordering::Relaxed);
        stats.yielded.fetch_add(3, Ordering::Relaxed);
        stats.row_jobs.fetch_add(64, Ordering::Relaxed);
        stats.col_jobs.fetch_add(128, Ordering::Relaxed);
        let s = stats.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.yielded, 3);
        assert_eq!(s.stage_jobs(), 192);
        assert_eq!(s.completed, 0);
    }
}
