//! L3 coordinator: an FFT service scheduling jobs over a pool of
//! simulated eGPU cores and the PJRT fast path.
//!
//! The paper's conclusion proposes deploying *many* eGPU instances
//! ("we can use one or both, or multiple copies of each"); this module
//! is that deployment: a router + worker pool where each worker owns an
//! eGPU SM (cycle-faithful virtual time) and the AOT-compiled JAX FFT
//! supplies the numeric fast path / cross-check. The offline image has
//! no tokio, so the runtime is std threads + channels — which is also
//! an honest model of a leader process feeding independent accelerator
//! cores.
//!
//! Every execution service presents one submission surface, the
//! [`request::FftCompute`] trait over a [`request::FftRequest`]
//! builder, with two dispatch paths:
//!
//! * [`FftService::request`] — one request, one queue hop; workers race
//!   for jobs on a shared queue (natural load balance);
//! * [`FftService::request_all`] — same-size Full-level requests are
//!   coalesced into per-size batches, and each batch rides one queue
//!   hop to one worker that serves every job with a single plan-cache
//!   lookup and one resident SM. Distinct sizes become distinct batch
//!   jobs, so a mixed-size batch still spreads across the pool.
//!
//! A request above the single-pass ceiling (4096 points) is served by
//! four-step decomposition ([`crate::fft::multipass`]): two stages of
//! ordinary ≤4096-point sub-jobs — pipelined through the batch path
//! when a [`request::MultipassGate`] permit is free, strictly
//! serialized otherwise — with a cooperative deadline checkpoint
//! between the passes. (The legacy `submit` / `submit_degraded` /
//! `submit_batch` shim families were removed in 0.4.0; the
//! `FftRequest` surface is the only way in.)
//!
//! Payload buffers follow the zero-copy memory discipline of
//! [`buffer`]: admission moves a request's samples into a [`JobSlot`]
//! leased from the process-global [`JobArena`], every layer after that
//! moves the same slot (never cloning the payload), workers write the
//! transform back into the slot they read from, and the reply hands
//! that slot to the caller — steady-state serving performs zero
//! per-job payload allocations on the lease-hit path.
//!
//! All workers share one [`PlanCache`]: generated FFT programs
//! (plan + schedule + twiddle image) are memoized per
//! `(points, radix, variant)` and handed out as `Arc`s, so codegen is
//! paid once per design point rather than once per core or per request.
//! Cache hit/miss/eviction counters and per-batch occupancy surface in
//! [`MetricsSnapshot`].
//!
//! For multi-core scale-out, [`shard::ShardedFftService`] replaces the
//! single shared queue with one queue per shard (each shard owning a
//! resident simulated SM), size-affinity routing and a work-stealing
//! overflow path — see the module docs in [`shard`].
//!
//! In front of either service sits the traffic frontend
//! ([`server::TrafficServer`]): N QoS classes ([`qos::QosClass`]) with
//! weighted fair queueing across classes (deficit round-robin),
//! earliest-deadline-first ordering within a class, bounded per-class
//! admission queues with a configurable backpressure policy (block /
//! shed / degrade down a floor-clamped `Full → Half → Quarter`
//! resolution ladder), an aging rule protecting background classes,
//! per-request deadlines, and queue-wait vs service-time latency
//! recorders with per-class breakdowns — plus the open-loop load
//! generator in [`loadgen`] driving it with Poisson or burst arrivals
//! over a per-class mix (`egpu-fft loadtest --class-mix`). Failures
//! are typed: every submit path answers with a [`ServiceError`]
//! instead of panicking when the worker pool is gone.
//!
//! Ahead of the class queues sits the tenancy layer
//! ([`tenant::TenantRegistry`], `egpu-fft serve --tenants`): per-tenant
//! token buckets (sustained rate + burst) and in-flight job-unit
//! quotas ([`qos::UnitQuota`]) throttle a tenant's requests *before*
//! they can occupy class-queue capacity (typed
//! [`ServiceError::TenantThrottled`]), per-tenant billing counters
//! surface in [`MetricsSnapshot::tenants`], and a priority tenant's
//! queued work makes background tenants' multi-pass jobs yield at the
//! between-pass checkpoint ([`tenant::PreemptWatch`]) — bounded
//! cross-tenant interference, gated by `benches/tenants.rs`.
//!
//! The sharded pool is *elastic*: `add_shard` / `retire_shard` resize
//! it while serving (epoch-versioned routing, drain-and-reroute
//! retirement), and the [`autoscale`] controller drives those calls
//! from the frontend's periodic [`server::PressureSample`] feed against
//! an SLO target — capacity follows traffic instead of being
//! provisioned for peak (`egpu-fft serve --autoscale`).
//!
//! Above both execution services sits multi-backend routing
//! ([`backend::BackendSet`], `egpu-fft serve --backends sim,pjrt`): a
//! measured per-backend, per-size cost model (EWMA seeded by a
//! startup calibration pass) picks a lane per request, a sampled
//! fraction of fast-path results is cross-checked bitwise against the
//! simulator (mismatch ⇒ counter + quarantine), and the autoscale
//! controller drives the routing mode as its third actuator — pinning
//! the measured-fastest lane under service-time pressure before it
//! degrades resolution or resizes the pool.

pub mod autoscale;
pub mod backend;
pub mod buffer;
pub mod loadgen;
pub mod metrics;
pub mod qos;
pub mod request;
pub mod server;
pub mod shard;
pub mod tenant;

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};
use thiserror::Error;

use crate::arch::{SmConfig, Variant};
use crate::fft::field;
use crate::fft::{self, cache::PlanCache, reference};
use crate::profile::Profile;
use crate::runtime::{spawn_pjrt_server, PjrtHandle};
use crate::sim::FftExecutor;
pub use autoscale::{
    AutoscaleController, AutoscaleEvent, AutoscaleLog, AutoscalePolicy, AutoscaleSample,
    ControllerCore, QosAction, ScaleAction,
};
pub use backend::{BackendSet, BackendSetConfig, FftBackend, RouteMode};
pub use buffer::{ArenaStats, JobArena, JobRing, JobSlot};
pub use loadgen::{ArrivalPattern, ClassLoadRow, LoadReport, LoadgenConfig, TenantLoadRow};
pub use metrics::{
    BackendStat, ClassStats, LatencyStats, Metrics, MetricsSnapshot, MultipassSnapshot,
    ServerStats, ShardStat, TenantStats,
};
pub use qos::{
    default_two_class, DegradeLadder, DegradeLevel, QosClass, QosScheduler, UnitQuota,
    DEFAULT_CLASS_CAPACITY,
};
pub use crate::fft::field::Workload;
pub use request::{FftCompute, FftRequest, MultipassGate, MultipassStats};
pub use server::{AdmissionPolicy, DegradeControl, ServedFft, ServerConfig};
pub use server::{PressureMeter, PressureSample, ServerResult, ServiceHandle, TrafficServer};
pub use shard::{ShardPoolConfig, ShardedFftService};
pub use tenant::{PreemptWatch, TenantDenial, TenantRegistry, TenantSpec, TokenBucket};

/// Typed, matchable errors from the serving stack. Execution services
/// deliver these wrapped in `anyhow::Error` (downcast to match); the
/// traffic frontend returns them directly.
#[derive(Debug, Error)]
pub enum ServiceError {
    /// The worker pool is gone: the service is shut down or every
    /// worker died. Replaces the old panic on a closed queue.
    #[error("worker pool gone: the service is shut down or every worker died")]
    WorkerGone,
    /// Admission control shed the request (queue at capacity).
    #[error("admission queue full ({capacity} requests queued): request shed")]
    QueueFull { capacity: usize },
    /// The request's deadline expired while it waited in the admission
    /// queue; it was never dispatched.
    #[error("deadline exceeded after {waited_us:.0}us in the admission queue")]
    DeadlineExceeded { waited_us: f64 },
    /// The request named a QoS class the server was not configured
    /// with.
    #[error("unknown QoS class index {class}")]
    UnknownClass { class: usize },
    /// The request named a tenant the server's tenancy layer was not
    /// configured with.
    #[error("unknown tenant index {tenant}")]
    UnknownTenant { tenant: usize },
    /// The tenancy layer refused the request: the tenant's token
    /// bucket is empty or its in-flight job-unit quota is exhausted.
    /// The request never occupied class-queue capacity.
    #[error("tenant {tenant} throttled: token bucket empty or job-unit quota exhausted")]
    TenantThrottled { tenant: usize },
    /// The execution backend failed the request (rendered message).
    #[error("backend error: {0}")]
    Backend(String),
    /// An actuator was configured over a service shape that cannot
    /// support it (e.g. autoscaling the fixed-size pool service, or
    /// the backend-swap actuator without a routed backend set) —
    /// rejected up front instead of erroring after startup work.
    #[error("actuator/service mismatch: {0}")]
    ActuatorMismatch(String),
}

/// Which execution engine serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Cycle-accurate eGPU simulation (returns a [`Profile`]).
    Simulator,
    /// AOT JAX artifact through PJRT (fast numerics, no profile).
    Pjrt,
    /// Both: PJRT numerics cross-checked against the simulator.
    Validate,
    /// No compute at all: jobs are dequeued, metered and replied with
    /// their input unchanged. Exists for the hotpath bench, which
    /// measures pure dispatch overhead (queue hop + slot movement +
    /// reply) with the FFT subtracted.
    Noop,
}

/// Configuration for an [`FftService`] worker pool.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Number of simulated eGPU cores (worker threads).
    pub cores: usize,
    /// The simulated eGPU design point each core models.
    pub variant: Variant,
    /// Nominal radix for generated programs (16 = the paper's best).
    pub radix: usize,
    /// Which execution engine serves requests.
    pub backend: Backend,
    /// Directory holding `fft{N}.hlo.txt` artifacts.
    pub artifacts_dir: String,
    /// Design points resident in the shared plan cache (LRU beyond).
    pub plan_cache_capacity: usize,
    /// How many above-ceiling (multi-pass) requests may have their
    /// stage batches pipelined through the pool concurrently; requests
    /// beyond this spill to strictly serialized sub-jobs (see
    /// [`request::MultipassGate`]). 0 = every large request spills.
    pub max_inflight_multipass: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cores: 4,
            variant: Variant::DP_VM_COMPLEX,
            radix: 16,
            backend: Backend::Simulator,
            artifacts_dir: "artifacts".into(),
            plan_cache_capacity: fft::cache::DEFAULT_PLAN_CACHE_CAPACITY,
            max_inflight_multipass: 2,
        }
    }
}

/// A served FFT result.
#[derive(Clone, Debug)]
pub struct FftResult {
    /// Service-assigned job id (submission order).
    pub id: u64,
    /// The transform, interleaved `(re, im)` at the served size — the
    /// same [`JobSlot`] the request arrived in, written in place
    /// (cloning a result deep-copies to the heap; dropping it releases
    /// an arena-backed buffer to the pool).
    pub output: JobSlot,
    /// Cycle profile (simulator backends only).
    pub profile: Option<Profile>,
    /// Which core served it (simulator backends) — PJRT jobs report
    /// `usize::MAX`.
    pub core: usize,
    /// Host-side service latency.
    pub wall_us: f64,
}

struct Job {
    kind: JobKind,
    submitted: Instant,
    /// QoS degrade level threaded through dispatch: the worker
    /// truncates the input to `len >> level.shift()` before serving, so
    /// routing, metrics and the executor all see the *served* size.
    /// Batch jobs always run at `Full`.
    level: qos::DegradeLevel,
    /// Which transform kernel serves the payload (FFT on the simulated
    /// SM / PJRT lane, NTT on the host integer datapath). Batch jobs
    /// are same-workload by construction — `serve_request_all` groups
    /// per workload before coalescing by size.
    workload: Workload,
}

impl Job {
    /// Number of requests this job carries (a batch chunk weighs its
    /// job count against queue depths and the steal threshold).
    fn weight(&self) -> u64 {
        match &self.kind {
            JobKind::Single { .. } => 1,
            JobKind::Batch { ids, .. } => ids.len() as u64,
        }
    }

    /// Effective (post-degrade) transform size, for affinity routing
    /// (batches are same-size by construction and always `Full`).
    fn points(&self) -> usize {
        match &self.kind {
            JobKind::Single { input, .. } => input.len() >> self.level.shift(),
            JobKind::Batch { inputs, .. } => inputs.first().map(|s| s.len()).unwrap_or(0),
        }
    }
}

enum JobKind {
    Single {
        id: u64,
        input: JobSlot,
        reply: Sender<Result<FftResult>>,
    },
    /// A coalesced group of same-size requests served by one worker;
    /// the reply carries one result per job (per-job error granularity,
    /// exactly as the sequential path).
    Batch {
        ids: Vec<u64>,
        inputs: Vec<JobSlot>,
        reply: Sender<Vec<Result<FftResult>>>,
    },
}

/// The running service: submit jobs, collect results, read metrics.
pub struct FftService {
    cfg: ServiceConfig,
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    plans: Arc<PlanCache>,
    mp_gate: request::MultipassGate,
    mp_stats: request::MultipassStats,
    next_id: AtomicU64,
}

impl FftService {
    /// Spawn the worker pool (and, for PJRT-backed configurations, the
    /// dedicated PJRT server thread). Fails on a zero-core or invalid
    /// variant configuration, or when the PJRT engine cannot start.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        if cfg.cores == 0 {
            return Err(anyhow!("need at least one core"));
        }
        if !cfg.variant.is_valid() {
            return Err(anyhow!("invalid variant {}", cfg.variant));
        }
        let metrics = Arc::new(Metrics::default());
        let plans = Arc::new(PlanCache::new(cfg.plan_cache_capacity));
        let (tx, rx) = channel::<Job>();
        // one shared queue; workers race for jobs -> natural load balance
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::new();
        let (engine, pjrt_join) = match cfg.backend {
            Backend::Pjrt | Backend::Validate => {
                let (handle, join) = spawn_pjrt_server(&cfg.artifacts_dir)?;
                (Some(handle), Some(join))
            }
            Backend::Simulator | Backend::Noop => (None, None),
        };
        for core in 0..cfg.cores {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg2 = cfg.clone();
            let engine = engine.clone();
            let plans = Arc::clone(&plans);
            workers.push(std::thread::spawn(move || {
                worker_loop(core, cfg2, rx, metrics, engine, plans)
            }));
        }
        if let Some(j) = pjrt_join {
            workers.push(j);
        }
        let mp_gate = request::MultipassGate::new(cfg.max_inflight_multipass);
        Ok(FftService {
            cfg,
            tx: Some(tx),
            workers,
            metrics,
            plans,
            mp_gate,
            mp_stats: request::MultipassStats::default(),
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit one request through the unified API; the returned channel
    /// yields the result. If the worker pool is gone (shutdown raced,
    /// or every worker died) the channel yields a typed
    /// [`ServiceError::WorkerGone`] — it never panics and never leaves
    /// the caller hanging on a dead channel.
    ///
    /// A request whose effective (post-degrade) size exceeds its pass
    /// ceiling is served by four-step decomposition over ordinary
    /// sub-jobs (see [`FftCompute::request`]): the orchestration runs
    /// on the calling thread and the channel is already resolved when
    /// this returns.
    pub fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        if req.needs_decomposition() {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            return request::serve_staged(self, &self.plans, &self.mp_stats, &self.mp_gate, id, req);
        }
        self.enqueue(req.input, req.level, req.workload)
    }

    /// Submit a set of requests and wait for every result, in
    /// submission order. Same-size Full-level requests within the pass
    /// ceiling are coalesced into per-size batch jobs — one plan-cache
    /// lookup and one resident SM per group, amortizing codegen,
    /// scheduling, twiddle upload and queue traffic — while degraded or
    /// above-ceiling requests are served individually. Output bits are
    /// identical to sequential [`FftService::request`] calls — batching
    /// changes dispatch, never numerics.
    ///
    /// Jobs fail individually (metrics record per-job served/error
    /// counts exactly as the sequential path); this convenience wrapper
    /// returns the first failure, if any.
    pub fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        request::serve_request_all(
            self,
            |inputs, workload| self.enqueue_batch(inputs, workload),
            |input, level, workload| self.enqueue(input, level, workload),
            reqs,
        )
    }

    /// Queue one single job at `level` (the unified
    /// [`FftService::request`] fronts it).
    fn enqueue(
        &self,
        input: JobSlot,
        level: qos::DegradeLevel,
        workload: Workload,
    ) -> Receiver<Result<FftResult>> {
        let (reply_tx, reply_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            kind: JobKind::Single { id, input, reply: reply_tx },
            submitted: Instant::now(),
            level,
            workload,
        };
        match self.tx.as_ref() {
            Some(tx) => send_or_fail(tx, job),
            None => fail_job(job),
        }
        reply_rx
    }

    /// Coalesce `inputs` (all carrying the same `workload` — callers
    /// group per workload first) into per-size groups (stable within
    /// each group), queue one batch job per group, and return every
    /// result in the original submission order.
    fn enqueue_batch(&self, inputs: Vec<JobSlot>, workload: Workload) -> Result<Vec<FftResult>> {
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let ids: Vec<u64> =
            (0..n).map(|_| self.next_id.fetch_add(1, Ordering::Relaxed)).collect();
        let groups = coalesce_by_size(&inputs);
        let mut inputs: Vec<Option<JobSlot>> = inputs.into_iter().map(Some).collect();
        let mut pending = Vec::with_capacity(groups.len());
        for (_points, idxs) in groups {
            let batch_ids: Vec<u64> = idxs.iter().map(|&i| ids[i]).collect();
            let batch_inputs: Vec<JobSlot> = idxs
                .iter()
                .map(|&i| inputs[i].take().expect("each input consumed once"))
                .collect();
            let (reply_tx, reply_rx) = channel();
            let job = Job {
                kind: JobKind::Batch { ids: batch_ids, inputs: batch_inputs, reply: reply_tx },
                submitted: Instant::now(),
                level: qos::DegradeLevel::Full,
                workload,
            };
            match self.tx.as_ref() {
                Some(tx) => send_or_fail(tx, job),
                None => fail_job(job),
            }
            pending.push((idxs, reply_rx));
        }
        collect_batch_results(n, pending)
    }

    /// Submit a batch and wait for every result (order preserved). Jobs
    /// are dispatched individually — use [`FftService::request_all`]
    /// for coalesced same-size dispatch.
    pub fn run_batch(&self, inputs: Vec<Vec<(f32, f32)>>) -> Result<Vec<FftResult>> {
        let handles: Vec<_> =
            inputs.into_iter().map(|i| self.request(FftRequest::new(i))).collect();
        handles
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))?)
            .collect()
    }

    /// Service metrics, including shared plan-cache and multi-pass
    /// counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plan_cache = self.plans.stats();
        snap.multipass = self.mp_stats.snapshot();
        snap
    }

    /// The shared plan cache (all workers hand out `Arc`s from it).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// The configuration the service was started with.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Drain and stop all workers. Closing the queue stops new
    /// submissions, but every job already queued or in flight is still
    /// served (workers drain the channel before exiting, and `join`
    /// waits for that), so replies handed out by `request` before the
    /// shutdown always arrive — pinned by `shutdown_drains_queued_jobs`.
    pub fn shutdown(mut self) {
        self.tx.take(); // closes the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl FftCompute for FftService {
    fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        FftService::request(self, req)
    }

    fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        FftService::request_all(self, reqs)
    }
}

impl Drop for FftService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-worker state: one simulated eGPU core with a resident executor
/// per FFT size, all sharing the service-wide plan cache. The executor
/// map is LRU-bounded by the plan-cache capacity so evicted design
/// points release their SM and pinned program instead of accumulating
/// on every core forever.
struct Core {
    id: usize,
    cfg: ServiceConfig,
    plans: Arc<PlanCache>,
    execs: HashMap<usize, (FftExecutor, u64)>, // by points, with last-use tick
    tick: u64,
}

impl Core {
    /// Fetch the shared program (counting a cache hit or miss) and this
    /// core's resident executor for `points`, rebuilding the executor
    /// when the cached program changed (e.g. after an LRU eviction).
    fn executor(&mut self, points: usize) -> Result<&mut FftExecutor> {
        let smcfg = SmConfig::for_radix(self.cfg.variant, self.cfg.radix);
        let fp = self.plans.get_or_build(&smcfg, points, self.cfg.radix)?;
        self.tick += 1;
        let tick = self.tick;
        if !self.execs.contains_key(&points) && self.execs.len() >= self.plans.capacity() {
            let victim = self
                .execs
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| *k)
                .expect("non-empty executor map");
            self.execs.remove(&victim);
        }
        match self.execs.entry(points) {
            Entry::Occupied(e) => {
                let slot = e.into_mut();
                slot.1 = tick;
                if !Arc::ptr_eq(slot.0.program(), &fp) {
                    slot.0 = FftExecutor::new(smcfg, fp)?;
                }
                Ok(&mut slot.0)
            }
            Entry::Vacant(e) => Ok(&mut e.insert((FftExecutor::new(smcfg, fp)?, tick)).0),
        }
    }
}

/// Send `job` to a worker queue; if the receiving side is gone (every
/// worker exited), answer the job's reply channel with a typed
/// [`ServiceError::WorkerGone`] instead of panicking. Shared by both
/// schedulers.
fn send_or_fail(tx: &Sender<Job>, job: Job) {
    if let Err(SendError(job)) = tx.send(job) {
        fail_job(job);
    }
}

/// Answer every reply slot of an undeliverable job with
/// [`ServiceError::WorkerGone`], so callers holding the receiver get a
/// typed error rather than a dead channel.
fn fail_job(job: Job) {
    match job.kind {
        JobKind::Single { reply, .. } => {
            let _ = reply.send(Err(ServiceError::WorkerGone.into()));
        }
        JobKind::Batch { ids, reply, .. } => {
            let _ = reply.send(ids.iter().map(|_| Err(ServiceError::WorkerGone.into())).collect());
        }
    }
}

/// Group batch inputs by transform size, preserving submission order
/// inside each group. Returns `(points, original indices)` per distinct
/// size in first-seen order. Shared by [`FftService::request_all`] and
/// the sharded scheduler's router.
fn coalesce_by_size(inputs: &[JobSlot]) -> Vec<(usize, Vec<usize>)> {
    let mut sizes: Vec<usize> = Vec::new(); // distinct, first-seen order
    let mut groups: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, input) in inputs.iter().enumerate() {
        let group = groups.entry(input.len()).or_default();
        if group.is_empty() {
            sizes.push(input.len());
        }
        group.push(i);
    }
    sizes
        .into_iter()
        .map(|points| {
            let idxs = groups.remove(&points).expect("group recorded");
            (points, idxs)
        })
        .collect()
}

/// Dispatched-but-unanswered batch chunks: the original input indices
/// each chunk covers, plus the reply channel its worker will fill.
type PendingBatches = Vec<(Vec<usize>, Receiver<Vec<Result<FftResult>>>)>;

/// Await every pending batch reply and reassemble results into the
/// original submission order (`n` total jobs).
fn collect_batch_results(n: usize, pending: PendingBatches) -> Result<Vec<FftResult>> {
    let mut slots: Vec<Option<Result<FftResult>>> = (0..n).map(|_| None).collect();
    for (idxs, rx) in pending {
        let results = rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))?;
        for (i, result) in idxs.into_iter().zip(results) {
            slots[i] = Some(result);
        }
    }
    slots
        .into_iter()
        .map(|s| s.expect("every slot filled"))
        .collect()
}

fn worker_loop(
    core_id: usize,
    cfg: ServiceConfig,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    engine: Option<PjrtHandle>,
    plans: Arc<PlanCache>,
) {
    let mut core = Core { id: core_id, cfg, plans, execs: HashMap::new(), tick: 0 };
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed
        };
        handle_job(&mut core, &engine, &metrics, job);
    }
}

/// Serve one dequeued job on `core`, recording metrics and replying.
/// Shared by the single-queue worker pool and the sharded scheduler
/// (identical serving code is what keeps sharded outputs bitwise equal
/// to the single-queue path).
fn handle_job(core: &mut Core, engine: &Option<PjrtHandle>, metrics: &Metrics, job: Job) {
    let level = job.level;
    let workload = job.workload;
    match job.kind {
        JobKind::Single { id, mut input, reply } => {
            // Apply the QoS degrade level where the job is served: the
            // executor, the metrics and the routing all see the
            // truncated (served) size, on both schedulers alike. (For
            // an NTT payload each `(f32, f32)` slot is one bit-packed
            // u64 element, so truncation keeps a power-of-two prefix
            // exactly as it does for complex samples.)
            if level != qos::DegradeLevel::Full {
                let keep = input.len() >> level.shift();
                input.truncate(keep);
            }
            if core.cfg.backend == Backend::Noop {
                // pure dispatch-overhead path: meter and reply with the
                // slot untouched (no compute, no copy, no allocation)
                let wall_us = job.submitted.elapsed().as_secs_f64() * 1e6;
                metrics.observe(workload, input.len(), wall_us, None);
                let _ = reply.send(Ok(FftResult {
                    id,
                    output: input,
                    profile: None,
                    core: core.id,
                    wall_us,
                }));
                return;
            }
            let res = serve_one(core, engine, id, &input, workload);
            let wall_us = job.submitted.elapsed().as_secs_f64() * 1e6;
            match res {
                Ok((output, profile, served_by)) => {
                    metrics.observe(workload, input.len(), wall_us, profile.as_ref());
                    // write the transform back into the slot the request
                    // arrived in: the reply reuses the leased buffer
                    input.copy_from(&output);
                    let _ = reply.send(Ok(FftResult {
                        id,
                        output: input,
                        profile,
                        core: served_by,
                        wall_us,
                    }));
                }
                Err(e) => {
                    metrics.observe_error();
                    let _ = reply.send(Err(e));
                }
            }
        }
        JobKind::Batch { ids, inputs, reply } => {
            let results = serve_batch(core, engine, &ids, inputs, job.submitted, workload);
            metrics.observe_batch(results.len());
            for r in &results {
                match r {
                    Ok(res) => metrics.observe(
                        workload,
                        res.output.len(),
                        res.wall_us,
                        res.profile.as_ref(),
                    ),
                    Err(_) => metrics.observe_error(),
                }
            }
            let _ = reply.send(results);
        }
    }
}

/// Serve one Goldilocks NTT job on the host integer datapath: unpack
/// the bit-packed wire payload, canonicalize into `[0, p)` (clients
/// may submit any `u64`), transform in place with the plan-cache's
/// shared root table, and re-pack. The f32 SIMT SM and the PJRT
/// artifact only implement the complex FFT — 64-bit modular arithmetic
/// does not fit their datapath — so every backend serves NTT here,
/// while admission, QoS, tenancy, sharding and decomposition above
/// stay workload-blind. No cycle profile is reported.
fn serve_ntt(
    core: &mut Core,
    input: &[(f32, f32)],
) -> Result<(Vec<(f32, f32)>, Option<Profile>, usize)> {
    let n = input.len();
    if !n.is_power_of_two() || n < 4 || n > fft::MAX_SINGLE_PASS_POINTS {
        // same typed rejection as an unplannable FFT size
        return Err(fft::FftError::Plan(fft::PlanError::BadSize(n)).into());
    }
    let roots = core.plans.ntt_roots(n);
    let mut elems: Vec<u64> =
        input.iter().map(|&w| field::canonicalize(field::unpack(w))).collect();
    field::ntt_with_roots(&mut elems, &roots);
    Ok((elems.into_iter().map(field::pack).collect(), None, core.id))
}

/// Serve one request; returns (output, profile, serving core id).
fn serve_one(
    core: &mut Core,
    engine: &Option<PjrtHandle>,
    id: u64,
    input: &[(f32, f32)],
    workload: Workload,
) -> Result<(Vec<(f32, f32)>, Option<Profile>, usize)> {
    if workload == Workload::Ntt {
        return serve_ntt(core, input);
    }
    match core.cfg.backend {
        Backend::Simulator => {
            let run = core.executor(input.len())?.run(input)?;
            Ok((run.output, Some(run.profile), core.id))
        }
        Backend::Pjrt => {
            let eng = engine.as_ref().expect("engine for pjrt backend");
            Ok((eng.fft(input)?, None, usize::MAX))
        }
        Backend::Validate => {
            let eng = engine.as_ref().expect("engine for validate backend");
            let fast = eng.fft(input)?;
            let run = core.executor(input.len())?.run(input)?;
            let err = cross_error(&run.output, &fast);
            if err > fft::F32_TOL {
                return Err(anyhow!(
                    "cross-check failed for job {id}: sim vs pjrt rms {err:e}"
                ));
            }
            Ok((fast, Some(run.profile), core.id))
        }
        // defensive: the no-op backend is short-circuited in
        // `handle_job` before compute; echo the input if reached
        Backend::Noop => Ok((input.to_vec(), None, core.id)),
    }
}

/// Serve a coalesced same-size batch on this worker: the simulator path
/// resolves the plan and the resident executor once and streams every
/// job through them, writing each transform back into the slot it
/// arrived in. Jobs fail individually; an unservable design point (no
/// valid plan) fails the whole group with one error per job.
fn serve_batch(
    core: &mut Core,
    engine: &Option<PjrtHandle>,
    ids: &[u64],
    inputs: Vec<JobSlot>,
    submitted: Instant,
    workload: Workload,
) -> Vec<Result<FftResult>> {
    let mut results = Vec::with_capacity(inputs.len());
    if workload == Workload::Ntt && core.cfg.backend != Backend::Noop {
        // NTT batches stream through the host kernel: one shared root
        // table for the whole same-size group, each transform written
        // back into the slot it arrived in.
        for (id, mut input) in ids.iter().zip(inputs) {
            results.push(serve_ntt(core, &input).map(|(output, profile, served_by)| {
                input.copy_from(&output);
                FftResult {
                    id: *id,
                    output: input,
                    profile,
                    core: served_by,
                    wall_us: submitted.elapsed().as_secs_f64() * 1e6,
                }
            }));
        }
        return results;
    }
    match core.cfg.backend {
        Backend::Simulator => {
            let points = inputs.first().map(|s| s.len()).unwrap_or(0);
            let core_id = core.id;
            match core.executor(points) {
                Ok(ex) => {
                    for (id, mut input) in ids.iter().zip(inputs) {
                        results.push(match ex.run(&input) {
                            Ok(run) => {
                                input.copy_from(&run.output);
                                Ok(FftResult {
                                    id: *id,
                                    output: input,
                                    profile: Some(run.profile),
                                    core: core_id,
                                    wall_us: submitted.elapsed().as_secs_f64() * 1e6,
                                })
                            }
                            Err(e) => Err(e.into()),
                        });
                    }
                }
                Err(e) => {
                    // anyhow::Error is not Clone: re-render it per job
                    let msg = format!("{e:#}");
                    for _ in ids {
                        results.push(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        Backend::Noop => {
            for (id, input) in ids.iter().zip(inputs) {
                results.push(Ok(FftResult {
                    id: *id,
                    output: input,
                    profile: None,
                    core: core.id,
                    wall_us: submitted.elapsed().as_secs_f64() * 1e6,
                }));
            }
        }
        Backend::Pjrt | Backend::Validate => {
            for (id, mut input) in ids.iter().zip(inputs) {
                results.push(serve_one(core, engine, *id, &input, workload).map(
                    |(output, profile, served_by)| {
                        input.copy_from(&output);
                        FftResult {
                            id: *id,
                            output: input,
                            profile,
                            core: served_by,
                            wall_us: submitted.elapsed().as_secs_f64() * 1e6,
                        }
                    },
                ));
            }
        }
    }
    results
}

/// Relative RMS between two f32 complex vectors.
pub fn cross_error(a: &[(f32, f32)], b: &[(f32, f32)]) -> f64 {
    let to = |v: &[(f32, f32)]| -> Vec<fft::Cpx> {
        v.iter().map(|&(r, i)| fft::Cpx::new(r as f64, i as f64)).collect()
    };
    reference::rms_rel_error(&to(a), &to(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::test_signal;

    fn signal(n: usize, seed: u64) -> Vec<(f32, f32)> {
        test_signal(n, seed).iter().map(|c| c.to_f32_pair()).collect()
    }

    #[test]
    fn simulator_service_end_to_end() {
        let svc = FftService::start(ServiceConfig {
            cores: 2,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<_> = (0..8).map(|i| signal(256, i)).collect();
        let results = svc.run_batch(inputs.clone()).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            let want = reference::fft(&test_signal(256, i as u64));
            let got: Vec<_> = r
                .output
                .iter()
                .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
                .collect();
            assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
            assert!(r.profile.is_some());
        }
        let m = svc.metrics();
        assert_eq!(m.served, 8);
        assert_eq!(m.errors, 0);
        assert!(m.virtual_us > 0.0);
        // the shared cache built fft256 once, every later lookup hit
        assert_eq!(m.plan_cache.entries, 1);
        assert!(m.plan_cache.hits >= 1);
        svc.shutdown();
    }

    #[test]
    fn mixed_sizes_route_correctly() {
        let svc = FftService::start(ServiceConfig {
            cores: 3,
            ..Default::default()
        })
        .unwrap();
        let results = svc
            .run_batch(vec![signal(256, 1), signal(1024, 2), signal(256, 3), signal(4096, 4)])
            .unwrap();
        assert_eq!(results[0].output.len(), 256);
        assert_eq!(results[1].output.len(), 1024);
        assert_eq!(results[3].output.len(), 4096);
        let m = svc.metrics();
        assert_eq!(m.served, 4);
        assert_eq!(m.by_points.get(&256).copied().unwrap_or(0), 2);
    }

    #[test]
    fn bad_size_surfaces_error_without_killing_workers() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let bad = svc.request(FftRequest::new(signal(100, 0))).recv().unwrap();
        assert!(bad.is_err());
        // service still alive
        let ok = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap();
        assert!(ok.is_ok());
        assert_eq!(svc.metrics().errors, 1);
    }

    #[test]
    fn dead_worker_surfaces_typed_worker_gone() {
        // a queue whose receiving side is gone stands in for a pool
        // where every worker died
        let (tx, rx) = channel::<Job>();
        drop(rx);
        let (reply_tx, reply_rx) = channel();
        let job = Job {
            kind: JobKind::Single { id: 0, input: JobSlot::from(signal(256, 0)), reply: reply_tx },
            submitted: Instant::now(),
            level: qos::DegradeLevel::Full,
            workload: Workload::Fft,
        };
        send_or_fail(&tx, job);
        let err = reply_rx.recv().expect("typed reply, not a dead channel").unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServiceError>(), Some(ServiceError::WorkerGone)),
            "want WorkerGone, got {err:#}"
        );
    }

    #[test]
    fn dead_worker_fails_batches_per_job() {
        let (tx, rx) = channel::<Job>();
        drop(rx);
        let (reply_tx, reply_rx) = channel();
        let job = Job {
            kind: JobKind::Batch {
                ids: vec![0, 1, 2],
                inputs: (0..3).map(|i| JobSlot::from(signal(256, i))).collect(),
                reply: reply_tx,
            },
            submitted: Instant::now(),
            level: qos::DegradeLevel::Full,
            workload: Workload::Fft,
        };
        send_or_fail(&tx, job);
        let results = reply_rx.recv().unwrap();
        assert_eq!(results.len(), 3, "one typed error per job in the batch");
        for r in results {
            let err = r.unwrap_err();
            assert!(matches!(
                err.downcast_ref::<ServiceError>(),
                Some(ServiceError::WorkerGone)
            ));
        }
    }

    #[test]
    fn degraded_dispatch_serves_and_meters_the_truncated_size() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let r = svc
            .request(FftRequest::new(signal(1024, 3)).with_level(qos::DegradeLevel::Quarter))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(r.output.len(), 256, "quarter resolution of a 1024-point request");
        let m = svc.metrics();
        assert_eq!(m.by_points.get(&256).copied().unwrap_or(0), 1, "metered at served size");
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        // one core, several queued jobs: shutdown must serve them all
        // before joining, so every receiver yields a real result
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let handles: Vec<_> =
            (0..6).map(|i| svc.request(FftRequest::new(signal(256, i)))).collect();
        svc.shutdown();
        for rx in handles {
            assert!(rx.recv().expect("reply sent before worker exit").is_ok());
        }
    }

    #[test]
    fn pjrt_backend_serves_if_artifacts_exist() {
        if !std::path::Path::new("artifacts/fft256.hlo.txt").exists() {
            eprintln!("WARNING: artifacts missing; skipping pjrt service test");
            return;
        }
        let svc = match FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Pjrt,
            ..Default::default()
        }) {
            Ok(svc) => svc,
            Err(e) => {
                // artifacts exist but the build lacks the pjrt feature
                eprintln!("WARNING: {e}; skipping pjrt service test");
                return;
            }
        };
        let r = svc.request(FftRequest::new(signal(256, 7))).recv().unwrap().unwrap();
        assert!(r.profile.is_none());
        let want = reference::fft(&test_signal(256, 7));
        let got: Vec<_> = r
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
    }

    #[test]
    fn validate_backend_cross_checks() {
        if !std::path::Path::new("artifacts/fft256.hlo.txt").exists() {
            eprintln!("WARNING: artifacts missing; skipping validate test");
            return;
        }
        let svc = match FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Validate,
            ..Default::default()
        }) {
            Ok(svc) => svc,
            Err(e) => {
                eprintln!("WARNING: {e}; skipping validate test");
                return;
            }
        };
        let r = svc.request(FftRequest::new(signal(1024, 9))).recv().unwrap().unwrap();
        assert!(r.profile.is_some()); // sim ran too
    }

    /// The no-op backend dequeues, meters and replies with the input
    /// slot unchanged — the dispatch-overhead-only engine the hotpath
    /// bench measures.
    #[test]
    fn noop_backend_echoes_input_without_compute() {
        let svc = FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Noop,
            ..Default::default()
        })
        .unwrap();
        let input = signal(256, 11);
        let r = svc.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
        assert_eq!(r.output, input, "no-op serving echoes the payload");
        assert!(r.profile.is_none());
        let m = svc.metrics();
        assert_eq!(m.served, 1);
        assert_eq!(m.errors, 0);
        svc.shutdown();
    }

    /// An above-ceiling request decomposes into sub-jobs and comes back
    /// within f32 tolerance of the direct reference transform; the
    /// multipass counters account for it.
    #[test]
    fn large_request_decomposes_and_matches_reference() {
        let svc = FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap();
        // a 1024-point request under a forced 64-point ceiling: 32 row
        // jobs of 32 points + 32 col jobs of 32 points
        let r = svc
            .request(FftRequest::new(signal(1024, 21)).with_max_pass_points(64))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(r.output.len(), 1024);
        assert_eq!(r.core, usize::MAX, "no single core served a decomposed request");
        assert!(r.profile.is_none());
        let got: Vec<_> =
            r.output.iter().map(|&(re, im)| fft::Cpx::new(re as f64, im as f64)).collect();
        let want = reference::fft(&test_signal(1024, 21));
        let err = reference::rms_rel_error(&got, &want);
        assert!(err < 5.0 * fft::F32_TOL, "multi-pass rms {err}");
        let m = svc.metrics();
        assert_eq!(m.multipass.requests, 1);
        assert_eq!(m.multipass.completed, 1);
        assert_eq!(m.multipass.reserved, 1, "permits free: the request pipelines");
        assert_eq!(m.multipass.row_jobs, 32);
        assert_eq!(m.multipass.col_jobs, 32);
        assert_eq!(m.served, 64, "every sub-job metered individually");
        svc.shutdown();
    }

    /// With a zero-permit gate every large request spills to serialized
    /// sub-jobs — and the output is bitwise identical to the pipelined
    /// path (the gate changes scheduling, never numerics).
    #[test]
    fn spilled_multipass_is_bitwise_identical_to_reserved() {
        let reserved = FftService::start(ServiceConfig { cores: 2, ..Default::default() })
            .unwrap();
        let spilled = FftService::start(ServiceConfig {
            cores: 2,
            max_inflight_multipass: 0,
            ..Default::default()
        })
        .unwrap();
        let req = || FftRequest::new(signal(2048, 33)).with_max_pass_points(128);
        let a = reserved.request(req()).recv().unwrap().unwrap();
        let b = spilled.request(req()).recv().unwrap().unwrap();
        assert_eq!(a.output, b.output, "reserve and spill paths are bitwise equal");
        assert_eq!(reserved.metrics().multipass.reserved, 1);
        assert_eq!(spilled.metrics().multipass.spilled, 1);
        assert_eq!(spilled.metrics().multipass.reserved, 0);
    }

    /// A Half-level above-ceiling request truncates *before*
    /// decomposition: it serves as one 512-point transform of the
    /// truncated signal, not per-pass truncation.
    #[test]
    fn degraded_large_request_truncates_before_decomposition() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let r = svc
            .request(
                FftRequest::new(signal(1024, 5))
                    .with_level(qos::DegradeLevel::Half)
                    .with_max_pass_points(64),
            )
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(r.output.len(), 512, "half of 1024, decomposed at 512");
        let mut truncated = test_signal(1024, 5);
        truncated.truncate(512);
        let want = reference::fft(&truncated);
        let got: Vec<_> =
            r.output.iter().map(|&(re, im)| fft::Cpx::new(re as f64, im as f64)).collect();
        let err = reference::rms_rel_error(&got, &want);
        assert!(err < 5.0 * fft::F32_TOL, "truncated-then-decomposed rms {err}");
        // 512 = 16 x 32: 16 row jobs + 32 col jobs
        assert_eq!(svc.metrics().multipass.stage_jobs(), 48);
        svc.shutdown();
    }

    /// The between-pass deadline checkpoint preempts a large request
    /// whose deadline already passed, with a typed error.
    #[test]
    fn multipass_deadline_preempts_between_passes() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let err = svc
            .request(
                FftRequest::new(signal(1024, 9))
                    .with_max_pass_points(64)
                    .with_deadline(std::time::Duration::ZERO),
            )
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ServiceError>(),
                Some(ServiceError::DeadlineExceeded { .. })
            ),
            "want DeadlineExceeded, got {err:#}"
        );
        let m = svc.metrics();
        assert_eq!(m.multipass.preempted, 1);
        assert_eq!(m.multipass.completed, 0);
        assert_eq!(m.multipass.col_jobs, 0, "stage 2 never submitted");
        assert_eq!(m.multipass.row_jobs, 32, "stage 1 had already run");
        svc.shutdown();
    }

    /// A single-pass NTT request through the pool service matches the
    /// naive O(n²) modular DFT oracle exactly — integer equality, no
    /// tolerance.
    #[test]
    fn ntt_request_matches_naive_modular_dft_exactly() {
        let svc = FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap();
        for (n, seed) in [(256usize, 7u64), (1024, 8)] {
            let input = field::test_elements(n, seed);
            let want = field::dft_naive(&input);
            let r = svc.request(FftRequest::ntt(input)).recv().unwrap().unwrap();
            assert!(r.profile.is_none(), "NTT runs on the host datapath, no cycle profile");
            let got: Vec<u64> = r.output.iter().map(|&w| field::unpack(w)).collect();
            assert_eq!(got, want, "n={n}: NTT service output differs from the oracle");
        }
        let m = svc.metrics();
        assert_eq!(m.served, 2);
        assert_eq!(m.by_workload.get(&Workload::Ntt).copied().unwrap_or(0), 2);
        svc.shutdown();
    }

    /// Non-canonical payloads (elements ≥ p) are reduced on unpack, so
    /// any u64 input is served as its canonical representative.
    #[test]
    fn ntt_request_canonicalizes_wire_payloads() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let canonical = field::test_elements(256, 3);
        // shift a few elements up by p: same residue class, different bits
        let mut shifted = canonical.clone();
        for x in shifted.iter_mut().take(4) {
            if *x < u64::MAX - field::P {
                *x += field::P;
            }
        }
        let a = svc.request(FftRequest::ntt(canonical)).recv().unwrap().unwrap();
        let b = svc.request(FftRequest::ntt(shifted)).recv().unwrap().unwrap();
        assert_eq!(&*a.output, &*b.output, "residue classes serve identically");
        svc.shutdown();
    }

    /// A mixed `request_all` keeps workloads apart: the same transform
    /// size carries an FFT and an NTT in one batch call, and each comes
    /// back served by its own kernel.
    #[test]
    fn mixed_workload_batch_keeps_kernels_apart() {
        let svc = FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap();
        let elems = field::test_elements(256, 5);
        let want_ntt = field::ntt(&elems);
        let reqs = vec![
            FftRequest::new(signal(256, 1)),
            FftRequest::ntt(elems),
            FftRequest::new(signal(256, 2)),
        ];
        let results = svc.request_all(reqs).unwrap();
        assert_eq!(results.len(), 3);
        for (i, seed) in [(0usize, 1u64), (2, 2)] {
            let want = reference::fft(&test_signal(256, seed));
            let got: Vec<_> = results[i]
                .output
                .iter()
                .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
                .collect();
            assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL, "slot {i}");
        }
        let got_ntt: Vec<u64> =
            results[1].output.iter().map(|&w| field::unpack(w)).collect();
        assert_eq!(got_ntt, want_ntt, "NTT slot served exactly");
        svc.shutdown();
    }

    /// A non-power-of-two NTT size gets the same typed plan rejection
    /// as an unplannable FFT, without killing the worker.
    #[test]
    fn bad_ntt_size_surfaces_typed_error() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let err = svc
            .request(FftRequest::ntt(vec![1u64; 100]))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<fft::FftError>(),
                Some(fft::FftError::Plan(fft::PlanError::BadSize(100)))
            ),
            "want PlanError::BadSize, got {err:#}"
        );
        let ok = svc.request(FftRequest::ntt(field::test_elements(256, 1))).recv().unwrap();
        assert!(ok.is_ok(), "worker survives a bad NTT size");
        svc.shutdown();
    }

    /// An undecomposable large size surfaces a typed multipass error.
    #[test]
    fn oversized_request_rejected_with_typed_error() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        // 1024 > 16^2: no single four-step level over a 16-point
        // ceiling can decompose it (the same typed error a 2^25-point
        // request gets against the real 4096 ceiling)
        let err = svc
            .request(FftRequest::new(signal(1024, 1)).with_max_pass_points(16))
            .recv()
            .unwrap()
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<crate::fft::MultipassError>(),
                Some(crate::fft::MultipassError::TooLarge { .. })
            ),
            "want MultipassError::TooLarge, got {err:#}"
        );
        svc.shutdown();
    }
}
