//! L3 coordinator: an FFT service scheduling jobs over a pool of
//! simulated eGPU cores and the PJRT fast path.
//!
//! The paper's conclusion proposes deploying *many* eGPU instances
//! ("we can use one or both, or multiple copies of each"); this module
//! is that deployment: a router + worker pool where each worker owns an
//! eGPU SM (cycle-faithful virtual time) and the AOT-compiled JAX FFT
//! supplies the numeric fast path / cross-check. The offline image has
//! no tokio, so the runtime is std threads + channels — which is also
//! an honest model of a leader process feeding independent accelerator
//! cores.

pub mod metrics;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::{SmConfig, Variant};
use crate::fft::{self, reference, FftProgram};
use crate::profile::Profile;
use crate::runtime::{spawn_pjrt_server, PjrtHandle};
use crate::sim::Sm;
pub use metrics::{Metrics, MetricsSnapshot};

/// Which execution engine serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Cycle-accurate eGPU simulation (returns a [`Profile`]).
    Simulator,
    /// AOT JAX artifact through PJRT (fast numerics, no profile).
    Pjrt,
    /// Both: PJRT numerics cross-checked against the simulator.
    Validate,
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Number of simulated eGPU cores (worker threads).
    pub cores: usize,
    pub variant: Variant,
    /// Nominal radix for generated programs (16 = the paper's best).
    pub radix: usize,
    pub backend: Backend,
    /// Directory holding `fft{N}.hlo.txt` artifacts.
    pub artifacts_dir: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cores: 4,
            variant: Variant::DP_VM_COMPLEX,
            radix: 16,
            backend: Backend::Simulator,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// A served FFT result.
#[derive(Clone, Debug)]
pub struct FftResult {
    pub id: u64,
    pub output: Vec<(f32, f32)>,
    /// Cycle profile (simulator backends only).
    pub profile: Option<Profile>,
    /// Which core served it (simulator backends) — PJRT jobs report
    /// `usize::MAX`.
    pub core: usize,
    /// Host-side service latency.
    pub wall_us: f64,
}

struct Job {
    id: u64,
    input: Vec<(f32, f32)>,
    reply: Sender<Result<FftResult>>,
    submitted: Instant,
}

/// The running service: submit jobs, collect results, read metrics.
pub struct FftService {
    cfg: ServiceConfig,
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl FftService {
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        if cfg.cores == 0 {
            return Err(anyhow!("need at least one core"));
        }
        if !cfg.variant.is_valid() {
            return Err(anyhow!("invalid variant {}", cfg.variant));
        }
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = channel::<Job>();
        // one shared queue; workers race for jobs -> natural load balance
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::new();
        let (engine, pjrt_join) = match cfg.backend {
            Backend::Pjrt | Backend::Validate => {
                let (handle, join) = spawn_pjrt_server(&cfg.artifacts_dir)?;
                (Some(handle), Some(join))
            }
            Backend::Simulator => (None, None),
        };
        let programs: ProgramCache = Arc::new(Mutex::new(HashMap::new()));
        for core in 0..cfg.cores {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg2 = cfg.clone();
            let engine = engine.clone();
            let programs = Arc::clone(&programs);
            workers.push(std::thread::spawn(move || {
                worker_loop(core, cfg2, rx, metrics, engine, programs)
            }));
        }
        if let Some(j) = pjrt_join {
            workers.push(j);
        }
        Ok(FftService { cfg, tx: Some(tx), workers, metrics, next_id: AtomicU64::new(0) })
    }

    /// Submit one FFT; the returned channel yields the result.
    pub fn submit(&self, input: Vec<(f32, f32)>) -> Receiver<Result<FftResult>> {
        let (reply_tx, reply_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Job { id, input, reply: reply_tx, submitted: Instant::now() };
        self.tx
            .as_ref()
            .expect("service running")
            .send(job)
            .expect("workers alive");
        reply_rx
    }

    /// Submit a batch and wait for every result (order preserved).
    pub fn run_batch(&self, inputs: Vec<Vec<(f32, f32)>>) -> Result<Vec<FftResult>> {
        let handles: Vec<_> = inputs.into_iter().map(|i| self.submit(i)).collect();
        handles
            .into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow!("worker dropped reply: {e}"))?)
            .collect()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // closes the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for FftService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Program cache shared by every worker (§Perf: codegen+scheduling of
/// a 4096-point program costs ~0.5 ms; generate once, not per core).
type ProgramCache = Arc<Mutex<HashMap<usize, Arc<FftProgram>>>>;

/// Per-worker state: one simulated eGPU core with per-size SMs and a
/// handle on the shared program cache.
struct Core {
    id: usize,
    cfg: ServiceConfig,
    programs: ProgramCache,
    sms: HashMap<usize, Sm>, // by points
}

impl Core {
    fn program(&mut self, points: usize) -> Result<Arc<FftProgram>> {
        if let Some(p) = self.programs.lock().unwrap().get(&points) {
            return Ok(Arc::clone(p));
        }
        // generate outside the lock (other sizes stay servable), then
        // double-check on insert
        let smcfg = SmConfig::for_radix(self.cfg.variant, self.cfg.radix);
        let fp = Arc::new(fft::generate(&smcfg, points, self.cfg.radix)?);
        let mut cache = self.programs.lock().unwrap();
        Ok(Arc::clone(cache.entry(points).or_insert(fp)))
    }

    fn simulate(&mut self, input: &[(f32, f32)]) -> Result<(Vec<(f32, f32)>, Profile)> {
        let points = input.len();
        let fp = self.program(points)?;
        let smcfg = SmConfig::for_radix(self.cfg.variant, self.cfg.radix);
        // §Perf: one SM per size per core, twiddle tables loaded once at
        // creation — the per-request work is data fill + run + readback.
        let sm = match self.sms.entry(points) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let mut sm = Sm::new(smcfg);
                sm.seed_thread_ids();
                fft::load_twiddles(&mut sm, &fp)?;
                e.insert(sm)
            }
        };
        fft::load_data(sm, &fp, input)?;
        let profile = sm.run(&fp.program, fp.plan.threads)?;
        let output = fft::read_output(sm, &fp)?;
        Ok((output, profile))
    }
}

fn worker_loop(
    core_id: usize,
    cfg: ServiceConfig,
    rx: Arc<std::sync::Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
    engine: Option<PjrtHandle>,
    programs: ProgramCache,
) {
    let mut core = Core { id: core_id, cfg: cfg.clone(), programs, sms: HashMap::new() };
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed
        };
        let res = serve(&mut core, &engine, &job);
        let wall_us = job.submitted.elapsed().as_secs_f64() * 1e6;
        match res {
            Ok((output, profile)) => {
                metrics.observe(job.input.len(), wall_us, profile.as_ref());
                let _ = job.reply.send(Ok(FftResult {
                    id: job.id,
                    output,
                    profile,
                    core: if engine.is_some() && profile_is_none(&profile) {
                        usize::MAX
                    } else {
                        core.id
                    },
                    wall_us,
                }));
            }
            Err(e) => {
                metrics.observe_error();
                let _ = job.reply.send(Err(e));
            }
        }
    }
}

fn profile_is_none(p: &Option<Profile>) -> bool {
    p.is_none()
}

fn serve(
    core: &mut Core,
    engine: &Option<PjrtHandle>,
    job: &Job,
) -> Result<(Vec<(f32, f32)>, Option<Profile>)> {
    match core.cfg.backend {
        Backend::Simulator => {
            let (out, prof) = core.simulate(&job.input)?;
            Ok((out, Some(prof)))
        }
        Backend::Pjrt => {
            let eng = engine.as_ref().expect("engine for pjrt backend");
            Ok((eng.fft(&job.input)?, None))
        }
        Backend::Validate => {
            let eng = engine.as_ref().expect("engine for validate backend");
            let fast = eng.fft(&job.input)?;
            let (sim, prof) = core.simulate(&job.input)?;
            let err = cross_error(&sim, &fast);
            if err > fft::F32_TOL {
                return Err(anyhow!(
                    "cross-check failed for job {}: sim vs pjrt rms {err:e}",
                    job.id
                ));
            }
            Ok((fast, Some(prof)))
        }
    }
}

/// Relative RMS between two f32 complex vectors.
pub fn cross_error(a: &[(f32, f32)], b: &[(f32, f32)]) -> f64 {
    let to = |v: &[(f32, f32)]| -> Vec<fft::Cpx> {
        v.iter().map(|&(r, i)| fft::Cpx::new(r as f64, i as f64)).collect()
    };
    reference::rms_rel_error(&to(a), &to(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::test_signal;

    fn signal(n: usize, seed: u64) -> Vec<(f32, f32)> {
        test_signal(n, seed).iter().map(|c| c.to_f32_pair()).collect()
    }

    #[test]
    fn simulator_service_end_to_end() {
        let svc = FftService::start(ServiceConfig {
            cores: 2,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<_> = (0..8).map(|i| signal(256, i)).collect();
        let results = svc.run_batch(inputs.clone()).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            let want = reference::fft(&test_signal(256, i as u64));
            let got: Vec<_> = r
                .output
                .iter()
                .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
                .collect();
            assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
            assert!(r.profile.is_some());
        }
        let m = svc.metrics();
        assert_eq!(m.served, 8);
        assert_eq!(m.errors, 0);
        assert!(m.virtual_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn mixed_sizes_route_correctly() {
        let svc = FftService::start(ServiceConfig {
            cores: 3,
            ..Default::default()
        })
        .unwrap();
        let results = svc
            .run_batch(vec![signal(256, 1), signal(1024, 2), signal(256, 3), signal(4096, 4)])
            .unwrap();
        assert_eq!(results[0].output.len(), 256);
        assert_eq!(results[1].output.len(), 1024);
        assert_eq!(results[3].output.len(), 4096);
        let m = svc.metrics();
        assert_eq!(m.served, 4);
        assert_eq!(m.by_points.get(&256).copied().unwrap_or(0), 2);
    }

    #[test]
    fn bad_size_surfaces_error_without_killing_workers() {
        let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
        let bad = svc.submit(signal(100, 0)).recv().unwrap();
        assert!(bad.is_err());
        // service still alive
        let ok = svc.submit(signal(256, 1)).recv().unwrap();
        assert!(ok.is_ok());
        assert_eq!(svc.metrics().errors, 1);
    }

    #[test]
    fn pjrt_backend_serves_if_artifacts_exist() {
        if !std::path::Path::new("artifacts/fft256.hlo.txt").exists() {
            eprintln!("WARNING: artifacts missing; skipping pjrt service test");
            return;
        }
        let svc = FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Pjrt,
            ..Default::default()
        })
        .unwrap();
        let r = svc.submit(signal(256, 7)).recv().unwrap().unwrap();
        assert!(r.profile.is_none());
        let want = reference::fft(&test_signal(256, 7));
        let got: Vec<_> = r
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
    }

    #[test]
    fn validate_backend_cross_checks() {
        if !std::path::Path::new("artifacts/fft256.hlo.txt").exists() {
            eprintln!("WARNING: artifacts missing; skipping validate test");
            return;
        }
        let svc = FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Validate,
            ..Default::default()
        })
        .unwrap();
        let r = svc.submit(signal(1024, 9)).recv().unwrap().unwrap();
        assert!(r.profile.is_some()); // sim ran too
    }
}
