//! Open-loop load generator for the traffic frontend.
//!
//! Drives a [`TrafficServer`] with a realistic arrival process —
//! requests are submitted on their own clock regardless of how fast
//! the service drains them (open loop), which is what exposes queueing,
//! shedding and deadline behaviour that a closed submit-and-wait loop
//! structurally cannot produce. Two arrival patterns:
//!
//! * **Poisson** — exponentially distributed interarrival gaps at the
//!   offered rate (the classic open-network model of independent
//!   users);
//! * **Burst** — the same mean rate delivered as back-to-back groups of
//!   [`LoadgenConfig::burst_size`] requests, stressing the admission
//!   queue and the shed path.
//!
//! Requests draw transform sizes from a mixed 256–4096 pool (or the
//! [`LoadgenConfig::large_n`] mix, which reaches past the single-pass
//! ceiling to 65536 points through the multi-pass path; or the
//! [`LoadgenConfig::ntt`] mix, which submits Goldilocks prime-field
//! NTT payloads through the same frontend), split
//! across the server's QoS classes by [`LoadgenConfig::class_mix`]
//! (arrival fractions per class index), and may carry a deadline. When
//! the server runs a tenant registry, [`LoadgenConfig::tenant_mix`]
//! splits arrivals across tenant indices the same way, which is how an
//! adversarial run offers one tenant far more than its token bucket
//! admits while a well-behaved tenant stays under its own rate. The
//! [`LoadReport`] accounts every submission — completed, shed,
//! expired, throttled, failed; `lost` (a reply channel dropped with no
//! answer) must be zero, which `rust/tests/server.rs` pins — and reports
//! offered vs achieved throughput, shed rate, deadline-miss rate,
//! tail latencies (queue wait and service time separately) and a
//! per-class breakdown as text or JSON. The RNG is a seeded xorshift
//! so a load test is reproducible.

use std::fmt::Write as _;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use anyhow::{bail, Error, Result};

use super::buffer::JobArena;
use super::metrics::{ClassStats, TenantStats};
use super::request::FftRequest;
use super::server::{ServerResult, TrafficServer};
use super::{ServiceError, Workload};
use crate::fft::field;
use crate::fft::reference;

/// Small deterministic xorshift64* generator — the offline image has no
/// `rand`, and load tests must be reproducible from a seed anyway.
pub struct Rng(u64);

impl Rng {
    /// Seed the generator (0 is remapped — xorshift has no zero state).
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform pick from a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

/// Arrival process shape (both deliver the same mean offered rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Exponentially distributed interarrival gaps at the offered rate.
    Poisson,
    /// Back-to-back groups of `burst_size` requests at the same mean
    /// rate.
    Burst,
}

impl std::fmt::Display for ArrivalPattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArrivalPattern::Poisson => write!(f, "poisson"),
            ArrivalPattern::Burst => write!(f, "burst"),
        }
    }
}

impl std::str::FromStr for ArrivalPattern {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "poisson" => Ok(ArrivalPattern::Poisson),
            "burst" => Ok(ArrivalPattern::Burst),
            other => bail!("unknown arrival pattern `{other}` (poisson|burst)"),
        }
    }
}

/// One load-test run: arrival process, offered rate and duration, the
/// request mix, and the seed that makes the run reproducible.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Arrival process shape.
    pub pattern: ArrivalPattern,
    /// Offered load, requests/s.
    pub rate_hz: f64,
    /// Length of the arrival window.
    pub duration: Duration,
    /// Requests per burst (Burst pattern only).
    pub burst_size: usize,
    /// Transform-size pool, drawn uniformly per request.
    pub sizes: Vec<usize>,
    /// Legacy two-class split: fraction of requests submitted to class
    /// 0 ("high"); the rest go to class 1 ("low"). Ignored when
    /// `class_mix` is non-empty.
    pub high_fraction: f64,
    /// Per-class arrival fractions, by class index (normalized over
    /// their sum). Empty derives the legacy two-class split from
    /// `high_fraction`.
    pub class_mix: Vec<f64>,
    /// Per-tenant arrival fractions, by tenant index (normalized over
    /// their sum, truncated to the server's tenant count). Empty keeps
    /// every request untenanted, bypassing the tenancy layer even when
    /// the server has one configured.
    pub tenant_mix: Vec<f64>,
    /// Per-request deadline (None = whatever the server defaults to).
    pub deadline: Option<Duration>,
    /// Which transform kernel every generated request asks for:
    /// complex-f32 FFT (the default) or the Goldilocks prime-field NTT
    /// (payloads are packed field elements instead of signals).
    pub workload: Workload,
    /// RNG seed: same seed, same arrival offsets and request mix.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            pattern: ArrivalPattern::Poisson,
            rate_hz: 1000.0,
            duration: Duration::from_secs(2),
            burst_size: 32,
            sizes: vec![256, 512, 1024, 2048, 4096],
            high_fraction: 0.5,
            class_mix: Vec::new(),
            tenant_mix: Vec::new(),
            deadline: Some(Duration::from_millis(25)),
            workload: Workload::Fft,
            seed: 42,
        }
    }
}

impl LoadgenConfig {
    /// A size mix that reaches past the 4096-point single-pass ceiling
    /// (8192 and 65536 points alongside ordinary sizes), exercising the
    /// four-step multi-pass path under open-loop load. The offered rate
    /// is far below the default because admission accounts each large
    /// request at its true multi-pass cost — a 65536-point request
    /// weighs 512 single-pass jobs against its class queue — and
    /// deadlines are off so large transforms are not preempted at the
    /// between-pass checkpoint before a run can measure them.
    pub fn large_n() -> Self {
        LoadgenConfig {
            rate_hz: 20.0,
            sizes: vec![1024, 4096, 8192, 65536],
            deadline: None,
            ..Default::default()
        }
    }

    /// The NTT mix: the default size pool and arrival process, but
    /// every request carries a Goldilocks prime-field payload and asks
    /// for the modular kernel — admission, QoS scheduling, sharding and
    /// tenancy treat it exactly like FFT traffic, so the same run
    /// shapes apply to both workloads.
    pub fn ntt() -> Self {
        LoadgenConfig { workload: Workload::Ntt, ..Default::default() }
    }
}

/// One QoS class's slice of a load-test run, pulled from the server's
/// per-class frontend counters after the run.
#[derive(Clone, Debug)]
pub struct ClassLoadRow {
    /// Class name, as configured on the server.
    pub name: String,
    /// The class's fair-share weight.
    pub weight: u32,
    /// Requests the generator submitted to this class.
    pub submitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests rejected at admission (queue full).
    pub shed: u64,
    /// Expired in queue + served late.
    pub deadline_misses: u64,
    /// Served at reduced resolution (any ladder level).
    pub degraded: u64,
    /// This class's share of all completions.
    pub served_fraction: f64,
    /// Per-class queue-wait p99, µs.
    pub queue_p99_us: f64,
}

impl ClassLoadRow {
    fn from_stats(c: &ClassStats, total_completed: u64) -> ClassLoadRow {
        ClassLoadRow {
            name: c.name.clone(),
            weight: c.weight,
            submitted: c.submitted,
            completed: c.completed,
            shed: c.shed,
            deadline_misses: c.expired + c.late,
            degraded: c.degraded(),
            served_fraction: c.served_fraction(total_completed),
            queue_p99_us: c.queue_wait.percentile_us(0.99),
        }
    }
}

/// One tenant's slice of a load-test run, pulled from the server's
/// tenant registry counters after the run. Empty unless both the
/// server and the run were configured with tenants.
#[derive(Clone, Debug)]
pub struct TenantLoadRow {
    /// Tenant name, as configured on the server.
    pub name: String,
    /// Whether the tenant preempts background multi-pass work.
    pub priority: bool,
    /// Requests offered under this tenant's id.
    pub submitted: u64,
    /// Requests past the token bucket and job-unit quota.
    pub admitted: u64,
    /// Requests refused by the bucket or quota before queueing.
    pub throttled: u64,
    /// Requests served to completion (billed).
    pub completed: u64,
    /// Job units billed to the tenant across completions.
    pub job_units: u64,
    /// Completion rate actually achieved, requests/s.
    pub achieved_rps: f64,
    /// Per-tenant queue-wait p99, µs.
    pub queue_p99_us: f64,
}

impl TenantLoadRow {
    fn from_stats(t: &TenantStats, elapsed_s: f64) -> TenantLoadRow {
        TenantLoadRow {
            name: t.name.clone(),
            priority: t.priority,
            submitted: t.submitted,
            admitted: t.admitted,
            throttled: t.throttled,
            completed: t.completed,
            job_units: t.job_units,
            achieved_rps: if elapsed_s > 0.0 { t.completed as f64 / elapsed_s } else { 0.0 },
            queue_p99_us: t.queue_wait.percentile_us(0.99),
        }
    }
}

/// Everything a load-test run observed. Constructed by [`run`];
/// serialized by [`LoadReport::to_json`] / rendered by
/// [`LoadReport::render`].
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Arrival process the run used.
    pub pattern: ArrivalPattern,
    /// Configured offered rate, requests/s.
    pub rate_hz: f64,
    /// Configured arrival-window length, seconds.
    pub duration_s: f64,
    /// Total submissions attempted.
    pub submitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests rejected at admission (queue full).
    pub shed: u64,
    /// Requests that expired in queue past their deadline.
    pub expired: u64,
    /// Requests served after their deadline had passed.
    pub late: u64,
    /// Requests served at reduced resolution (any ladder level).
    pub degraded: u64,
    /// Requests refused at the tenancy layer (token bucket empty or
    /// job-unit quota exhausted) before touching a class queue.
    pub throttled: u64,
    /// Requests that failed with any other typed error.
    pub failed: u64,
    /// Reply channels that closed without any answer — always 0 unless
    /// the frontend dropped a request on the floor.
    pub lost: u64,
    /// Completions dispatched from the high-priority (class 0) queue.
    pub served_high: u64,
    /// Completions dispatched from lower-priority queues.
    pub served_low: u64,
    /// Aged background promotions observed during the run.
    pub aged: u64,
    /// Submission rate actually generated, requests/s.
    pub offered_rps: f64,
    /// Completion rate actually achieved, requests/s.
    pub achieved_rps: f64,
    /// `shed / submitted`.
    pub shed_rate: f64,
    /// `(expired + late) / (completed + expired)`.
    pub deadline_miss_rate: f64,
    /// p50/p90/p99/p999/mean/max, µs.
    pub queue_wait_us: [f64; 6],
    /// p50/p90/p99/p999/mean/max, µs.
    pub service_time_us: [f64; 6],
    /// Wall time from first submission to last reply, seconds.
    pub elapsed_s: f64,
    /// Every submission got a result or a typed error.
    pub accounted: bool,
    /// Per-QoS-class breakdown, in the server's class order.
    pub per_class: Vec<ClassLoadRow>,
    /// Per-tenant breakdown, in the server's tenant order (empty when
    /// the run was untenanted).
    pub per_tenant: Vec<TenantLoadRow>,
}

impl LoadReport {
    /// Serialize the report as a self-contained JSON object (no
    /// dependencies — hand-written RFC 8259 escaping for class names).
    pub fn to_json(&self) -> String {
        let lat = |l: &[f64; 6]| {
            format!(
                "{{\"p50\": {:.1}, \"p90\": {:.1}, \"p99\": {:.1}, \"p999\": {:.1}, \
                 \"mean\": {:.1}, \"max\": {:.1}}}",
                l[0], l[1], l[2], l[3], l[4], l[5]
            )
        };
        let mut s = String::from("{\n");
        let _ = writeln!(s, "  \"pattern\": \"{}\",", self.pattern);
        let _ = writeln!(s, "  \"rate_hz\": {:.1},", self.rate_hz);
        let _ = writeln!(s, "  \"duration_s\": {:.3},", self.duration_s);
        let _ = writeln!(s, "  \"submitted\": {},", self.submitted);
        let _ = writeln!(s, "  \"completed\": {},", self.completed);
        let _ = writeln!(s, "  \"shed\": {},", self.shed);
        let _ = writeln!(s, "  \"expired\": {},", self.expired);
        let _ = writeln!(s, "  \"late\": {},", self.late);
        let _ = writeln!(s, "  \"degraded\": {},", self.degraded);
        let _ = writeln!(s, "  \"throttled\": {},", self.throttled);
        let _ = writeln!(s, "  \"failed\": {},", self.failed);
        let _ = writeln!(s, "  \"lost\": {},", self.lost);
        let _ = writeln!(s, "  \"served_high\": {},", self.served_high);
        let _ = writeln!(s, "  \"served_low\": {},", self.served_low);
        let _ = writeln!(s, "  \"aged\": {},", self.aged);
        let _ = writeln!(s, "  \"offered_rps\": {:.1},", self.offered_rps);
        let _ = writeln!(s, "  \"achieved_rps\": {:.1},", self.achieved_rps);
        let _ = writeln!(s, "  \"shed_rate\": {:.4},", self.shed_rate);
        let _ = writeln!(s, "  \"deadline_miss_rate\": {:.4},", self.deadline_miss_rate);
        let _ = writeln!(s, "  \"queue_wait_us\": {},", lat(&self.queue_wait_us));
        let _ = writeln!(s, "  \"service_time_us\": {},", lat(&self.service_time_us));
        let _ = writeln!(s, "  \"elapsed_s\": {:.3},", self.elapsed_s);
        let _ = writeln!(s, "  \"accounted\": {},", self.accounted);
        // class names are user-supplied (QosClass::new takes any str):
        // escape everything RFC 8259 forbids inside a string literal —
        // backslash, quote, and the U+0000..=U+001F control range
        let esc = |name: &str| -> String {
            let mut out = String::with_capacity(name.len());
            for ch in name.chars() {
                match ch {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out
        };
        s.push_str("  \"classes\": [");
        for (i, c) in self.per_class.iter().enumerate() {
            let _ = write!(
                s,
                "{}\n    {{\"name\": \"{}\", \"weight\": {}, \"submitted\": {}, \
                 \"completed\": {}, \"shed\": {}, \"deadline_misses\": {}, \
                 \"degraded\": {}, \"served_fraction\": {:.4}, \"queue_p99_us\": {:.1}}}",
                if i == 0 { "" } else { "," },
                esc(&c.name),
                c.weight,
                c.submitted,
                c.completed,
                c.shed,
                c.deadline_misses,
                c.degraded,
                c.served_fraction,
                c.queue_p99_us
            );
        }
        if !self.per_class.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("],\n");
        s.push_str("  \"tenants\": [");
        for (i, t) in self.per_tenant.iter().enumerate() {
            let _ = write!(
                s,
                "{}\n    {{\"name\": \"{}\", \"priority\": {}, \"submitted\": {}, \
                 \"admitted\": {}, \"throttled\": {}, \"completed\": {}, \
                 \"job_units\": {}, \"achieved_rps\": {:.1}, \"queue_p99_us\": {:.1}}}",
                if i == 0 { "" } else { "," },
                esc(&t.name),
                t.priority,
                t.submitted,
                t.admitted,
                t.throttled,
                t.completed,
                t.job_units,
                t.achieved_rps,
                t.queue_p99_us
            );
        }
        if !self.per_tenant.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}");
        s
    }

    /// Human-readable multi-line summary of the run.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "loadtest: {} arrivals at {:.0} req/s offered for {:.1}s",
            self.pattern, self.rate_hz, self.duration_s
        );
        let _ = writeln!(
            s,
            "  offered {:.0} rps -> achieved {:.0} rps ({} submitted, {} completed)",
            self.offered_rps, self.achieved_rps, self.submitted, self.completed
        );
        let _ = writeln!(
            s,
            "  shed {} ({:.1}%), degraded {}, expired {} + late {} \
             (deadline miss rate {:.1}%), throttled {}, failed {}, lost {}",
            self.shed,
            100.0 * self.shed_rate,
            self.degraded,
            self.expired,
            self.late,
            100.0 * self.deadline_miss_rate,
            self.throttled,
            self.failed,
            self.lost
        );
        let _ = writeln!(
            s,
            "  priorities: {} high / {} low served, {} aged promotions",
            self.served_high, self.served_low, self.aged
        );
        let _ = writeln!(
            s,
            "  queue wait   p50 {:>7.0}us  p90 {:>7.0}us  p99 {:>7.0}us  p999 {:>7.0}us",
            self.queue_wait_us[0], self.queue_wait_us[1], self.queue_wait_us[2],
            self.queue_wait_us[3]
        );
        let _ = writeln!(
            s,
            "  service time p50 {:>7.0}us  p90 {:>7.0}us  p99 {:>7.0}us  p999 {:>7.0}us",
            self.service_time_us[0], self.service_time_us[1], self.service_time_us[2],
            self.service_time_us[3]
        );
        for c in &self.per_class {
            let _ = writeln!(
                s,
                "  class {:<10} (w{}): {:>6} submitted, {:>6} served ({:.3} share), \
                 {} shed, {} miss, {} degraded, queue p99 {:>7.0}us",
                c.name,
                c.weight,
                c.submitted,
                c.completed,
                c.served_fraction,
                c.shed,
                c.deadline_misses,
                c.degraded,
                c.queue_p99_us
            );
        }
        for t in &self.per_tenant {
            let _ = writeln!(
                s,
                "  tenant {:<10}{}: {:>6} submitted, {:>6} admitted, {:>6} throttled, \
                 {:>6} completed ({:.0} rps), {} job-units, queue p99 {:>7.0}us",
                t.name,
                if t.priority { " [priority]" } else { "" },
                t.submitted,
                t.admitted,
                t.throttled,
                t.completed,
                t.achieved_rps,
                t.job_units,
                t.queue_p99_us
            );
        }
        let _ = writeln!(
            s,
            "  accounting: every request answered = {}",
            if self.accounted { "yes" } else { "NO — BUG" }
        );
        s
    }
}

/// Arrival offsets (seconds from start) for one run of `cfg`.
fn arrivals(cfg: &LoadgenConfig, rng: &mut Rng) -> Vec<f64> {
    let dur = cfg.duration.as_secs_f64();
    let mut out = Vec::new();
    match cfg.pattern {
        ArrivalPattern::Poisson => {
            let mut t = 0.0;
            loop {
                t += -(1.0 - rng.next_f64()).ln() / cfg.rate_hz;
                if t >= dur {
                    break;
                }
                out.push(t);
            }
        }
        ArrivalPattern::Burst => {
            let period = cfg.burst_size as f64 / cfg.rate_hz;
            let mut t = 0.0;
            while t < dur {
                for _ in 0..cfg.burst_size {
                    out.push(t);
                }
                t += period;
            }
        }
    }
    out
}

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

/// The effective class-arrival distribution: an explicit per-class
/// mix, truncated to the server's class count so a long mix never
/// submits to an unknown class, with negative fractions clamped to
/// zero. An empty mix derives a default that covers *every* class: the
/// legacy `high_fraction` split when the server has exactly the
/// two-class legacy configuration, a uniform split otherwise (so an
/// N-class server without an explicit `--class-mix` still receives
/// traffic on all N classes instead of silently starving classes 2+).
fn resolve_class_mix(cfg: &LoadgenConfig, n_classes: usize) -> Vec<f64> {
    let mix = if !cfg.class_mix.is_empty() {
        cfg.class_mix.clone()
    } else if n_classes == 2 {
        vec![cfg.high_fraction, 1.0 - cfg.high_fraction]
    } else {
        vec![1.0; n_classes.max(1)]
    };
    mix.into_iter().take(n_classes.max(1)).map(|f| f.max(0.0)).collect()
}

/// Map `r` in `[0, 1)` onto a class index by the cumulative mix (a mix
/// summing to zero lands everything on the last class).
fn pick_from_mix(mix: &[f64], r: f64) -> usize {
    let total: f64 = mix.iter().sum();
    let mut acc = 0.0;
    for (c, &f) in mix.iter().enumerate() {
        acc += f;
        if r * total < acc {
            return c;
        }
    }
    mix.len().saturating_sub(1)
}

/// Run one open-loop load test against `server` and account for every
/// submission. The server should be freshly started: tail latencies are
/// read from its cumulative frontend histograms.
pub fn run(server: &TrafficServer, cfg: &LoadgenConfig) -> LoadReport {
    let mut rng = Rng::new(cfg.seed);
    let offsets = arrivals(cfg, &mut rng);
    let mix = resolve_class_mix(cfg, server.config().classes.len());
    let pick_class = |r: f64| pick_from_mix(&mix, r);
    // Tenant fractions are truncated to the registry size so a long
    // mix never submits an unknown tenant index; without a registry the
    // mix is ignored and every request stays untenanted.
    let t_mix: Vec<f64> = match server.tenant_registry() {
        Some(reg) => {
            cfg.tenant_mix.iter().take(reg.len()).map(|f| f.max(0.0)).collect()
        }
        None => Vec::new(),
    };
    // One prototype signal per distinct size, generated *before* the
    // clock starts: generating a fresh 4096-point test signal per
    // request would eat a large slice of a 50µs interarrival gap and
    // silently erode the offered rate. Submission copies a prototype
    // into a leased arena slot (one memcpy, no allocation while the
    // arena has free slots) — the cheapest input the API allows.
    let prototypes: Vec<Vec<(f32, f32)>> = cfg
        .sizes
        .iter()
        .enumerate()
        .map(|(k, &points)| {
            let seed = cfg.seed.wrapping_add(k as u64);
            match cfg.workload {
                Workload::Fft => signal(points, seed),
                Workload::Ntt => {
                    field::test_elements(points, seed).into_iter().map(field::pack).collect()
                }
            }
        })
        .collect();
    let start = Instant::now();
    let mut pending: Vec<Receiver<ServerResult>> = Vec::with_capacity(offsets.len());
    let mut submitted = 0u64;
    let mut shed = 0u64;
    let mut throttled = 0u64;
    let mut rejected = 0u64;
    for &offset in &offsets {
        let target = start + Duration::from_secs_f64(offset);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let idx = (rng.next_u64() % prototypes.len() as u64) as usize;
        let class = pick_class(rng.next_f64());
        submitted += 1;
        let slot = JobArena::global().lease_copy(&prototypes[idx]);
        let mut req = FftRequest::with_input_slot(slot)
            .with_workload(cfg.workload)
            .with_class(class);
        if !t_mix.is_empty() {
            req = req.with_tenant(pick_from_mix(&t_mix, rng.next_f64()));
        }
        if let Some(d) = cfg.deadline {
            req = req.with_deadline(d);
        }
        match server.request(req) {
            Ok(rx) => pending.push(rx),
            Err(ServiceError::QueueFull { .. }) => shed += 1,
            Err(ServiceError::TenantThrottled { .. }) => throttled += 1,
            Err(_) => rejected += 1,
        }
    }
    let gen_elapsed = start.elapsed().as_secs_f64();

    let (mut completed, mut expired, mut late, mut degraded) = (0u64, 0u64, 0u64, 0u64);
    let (mut failed, mut lost) = (0u64, 0u64);
    for rx in pending {
        match rx.recv() {
            Ok(Ok(s)) => {
                completed += 1;
                if s.degraded {
                    degraded += 1;
                }
                if s.deadline_missed {
                    late += 1;
                }
            }
            Ok(Err(ServiceError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => lost += 1,
        }
    }
    let elapsed = start.elapsed().as_secs_f64();

    let snap = server.metrics();
    let sv = &snap.server;
    let lat = |l: &super::metrics::LatencyStats| {
        [
            l.percentile_us(0.50),
            l.percentile_us(0.90),
            l.percentile_us(0.99),
            l.percentile_us(0.999),
            l.mean_us(),
            l.max_us,
        ]
    };
    LoadReport {
        pattern: cfg.pattern,
        rate_hz: cfg.rate_hz,
        duration_s: cfg.duration.as_secs_f64(),
        submitted,
        completed,
        shed,
        expired,
        late,
        degraded,
        throttled,
        failed: failed + rejected,
        lost,
        served_high: sv.served_high,
        served_low: sv.served_low,
        aged: sv.aged,
        offered_rps: if gen_elapsed > 0.0 { submitted as f64 / gen_elapsed } else { 0.0 },
        achieved_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
        shed_rate: if submitted == 0 { 0.0 } else { shed as f64 / submitted as f64 },
        deadline_miss_rate: sv.deadline_miss_rate(),
        queue_wait_us: lat(&sv.queue_wait),
        service_time_us: lat(&sv.service_time),
        elapsed_s: elapsed,
        accounted: lost == 0
            && completed + expired + shed + throttled + failed + rejected == submitted,
        per_class: sv.per_class.iter().map(|c| ClassLoadRow::from_stats(c, sv.completed)).collect(),
        per_tenant: snap.tenants.iter().map(|t| TenantLoadRow::from_stats(t, elapsed)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_and_uniformish() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut r = Rng::new(9);
        let mean: f64 = (0..10_000).map(|_| r.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "xorshift mean {mean}");
    }

    #[test]
    fn poisson_arrivals_hit_the_offered_rate() {
        let cfg = LoadgenConfig {
            rate_hz: 5000.0,
            duration: Duration::from_secs(2),
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let a = arrivals(&cfg, &mut rng);
        let expect = 10_000.0;
        assert!(
            (a.len() as f64 - expect).abs() < expect * 0.1,
            "poisson arrival count {} vs expected {expect}",
            a.len()
        );
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
        assert!(a.last().copied().unwrap_or(0.0) < 2.0);
    }

    #[test]
    fn burst_arrivals_come_in_groups_at_the_same_mean_rate() {
        let cfg = LoadgenConfig {
            pattern: ArrivalPattern::Burst,
            rate_hz: 1000.0,
            burst_size: 50,
            duration: Duration::from_secs(1),
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let a = arrivals(&cfg, &mut rng);
        assert_eq!(a.len() % 50, 0, "whole bursts only");
        assert!((a.len() as f64 - 1000.0).abs() <= 50.0, "mean rate held: {}", a.len());
        assert_eq!(a[0], a[49], "a burst arrives back-to-back");
        assert!(a[50] > a[49], "bursts are separated by the period");
    }

    #[test]
    fn large_n_mix_reaches_past_the_single_pass_ceiling() {
        let cfg = LoadgenConfig::large_n();
        assert!(cfg.sizes.iter().any(|&s| s > crate::fft::MAX_SINGLE_PASS_POINTS));
        assert!(cfg.sizes.iter().all(|&s| s.is_power_of_two()));
        assert!(cfg.rate_hz < LoadgenConfig::default().rate_hz);
        assert!(cfg.deadline.is_none());
    }

    #[test]
    fn ntt_mix_carries_field_payloads_on_the_default_shape() {
        let cfg = LoadgenConfig::ntt();
        assert_eq!(cfg.workload, Workload::Ntt);
        assert_eq!(cfg.sizes, LoadgenConfig::default().sizes, "same size pool as FFT runs");
        // A prototype payload built the way run() builds it must decode
        // back to canonical field elements.
        let packed: Vec<(f32, f32)> =
            field::test_elements(256, 7).into_iter().map(field::pack).collect();
        assert!(packed.iter().all(|&w| field::unpack(w) < field::P));
    }

    #[test]
    fn pattern_parsing_round_trips() {
        assert_eq!("poisson".parse::<ArrivalPattern>().unwrap(), ArrivalPattern::Poisson);
        assert_eq!("BURST".parse::<ArrivalPattern>().unwrap(), ArrivalPattern::Burst);
        assert!("uniform".parse::<ArrivalPattern>().is_err());
        assert_eq!(ArrivalPattern::Poisson.to_string(), "poisson");
    }

    #[test]
    fn report_json_has_the_gated_fields() {
        let r = LoadReport {
            pattern: ArrivalPattern::Poisson,
            rate_hz: 5000.0,
            duration_s: 5.0,
            submitted: 10,
            completed: 8,
            shed: 1,
            expired: 1,
            late: 0,
            degraded: 0,
            throttled: 2,
            failed: 0,
            lost: 0,
            served_high: 5,
            served_low: 3,
            aged: 1,
            offered_rps: 5000.0,
            achieved_rps: 4000.0,
            shed_rate: 0.1,
            deadline_miss_rate: 0.111,
            queue_wait_us: [10.0, 20.0, 40.0, 80.0, 15.0, 100.0],
            service_time_us: [5.0, 10.0, 20.0, 40.0, 8.0, 50.0],
            elapsed_s: 5.2,
            accounted: true,
            per_class: vec![
                ClassLoadRow {
                    name: "gold".into(),
                    weight: 5,
                    submitted: 6,
                    completed: 5,
                    shed: 1,
                    deadline_misses: 1,
                    degraded: 2,
                    served_fraction: 0.625,
                    queue_p99_us: 40.0,
                },
                ClassLoadRow {
                    name: "we\"ird\\\nx".into(),
                    weight: 1,
                    submitted: 1,
                    completed: 1,
                    shed: 0,
                    deadline_misses: 0,
                    degraded: 0,
                    served_fraction: 0.125,
                    queue_p99_us: 10.0,
                },
            ],
            per_tenant: vec![TenantLoadRow {
                name: "victim".into(),
                priority: true,
                submitted: 4,
                admitted: 4,
                throttled: 0,
                completed: 4,
                job_units: 4,
                achieved_rps: 0.8,
                queue_p99_us: 40.0,
            }],
        };
        let j = r.to_json();
        for key in [
            "\"achieved_rps\"",
            "\"shed_rate\"",
            "\"deadline_miss_rate\"",
            "\"queue_wait_us\"",
            "\"service_time_us\"",
            "\"p50\"",
            "\"p99\"",
            "\"accounted\": true",
            "\"classes\": [",
            "\"name\": \"gold\"",
            "\"served_fraction\": 0.6250",
            "\"name\": \"we\\\"ird\\\\\\u000ax\"",
            "\"throttled\": 2",
            "\"tenants\": [",
            "\"name\": \"victim\"",
            "\"priority\": true",
            "\"job_units\": 4",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        let text = r.render();
        assert!(text.contains("every request answered = yes"));
        assert!(text.contains("class gold"), "{text}");
        assert!(text.contains("tenant victim"), "{text}");
        assert!(text.contains("[priority]"), "{text}");
        assert!(text.contains("throttled 2"), "{text}");
    }

    #[test]
    fn class_mix_resolution_and_cumulative_pick() {
        // empty mix + two classes: the legacy high/low split
        let legacy = LoadgenConfig { high_fraction: 0.8, ..Default::default() };
        assert_eq!(resolve_class_mix(&legacy, 2), vec![0.8, 0.19999999999999996]);
        // empty mix + N != 2 classes: uniform, so every class gets
        // traffic (a 2-entry legacy split would starve classes 2+)
        assert_eq!(resolve_class_mix(&legacy, 3), vec![1.0, 1.0, 1.0]);
        assert_eq!(resolve_class_mix(&legacy, 1), vec![1.0]);
        // explicit mixes pass through (clamped at zero, truncated)
        let cfg = LoadgenConfig {
            class_mix: vec![0.5, 0.3, 0.2, -1.0],
            ..Default::default()
        };
        assert_eq!(resolve_class_mix(&cfg, 3), vec![0.5, 0.3, 0.2]);

        let mix = [0.5, 0.3, 0.2];
        assert_eq!(pick_from_mix(&mix, 0.0), 0);
        assert_eq!(pick_from_mix(&mix, 0.49), 0);
        assert_eq!(pick_from_mix(&mix, 0.51), 1);
        assert_eq!(pick_from_mix(&mix, 0.79), 1);
        assert_eq!(pick_from_mix(&mix, 0.81), 2);
        assert_eq!(pick_from_mix(&mix, 0.999), 2);
        // unnormalized mixes work by ratio; an all-zero mix degenerates
        assert_eq!(pick_from_mix(&[5.0, 3.0], 0.7), 1);
        assert_eq!(pick_from_mix(&[0.0, 0.0], 0.3), 1);
    }
}
