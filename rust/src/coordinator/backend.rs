//! Multi-backend routing: the controller's third actuator.
//!
//! The companion paper "A Statically and Dynamically Scalable Soft
//! GPGPU" (arXiv:2401.04261) scales one engine across backends of
//! different capability; this module is that split on the request path.
//! A [`BackendSet`] holds the simulator execution service (the pool or
//! the sharded scheduler, wrapped in a [`ServiceHandle`]) plus any
//! number of registered *alternate* lanes implementing [`FftBackend`]
//! (the PJRT fast path, when the `pjrt` feature and artifacts exist),
//! and routes each request to the lane the **measured** cost model says
//! is cheapest right now.
//!
//! Invariants:
//!
//! * **The cost model is measured, never assumed.** Per-lane,
//!   per-size service time is an EWMA seeded by a calibration pass
//!   ([`BackendSet::calibrate`]) and updated from every served request
//!   — there is no hardcoded speedup constant anywhere. An alternate
//!   lane is only routable for sizes it proved it can serve during
//!   calibration; every other size goes to the simulator.
//! * **Routing never changes numerics.** A set with no (or only
//!   quarantined) alternates sends every request down the simulator
//!   path unchanged, bitwise identical to the unrouted handle. The QoS
//!   degrade level truncates the input to `len >> level.shift()`
//!   *before* an alternate serves it — the same truncation the
//!   simulator worker applies — so a degraded request is served on the
//!   same samples whichever lane takes it.
//! * **Fast-path results are spot-checked.** A configurable sampled
//!   fraction of alternate-served requests
//!   ([`BackendSetConfig::validate_fraction`], deterministic
//!   fixed-point sampling — exact for 1%/10%/100%) is re-served by the
//!   simulator and compared with [`super::cross_error`] against
//!   [`crate::fft::F32_TOL`]. A mismatch increments the lane's counter,
//!   **quarantines** the lane (the router stops sending it traffic),
//!   and the caller receives the *simulator's* result — a corrupted
//!   fast path can never leak a wrong answer that a scheduled check
//!   caught.
//! * **The router is the swap actuator.** [`RouteMode::Balance`] (the
//!   default) scores a lane as `ewma_us * (1 + inflight/parallelism)`,
//!   spreading load in proportion to measured capacity;
//!   [`RouteMode::Fastest`] scores by raw EWMA, pinning all traffic to
//!   the measured-fastest lane. The autoscale controller flips the mode
//!   under service-time pressure
//!   ([`super::AutoscalePolicy::swap_service_p99_ms`]) — the
//!   swap-before-scale step — and releases it when the SLO is healthy.
//!
//! An alternate lane that *errors* is not trusted again blindly: the
//! failure is counted, its cost entry for that size is penalized so the
//! router backs off, and the request falls back to the simulator —
//! every submitted request is still answered.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::buffer::JobSlot;
use super::metrics::BackendStat;
use super::qos::DegradeLevel;
use super::request::{FftCompute, FftRequest};
use super::server::ServiceHandle;
use super::{cross_error, FftResult, ServiceError, Workload};
use crate::fft::{self, reference};
use crate::runtime::PjrtHandle;

/// An alternate FFT execution lane the router can send requests to.
///
/// Implementations must be thread-safe: the router calls [`FftBackend::fft`]
/// concurrently from every dispatcher thread.
pub trait FftBackend: Send + Sync {
    /// Stable lane name, for metrics and rendering.
    fn name(&self) -> &str;

    /// Serve one transform on an interleaved `(re, im)` signal. The
    /// input is already truncated to its served (post-degrade) size.
    fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>>;
}

impl FftBackend for PjrtHandle {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
        PjrtHandle::fft(self, input)
    }
}

/// How the router weighs the measured cost model — the state the
/// controller's swap actuator flips.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteMode {
    /// Score each lane as `ewma_us * (1 + inflight / parallelism)`:
    /// requests spread across lanes in proportion to measured capacity
    /// and back off a lane as its in-flight load builds.
    Balance,
    /// Score each lane by raw EWMA: every request goes to the
    /// measured-fastest lane regardless of load — the controller's
    /// swap-before-scale pin under service-time pressure.
    Fastest,
}

impl RouteMode {
    fn as_u8(self) -> u8 {
        match self {
            RouteMode::Balance => 0,
            RouteMode::Fastest => 1,
        }
    }

    fn from_u8(v: u8) -> RouteMode {
        if v == 1 {
            RouteMode::Fastest
        } else {
            RouteMode::Balance
        }
    }
}

/// Configuration for a [`BackendSet`].
#[derive(Clone, Debug)]
pub struct BackendSetConfig {
    /// Fraction of alternate-served requests to cross-check against the
    /// simulator, in `[0, 1]`. Sampling is deterministic (fixed-point
    /// accumulator in 1/1000 steps), so 0.01 validates exactly every
    /// 100th alternate-served request. `0.0` disables validation.
    pub validate_fraction: f64,
    /// Transform sizes the calibration pass seeds the cost model with.
    /// Every size must be servable by the simulator; an alternate that
    /// fails a size during calibration is simply not routable for it.
    pub calibrate_sizes: Vec<usize>,
    /// Timed samples per `(lane, size)` during calibration (after one
    /// untimed warm-up serve).
    pub calibrate_samples: usize,
    /// EWMA smoothing factor in `(0, 1]` — the weight of the newest
    /// measured service time.
    pub ewma_alpha: f64,
}

impl Default for BackendSetConfig {
    fn default() -> Self {
        BackendSetConfig {
            validate_fraction: 0.0,
            calibrate_sizes: vec![256, 1024, 4096],
            calibrate_samples: 2,
            ewma_alpha: 0.25,
        }
    }
}

/// One lane's live counters and its slice of the cost model.
#[derive(Default)]
struct LaneStats {
    inflight: AtomicU64,
    served: AtomicU64,
    failed: AtomicU64,
    validate_checks: AtomicU64,
    validate_mismatches: AtomicU64,
    /// Accumulated measured service time over served requests, µs.
    sum_us: AtomicU64,
    quarantined: AtomicBool,
    /// EWMA of measured service time by served size, µs.
    cost: Mutex<HashMap<usize, f64>>,
}

impl LaneStats {
    fn stat(&self, name: &str) -> BackendStat {
        let served = self.served.load(Ordering::Relaxed);
        BackendStat {
            name: name.to_string(),
            served,
            failed: self.failed.load(Ordering::Relaxed),
            validate_checks: self.validate_checks.load(Ordering::Relaxed),
            validate_mismatches: self.validate_mismatches.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            mean_service_us: if served == 0 {
                0.0
            } else {
                self.sum_us.load(Ordering::Relaxed) as f64 / served as f64
            },
        }
    }
}

/// A registered alternate lane.
struct Alternate {
    name: String,
    backend: Box<dyn FftBackend>,
    /// Concurrent requests the lane serves without queueing (1 for the
    /// single-threaded PJRT server).
    parallelism: usize,
    stats: LaneStats,
}

/// The simulator service plus alternate lanes, a measured per-backend
/// cost model, and the router that picks a lane per request.
///
/// Wrapped in [`ServiceHandle::Routed`], the whole serving stack —
/// `TrafficServer`, metrics, the autoscale controller — sees it as just
/// another execution service; [`ServiceHandle::as_sharded`] delegates
/// to the inner simulator handle, so shard autoscaling composes with
/// routing.
pub struct BackendSet {
    cfg: BackendSetConfig,
    /// The simulator execution service (never `Routed` — rejected at
    /// construction, so routing never nests).
    sim: Box<ServiceHandle>,
    sim_stats: LaneStats,
    alternates: Vec<Alternate>,
    mode: AtomicU8,
    /// Fixed-point (1/1000) validation-sampling accumulator.
    validate_acc: AtomicU64,
    next_id: AtomicU64,
}

impl BackendSet {
    /// Build a set over the simulator service. Fails when `sim` is
    /// itself routed (routing does not nest), or the configuration is
    /// out of range.
    pub fn new(sim: ServiceHandle, cfg: BackendSetConfig) -> Result<BackendSet> {
        if matches!(sim, ServiceHandle::Routed(_)) {
            return Err(anyhow!("BackendSet cannot wrap an already-routed ServiceHandle"));
        }
        if !(0.0..=1.0).contains(&cfg.validate_fraction) {
            return Err(anyhow!(
                "validate_fraction ({}) must be in [0, 1]",
                cfg.validate_fraction
            ));
        }
        if !(cfg.ewma_alpha > 0.0 && cfg.ewma_alpha <= 1.0) {
            return Err(anyhow!("ewma_alpha ({}) must be in (0, 1]", cfg.ewma_alpha));
        }
        if cfg.calibrate_samples == 0 {
            return Err(anyhow!("calibrate_samples must be at least 1"));
        }
        if cfg.calibrate_sizes.is_empty() {
            return Err(anyhow!("calibrate_sizes must name at least one transform size"));
        }
        Ok(BackendSet {
            cfg,
            sim: Box::new(sim),
            sim_stats: LaneStats::default(),
            alternates: Vec::new(),
            mode: AtomicU8::new(RouteMode::Balance.as_u8()),
            validate_acc: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
        })
    }

    /// Register an alternate lane. `parallelism` is the number of
    /// concurrent requests the lane serves without queueing (1 for the
    /// single-threaded PJRT server). Names must be unique and not
    /// `sim`.
    pub fn register(
        &mut self,
        name: &str,
        backend: Box<dyn FftBackend>,
        parallelism: usize,
    ) -> Result<()> {
        if name == "sim" || self.alternates.iter().any(|a| a.name == name) {
            return Err(anyhow!("backend lane name `{name}` already taken"));
        }
        if parallelism == 0 {
            return Err(anyhow!("lane `{name}` needs parallelism of at least 1"));
        }
        self.alternates.push(Alternate {
            name: name.to_string(),
            backend,
            parallelism,
            stats: LaneStats::default(),
        });
        Ok(())
    }

    /// Seed the cost model: for each configured size, serve one warm-up
    /// plus [`BackendSetConfig::calibrate_samples`] timed transforms on
    /// the simulator and on every alternate, recording the mean as the
    /// initial EWMA. An alternate that fails a size is left without a
    /// cost entry for it — the router will never send it that size.
    /// Calibration traffic does not count toward lane serve counters.
    pub fn calibrate(&self) -> Result<()> {
        for &points in &self.cfg.calibrate_sizes {
            let input: Vec<(f32, f32)> =
                reference::test_signal(points, 7).iter().map(|c| c.to_f32_pair()).collect();
            self.sim_recv(input.clone())?; // warm: plan cache + resident SM
            let mut total = 0.0;
            for _ in 0..self.cfg.calibrate_samples {
                let t0 = Instant::now();
                self.sim_recv(input.clone())?;
                total += t0.elapsed().as_secs_f64() * 1e6;
            }
            self.sim_stats
                .cost
                .lock()
                .unwrap()
                .insert(points, total / self.cfg.calibrate_samples as f64);
            for alt in &self.alternates {
                if alt.backend.fft(&input).is_err() {
                    continue; // size unsupported by this lane
                }
                let mut total = 0.0;
                let mut ok = true;
                for _ in 0..self.cfg.calibrate_samples {
                    let t0 = Instant::now();
                    if alt.backend.fft(&input).is_err() {
                        ok = false;
                        break;
                    }
                    total += t0.elapsed().as_secs_f64() * 1e6;
                }
                if ok {
                    alt.stats
                        .cost
                        .lock()
                        .unwrap()
                        .insert(points, total / self.cfg.calibrate_samples as f64);
                }
            }
        }
        Ok(())
    }

    /// The current routing mode (the swap actuator's state).
    pub fn mode(&self) -> RouteMode {
        RouteMode::from_u8(self.mode.load(Ordering::Relaxed))
    }

    /// Set the routing mode — the autoscale controller's swap actuator.
    pub fn set_mode(&self, mode: RouteMode) {
        self.mode.store(mode.as_u8(), Ordering::Relaxed);
    }

    /// The wrapped simulator execution service.
    pub fn sim(&self) -> &ServiceHandle {
        &self.sim
    }

    /// The configured validation sampling fraction.
    pub fn validate_fraction(&self) -> f64 {
        self.cfg.validate_fraction
    }

    /// Per-lane counters; the first entry is always the simulator lane.
    pub fn stats(&self) -> Vec<BackendStat> {
        let mut out = vec![self.sim_stats.stat("sim")];
        out.extend(self.alternates.iter().map(|a| a.stats.stat(&a.name)));
        out
    }

    /// Route one [`FftRequest`] and serve it. The returned channel is
    /// already resolved or resolves when the simulator finishes —
    /// semantically identical to the other [`ServiceHandle`] variants,
    /// whose dispatcher blocks on the reply immediately after
    /// submitting.
    ///
    /// A request whose effective size exceeds its pass ceiling bypasses
    /// the lane router entirely and is delegated whole to the simulator
    /// service, which serves it by four-step decomposition (see
    /// [`FftCompute::request`]); alternate lanes only ever see
    /// single-pass sizes, which is also all the calibration pass ever
    /// seeds cost entries for. An NTT request takes the same bypass:
    /// alternate lanes speak f32 complex arithmetic only, so the
    /// modular kernel is always served by the simulator service (which
    /// runs it in exact u64 arithmetic on the host) — routing can never
    /// hand a prime-field transform to a float lane.
    pub fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        if req.needs_decomposition() || req.workload == Workload::Ntt {
            return self.sim.request(req);
        }
        let FftRequest { input, level, .. } = req;
        let points = input.len() >> level.shift();
        let result = match self.route(points) {
            None => self.serve_sim(input, level),
            Some(idx) => self.serve_alternate(idx, input, level),
        };
        let (tx, rx) = channel();
        let _ = tx.send(result);
        rx
    }

    /// Submit a set of requests and wait for every result, in
    /// submission order. Requests are routed individually (lane choice
    /// is per-request by measured cost, so there is no cross-request
    /// coalescing here); the first failure, if any, is returned.
    pub fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        let handles: Vec<_> = reqs.into_iter().map(|r| self.request(r)).collect();
        handles
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))?)
            .collect()
    }

    /// Drive every input through the router with `workers` concurrent
    /// submitters; results come back in submission order and the first
    /// failure, if any, is returned (mirroring
    /// [`super::FftService::run_batch`]).
    pub fn run_batch(
        &self,
        inputs: Vec<Vec<(f32, f32)>>,
        workers: usize,
    ) -> Result<Vec<FftResult>> {
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let jobs: Vec<Mutex<Option<Vec<(f32, f32)>>>> =
            inputs.into_iter().map(|i| Mutex::new(Some(i))).collect();
        let slots: Vec<Mutex<Option<Result<FftResult>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers.clamp(1, n) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let input = jobs[i].lock().unwrap().take().expect("each job taken once");
                    let r = self
                        .request(FftRequest::new(input))
                        .recv()
                        .map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))
                        .and_then(|r| r);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every slot filled"))
            .collect()
    }

    /// Shut the simulator service down; alternate lanes are dropped
    /// (the PJRT server thread exits when its last handle drops).
    pub fn shutdown(self) {
        (*self.sim).shutdown();
    }

    /// Pick a lane for a request of `points` served samples: `None` is
    /// the simulator, `Some(i)` an alternate. Quarantined lanes and
    /// lanes with no cost entry for the size are never chosen.
    fn route(&self, points: usize) -> Option<usize> {
        let mode = self.mode();
        let mut best = None;
        let mut best_score = self
            .lane_score(&self.sim_stats, points, self.sim_parallelism(), mode)
            .unwrap_or(f64::INFINITY);
        for (i, alt) in self.alternates.iter().enumerate() {
            if alt.stats.quarantined.load(Ordering::Relaxed) {
                continue;
            }
            let Some(score) = self.lane_score(&alt.stats, points, alt.parallelism, mode) else {
                continue;
            };
            if score < best_score {
                best_score = score;
                best = Some(i);
            }
        }
        best
    }

    fn lane_score(
        &self,
        stats: &LaneStats,
        points: usize,
        parallelism: usize,
        mode: RouteMode,
    ) -> Option<f64> {
        let ewma = stats.cost.lock().unwrap().get(&points).copied()?;
        Some(match mode {
            RouteMode::Fastest => ewma,
            RouteMode::Balance => {
                let load = stats.inflight.load(Ordering::Relaxed) as f64;
                ewma * (1.0 + load / parallelism.max(1) as f64)
            }
        })
    }

    /// The simulator lane's parallelism, live — it tracks shard
    /// autoscaling.
    fn sim_parallelism(&self) -> usize {
        match &*self.sim {
            ServiceHandle::Pool(s) => s.config().cores,
            ServiceHandle::Sharded(s) => s.shards().max(1),
            ServiceHandle::Routed(_) => unreachable!("rejected in BackendSet::new"),
        }
    }

    /// Deterministic sampling: accumulate `fraction` in 1/1000 steps
    /// and validate each time the accumulator crosses a whole unit —
    /// exact for 1%/10%/100%, and independent of timing.
    fn should_validate(&self) -> bool {
        if self.cfg.validate_fraction <= 0.0 {
            return false;
        }
        let inc = (self.cfg.validate_fraction * 1000.0).round() as u64;
        let prev = self.validate_acc.fetch_add(inc, Ordering::Relaxed);
        (prev + inc) / 1000 > prev / 1000
    }

    fn update_cost(&self, stats: &LaneStats, points: usize, us: f64) {
        let mut cost = stats.cost.lock().unwrap();
        let entry = cost.entry(points).or_insert(us);
        *entry = self.cfg.ewma_alpha * us + (1.0 - self.cfg.ewma_alpha) * *entry;
    }

    /// Serve through the simulator, metering the lane. The slot travels
    /// to the worker and back unchanged — no payload copy on this path.
    fn serve_sim(&self, input: JobSlot, level: DegradeLevel) -> Result<FftResult> {
        let points = input.len() >> level.shift();
        self.sim_stats.inflight.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let result = self.sim.request(FftRequest::with_input_slot(input).with_level(level)).recv();
        let us = t0.elapsed().as_secs_f64() * 1e6;
        self.sim_stats.inflight.fetch_sub(1, Ordering::Relaxed);
        let result = result
            .map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))
            .and_then(|r| r);
        match &result {
            Ok(_) => {
                self.sim_stats.served.fetch_add(1, Ordering::Relaxed);
                self.sim_stats.sum_us.fetch_add(us as u64, Ordering::Relaxed);
                self.update_cost(&self.sim_stats, points, us);
            }
            Err(_) => {
                self.sim_stats.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        result
    }

    /// One un-metered simulator round-trip (calibration and validation
    /// re-serves — traffic that must not skew the lane counters the
    /// router tests and benches assert on).
    fn sim_recv(&self, input: Vec<(f32, f32)>) -> Result<FftResult> {
        self.sim
            .request(FftRequest::new(input))
            .recv()
            .map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))
            .and_then(|r| r)
    }

    /// Serve on alternate `idx`, spot-checking a sampled fraction
    /// against the simulator and falling back to it on lane failure.
    fn serve_alternate(
        &self,
        idx: usize,
        mut input: JobSlot,
        level: DegradeLevel,
    ) -> Result<FftResult> {
        let alt = &self.alternates[idx];
        if level != DegradeLevel::Full {
            // Same truncation the simulator worker applies: both lanes
            // serve the identical degraded signal.
            let keep = input.len() >> level.shift();
            input.truncate(keep);
        }
        let points = input.len();
        alt.stats.inflight.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let fast = alt.backend.fft(&input);
        let us = t0.elapsed().as_secs_f64() * 1e6;
        alt.stats.inflight.fetch_sub(1, Ordering::Relaxed);
        match fast {
            Ok(output) => {
                if self.should_validate() {
                    alt.stats.validate_checks.fetch_add(1, Ordering::Relaxed);
                    let reference = self.sim_recv(input.to_vec())?;
                    if cross_error(&reference.output, &output) > fft::F32_TOL {
                        alt.stats.validate_mismatches.fetch_add(1, Ordering::Relaxed);
                        alt.stats.quarantined.store(true, Ordering::Relaxed);
                        // The simulator is the trusted oracle: its
                        // result is what the caller receives.
                        return Ok(reference);
                    }
                }
                alt.stats.served.fetch_add(1, Ordering::Relaxed);
                alt.stats.sum_us.fetch_add(us as u64, Ordering::Relaxed);
                self.update_cost(&alt.stats, points, us);
                Ok(FftResult {
                    id: self.next_id.fetch_add(1, Ordering::Relaxed),
                    output: JobSlot::from(output),
                    profile: None,
                    core: usize::MAX,
                    wall_us: us,
                })
            }
            Err(_) => {
                alt.stats.failed.fetch_add(1, Ordering::Relaxed);
                // Penalize the lane's cost entry so the router backs
                // off, then serve the request anyway via the simulator.
                if let Some(e) = alt.stats.cost.lock().unwrap().get_mut(&points) {
                    *e *= 8.0;
                }
                self.serve_sim(input, DegradeLevel::Full)
            }
        }
    }
}

impl FftCompute for BackendSet {
    fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        BackendSet::request(self, req)
    }

    fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        BackendSet::request_all(self, reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FftService, ServiceConfig};
    use super::*;

    fn sim_pool() -> ServiceHandle {
        ServiceHandle::Pool(
            FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
        )
    }

    fn set_with(fraction: f64) -> BackendSet {
        BackendSet::new(
            sim_pool(),
            BackendSetConfig { validate_fraction: fraction, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(BackendSet::new(
            sim_pool(),
            BackendSetConfig { validate_fraction: 1.5, ..Default::default() }
        )
        .is_err());
        assert!(BackendSet::new(
            sim_pool(),
            BackendSetConfig { ewma_alpha: 0.0, ..Default::default() }
        )
        .is_err());
        assert!(BackendSet::new(
            sim_pool(),
            BackendSetConfig { calibrate_samples: 0, ..Default::default() }
        )
        .is_err());
        assert!(BackendSet::new(
            sim_pool(),
            BackendSetConfig { calibrate_sizes: Vec::new(), ..Default::default() }
        )
        .is_err());
        let set = set_with(0.0);
        assert!(matches!(
            BackendSet::new(ServiceHandle::Routed(set), BackendSetConfig::default()),
            Err(_)
        ));
    }

    #[test]
    fn validation_sampling_is_deterministic_and_exact() {
        for (fraction, want) in [(0.0, 0), (0.01, 10), (0.1, 100), (1.0, 1000)] {
            let set = set_with(fraction);
            let fired = (0..1000).filter(|_| set.should_validate()).count();
            assert_eq!(fired, want, "fraction {fraction}");
            set.shutdown();
        }
    }

    #[test]
    fn ntt_requests_bypass_the_lane_router_and_stay_exact() {
        use crate::fft::field;
        struct Nop;
        impl FftBackend for Nop {
            fn name(&self) -> &str {
                "nop"
            }
            fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
                Ok(input.to_vec())
            }
        }
        let mut set = set_with(0.0);
        set.register("nop", Box::new(Nop), 1).unwrap();
        // Make the float lane irresistibly cheap for 256 points: if the
        // router ever saw the NTT request, it would hand it to `nop`
        // (an echo) and the answer would be wrong.
        set.sim_stats.cost.lock().unwrap().insert(256, 1000.0);
        set.alternates[0].stats.cost.lock().unwrap().insert(256, 1.0);
        let elems = field::test_elements(256, 5);
        let r = set.request(FftRequest::ntt(elems.clone())).recv().unwrap().unwrap();
        let got: Vec<u64> = r.output.iter().map(|&w| field::unpack(w)).collect();
        assert_eq!(got, field::ntt(&elems), "NTT served exactly, never by a float lane");
        assert_eq!(
            set.alternates[0].stats.served.load(Ordering::Relaxed),
            0,
            "the alternate never saw the modular transform"
        );
        set.shutdown();
    }

    #[test]
    fn router_prefers_the_measured_cheaper_lane() {
        struct Nop;
        impl FftBackend for Nop {
            fn name(&self) -> &str {
                "nop"
            }
            fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
                Ok(input.to_vec())
            }
        }
        let mut set = set_with(0.0);
        set.register("nop", Box::new(Nop), 1).unwrap();
        set.sim_stats.cost.lock().unwrap().insert(256, 1000.0);
        set.alternates[0].stats.cost.lock().unwrap().insert(256, 10.0);
        assert_eq!(set.route(256), Some(0), "cheaper alternate wins");
        // no cost entry for 1024 on the alternate: sim keeps the size
        set.sim_stats.cost.lock().unwrap().insert(1024, 1000.0);
        assert_eq!(set.route(1024), None);
        // quarantine removes the lane from routing entirely
        set.alternates[0].stats.quarantined.store(true, Ordering::Relaxed);
        assert_eq!(set.route(256), None);
        set.shutdown();
    }

    #[test]
    fn balance_mode_backs_off_a_loaded_lane_and_fastest_pins_it() {
        struct Nop;
        impl FftBackend for Nop {
            fn name(&self) -> &str {
                "nop"
            }
            fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
                Ok(input.to_vec())
            }
        }
        let mut set = set_with(0.0);
        set.register("nop", Box::new(Nop), 1).unwrap();
        set.sim_stats.cost.lock().unwrap().insert(256, 100.0);
        set.alternates[0].stats.cost.lock().unwrap().insert(256, 60.0);
        // 4 requests in flight on the alternate: 60 * (1 + 4) = 300 > 100
        set.alternates[0].stats.inflight.store(4, Ordering::Relaxed);
        assert_eq!(set.route(256), None, "Balance backs off the loaded lane");
        set.set_mode(RouteMode::Fastest);
        assert_eq!(set.route(256), Some(0), "Fastest ignores load");
        set.shutdown();
    }
}
