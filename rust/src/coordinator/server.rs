//! Traffic frontend: admission control, deadlines, and priority
//! scheduling in front of the FFT execution services.
//!
//! PR 1/2 built the execution side (batched dispatch, shared plan
//! cache, sharded scheduler); this module is the front door the
//! ROADMAP's "heavy traffic" north star needs. A [`TrafficServer`]
//! wraps either execution service (see [`ServiceHandle`]) with:
//!
//! * **bounded admission queues** — one FIFO per priority class, with a
//!   shared capacity and a configurable [`AdmissionPolicy`] when full:
//!   `Block` (backpressure onto the caller), `Shed` (reject with the
//!   typed [`ServiceError::QueueFull`] — never a silent drop), or
//!   `Degrade` (admit at half resolution under pressure, shed only at
//!   the hard limit);
//! * **per-request deadlines** — a request whose deadline expires while
//!   queued is answered with [`ServiceError::DeadlineExceeded`] instead
//!   of wasting a backend slot; one served past its deadline is
//!   delivered but flagged and counted as a late miss;
//! * **two priority classes with aging** — `High` is served first, but
//!   once the oldest `Low` request has waited [`ServerConfig::aging`]
//!   it jumps the line, so sustained high-priority load can delay low
//!   priority by at most the aging bound plus one service time per
//!   dispatcher (pinned by `rust/tests/server.rs`);
//! * **a latency recorder** — queue wait and service time go into two
//!   separate log₂-bucketed histograms
//!   ([`super::metrics::LatencyRecorder`]), so p50/p90/p99/p999 of
//!   "waiting for a slot" and "the backend being slow" are separately
//!   visible in [`MetricsSnapshot::server`].
//!
//! Dispatch is a small pool of dispatcher threads, each forwarding one
//! admitted request at a time into the wrapped service and waiting for
//! its reply — so [`ServerConfig::dispatchers`] is also the in-flight
//! bound seen by the execution layer. `shutdown` closes admission,
//! drains every already-admitted request (serving it or answering with
//! a typed error), joins the dispatchers, and only then shuts the inner
//! service down.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::{LatencyRecorder, ServerStats};
use super::{FftResult, FftService, MetricsSnapshot, ServiceError, ShardedFftService};

/// Request priority class. `High` is served first; `Low` is protected
/// from starvation by the aging rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    High,
    Low,
}

/// What happens when a request arrives and the admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the submitting thread until a slot frees (closed-loop
    /// backpressure; `submit` never returns `QueueFull`).
    Block,
    /// Reject immediately with [`ServiceError::QueueFull`] — load is
    /// shed at the edge, and the caller always gets a typed error.
    Shed,
    /// Two-level degradation: once the queue is at half capacity,
    /// admit requests at *half resolution* (the input is truncated to
    /// the leading `points/2` samples, a coarser spectrum that costs
    /// roughly half the backend time — flagged in
    /// [`ServedFft::degraded`]); at the hard capacity limit, shed with
    /// a typed error exactly as [`AdmissionPolicy::Shed`].
    Degrade,
}

/// Per-request submission options.
#[derive(Clone, Copy, Debug)]
pub struct RequestOpts {
    pub priority: Priority,
    /// Relative deadline; `None` falls back to
    /// [`ServerConfig::default_deadline`].
    pub deadline: Option<Duration>,
}

impl Default for RequestOpts {
    fn default() -> Self {
        RequestOpts { priority: Priority::High, deadline: None }
    }
}

/// Traffic-frontend configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Admission-queue capacity, shared across both priority classes.
    pub queue_capacity: usize,
    pub policy: AdmissionPolicy,
    /// Dispatcher threads — also the in-flight bound on the wrapped
    /// execution service.
    pub dispatchers: usize,
    /// Once the oldest low-priority request has waited this long it is
    /// served before any high-priority work (starvation freedom).
    pub aging: Duration,
    /// Deadline applied to requests that do not carry their own.
    pub default_deadline: Option<Duration>,
    /// `Degrade` never truncates below this many points.
    pub min_degraded_points: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_capacity: 64,
            policy: AdmissionPolicy::Block,
            dispatchers: 4,
            aging: Duration::from_millis(10),
            default_deadline: None,
            min_degraded_points: 256,
        }
    }
}

/// A successfully served request, with its latency split into queue
/// wait and service time.
#[derive(Clone, Debug)]
pub struct ServedFft {
    pub result: FftResult,
    pub priority: Priority,
    /// Admission to dispatch, µs.
    pub queue_us: f64,
    /// Dispatch to backend completion, µs.
    pub service_us: f64,
    /// Served at half resolution by the `Degrade` policy.
    pub degraded: bool,
    /// Completed after its deadline (still delivered; counted as a
    /// late miss in [`ServerStats`]).
    pub deadline_missed: bool,
}

/// What a [`TrafficServer::submit`] reply channel yields.
pub type ServerResult = std::result::Result<ServedFft, ServiceError>;

/// Either execution service, so the frontend (and the load generator)
/// can sit on the single-queue pool or the sharded scheduler.
pub enum ServiceHandle {
    Pool(FftService),
    Sharded(ShardedFftService),
}

impl ServiceHandle {
    fn submit(&self, input: Vec<(f32, f32)>) -> Receiver<Result<FftResult>> {
        match self {
            ServiceHandle::Pool(s) => s.submit(input),
            ServiceHandle::Sharded(s) => s.submit(input),
        }
    }

    /// Execution-layer metrics (the frontend merges its own on top).
    pub fn metrics(&self) -> MetricsSnapshot {
        match self {
            ServiceHandle::Pool(s) => s.metrics(),
            ServiceHandle::Sharded(s) => s.metrics(),
        }
    }

    /// The sharded scheduler, when that is what this handle wraps —
    /// the resizable backend the autoscale controller needs.
    pub fn as_sharded(&self) -> Option<&ShardedFftService> {
        match self {
            ServiceHandle::Sharded(s) => Some(s),
            ServiceHandle::Pool(_) => None,
        }
    }

    pub fn shutdown(self) {
        match self {
            ServiceHandle::Pool(s) => s.shutdown(),
            ServiceHandle::Sharded(s) => s.shutdown(),
        }
    }
}

/// One admitted-but-not-yet-dispatched request.
struct Pending {
    input: Vec<(f32, f32)>,
    priority: Priority,
    deadline: Option<Instant>,
    degraded: bool,
    enqueued: Instant,
    reply: Sender<ServerResult>,
}

#[derive(Default)]
struct QueueState {
    high: VecDeque<Pending>,
    low: VecDeque<Pending>,
    closed: bool,
}

impl QueueState {
    fn depth(&self) -> usize {
        self.high.len() + self.low.len()
    }
}

/// The shared admission queue: one mutex-guarded state, a condvar for
/// dispatchers waiting for work and one for blocked submitters waiting
/// for space.
struct Admission {
    state: Mutex<QueueState>,
    work: Condvar,
    space: Condvar,
}

#[derive(Default)]
struct ServerMetrics {
    submitted: AtomicU64,
    admitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    degraded: AtomicU64,
    expired: AtomicU64,
    late: AtomicU64,
    failed: AtomicU64,
    served_high: AtomicU64,
    served_low: AtomicU64,
    aged: AtomicU64,
    max_queue_depth: AtomicUsize,
    queue_wait: LatencyRecorder,
    service_time: LatencyRecorder,
}

impl ServerMetrics {
    fn snapshot(&self) -> ServerStats {
        ServerStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            late: self.late.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            served_high: self.served_high.load(Ordering::Relaxed),
            served_low: self.served_low.load(Ordering::Relaxed),
            aged: self.aged.load(Ordering::Relaxed),
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            queue_wait: self.queue_wait.snapshot(),
            service_time: self.service_time.snapshot(),
        }
    }
}

/// Pop the next request to dispatch: the oldest low-priority request if
/// it has aged past the threshold (counted as an aged promotion when it
/// actually jumps waiting high-priority work), otherwise high before
/// low.
fn pop_next(st: &mut QueueState, aging: Duration, m: &ServerMetrics) -> Option<Pending> {
    if let Some(front) = st.low.front() {
        if front.enqueued.elapsed() >= aging {
            if !st.high.is_empty() {
                m.aged.fetch_add(1, Ordering::Relaxed);
            }
            return st.low.pop_front();
        }
    }
    if let Some(r) = st.high.pop_front() {
        return Some(r);
    }
    st.low.pop_front()
}

/// One reading of the frontend's pressure signals, covering the
/// interval since the previous sample from the same
/// [`PressureMeter`] — exactly the demand signals the scalable-GPGPU
/// companion paper proposes sizing the pool with, and what
/// `coordinator::autoscale` consumes.
#[derive(Clone, Copy, Debug)]
pub struct PressureSample {
    /// When the sample was taken.
    pub at: Instant,
    /// Admitted-but-not-yet-dispatched requests right now (a gauge,
    /// not an interval counter).
    pub queue_depth: usize,
    /// Submissions in the interval.
    pub submitted: u64,
    /// Completions in the interval.
    pub completed: u64,
    /// Requests shed at admission in the interval.
    pub shed: u64,
    /// Requests whose deadline expired in queue in the interval.
    pub expired: u64,
    /// Interval shed fraction (`shed / submitted`).
    pub shed_rate: f64,
    /// Interval deadline-miss fraction (expired + late, over admitted).
    pub deadline_miss_rate: f64,
    /// Interval queue-wait p99, µs — the component of latency that
    /// adding capacity actually removes.
    pub queue_p99_us: f64,
    /// Interval service-time p99, µs.
    pub service_p99_us: f64,
}

/// Computes [`PressureSample`]s as deltas between successive frontend
/// snapshots. Each meter carries its own `last` snapshot, so several
/// consumers (an autoscaler, a bench, a dashboard) can sample the same
/// server at independent cadences.
pub struct PressureMeter {
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    last: ServerStats,
}

impl PressureMeter {
    /// Take one sample covering the interval since the previous call
    /// (or since the meter was created).
    pub fn sample(&mut self) -> PressureSample {
        let cur = self.metrics.snapshot();
        let iv = cur.interval_since(&self.last);
        let queue_depth = self.admission.state.lock().unwrap().depth();
        let sample = PressureSample {
            at: Instant::now(),
            queue_depth,
            submitted: iv.submitted,
            completed: iv.completed,
            shed: iv.shed,
            expired: iv.expired,
            shed_rate: iv.shed_rate(),
            deadline_miss_rate: iv.deadline_miss_rate(),
            queue_p99_us: iv.queue_wait.percentile_us(0.99),
            service_p99_us: iv.service_time.percentile_us(0.99),
        };
        self.last = cur;
        sample
    }
}

/// The admission-controlled frontend over an FFT execution service.
pub struct TrafficServer {
    cfg: ServerConfig,
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    inner: Option<Arc<ServiceHandle>>,
    dispatchers: Vec<JoinHandle<()>>,
    /// Periodic pressure-feed sampler threads (see `pressure_feed`).
    samplers: Mutex<Vec<JoinHandle<()>>>,
}

impl TrafficServer {
    pub fn start(inner: ServiceHandle, cfg: ServerConfig) -> Result<Self> {
        if cfg.queue_capacity == 0 {
            return Err(anyhow!("queue_capacity must be at least 1"));
        }
        if cfg.dispatchers == 0 {
            return Err(anyhow!("need at least one dispatcher"));
        }
        let admission = Arc::new(Admission {
            state: Mutex::new(QueueState::default()),
            work: Condvar::new(),
            space: Condvar::new(),
        });
        let metrics = Arc::new(ServerMetrics::default());
        let inner = Arc::new(inner);
        let mut dispatchers = Vec::with_capacity(cfg.dispatchers);
        for _ in 0..cfg.dispatchers {
            let admission = Arc::clone(&admission);
            let metrics = Arc::clone(&metrics);
            let inner = Arc::clone(&inner);
            let aging = cfg.aging;
            dispatchers.push(std::thread::spawn(move || {
                dispatcher_loop(admission, metrics, inner, aging)
            }));
        }
        Ok(TrafficServer {
            cfg,
            admission,
            metrics,
            inner: Some(inner),
            dispatchers,
            samplers: Mutex::new(Vec::new()),
        })
    }

    /// A shared handle to the wrapped execution service, so a
    /// controller (e.g. `coordinator::autoscale`) can resize the shard
    /// pool the server dispatches into. Drop the clone before calling
    /// [`TrafficServer::shutdown`], or the inner service cannot be
    /// unwrapped and shut down.
    pub fn service(&self) -> Arc<ServiceHandle> {
        Arc::clone(self.inner.as_ref().expect("inner service present until shutdown"))
    }

    /// A fresh pressure meter over this server's frontend counters
    /// (first `sample()` covers everything since server start).
    pub fn pressure_meter(&self) -> PressureMeter {
        PressureMeter {
            admission: Arc::clone(&self.admission),
            metrics: Arc::clone(&self.metrics),
            last: ServerStats::default(),
        }
    }

    /// A periodic [`PressureSample`] feed: a sampler thread meters the
    /// frontend every `interval` and sends the sample down the returned
    /// channel. The sampler exits when the receiver is dropped or the
    /// server shuts down (shutdown joins it, waiting at most one
    /// interval).
    pub fn pressure_feed(&self, interval: Duration) -> Receiver<PressureSample> {
        let (tx, rx) = channel();
        let mut meter = self.pressure_meter();
        let admission = Arc::clone(&self.admission);
        let handle = std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            if tx.send(meter.sample()).is_err() {
                return; // consumer gone
            }
            if admission.state.lock().unwrap().closed {
                return; // server shut down
            }
        });
        self.samplers.lock().unwrap().push(handle);
        rx
    }

    /// Submit one FFT through admission control. Returns the reply
    /// channel on admission, or a typed error when the request is shed
    /// (`Shed`/`Degrade` at the hard limit) or the server is shut down.
    /// Every admitted request is answered — with a [`ServedFft`] or a
    /// typed [`ServiceError`] — never silently dropped.
    pub fn submit(
        &self,
        input: Vec<(f32, f32)>,
        opts: RequestOpts,
    ) -> std::result::Result<Receiver<ServerResult>, ServiceError> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let deadline = opts.deadline.or(self.cfg.default_deadline).map(|d| now + d);
        let mut st = self.admission.state.lock().unwrap();
        let degraded = loop {
            if st.closed {
                return Err(ServiceError::WorkerGone);
            }
            let depth = st.depth();
            if depth < self.cfg.queue_capacity {
                // Degrade kicks in at half capacity: coarser answers
                // under pressure, full resolution when the queue is
                // healthy.
                break self.cfg.policy == AdmissionPolicy::Degrade
                    && depth >= self.cfg.queue_capacity / 2
                    && input.len() / 2 >= self.cfg.min_degraded_points;
            }
            match self.cfg.policy {
                AdmissionPolicy::Block => st = self.admission.space.wait(st).unwrap(),
                AdmissionPolicy::Shed | AdmissionPolicy::Degrade => {
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(ServiceError::QueueFull { capacity: self.cfg.queue_capacity });
                }
            }
        };
        let (reply, rx) = channel();
        let req = Pending {
            input,
            priority: opts.priority,
            deadline,
            degraded,
            enqueued: now,
            reply,
        };
        match opts.priority {
            Priority::High => st.high.push_back(req),
            Priority::Low => st.low.push_back(req),
        }
        let depth = st.depth();
        drop(st);
        self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
        self.admission.work.notify_one();
        Ok(rx)
    }

    /// Queued (admitted, not yet dispatched) requests right now.
    pub fn queue_depth(&self) -> usize {
        self.admission.state.lock().unwrap().depth()
    }

    /// Execution-layer metrics with the frontend counters merged in
    /// ([`MetricsSnapshot::server`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self
            .inner
            .as_ref()
            .expect("inner service present until shutdown")
            .metrics();
        snap.server = self.metrics.snapshot();
        snap
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Close admission, drain every admitted request (each is served or
    /// answered with a typed error), join the dispatchers, then shut
    /// the inner execution service down.
    pub fn shutdown(mut self) {
        self.close_and_join();
        if let Some(inner) = self.inner.take() {
            match Arc::try_unwrap(inner) {
                Ok(handle) => handle.shutdown(),
                Err(_) => eprintln!(
                    "warning: TrafficServer::shutdown could not stop the inner \
                     service — a handle from TrafficServer::service() is still \
                     alive (stop the AutoscaleController first); backend worker \
                     threads stop when the last handle drops"
                ),
            }
        }
    }

    fn close_and_join(&mut self) {
        self.admission.state.lock().unwrap().closed = true;
        self.admission.work.notify_all();
        self.admission.space.notify_all();
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
        // Pressure-feed samplers notice the closed flag within one
        // interval (or exit early when their receiver is gone).
        for s in self.samplers.lock().unwrap().drain(..) {
            let _ = s.join();
        }
    }
}

impl Drop for TrafficServer {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn dispatcher_loop(
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    inner: Arc<ServiceHandle>,
    aging: Duration,
) {
    loop {
        let req = {
            let mut st = admission.state.lock().unwrap();
            loop {
                if let Some(r) = pop_next(&mut st, aging, &metrics) {
                    break Some(r);
                }
                if st.closed {
                    break None;
                }
                st = admission.work.wait(st).unwrap();
            }
        };
        let Some(mut req) = req else { return };
        admission.space.notify_one();

        let queue_us = req.enqueued.elapsed().as_secs_f64() * 1e6;
        metrics.queue_wait.record(queue_us);
        if let Some(d) = req.deadline {
            if Instant::now() > d {
                metrics.expired.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .reply
                    .send(Err(ServiceError::DeadlineExceeded { waited_us: queue_us }));
                continue;
            }
        }
        if req.degraded {
            let half = req.input.len() / 2;
            req.input.truncate(half);
            metrics.degraded.fetch_add(1, Ordering::Relaxed);
        }

        let t0 = Instant::now();
        let backend = inner.submit(req.input).recv();
        let service_us = t0.elapsed().as_secs_f64() * 1e6;
        metrics.service_time.record(service_us);

        let outcome = match backend {
            Err(_) => Err(ServiceError::WorkerGone),
            Ok(Err(e)) => Err(match e.downcast::<ServiceError>() {
                Ok(se) => se,
                Err(e) => ServiceError::Backend(format!("{e:#}")),
            }),
            Ok(Ok(r)) => Ok(r),
        };
        match outcome {
            Ok(result) => {
                let deadline_missed = req.deadline.is_some_and(|d| Instant::now() > d);
                if deadline_missed {
                    metrics.late.fetch_add(1, Ordering::Relaxed);
                }
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                match req.priority {
                    Priority::High => metrics.served_high.fetch_add(1, Ordering::Relaxed),
                    Priority::Low => metrics.served_low.fetch_add(1, Ordering::Relaxed),
                };
                let _ = req.reply.send(Ok(ServedFft {
                    result,
                    priority: req.priority,
                    queue_us,
                    service_us,
                    degraded: req.degraded,
                    deadline_missed,
                }));
            }
            Err(e) => {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(e));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;

    fn pending(priority: Priority, age: Duration) -> Pending {
        let (reply, _rx) = channel();
        Pending {
            input: Vec::new(),
            priority,
            deadline: None,
            degraded: false,
            enqueued: Instant::now() - age,
            reply,
        }
    }

    #[test]
    fn pop_prefers_high_until_low_ages() {
        let m = ServerMetrics::default();
        let mut st = QueueState::default();
        st.high.push_back(pending(Priority::High, Duration::ZERO));
        st.low.push_back(pending(Priority::Low, Duration::ZERO));
        let first = pop_next(&mut st, Duration::from_secs(3600), &m).unwrap();
        assert_eq!(first.priority, Priority::High);
        assert_eq!(m.aged.load(Ordering::Relaxed), 0);
        let second = pop_next(&mut st, Duration::from_secs(3600), &m).unwrap();
        assert_eq!(second.priority, Priority::Low, "low still drains when high is empty");
        assert_eq!(m.aged.load(Ordering::Relaxed), 0, "no promotion without waiting high work");
    }

    #[test]
    fn aged_low_jumps_waiting_high_work() {
        let m = ServerMetrics::default();
        let mut st = QueueState::default();
        st.high.push_back(pending(Priority::High, Duration::ZERO));
        st.low.push_back(pending(Priority::Low, Duration::from_secs(5)));
        let first = pop_next(&mut st, Duration::from_millis(1), &m).unwrap();
        assert_eq!(first.priority, Priority::Low);
        assert_eq!(m.aged.load(Ordering::Relaxed), 1);
        assert_eq!(st.high.len(), 1);
    }

    #[test]
    fn pressure_meter_reports_interval_deltas() {
        let m = Arc::new(ServerMetrics::default());
        let adm = Arc::new(Admission {
            state: Mutex::new(QueueState::default()),
            work: Condvar::new(),
            space: Condvar::new(),
        });
        let mut meter = PressureMeter {
            admission: Arc::clone(&adm),
            metrics: Arc::clone(&m),
            last: ServerStats::default(),
        };
        m.submitted.fetch_add(10, Ordering::Relaxed);
        m.shed.fetch_add(5, Ordering::Relaxed);
        let s1 = meter.sample();
        assert_eq!(s1.submitted, 10);
        assert_eq!(s1.shed, 5);
        assert!((s1.shed_rate - 0.5).abs() < 1e-12);
        // no new traffic: the next interval is clean, not cumulative
        let s2 = meter.sample();
        assert_eq!(s2.submitted, 0);
        assert_eq!(s2.shed_rate, 0.0);
        m.submitted.fetch_add(4, Ordering::Relaxed);
        let s3 = meter.sample();
        assert_eq!(s3.submitted, 4);
        assert_eq!(s3.shed, 0);
        assert_eq!(s3.queue_depth, 0);
    }

    #[test]
    fn invalid_configs_rejected() {
        let pool = || {
            ServiceHandle::Pool(
                FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
            )
        };
        assert!(TrafficServer::start(
            pool(),
            ServerConfig { queue_capacity: 0, ..Default::default() }
        )
        .is_err());
        assert!(TrafficServer::start(
            pool(),
            ServerConfig { dispatchers: 0, ..Default::default() }
        )
        .is_err());
    }
}
