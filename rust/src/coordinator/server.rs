//! Traffic frontend: admission control, deadlines, and N-class QoS
//! scheduling in front of the FFT execution services.
//!
//! PR 1/2 built the execution side (batched dispatch, shared plan
//! cache, sharded scheduler) and PR 4 made capacity elastic; this
//! module is the front door that decides *who* gets that capacity. A
//! [`TrafficServer`] wraps an execution service — the single-queue
//! pool, the sharded scheduler, or a routed multi-backend set (see
//! [`ServiceHandle`]) — with:
//!
//! * **N QoS classes** ([`super::qos::QosClass`], configured through
//!   [`ServerConfig::classes`]) — each with a fair-share weight, a
//!   bounded admission queue, and an optional per-class default
//!   deadline. Dispatch order across classes is weighted fair queueing
//!   (deficit round-robin); within a class it is earliest-deadline
//!   first. Weight-0 *background* classes are served only when the
//!   weighted queues are idle or via the aging rule, which preserves
//!   the original two-priority frontend as the special case
//!   `[{high, w1}, {low, w0}]` (see [`super::qos::default_two_class`]).
//! * **a configurable [`AdmissionPolicy`]** when a class queue fills:
//!   `Block` (backpressure onto the caller), `Shed` (reject with the
//!   typed [`ServiceError::QueueFull`] — never a silent drop), or
//!   `Degrade` (walk the `Full → Half → Quarter` resolution ladder as
//!   the class queue deepens, floor-clamped by
//!   [`ServerConfig::min_degraded_points`]; shed only at the hard
//!   class limit);
//! * **a controller-driven operating level** — [`DegradeControl`]
//!   exposes a shared degrade level that the autoscale controller can
//!   raise under pressure instead of (or before) adding shards; it
//!   applies to every admitted request, on top of any queue-driven
//!   degradation, and is floor-clamped by the same ladder;
//! * **per-request deadlines** — a request whose deadline expires while
//!   queued is answered with [`ServiceError::DeadlineExceeded`] instead
//!   of wasting a backend slot; one served past its deadline is
//!   delivered but flagged and counted as a late miss;
//! * **latency recorders** — queue wait and service time go into two
//!   separate log₂-bucketed histograms, plus a per-class queue-wait
//!   histogram, so per-class p99s surface in
//!   [`MetricsSnapshot::server`] ([`super::metrics::ClassStats`]).
//!
//! When [`ServerConfig::tenants`] is configured, a **tenancy layer**
//! ([`super::tenant::TenantRegistry`]) runs *ahead of* the class
//! queues: a request carrying [`FftRequest::tenant`] must pass its
//! tenant's token bucket (sustained rate + burst) and in-flight
//! job-unit quota before it may occupy any class-queue slot. A
//! throttled request is answered immediately with
//! [`ServiceError::TenantThrottled`] — it is never queued, never ages,
//! and is invisible to the class counters, so one abusive tenant
//! cannot convert its excess offered load into queue occupancy that
//! delays anyone else. Requests without a tenant id bypass the layer
//! (operator/system traffic). Per-tenant billing counters surface in
//! [`MetricsSnapshot::tenants`], and while a *priority* tenant's
//! request waits in a class queue, non-priority tenants' decomposed
//! requests are handed a [`super::tenant::PreemptWatch`] so they yield
//! at the between-pass checkpoint.
//!
//! Dispatch is a small pool of dispatcher threads, each forwarding one
//! admitted request at a time into the wrapped service as an
//! [`FftRequest`] and waiting for its reply — so
//! [`ServerConfig::dispatchers`] is also the in-flight bound seen by
//! the execution layer. The degrade level travels *with* the request
//! ([`FftRequest::level`]), so the backend truncates, routes and meters
//! the transform at its served size, and the remaining deadline budget
//! rides along so a decomposed large transform can be preempted at its
//! between-pass checkpoint. Admission itself accounts queued work in
//! single-pass job units ([`crate::fft::multipass::job_cost`]): a
//! request above the 4096-point single-pass ceiling weighs its full
//! `n1 + n2` decomposition against its class queue, so the full-check,
//! the degrade ladder and the pressure feed all see the true backend
//! cost of large-N traffic. `shutdown` closes admission, drains every
//! already-admitted request (serving it or answering with a typed
//! error), joins the dispatchers, and only then shuts the inner
//! service down.

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::backend::BackendSet;
use super::buffer::JobSlot;
use super::metrics::{ClassStats, LatencyRecorder, ServerStats};
use super::qos::{default_two_class, DegradeLadder, DegradeLevel, QosClass, QosScheduler};
use super::request::{FftCompute, FftRequest};
use super::tenant::{TenantDenial, TenantRegistry, TenantSpec};
use super::{FftResult, FftService, MetricsSnapshot, ServiceError, ShardedFftService, Workload};
use crate::fft::multipass;

/// What happens when a request arrives and its class queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the submitting thread until a slot frees in the request's
    /// class (closed-loop backpressure; `request` never returns
    /// `QueueFull`).
    Block,
    /// Reject immediately with [`ServiceError::QueueFull`] — load is
    /// shed at the edge, and the caller always gets a typed error.
    Shed,
    /// Degrade-ladder admission: as a class queue deepens, requests are
    /// admitted at reduced resolution — `Half` once the queue is at
    /// half its capacity, `Quarter` at three quarters (each truncating
    /// the input to its leading samples, a coarser spectrum that costs
    /// roughly proportionally less backend time — level recorded in
    /// [`ServedFft::level`]). The ladder never truncates below
    /// [`ServerConfig::min_degraded_points`]; at the hard class limit
    /// the request is shed with a typed error exactly as
    /// [`AdmissionPolicy::Shed`].
    Degrade,
}

/// Traffic-frontend configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// QoS classes, in priority/configuration order (requests address
    /// them by index through [`FftRequest::with_class`]). Each class
    /// carries its own admission-queue capacity
    /// ([`QosClass::capacity`], default
    /// [`super::qos::DEFAULT_CLASS_CAPACITY`], overridden with
    /// [`QosClass::with_capacity`]) — the shared
    /// `ServerConfig::queue_capacity` fallback was removed in 0.4.0.
    pub classes: Vec<QosClass>,
    /// What happens when a request's class queue is full.
    pub policy: AdmissionPolicy,
    /// Dispatcher threads — also the in-flight bound on the wrapped
    /// execution service.
    pub dispatchers: usize,
    /// Once the oldest request of a background (weight-0) class has
    /// waited this long it is served before any weighted work
    /// (starvation freedom for classes outside the fair-share
    /// rotation).
    pub aging: Duration,
    /// Deadline applied to requests that carry none of their own and
    /// whose class has no `deadline_default`.
    pub default_deadline: Option<Duration>,
    /// The degrade ladder never truncates below this many points
    /// (radix/variant-aware floor: see
    /// [`super::qos::DegradeLadder::for_radix`]).
    pub min_degraded_points: usize,
    /// Tenancy layer: per-tenant token buckets + job-unit quotas
    /// applied *before* class-queue admission (requests address
    /// tenants by index through [`FftRequest::with_tenant`]). Empty =
    /// no tenancy layer; requests without a tenant id always bypass
    /// it.
    pub tenants: Vec<TenantSpec>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            classes: default_two_class(),
            policy: AdmissionPolicy::Block,
            dispatchers: 4,
            aging: Duration::from_millis(10),
            default_deadline: None,
            min_degraded_points: 256,
            tenants: Vec::new(),
        }
    }
}

/// A successfully served request, with its latency split into queue
/// wait and service time.
#[derive(Clone, Debug)]
pub struct ServedFft {
    /// The execution-layer result (output, profile, serving core).
    pub result: FftResult,
    /// The QoS class this request was submitted under.
    pub class: usize,
    /// Admission to dispatch, µs.
    pub queue_us: f64,
    /// Dispatch to backend completion, µs.
    pub service_us: f64,
    /// Resolution level the request was served at.
    pub level: DegradeLevel,
    /// Served at reduced resolution (`level != Full`).
    pub degraded: bool,
    /// Completed after its deadline (still delivered; counted as a
    /// late miss in [`ServerStats`]).
    pub deadline_missed: bool,
}

/// What a [`TrafficServer::request`] reply channel yields.
pub type ServerResult = std::result::Result<ServedFft, ServiceError>;

/// An execution service behind the frontend: the single-queue pool,
/// the sharded scheduler, or a routed multi-backend set (which itself
/// wraps one of the first two as its simulator lane).
pub enum ServiceHandle {
    /// The single shared-queue worker pool ([`FftService`]).
    Pool(FftService),
    /// The elastic sharded scheduler ([`ShardedFftService`]).
    Sharded(ShardedFftService),
    /// A routed multi-backend set ([`BackendSet`]): a simulator lane
    /// plus alternate lanes behind a measured cost model and sampled
    /// validation.
    Routed(BackendSet),
}

impl ServiceHandle {
    /// The wrapped service as the unified [`FftCompute`] surface — one
    /// match for every variant, so the three lanes cannot drift apart
    /// in method naming or submit semantics again (the pre-redesign
    /// dispatch called `submit_degraded` on two variants and `submit`
    /// on the third).
    fn compute(&self) -> &dyn FftCompute {
        match self {
            ServiceHandle::Pool(s) => s,
            ServiceHandle::Sharded(s) => s,
            ServiceHandle::Routed(s) => s,
        }
    }

    /// Execution-layer metrics (the frontend merges its own on top).
    /// For a routed set this is the simulator lane's snapshot with the
    /// per-backend counters ([`MetricsSnapshot::backends`]) merged in.
    pub fn metrics(&self) -> MetricsSnapshot {
        match self {
            ServiceHandle::Pool(s) => s.metrics(),
            ServiceHandle::Sharded(s) => s.metrics(),
            ServiceHandle::Routed(s) => {
                let mut snap = s.sim().metrics();
                snap.backends = s.stats();
                snap
            }
        }
    }

    /// The sharded scheduler, when that is what this handle wraps —
    /// the resizable backend the autoscale controller needs. A routed
    /// set delegates to its simulator lane, so shard autoscaling
    /// composes with backend routing.
    pub fn as_sharded(&self) -> Option<&ShardedFftService> {
        match self {
            ServiceHandle::Sharded(s) => Some(s),
            ServiceHandle::Pool(_) => None,
            ServiceHandle::Routed(s) => s.sim().as_sharded(),
        }
    }

    /// The routed backend set, when that is what this handle wraps —
    /// the swap actuator the autoscale controller drives.
    pub fn as_routed(&self) -> Option<&BackendSet> {
        match self {
            ServiceHandle::Routed(s) => Some(s),
            ServiceHandle::Pool(_) | ServiceHandle::Sharded(_) => None,
        }
    }

    /// Shut the wrapped execution service down (drains in-flight work).
    pub fn shutdown(self) {
        match self {
            ServiceHandle::Pool(s) => s.shutdown(),
            ServiceHandle::Sharded(s) => s.shutdown(),
            ServiceHandle::Routed(s) => s.shutdown(),
        }
    }
}

impl FftCompute for ServiceHandle {
    fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        self.compute().request(req)
    }

    fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        self.compute().request_all(reqs)
    }
}

/// One admitted-but-not-yet-dispatched request (the scheduler core
/// carries class, deadline and enqueue time).
struct Pending {
    input: JobSlot,
    /// Which transform kernel the request asked for — rides through the
    /// class queues untouched so the dispatcher rebuilds the backend
    /// request under the same workload it was admitted with.
    workload: Workload,
    /// Effective degrade level decided at admission (queue-driven level
    /// merged with the controller's operating level, floor-clamped).
    level: DegradeLevel,
    /// Admission cost in single-pass job units: 1 for a request the
    /// backend serves in one pass, `n1 + n2` for one it serves by
    /// four-step decomposition ([`multipass::job_cost`]) — so a
    /// 2^20-point request weighs its true 2048 sub-jobs against its
    /// class queue, not 1.
    cost: u64,
    /// Tenant index + the job units charged against its quota at
    /// admission (`None` for untenanted requests or servers without a
    /// tenancy layer). The dispatcher settles the charge — billed on
    /// completion, released on expiry/failure.
    tenant: Option<(usize, u64)>,
    reply: Sender<ServerResult>,
}

struct QueueState {
    sched: QosScheduler<Pending>,
    /// Per-class queued backlog in single-pass job units (the sum of
    /// queued [`Pending::cost`]s): what the admission full-check and
    /// the queue-driven degrade ladder measure pressure in. For
    /// all-single-pass traffic every cost is 1, so this equals the
    /// request depth and legacy thresholds are unchanged.
    cost: Vec<u64>,
    closed: bool,
}

/// The shared admission queue: one mutex-guarded scheduler, a condvar
/// for dispatchers waiting for work and one for blocked submitters
/// waiting for space in their class.
struct Admission {
    state: Mutex<QueueState>,
    work: Condvar,
    space: Condvar,
}

/// Per-class atomic counters behind [`ClassStats`].
#[derive(Default)]
struct ClassCounters {
    submitted: AtomicU64,
    admitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    late: AtomicU64,
    failed: AtomicU64,
    degraded_half: AtomicU64,
    degraded_quarter: AtomicU64,
    aged: AtomicU64,
    max_queue_depth: AtomicUsize,
    queue_wait: LatencyRecorder,
}

struct ServerMetrics {
    submitted: AtomicU64,
    admitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    degraded: AtomicU64,
    expired: AtomicU64,
    late: AtomicU64,
    failed: AtomicU64,
    aged: AtomicU64,
    max_queue_depth: AtomicUsize,
    queue_wait: LatencyRecorder,
    service_time: LatencyRecorder,
    /// One counter block per QoS class, plus the metadata snapshots
    /// need (name, weight, resolved capacity).
    classes: Vec<(QosClass, usize, ClassCounters)>,
}

impl ServerMetrics {
    fn new(classes: &[QosClass], caps: &[usize]) -> ServerMetrics {
        ServerMetrics {
            submitted: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            late: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            aged: AtomicU64::new(0),
            max_queue_depth: AtomicUsize::new(0),
            queue_wait: LatencyRecorder::default(),
            service_time: LatencyRecorder::default(),
            classes: classes
                .iter()
                .zip(caps)
                .map(|(c, &cap)| (c.clone(), cap, ClassCounters::default()))
                .collect(),
        }
    }

    fn class(&self, c: usize) -> &ClassCounters {
        &self.classes[c].2
    }

    fn snapshot(&self) -> ServerStats {
        let per_class: Vec<ClassStats> = self
            .classes
            .iter()
            .map(|(meta, cap, c)| ClassStats {
                name: meta.name.clone(),
                weight: meta.weight,
                capacity: *cap,
                submitted: c.submitted.load(Ordering::Relaxed),
                admitted: c.admitted.load(Ordering::Relaxed),
                completed: c.completed.load(Ordering::Relaxed),
                shed: c.shed.load(Ordering::Relaxed),
                expired: c.expired.load(Ordering::Relaxed),
                late: c.late.load(Ordering::Relaxed),
                failed: c.failed.load(Ordering::Relaxed),
                degraded_half: c.degraded_half.load(Ordering::Relaxed),
                degraded_quarter: c.degraded_quarter.load(Ordering::Relaxed),
                aged: c.aged.load(Ordering::Relaxed),
                max_queue_depth: c.max_queue_depth.load(Ordering::Relaxed),
                queue_wait: c.queue_wait.snapshot(),
            })
            .collect();
        ServerStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            late: self.late.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            // legacy aggregates: class 0 vs the rest (exact for the
            // default two-class configuration)
            served_high: per_class.first().map(|c| c.completed).unwrap_or(0),
            served_low: per_class.iter().skip(1).map(|c| c.completed).sum(),
            aged: self.aged.load(Ordering::Relaxed),
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            queue_wait: self.queue_wait.snapshot(),
            service_time: self.service_time.snapshot(),
            per_class,
        }
    }
}

/// A shared handle on the frontend's *operating* degrade level — the
/// controller-driven lever. The level applies to every admitted
/// request (merged with any queue-driven degradation by taking the
/// deeper of the two, then floor-clamped), so a controller can halve
/// per-request service cost across the board instead of adding a
/// shard.
#[derive(Clone)]
pub struct DegradeControl {
    level: Arc<AtomicU8>,
}

impl DegradeControl {
    /// The current operating degrade level.
    pub fn get(&self) -> DegradeLevel {
        DegradeLevel::from_u8(self.level.load(Ordering::Relaxed))
    }

    /// Set the operating degrade level directly.
    pub fn set(&self, level: DegradeLevel) {
        self.level.store(level.as_u8(), Ordering::Relaxed);
    }

    /// One step deeper, clamped at `max`; returns the new level.
    pub fn deepen(&self, max: DegradeLevel) -> DegradeLevel {
        let next = self.get().deeper().min(max);
        self.set(next);
        next
    }

    /// One step back toward full resolution; returns the new level.
    pub fn restore(&self) -> DegradeLevel {
        let next = self.get().shallower();
        self.set(next);
        next
    }
}

/// One reading of the frontend's pressure signals, covering the
/// interval since the previous sample from the same
/// [`PressureMeter`] — exactly the demand signals the scalable-GPGPU
/// companion paper proposes sizing the pool with, and what
/// `coordinator::autoscale` consumes.
#[derive(Clone, Copy, Debug)]
pub struct PressureSample {
    /// When the sample was taken.
    pub at: Instant,
    /// Admitted-but-not-yet-dispatched requests right now (a gauge,
    /// not an interval counter).
    pub queue_depth: usize,
    /// Submissions in the interval.
    pub submitted: u64,
    /// Completions in the interval.
    pub completed: u64,
    /// Requests shed at admission in the interval.
    pub shed: u64,
    /// Requests whose deadline expired in queue in the interval.
    pub expired: u64,
    /// Interval shed fraction (`shed / submitted`).
    pub shed_rate: f64,
    /// Interval deadline-miss fraction (expired + late, over admitted).
    pub deadline_miss_rate: f64,
    /// Interval queue-wait p99, µs — the component of latency that
    /// adding capacity actually removes.
    pub queue_p99_us: f64,
    /// Interval service-time p99, µs.
    pub service_p99_us: f64,
    /// The controller-driven operating degrade level right now (a
    /// gauge).
    pub operating_level: DegradeLevel,
}

/// Computes [`PressureSample`]s as deltas between successive frontend
/// snapshots. Each meter carries its own `last` snapshot, so several
/// consumers (an autoscaler, a bench, a dashboard) can sample the same
/// server at independent cadences.
pub struct PressureMeter {
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    operating: Arc<AtomicU8>,
    last: ServerStats,
}

impl PressureMeter {
    /// Take one sample covering the interval since the previous call
    /// (or since the meter was created).
    pub fn sample(&mut self) -> PressureSample {
        let cur = self.metrics.snapshot();
        let iv = cur.interval_since(&self.last);
        let queue_depth = self.admission.state.lock().unwrap().sched.total_depth();
        let sample = PressureSample {
            at: Instant::now(),
            queue_depth,
            submitted: iv.submitted,
            completed: iv.completed,
            shed: iv.shed,
            expired: iv.expired,
            shed_rate: iv.shed_rate(),
            deadline_miss_rate: iv.deadline_miss_rate(),
            queue_p99_us: iv.queue_wait.percentile_us(0.99),
            service_p99_us: iv.service_time.percentile_us(0.99),
            operating_level: DegradeLevel::from_u8(self.operating.load(Ordering::Relaxed)),
        };
        self.last = cur;
        sample
    }
}

/// The admission-controlled QoS frontend over an FFT execution service.
pub struct TrafficServer {
    cfg: ServerConfig,
    /// Resolved per-class queue capacities.
    caps: Vec<usize>,
    ladder: DegradeLadder,
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    operating: Arc<AtomicU8>,
    tenants: Option<Arc<TenantRegistry>>,
    inner: Option<Arc<ServiceHandle>>,
    dispatchers: Vec<JoinHandle<()>>,
    /// Periodic pressure-feed sampler threads (see `pressure_feed`).
    samplers: Mutex<Vec<JoinHandle<()>>>,
}

impl TrafficServer {
    /// Start the frontend over an execution service: validate the QoS
    /// class configuration, resolve per-class queue capacities, and
    /// spawn the dispatcher pool.
    ///
    /// ```
    /// use egpu_fft::coordinator::{
    ///     FftRequest, FftService, ServerConfig, ServiceConfig, ServiceHandle, TrafficServer,
    /// };
    ///
    /// let service = ServiceHandle::Pool(FftService::start(ServiceConfig {
    ///     cores: 1,
    ///     ..Default::default()
    /// })?);
    /// let server = TrafficServer::start(service, ServerConfig::default())?;
    /// let reply = server.request(FftRequest::new(vec![(1.0, 0.0); 256]))?;
    /// let served = reply.recv()?.expect("request served");
    /// assert_eq!(served.result.output.len(), 256);
    /// server.shutdown();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn start(inner: ServiceHandle, cfg: ServerConfig) -> Result<Self> {
        if cfg.classes.is_empty() {
            return Err(anyhow!("at least one QoS class is required"));
        }
        for (i, a) in cfg.classes.iter().enumerate() {
            if cfg.classes[..i].iter().any(|b| b.name == a.name) {
                return Err(anyhow!("duplicate QoS class name `{}`", a.name));
            }
        }
        let caps: Vec<usize> = cfg.classes.iter().map(|c| c.capacity).collect();
        if let Some(i) = caps.iter().position(|&c| c == 0) {
            return Err(anyhow!(
                "class `{}` has a zero queue capacity: set QosClass::with_capacity",
                cfg.classes[i].name
            ));
        }
        if cfg.dispatchers == 0 {
            return Err(anyhow!("need at least one dispatcher"));
        }
        let ladder = DegradeLadder { min_points: cfg.min_degraded_points };
        let admission = Arc::new(Admission {
            state: Mutex::new(QueueState {
                sched: QosScheduler::new(cfg.classes.clone(), caps.clone(), cfg.aging),
                cost: vec![0; cfg.classes.len()],
                closed: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        });
        let metrics = Arc::new(ServerMetrics::new(&cfg.classes, &caps));
        let operating = Arc::new(AtomicU8::new(DegradeLevel::Full.as_u8()));
        let tenants = if cfg.tenants.is_empty() {
            None
        } else {
            Some(Arc::new(TenantRegistry::new(cfg.tenants.clone(), Instant::now())?))
        };
        let inner = Arc::new(inner);
        let mut dispatchers = Vec::with_capacity(cfg.dispatchers);
        for _ in 0..cfg.dispatchers {
            let admission = Arc::clone(&admission);
            let metrics = Arc::clone(&metrics);
            let inner = Arc::clone(&inner);
            let tenants = tenants.clone();
            dispatchers.push(std::thread::spawn(move || {
                dispatcher_loop(admission, metrics, inner, tenants)
            }));
        }
        Ok(TrafficServer {
            cfg,
            caps,
            ladder,
            admission,
            metrics,
            operating,
            tenants,
            inner: Some(inner),
            dispatchers,
            samplers: Mutex::new(Vec::new()),
        })
    }

    /// The tenancy registry, when [`ServerConfig::tenants`] configured
    /// one — the handle tests and harnesses use to inspect per-tenant
    /// counters or obtain the preemption watch directly.
    pub fn tenant_registry(&self) -> Option<&TenantRegistry> {
        self.tenants.as_deref()
    }

    /// A shared handle to the wrapped execution service, so a
    /// controller (e.g. `coordinator::autoscale`) can resize the shard
    /// pool the server dispatches into. Drop the clone before calling
    /// [`TrafficServer::shutdown`], or the inner service cannot be
    /// unwrapped and shut down.
    pub fn service(&self) -> Arc<ServiceHandle> {
        Arc::clone(self.inner.as_ref().expect("inner service present until shutdown"))
    }

    /// The controller-facing handle on the operating degrade level.
    pub fn degrade_control(&self) -> DegradeControl {
        DegradeControl { level: Arc::clone(&self.operating) }
    }

    /// A fresh pressure meter over this server's frontend counters
    /// (first `sample()` covers everything since server start).
    pub fn pressure_meter(&self) -> PressureMeter {
        PressureMeter {
            admission: Arc::clone(&self.admission),
            metrics: Arc::clone(&self.metrics),
            operating: Arc::clone(&self.operating),
            last: ServerStats::default(),
        }
    }

    /// A periodic [`PressureSample`] feed: a sampler thread meters the
    /// frontend every `interval` and sends the sample down the returned
    /// channel. The sampler exits when the receiver is dropped or the
    /// server shuts down (shutdown joins it, waiting at most one
    /// interval).
    pub fn pressure_feed(&self, interval: Duration) -> Receiver<PressureSample> {
        let (tx, rx) = channel();
        let mut meter = self.pressure_meter();
        let admission = Arc::clone(&self.admission);
        let handle = std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            if tx.send(meter.sample()).is_err() {
                return; // consumer gone
            }
            if admission.state.lock().unwrap().closed {
                return; // server shut down
            }
        });
        self.samplers.lock().unwrap().push(handle);
        rx
    }

    /// Submit one [`FftRequest`] through admission control. Returns the
    /// reply channel on admission, or a typed error when the request is
    /// shed (`Shed`/`Degrade` at the hard class limit), throttled by
    /// the tenancy layer ([`ServiceError::TenantThrottled`]), names an
    /// unknown class or tenant, or the server is shut down. Every
    /// admitted request is answered — with a [`ServedFft`] or a typed
    /// [`ServiceError`] — never silently dropped.
    ///
    /// With [`ServerConfig::tenants`] configured, a request naming a
    /// tenant passes that tenant's token bucket and job-unit quota
    /// *before* any class counter moves or queue slot is taken: a
    /// throttled request is invisible to class statistics and queue
    /// occupancy. The units charged are the request's own job cost at
    /// its submitted level (queue-driven degradation can only shrink
    /// the real cost, so the charge is conservative); they are billed
    /// on completion and refunded when the request is shed downstream,
    /// expires, or fails. One bucket token per request is consumed at
    /// admission and is *not* refunded on a downstream shed — rate is
    /// spent by asking.
    ///
    /// Admission measures class pressure in **single-pass job units**
    /// ([`multipass::job_cost`]): a request the backend must serve by
    /// four-step decomposition counts as its full `n1 + n2` sub-jobs
    /// against the class queue (a 2^20-point request weighs 2048, not
    /// 1), so the full-check, the `Degrade` ladder thresholds and
    /// `Block` backpressure all see the true backend work a queued
    /// large transform represents. A large request is always admissible
    /// when its class queue is empty — accounting adds pressure, never
    /// a permanent rejection.
    pub fn request(
        &self,
        req: FftRequest,
    ) -> std::result::Result<Receiver<ServerResult>, ServiceError> {
        let class = req.class;
        if class >= self.cfg.classes.len() {
            return Err(ServiceError::UnknownClass { class });
        }
        let now = Instant::now();
        let ceiling = req.pass_ceiling();
        // Tenancy runs ahead of everything else: a throttled request
        // never occupies a queue slot and never appears in the class /
        // server traffic counters (only in its tenant's own).
        let tenant = match (&self.tenants, req.tenant) {
            (Some(reg), Some(t)) => {
                let units = multipass::job_cost(req.effective_points(), ceiling);
                match reg.admit(t, units, now) {
                    Ok(()) => Some((t, units)),
                    Err(TenantDenial::Unknown) => {
                        return Err(ServiceError::UnknownTenant { tenant: t });
                    }
                    Err(TenantDenial::Throttled) => {
                        return Err(ServiceError::TenantThrottled { tenant: t });
                    }
                }
            }
            _ => None,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.class(class).submitted.fetch_add(1, Ordering::Relaxed);
        let deadline = req
            .deadline
            .or(self.cfg.classes[class].deadline_default)
            .or(self.cfg.default_deadline)
            .map(|d| now + d);
        let input = req.input;
        let workload = req.workload;
        // An admitted-by-tenancy request that still fails class
        // admission (shed, or server closed) refunds its quota units —
        // the bucket token stays spent (see the method docs).
        let refund = |e: ServiceError| {
            if let (Some(reg), Some((t, u))) = (&self.tenants, tenant) {
                reg.aborted(t, u);
            }
            e
        };
        let mut st = self.admission.state.lock().unwrap();
        let level = loop {
            if st.closed {
                return Err(refund(ServiceError::WorkerGone));
            }
            let depth = st.sched.depth(class);
            let cap = self.caps[class];
            // Queued backlog in single-pass job units; equals `depth`
            // when every queued request is single-pass.
            let backlog = st.cost[class];
            if depth < cap && (backlog < cap as u64 || depth == 0) {
                // Queue-driven ladder (Degrade policy only): Half at
                // half the class capacity, Quarter at three quarters —
                // coarser answers as this class's pressure builds, full
                // resolution when its queue is healthy. Pressure is the
                // job-unit backlog, so one queued multi-pass request
                // can push the ladder on its own.
                let queue_level = if self.cfg.policy == AdmissionPolicy::Degrade {
                    if backlog >= (3 * cap as u64) / 4 {
                        DegradeLevel::Quarter
                    } else if backlog >= cap as u64 / 2 {
                        DegradeLevel::Half
                    } else {
                        DegradeLevel::Full
                    }
                } else {
                    DegradeLevel::Full
                };
                let operating = DegradeLevel::from_u8(self.operating.load(Ordering::Relaxed));
                break self.ladder.clamp(queue_level.max(operating), input.len());
            }
            match self.cfg.policy {
                AdmissionPolicy::Block => st = self.admission.space.wait(st).unwrap(),
                AdmissionPolicy::Shed | AdmissionPolicy::Degrade => {
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    self.metrics.class(class).shed.fetch_add(1, Ordering::Relaxed);
                    return Err(refund(ServiceError::QueueFull { capacity: cap }));
                }
            }
        };
        let served_points = input.len() >> level.shift();
        let cost = multipass::job_cost(served_points, ceiling);
        let (reply, rx) = channel();
        let pending = Pending { input, workload, level, cost, tenant, reply };
        st.sched
            .try_enqueue(class, deadline, now, pending)
            .expect("capacity checked under the same lock");
        st.cost[class] += cost;
        let class_depth = st.sched.depth(class);
        let depth = st.sched.total_depth();
        drop(st);
        if let (Some(reg), Some((t, _))) = (&self.tenants, tenant) {
            // now actually queued: a priority tenant's waiting request
            // raises the cross-pass preemption signal
            reg.enqueued(t);
        }
        self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
        let cc = self.metrics.class(class);
        cc.admitted.fetch_add(1, Ordering::Relaxed);
        cc.max_queue_depth.fetch_max(class_depth, Ordering::Relaxed);
        self.admission.work.notify_one();
        Ok(rx)
    }

    /// Queued (admitted, not yet dispatched) requests right now, all
    /// classes.
    pub fn queue_depth(&self) -> usize {
        self.admission.state.lock().unwrap().sched.total_depth()
    }

    /// Execution-layer metrics with the frontend counters merged in
    /// ([`MetricsSnapshot::server`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self
            .inner
            .as_ref()
            .expect("inner service present until shutdown")
            .metrics();
        snap.server = self.metrics.snapshot();
        if let Some(reg) = &self.tenants {
            snap.tenants = reg.snapshot();
        }
        snap
    }

    /// The configuration the server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Per-class queue capacities, as configured on each
    /// [`QosClass`].
    pub fn class_capacities(&self) -> &[usize] {
        &self.caps
    }

    /// Close admission, drain every admitted request (each is served or
    /// answered with a typed error), join the dispatchers, then shut
    /// the inner execution service down.
    pub fn shutdown(mut self) {
        self.close_and_join();
        if let Some(inner) = self.inner.take() {
            match Arc::try_unwrap(inner) {
                Ok(handle) => handle.shutdown(),
                Err(_) => eprintln!(
                    "warning: TrafficServer::shutdown could not stop the inner \
                     service — a handle from TrafficServer::service() is still \
                     alive (stop the AutoscaleController first); backend worker \
                     threads stop when the last handle drops"
                ),
            }
        }
    }

    fn close_and_join(&mut self) {
        self.admission.state.lock().unwrap().closed = true;
        self.admission.work.notify_all();
        self.admission.space.notify_all();
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
        // Pressure-feed samplers notice the closed flag within one
        // interval (or exit early when their receiver is gone).
        for s in self.samplers.lock().unwrap().drain(..) {
            let _ = s.join();
        }
    }
}

impl Drop for TrafficServer {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn dispatcher_loop(
    admission: Arc<Admission>,
    metrics: Arc<ServerMetrics>,
    inner: Arc<ServiceHandle>,
    tenants: Option<Arc<TenantRegistry>>,
) {
    loop {
        let popped = {
            let mut st = admission.state.lock().unwrap();
            loop {
                if let Some(p) = st.sched.pop(Instant::now()) {
                    st.cost[p.item.class] -= p.item.payload.cost;
                    break Some(p);
                }
                if st.closed {
                    break None;
                }
                st = admission.work.wait(st).unwrap();
            }
        };
        let Some(popped) = popped else { return };
        // Per-class caps mean a freed slot only helps submitters of
        // this class; wake them all so the right one rechecks.
        admission.space.notify_all();
        let class = popped.item.class;
        let cc = metrics.class(class);
        if popped.aged {
            metrics.aged.fetch_add(1, Ordering::Relaxed);
            cc.aged.fetch_add(1, Ordering::Relaxed);
        }

        let queue_us = popped.item.enqueued.elapsed().as_secs_f64() * 1e6;
        metrics.queue_wait.record(queue_us);
        cc.queue_wait.record(queue_us);
        let deadline = popped.item.deadline;
        let req = popped.item.payload;
        if let (Some(reg), Some((t, _))) = (&tenants, req.tenant) {
            // left the queue: lowers the priority-waiting signal and
            // records this tenant's queue wait
            reg.dispatched(t, queue_us);
        }
        if let Some(d) = deadline {
            if Instant::now() > d {
                metrics.expired.fetch_add(1, Ordering::Relaxed);
                cc.expired.fetch_add(1, Ordering::Relaxed);
                if let (Some(reg), Some((t, u))) = (&tenants, req.tenant) {
                    reg.aborted(t, u);
                }
                let _ = req
                    .reply
                    .send(Err(ServiceError::DeadlineExceeded { waited_us: queue_us }));
                continue;
            }
        }
        match req.level {
            DegradeLevel::Full => {}
            DegradeLevel::Half => {
                metrics.degraded.fetch_add(1, Ordering::Relaxed);
                cc.degraded_half.fetch_add(1, Ordering::Relaxed);
            }
            DegradeLevel::Quarter => {
                metrics.degraded.fetch_add(1, Ordering::Relaxed);
                cc.degraded_quarter.fetch_add(1, Ordering::Relaxed);
            }
        }

        let t0 = Instant::now();
        let mut freq = FftRequest::with_input_slot(req.input)
            .with_workload(req.workload)
            .with_level(req.level);
        if let Some(d) = deadline {
            // Remaining budget rides the request so a decomposed large
            // transform can be preempted at its between-pass checkpoint
            // instead of burning backend time past the deadline.
            freq = freq.with_deadline(d.saturating_duration_since(t0));
        }
        if let (Some(reg), Some((t, _))) = (&tenants, req.tenant) {
            // A non-priority tenant's decomposed request carries the
            // preemption watch: it yields at the between-pass
            // checkpoint while a priority tenant's work is queued.
            if freq.needs_decomposition() && !reg.spec(t).is_some_and(|s| s.priority) {
                freq = freq.with_preempt_watch(reg.watch());
            }
        }
        let backend = inner.request(freq).recv();
        let service_us = t0.elapsed().as_secs_f64() * 1e6;
        metrics.service_time.record(service_us);

        let outcome = match backend {
            Err(_) => Err(ServiceError::WorkerGone),
            Ok(Err(e)) => Err(match e.downcast::<ServiceError>() {
                Ok(se) => se,
                Err(e) => ServiceError::Backend(format!("{e:#}")),
            }),
            Ok(Ok(r)) => Ok(r),
        };
        match outcome {
            Ok(result) => {
                let deadline_missed = deadline.is_some_and(|d| Instant::now() > d);
                if deadline_missed {
                    metrics.late.fetch_add(1, Ordering::Relaxed);
                    cc.late.fetch_add(1, Ordering::Relaxed);
                }
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                cc.completed.fetch_add(1, Ordering::Relaxed);
                if let (Some(reg), Some((t, u))) = (&tenants, req.tenant) {
                    reg.completed(t, u);
                }
                let _ = req.reply.send(Ok(ServedFft {
                    result,
                    class,
                    queue_us,
                    service_us,
                    level: req.level,
                    degraded: req.level != DegradeLevel::Full,
                    deadline_missed,
                }));
            }
            Err(e) => {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                cc.failed.fetch_add(1, Ordering::Relaxed);
                if let (Some(reg), Some((t, u))) = (&tenants, req.tenant) {
                    reg.aborted(t, u);
                }
                let _ = req.reply.send(Err(e));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;

    #[test]
    fn pressure_meter_reports_interval_deltas_and_level() {
        let classes = default_two_class();
        let caps = vec![64, 64];
        let m = Arc::new(ServerMetrics::new(&classes, &caps));
        let adm = Arc::new(Admission {
            state: Mutex::new(QueueState {
                sched: QosScheduler::new(classes, caps, Duration::from_millis(10)),
                cost: vec![0; 2],
                closed: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        });
        let operating = Arc::new(AtomicU8::new(DegradeLevel::Full.as_u8()));
        let mut meter = PressureMeter {
            admission: Arc::clone(&adm),
            metrics: Arc::clone(&m),
            operating: Arc::clone(&operating),
            last: ServerStats::default(),
        };
        m.submitted.fetch_add(10, Ordering::Relaxed);
        m.shed.fetch_add(5, Ordering::Relaxed);
        let s1 = meter.sample();
        assert_eq!(s1.submitted, 10);
        assert_eq!(s1.shed, 5);
        assert!((s1.shed_rate - 0.5).abs() < 1e-12);
        assert_eq!(s1.operating_level, DegradeLevel::Full);
        // no new traffic: the next interval is clean, not cumulative
        let s2 = meter.sample();
        assert_eq!(s2.submitted, 0);
        assert_eq!(s2.shed_rate, 0.0);
        operating.store(DegradeLevel::Half.as_u8(), Ordering::Relaxed);
        m.submitted.fetch_add(4, Ordering::Relaxed);
        let s3 = meter.sample();
        assert_eq!(s3.submitted, 4);
        assert_eq!(s3.shed, 0);
        assert_eq!(s3.queue_depth, 0);
        assert_eq!(s3.operating_level, DegradeLevel::Half);
    }

    #[test]
    fn per_class_snapshot_carries_meta_and_legacy_aggregates() {
        let classes = vec![QosClass::new("gold", 5), QosClass::new("bg", 0)];
        let m = ServerMetrics::new(&classes, &[8, 16]);
        m.class(0).completed.fetch_add(3, Ordering::Relaxed);
        m.class(1).completed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.per_class.len(), 2);
        assert_eq!(s.per_class[0].name, "gold");
        assert_eq!(s.per_class[0].weight, 5);
        assert_eq!(s.per_class[0].capacity, 8);
        assert_eq!(s.per_class[1].capacity, 16);
        assert_eq!(s.served_high, 3, "legacy aggregate = class 0");
        assert_eq!(s.served_low, 2, "legacy aggregate = the rest");
    }

    #[test]
    fn degrade_control_walks_the_ladder() {
        let ctl = DegradeControl { level: Arc::new(AtomicU8::new(0)) };
        assert_eq!(ctl.get(), DegradeLevel::Full);
        assert_eq!(ctl.deepen(DegradeLevel::Quarter), DegradeLevel::Half);
        assert_eq!(ctl.deepen(DegradeLevel::Quarter), DegradeLevel::Quarter);
        assert_eq!(ctl.deepen(DegradeLevel::Quarter), DegradeLevel::Quarter, "saturates");
        assert_eq!(ctl.restore(), DegradeLevel::Half);
        assert_eq!(ctl.restore(), DegradeLevel::Full);
        assert_eq!(ctl.restore(), DegradeLevel::Full, "saturates at Full");
        ctl.set(DegradeLevel::Full);
        assert_eq!(ctl.deepen(DegradeLevel::Half), DegradeLevel::Half);
        assert_eq!(ctl.deepen(DegradeLevel::Half), DegradeLevel::Half, "max clamps");
    }

    #[test]
    fn invalid_configs_rejected() {
        let pool = || {
            ServiceHandle::Pool(
                FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
            )
        };
        // a class configured with zero queue capacity is rejected
        assert!(TrafficServer::start(
            pool(),
            ServerConfig {
                classes: vec![QosClass::new("zero", 1).with_capacity(0)],
                ..Default::default()
            }
        )
        .is_err());
        assert!(TrafficServer::start(
            pool(),
            ServerConfig { dispatchers: 0, ..Default::default() }
        )
        .is_err());
        assert!(TrafficServer::start(
            pool(),
            ServerConfig { classes: Vec::new(), ..Default::default() }
        )
        .is_err());
        assert!(TrafficServer::start(
            pool(),
            ServerConfig {
                classes: vec![QosClass::new("a", 1), QosClass::new("a", 2)],
                ..Default::default()
            }
        )
        .is_err());
        // builder-default capacities need no explicit override
        assert!(TrafficServer::start(
            pool(),
            ServerConfig {
                classes: vec![QosClass::new("only", 1).with_capacity(4)],
                ..Default::default()
            }
        )
        .is_ok());
    }

    #[test]
    fn tenant_config_is_validated_and_optional() {
        let pool = || {
            ServiceHandle::Pool(
                FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
            )
        };
        // duplicate tenant names are rejected up front
        assert!(TrafficServer::start(
            pool(),
            ServerConfig {
                tenants: vec![TenantSpec::new("a", 10.0, 1), TenantSpec::new("a", 5.0, 1)],
                ..Default::default()
            }
        )
        .is_err());
        // no tenants configured: the layer is absent entirely
        let server = TrafficServer::start(pool(), ServerConfig::default()).unwrap();
        assert!(server.tenant_registry().is_none());
        assert!(server.metrics().tenants.is_empty());
        server.shutdown();
        // configured: the registry and its snapshot surface
        let server = TrafficServer::start(
            pool(),
            ServerConfig {
                tenants: vec![TenantSpec::new("solo", 100.0, 8)],
                ..Default::default()
            },
        )
        .unwrap();
        let reg = server.tenant_registry().expect("registry configured");
        assert_eq!(reg.index_of("solo"), Some(0));
        assert_eq!(server.metrics().tenants.len(), 1);
        server.shutdown();
    }

    #[test]
    fn unknown_class_is_a_typed_error() {
        let server = TrafficServer::start(
            ServiceHandle::Pool(
                FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
            ),
            ServerConfig::default(),
        )
        .unwrap();
        match server.request(FftRequest::new(vec![(0.0, 0.0); 256]).with_class(9)) {
            Err(ServiceError::UnknownClass { class }) => assert_eq!(class, 9),
            other => panic!("want UnknownClass, got {:?}", other.map(|_| ())),
        }
        assert_eq!(server.metrics().server.submitted, 0, "not counted as traffic");
        server.shutdown();
    }
}
