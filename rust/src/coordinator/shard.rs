//! Multi-core sharded FFT scheduler.
//!
//! The paper's companion work ("A Statically and Dynamically Scalable
//! Soft GPGPU") makes the case that the eGPU scales by *replication*:
//! many small, high-fmax SMs rather than one big one. The single-queue
//! [`super::FftService`] models one leader feeding a pool through a
//! shared (mutex-guarded) queue; at high core counts that queue — and
//! the cold executor maps behind it — become the bottleneck. This
//! module is the replicated deployment:
//!
//! * **one queue per shard** — each shard owns a private channel and a
//!   worker thread with one resident simulated SM, so dispatch never
//!   takes a shared lock;
//! * **size-affinity routing** — a given transform size always has the
//!   same *home* shard, keeping that shard's resident
//!   [`crate::sim::FftExecutor`] warm (twiddles stay uploaded, no
//!   executor churn);
//! * **work-stealing overflow** — when the home shard's queue depth
//!   (queued + in-flight) exceeds [`ShardPoolConfig::steal_threshold`],
//!   the job is redirected to the least-loaded shard instead, so a
//!   skewed size distribution still uses the whole pool;
//! * **batch chunking** — a coalesced same-size group from
//!   [`ShardedFftService::submit_batch`] larger than
//!   [`ShardPoolConfig::min_chunk`] is split into up to one chunk per
//!   shard, so a homogeneous batch parallelizes instead of serializing
//!   on its home shard;
//! * **one process-wide [`PlanCache`]** — every shard hands out `Arc`s
//!   from the same cache, so a program is generated once and executed
//!   everywhere (the cache counts lock contention so the sharing cost
//!   is observable).
//!
//! Shards run exactly the same serving code as the single-queue pool
//! (`handle_job` → `serve_one` / `serve_batch`), so sharded outputs are
//! bitwise identical to single-shard results — sharding changes
//! scheduling, never numerics (enforced by `rust/tests/shard.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::metrics::ShardStat;
use super::{
    coalesce_by_size, collect_batch_results, fail_job, handle_job, Backend, Core, FftResult, Job,
    JobKind, Metrics, MetricsSnapshot, ServiceConfig, ServiceError,
};
use crate::fft::cache::PlanCache;
use crate::runtime::{spawn_pjrt_server, PjrtHandle};

/// Configuration for the sharded scheduler.
#[derive(Clone, Debug)]
pub struct ShardPoolConfig {
    /// Number of shards (resident simulated SMs). `0` means one shard
    /// per available hardware thread.
    pub shards: usize,
    /// Queue depth (queued + in-flight jobs) beyond which the router
    /// overflows an affine job onto the least-loaded shard. `0` steals
    /// on any backlog (maximum balance); larger values trade balance
    /// for executor locality.
    pub steal_threshold: usize,
    /// Minimum same-size group length per chunk when a coalesced batch
    /// is split across shards.
    pub min_chunk: usize,
    /// Per-shard service settings. `cores` is ignored: each shard runs
    /// exactly one resident-SM worker.
    pub service: ServiceConfig,
}

impl Default for ShardPoolConfig {
    fn default() -> Self {
        ShardPoolConfig {
            shards: 0,
            steal_threshold: 2,
            min_chunk: 8,
            service: ServiceConfig::default(),
        }
    }
}

/// Per-shard scheduler counters (lock-free; read by `metrics()`).
#[derive(Default)]
struct ShardCounters {
    /// Jobs processed (successes and errors), counted at dequeue.
    handled: AtomicU64,
    /// Jobs served through coalesced batch chunks.
    batch_jobs: AtomicU64,
    /// Jobs that arrived via their size-affinity home route.
    affine: AtomicU64,
    /// Jobs that arrived via the work-stealing overflow route.
    stolen: AtomicU64,
    /// Queued + in-flight jobs right now.
    depth: AtomicUsize,
    /// Peak queue depth observed.
    max_depth: AtomicUsize,
    /// Time spent serving jobs, µs.
    busy_us: AtomicU64,
}

struct Shard {
    tx: Sender<Job>,
    counters: Arc<ShardCounters>,
}

/// The sharded service: N independent shards, each owning a resident
/// simulated eGPU SM, fed through per-shard queues by a size-affinity
/// router with work-stealing overflow. All shards share one
/// [`PlanCache`].
pub struct ShardedFftService {
    cfg: ShardPoolConfig,
    shards: Vec<Shard>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    plans: Arc<PlanCache>,
    steals: AtomicU64,
    next_id: AtomicU64,
    started: Instant,
}

impl ShardedFftService {
    pub fn start(cfg: ShardPoolConfig) -> Result<Self> {
        if !cfg.service.variant.is_valid() {
            return Err(anyhow!("invalid variant {}", cfg.service.variant));
        }
        let n = if cfg.shards == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            cfg.shards
        };
        let metrics = Arc::new(Metrics::default());
        let plans = Arc::new(PlanCache::new(cfg.service.plan_cache_capacity));
        let (engine, pjrt_join) = match cfg.service.backend {
            Backend::Pjrt | Backend::Validate => {
                let (handle, join) = spawn_pjrt_server(&cfg.service.artifacts_dir)?;
                (Some(handle), Some(join))
            }
            Backend::Simulator => (None, None),
        };
        let mut shards = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n + 1);
        for shard_id in 0..n {
            let (tx, rx) = channel::<Job>();
            let counters = Arc::new(ShardCounters::default());
            let scfg = cfg.service.clone();
            let metrics2 = Arc::clone(&metrics);
            let plans2 = Arc::clone(&plans);
            let engine2 = engine.clone();
            let counters2 = Arc::clone(&counters);
            workers.push(std::thread::spawn(move || {
                shard_loop(shard_id, scfg, rx, metrics2, engine2, plans2, counters2)
            }));
            shards.push(Shard { tx, counters });
        }
        if let Some(j) = pjrt_join {
            workers.push(j);
        }
        Ok(ShardedFftService {
            cfg,
            shards,
            workers,
            metrics,
            plans,
            steals: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// Number of shards actually running (after `shards: 0` resolves to
    /// the available hardware parallelism).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The home shard for a transform size: deterministic, so a size
    /// always finds its warm resident executor when the pool is not
    /// overloaded.
    fn affinity(&self, points: usize) -> usize {
        (points.trailing_zeros() as usize) % self.shards.len()
    }

    /// The shard with the fewest queued + in-flight jobs right now
    /// (first such shard on ties).
    fn least_loaded(&self) -> usize {
        self.shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.counters.depth.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .expect("at least one shard")
    }

    /// Pick the serving shard for a `points`-sized job: the affine home
    /// shard unless its queue depth (in jobs) exceeds the steal
    /// threshold, in which case the least-loaded shard takes the job.
    /// Returns `(shard, served by the affine route)`.
    fn route(&self, points: usize) -> (usize, bool) {
        let home = self.affinity(points);
        let depth = self.shards[home].counters.depth.load(Ordering::Relaxed);
        if depth <= self.cfg.steal_threshold {
            return (home, true);
        }
        let victim = self.least_loaded();
        (victim, victim == home)
    }

    /// Enqueue `job` (carrying `jobs` requests) on `shard`, maintaining
    /// the queue-depth gauge (in jobs, so a 16-job batch chunk weighs 16
    /// against the steal threshold) and the routing counters. If the
    /// shard's worker is gone, the job is answered with a typed
    /// [`ServiceError::WorkerGone`] instead of panicking.
    fn dispatch(&self, shard: usize, job: Job, affine: bool, jobs: u64) {
        let c = &self.shards[shard].counters;
        let depth = c.depth.fetch_add(jobs as usize, Ordering::Relaxed) + jobs as usize;
        c.max_depth.fetch_max(depth, Ordering::Relaxed);
        if affine {
            c.affine.fetch_add(jobs, Ordering::Relaxed);
        } else {
            c.stolen.fetch_add(jobs, Ordering::Relaxed);
            self.steals.fetch_add(jobs, Ordering::Relaxed);
        }
        if let Err(std::sync::mpsc::SendError(job)) = self.shards[shard].tx.send(job) {
            c.depth.fetch_sub(jobs as usize, Ordering::Relaxed);
            fail_job(job);
        }
    }

    /// Submit one FFT; the returned channel yields the result.
    pub fn submit(&self, input: Vec<(f32, f32)>) -> Receiver<Result<FftResult>> {
        let (reply_tx, reply_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (shard, affine) = self.route(input.len());
        let job = Job {
            kind: JobKind::Single { id, input, reply: reply_tx },
            submitted: Instant::now(),
        };
        self.dispatch(shard, job, affine, 1);
        reply_rx
    }

    /// Batched dispatch across the shard pool: coalesce `inputs` into
    /// per-size groups exactly as [`super::FftService::submit_batch`],
    /// then split each group into up to one chunk per shard (chunks of
    /// at least `min_chunk` jobs). The first chunk follows affinity
    /// routing; the rest go straight to the least-loaded shards, so a
    /// homogeneous batch parallelizes pool-wide at any steal threshold.
    /// Results come back in the original submission order and are
    /// bitwise identical to the single-shard path.
    pub fn submit_batch(&self, inputs: Vec<Vec<(f32, f32)>>) -> Result<Vec<FftResult>> {
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let ids: Vec<u64> =
            (0..n).map(|_| self.next_id.fetch_add(1, Ordering::Relaxed)).collect();
        let groups = coalesce_by_size(&inputs);
        let mut inputs: Vec<Option<Vec<(f32, f32)>>> = inputs.into_iter().map(Some).collect();
        let mut pending = Vec::new();
        for (points, idxs) in groups {
            let chunks = self.split_group(&idxs);
            let spread = chunks.len() > 1;
            for (ci, chunk) in chunks.into_iter().enumerate() {
                let batch_ids: Vec<u64> = chunk.iter().map(|&i| ids[i]).collect();
                let batch_inputs: Vec<Vec<(f32, f32)>> = chunk
                    .iter()
                    .map(|&i| inputs[i].take().expect("each input consumed once"))
                    .collect();
                let (reply_tx, reply_rx) = channel();
                let job = Job {
                    kind: JobKind::Batch { ids: batch_ids, inputs: batch_inputs, reply: reply_tx },
                    submitted: Instant::now(),
                };
                // The first chunk follows normal affinity routing; the
                // rest of a split group go straight to the least-loaded
                // shards — spreading must not depend on the steal
                // threshold, or a locality-biased threshold would
                // serialize the whole batch on its home shard.
                let (shard, affine) = if spread && ci > 0 {
                    let victim = self.least_loaded();
                    (victim, victim == self.affinity(points))
                } else {
                    self.route(points)
                };
                self.dispatch(shard, job, affine, chunk.len() as u64);
                pending.push((chunk, reply_rx));
            }
        }
        collect_batch_results(n, pending)
    }

    /// Split one same-size group into at most one chunk per shard, each
    /// of at least `min_chunk` jobs, so a large homogeneous batch runs
    /// pool-wide instead of serializing on its home shard.
    fn split_group(&self, idxs: &[usize]) -> Vec<Vec<usize>> {
        let chunks = (idxs.len() / self.cfg.min_chunk.max(1)).clamp(1, self.shards.len());
        let per = idxs.len().div_ceil(chunks);
        idxs.chunks(per).map(|c| c.to_vec()).collect()
    }

    /// Submit every input individually and wait for all results in
    /// submission order.
    pub fn run_batch(&self, inputs: Vec<Vec<(f32, f32)>>) -> Result<Vec<FftResult>> {
        let handles: Vec<_> = inputs.into_iter().map(|i| self.submit(i)).collect();
        handles
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))?)
            .collect()
    }

    /// Service metrics including per-shard scheduler counters, steal
    /// totals, aggregate throughput and shared plan-cache stats.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plan_cache = self.plans.stats();
        snap.steals = self.steals.load(Ordering::Relaxed);
        let elapsed_us = (self.started.elapsed().as_micros() as u64).max(1);
        snap.agg_jobs_per_s = snap.served as f64 / (elapsed_us as f64 / 1e6);
        snap.shards = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let c = &s.counters;
                let busy_us = c.busy_us.load(Ordering::Relaxed);
                ShardStat {
                    shard: i,
                    handled: c.handled.load(Ordering::Relaxed),
                    batch_jobs: c.batch_jobs.load(Ordering::Relaxed),
                    affine: c.affine.load(Ordering::Relaxed),
                    stolen: c.stolen.load(Ordering::Relaxed),
                    queue_depth: c.depth.load(Ordering::Relaxed),
                    max_queue_depth: c.max_depth.load(Ordering::Relaxed),
                    busy_us,
                    occupancy: (busy_us as f64 / elapsed_us as f64).min(1.0),
                }
            })
            .collect();
        snap
    }

    /// The process-wide plan cache shared by every shard.
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    pub fn config(&self) -> &ShardPoolConfig {
        &self.cfg
    }

    /// Drain and stop all shard workers.
    pub fn shutdown(mut self) {
        self.shards.clear(); // drops every sender -> queues close
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ShardedFftService {
    fn drop(&mut self) {
        self.shards.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard's worker: a private queue feeding one resident simulated
/// SM, serving jobs with exactly the same code as the single-queue
/// pool. The depth gauge counts a job until it is *served* (not merely
/// dequeued), so the router sees in-flight work as load.
fn shard_loop(
    shard_id: usize,
    cfg: ServiceConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    engine: Option<PjrtHandle>,
    plans: Arc<PlanCache>,
    counters: Arc<ShardCounters>,
) {
    let mut core = Core { id: shard_id, cfg, plans, execs: HashMap::new(), tick: 0 };
    while let Ok(job) = rx.recv() {
        let (jobs, is_batch) = match &job.kind {
            JobKind::Single { .. } => (1u64, false),
            JobKind::Batch { ids, .. } => (ids.len() as u64, true),
        };
        // Count the job *before* serving: replies are sent inside
        // `handle_job`, so a snapshot taken after a caller's `recv`
        // returns must never be behind on these counters.
        counters.handled.fetch_add(jobs, Ordering::Relaxed);
        if is_batch {
            counters.batch_jobs.fetch_add(jobs, Ordering::Relaxed);
        }
        let t0 = Instant::now();
        handle_job(&mut core, &engine, &metrics, job);
        counters
            .busy_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        counters.depth.fetch_sub(jobs as usize, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{self, reference};

    fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
        reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
    }

    fn pool(shards: usize, steal_threshold: usize) -> ShardedFftService {
        ShardedFftService::start(ShardPoolConfig {
            shards,
            steal_threshold,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn sharded_service_end_to_end() {
        let svc = pool(2, 2);
        let results = svc.run_batch((0..8).map(|i| signal(256, i)).collect()).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            let want = reference::fft(&reference::test_signal(256, i as u64));
            let got: Vec<_> = r
                .output
                .iter()
                .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
                .collect();
            assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
        }
        let m = svc.metrics();
        assert_eq!(m.served, 8);
        assert_eq!(m.errors, 0);
        assert_eq!(m.shards.len(), 2);
        assert_eq!(m.shards.iter().map(|s| s.handled).sum::<u64>(), 8);
        assert!(m.agg_jobs_per_s > 0.0);
        svc.shutdown();
    }

    #[test]
    fn auto_shard_count_uses_available_parallelism() {
        let svc = pool(0, 2);
        assert!(svc.shards() >= 1);
        let r = svc.submit(signal(256, 1)).recv().unwrap().unwrap();
        assert_eq!(r.output.len(), 256);
        svc.shutdown();
    }

    #[test]
    fn split_group_respects_min_chunk_and_shard_count() {
        let svc = ShardedFftService::start(ShardPoolConfig {
            shards: 4,
            min_chunk: 8,
            ..Default::default()
        })
        .unwrap();
        let idxs: Vec<usize> = (0..64).collect();
        let chunks = svc.split_group(&idxs);
        assert_eq!(chunks.len(), 4, "64 jobs / min_chunk 8 caps at 4 shards");
        assert!(chunks.iter().all(|c| c.len() == 16));
        let small: Vec<usize> = (0..5).collect();
        assert_eq!(svc.split_group(&small).len(), 1, "below min_chunk stays whole");
        let rejoined: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(rejoined, idxs, "chunking preserves order");
        svc.shutdown();
    }

    #[test]
    fn bad_size_errors_without_killing_shards() {
        let svc = pool(2, 2);
        let bad = svc.submit(signal(100, 0)).recv().unwrap();
        assert!(bad.is_err());
        let ok = svc.submit(signal(256, 1)).recv().unwrap();
        assert!(ok.is_ok());
        assert_eq!(svc.metrics().errors, 1);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let svc = pool(2, 2);
        assert!(svc.submit_batch(Vec::new()).unwrap().is_empty());
        assert_eq!(svc.metrics().served, 0);
        svc.shutdown();
    }

    #[test]
    fn invalid_variant_rejected() {
        let bad = crate::arch::Variant { mem: crate::arch::MemPorts::Qp, vm: true, complex: false };
        let err = ShardedFftService::start(ShardPoolConfig {
            shards: 1,
            service: ServiceConfig { variant: bad, ..Default::default() },
            ..Default::default()
        });
        assert!(err.is_err());
    }
}
