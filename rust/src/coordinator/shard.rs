//! Multi-core sharded FFT scheduler with a dynamically sizable pool.
//!
//! The paper's companion work ("A Statically and Dynamically Scalable
//! Soft GPGPU") makes the case that the eGPU scales by *replication*:
//! many small, high-fmax SMs rather than one big one — and that the
//! replica count itself should track demand. The single-queue
//! [`super::FftService`] models one leader feeding a pool through a
//! shared (mutex-guarded) queue; at high core counts that queue — and
//! the cold executor maps behind it — become the bottleneck. This
//! module is the replicated deployment:
//!
//! * **one queue per shard** — each shard owns a private bounded SPSC
//!   ring ([`super::buffer::JobRing`]: one producer, the dispatcher;
//!   one consumer, the shard worker — no per-send heap node, unlike an
//!   `mpsc` channel) and a worker thread with one resident simulated
//!   SM, so dispatch never takes a shared lock on the hot path (routing
//!   takes a read lock on the epoch-versioned table, which is
//!   uncontended unless the pool is resizing). The drain-on-retire
//!   path keeps its `mpsc` channel: it runs once per retirement, off
//!   the hot path;
//! * **size-affinity routing** — a given transform size always has the
//!   same *home* shard within a routing epoch, keeping that shard's
//!   resident [`crate::sim::FftExecutor`] warm (twiddles stay uploaded,
//!   no executor churn);
//! * **work-stealing overflow** — when the home shard's queue depth
//!   (queued + in-flight) exceeds [`ShardPoolConfig::steal_threshold`],
//!   the job is redirected to the least-loaded shard instead, so a
//!   skewed size distribution still uses the whole pool;
//! * **batch chunking** — a coalesced same-size group from
//!   [`ShardedFftService::request_all`] larger than
//!   [`ShardPoolConfig::min_chunk`] is split into up to one chunk per
//!   shard, so a homogeneous batch parallelizes instead of serializing
//!   on its home shard. Multi-pass large-N requests ride this same
//!   path: each four-step stage arrives as one same-size group, so a
//!   single 2^20-point transform pipelines across the whole pool;
//! * **one process-wide [`PlanCache`]** — every shard hands out `Arc`s
//!   from the same cache, so a program is generated once and executed
//!   everywhere (the cache counts lock contention so the sharing cost
//!   is observable).
//!
//! **Elasticity.** The pool is resizable while serving:
//! [`ShardedFftService::add_shard`] spawns a new shard and
//! [`ShardedFftService::retire_shard`] removes one — the retiring
//! worker finishes its in-flight job, hands every still-queued job back
//! through a drain channel, and `retire_shard` re-routes each through
//! the current affinity map before the worker exits, so no admitted job
//! is ever lost. The routing table is *epoch-versioned*: every resize
//! bumps [`ShardedFftService::epoch`], and each routing decision is
//! made and dispatched under one read lock, so a job is never routed
//! with one epoch's affinity map and enqueued under another. Shard ids
//! are stable (assigned once, never reused) and a retired shard's final
//! counters stay in [`MetricsSnapshot::shards`] flagged
//! [`ShardStat::retired`], so snapshots across resizes keep complete
//! aggregate accounting. The `coordinator::autoscale` controller drives
//! these two calls from the traffic frontend's pressure feed.
//!
//! Shards run exactly the same serving code as the single-queue pool
//! (`handle_job` → `serve_one` / `serve_batch`), so sharded outputs are
//! bitwise identical to single-shard results — sharding *and resizing*
//! change scheduling, never numerics (enforced by `rust/tests/shard.rs`
//! and `rust/tests/autoscale.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::buffer::{JobRing, JobSlot};
use super::metrics::ShardStat;
use super::request::{self, FftCompute, FftRequest};
use super::{
    coalesce_by_size, collect_batch_results, fail_job, handle_job, Backend, Core, FftResult, Job,
    JobKind, Metrics, MetricsSnapshot, ServiceConfig, ServiceError, Workload,
};
use crate::fft::cache::PlanCache;
use crate::runtime::{spawn_pjrt_server, PjrtHandle};

/// Configuration for the sharded scheduler.
#[derive(Clone, Debug)]
pub struct ShardPoolConfig {
    /// Number of shards (resident simulated SMs) at startup. `0` means
    /// one shard per available hardware thread. The pool can be resized
    /// afterwards with `add_shard` / `retire_shard`.
    pub shards: usize,
    /// Queue depth (queued + in-flight jobs) beyond which the router
    /// overflows an affine job onto the least-loaded shard. `0` steals
    /// on any backlog (maximum balance); larger values trade balance
    /// for executor locality.
    pub steal_threshold: usize,
    /// Minimum same-size group length per chunk when a coalesced batch
    /// is split across shards.
    pub min_chunk: usize,
    /// Capacity of each shard's bounded SPSC job ring (in jobs — a
    /// batch chunk counts as one). A dispatcher hitting a full ring
    /// blocks until the worker pops, which is backpressure, not loss;
    /// the frontend's admission queues bound how much can ever pile up
    /// here.
    pub ring_capacity: usize,
    /// Per-shard service settings. `cores` is ignored: each shard runs
    /// exactly one resident-SM worker.
    pub service: ServiceConfig,
}

impl Default for ShardPoolConfig {
    fn default() -> Self {
        ShardPoolConfig {
            shards: 0,
            steal_threshold: 2,
            min_chunk: 8,
            ring_capacity: 1024,
            service: ServiceConfig::default(),
        }
    }
}

/// Per-shard scheduler counters (lock-free; read by `metrics()`).
#[derive(Default)]
struct ShardCounters {
    /// Jobs processed (successes and errors), counted at dequeue.
    handled: AtomicU64,
    /// Jobs served through coalesced batch chunks.
    batch_jobs: AtomicU64,
    /// Jobs that arrived via their size-affinity home route.
    affine: AtomicU64,
    /// Jobs that arrived via the work-stealing overflow route.
    stolen: AtomicU64,
    /// Queued + in-flight jobs right now.
    depth: AtomicUsize,
    /// Peak queue depth observed.
    max_depth: AtomicUsize,
    /// Time spent serving jobs, µs.
    busy_us: AtomicU64,
}

/// One live shard: a stable id (assigned once, never reused), its
/// queue, its counters, the retirement flag its worker polls, and the
/// drain channel queued jobs come back through at retirement.
struct ShardSlot {
    id: usize,
    ring: Arc<JobRing<Job>>,
    counters: Arc<ShardCounters>,
    retiring: Arc<AtomicBool>,
    /// Receiver for jobs the worker hands back during retirement. The
    /// Mutex exists only to keep `RoutingState: Sync`; it is locked
    /// exactly once, by `retire_shard`, after the slot leaves the
    /// table.
    drain: Mutex<Receiver<Job>>,
    worker: Option<JoinHandle<()>>,
}

/// The epoch-versioned routing table. A routing decision (affinity /
/// least-loaded / steal) is only meaningful against one consistent view
/// of the pool, so decisions and the dispatch they produce happen under
/// a single read lock; every resize takes the write lock and bumps
/// `epoch`.
struct RoutingState {
    slots: Vec<ShardSlot>,
    epoch: u64,
}

impl RoutingState {
    /// The home shard *position* for a transform size: deterministic
    /// within an epoch, so a size always finds its warm resident
    /// executor when the pool is not overloaded.
    fn affinity(&self, points: usize) -> usize {
        (points.trailing_zeros() as usize) % self.slots.len()
    }

    /// The position of the shard with the fewest queued + in-flight
    /// jobs right now (first such shard on ties).
    fn least_loaded(&self) -> usize {
        self.slots
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.counters.depth.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .expect("at least one shard")
    }

    /// Pick the serving shard for a `points`-sized job: the affine home
    /// shard unless its queue depth (in jobs) exceeds the steal
    /// threshold, in which case the least-loaded shard takes the job.
    /// Returns `(position, served by the affine route)`.
    fn route(&self, steal_threshold: usize, points: usize) -> (usize, bool) {
        let home = self.affinity(points);
        let depth = self.slots[home].counters.depth.load(Ordering::Relaxed);
        if depth <= steal_threshold {
            return (home, true);
        }
        let victim = self.least_loaded();
        (victim, victim == home)
    }
}

/// Everything one shard worker owns (bundled so `shard_loop` stays a
/// single-argument function).
struct ShardWorker {
    id: usize,
    cfg: ServiceConfig,
    ring: Arc<JobRing<Job>>,
    metrics: Arc<Metrics>,
    engine: Option<PjrtHandle>,
    plans: Arc<PlanCache>,
    counters: Arc<ShardCounters>,
    retiring: Arc<AtomicBool>,
    drain: Sender<Job>,
}

/// The sharded service: N independent shards, each owning a resident
/// simulated eGPU SM, fed through per-shard queues by a size-affinity
/// router with work-stealing overflow. All shards share one
/// [`PlanCache`]. The pool is elastic: see [`Self::add_shard`] and
/// [`Self::retire_shard`].
pub struct ShardedFftService {
    cfg: ShardPoolConfig,
    routing: RwLock<RoutingState>,
    /// Shards mid-retirement: popped from the routing table but not yet
    /// frozen into `retired`. Snapshots read these live counters so a
    /// retiring shard's history never vanishes from aggregate
    /// accounting, even for the duration of its drain.
    draining: Mutex<Vec<(usize, Arc<ShardCounters>)>>,
    /// Final counters of retired shards, merged into every snapshot
    /// (individually up to [`RETIRED_STATS_CAP`], folded into one
    /// cumulative entry beyond that).
    retired: Mutex<Vec<ShardStat>>,
    pjrt_workers: Vec<JoinHandle<()>>,
    engine: Option<PjrtHandle>,
    metrics: Arc<Metrics>,
    plans: Arc<PlanCache>,
    steals: AtomicU64,
    next_id: AtomicU64,
    next_shard_id: AtomicUsize,
    /// Admission gate for pipelined multi-pass requests (see
    /// [`super::ServiceConfig::max_inflight_multipass`]).
    mp_gate: request::MultipassGate,
    /// Multi-pass orchestration counters, merged into every snapshot.
    mp_stats: request::MultipassStats,
    started: Instant,
}

impl ShardedFftService {
    /// Spawn the shard pool: `cfg.shards` worker shards (0 = one per
    /// hardware thread), a shared plan cache, and — for the PJRT
    /// backends — the runtime server thread.
    pub fn start(cfg: ShardPoolConfig) -> Result<Self> {
        if !cfg.service.variant.is_valid() {
            return Err(anyhow!("invalid variant {}", cfg.service.variant));
        }
        let n = if cfg.shards == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            cfg.shards
        };
        let metrics = Arc::new(Metrics::default());
        let plans = Arc::new(PlanCache::new(cfg.service.plan_cache_capacity));
        let (engine, pjrt_join) = match cfg.service.backend {
            Backend::Pjrt | Backend::Validate => {
                let (handle, join) = spawn_pjrt_server(&cfg.service.artifacts_dir)?;
                (Some(handle), Some(join))
            }
            Backend::Simulator | Backend::Noop => (None, None),
        };
        let mp_gate = request::MultipassGate::new(cfg.service.max_inflight_multipass);
        let svc = ShardedFftService {
            cfg,
            routing: RwLock::new(RoutingState { slots: Vec::with_capacity(n), epoch: 0 }),
            draining: Mutex::new(Vec::new()),
            retired: Mutex::new(Vec::new()),
            pjrt_workers: pjrt_join.into_iter().collect(),
            engine,
            metrics,
            plans,
            steals: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            next_shard_id: AtomicUsize::new(0),
            mp_gate,
            mp_stats: request::MultipassStats::default(),
            started: Instant::now(),
        };
        {
            let mut rt = svc.routing.write().unwrap();
            for _ in 0..n {
                let slot = svc.spawn_slot();
                rt.slots.push(slot);
            }
        }
        Ok(svc)
    }

    /// Spawn one shard worker with a fresh stable id. The caller
    /// decides when (and under which epoch) the slot joins the table.
    fn spawn_slot(&self) -> ShardSlot {
        let id = self.next_shard_id.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(JobRing::new(self.cfg.ring_capacity));
        let (drain_tx, drain_rx) = channel::<Job>();
        let counters = Arc::new(ShardCounters::default());
        let retiring = Arc::new(AtomicBool::new(false));
        let worker = ShardWorker {
            id,
            cfg: self.cfg.service.clone(),
            ring: Arc::clone(&ring),
            metrics: Arc::clone(&self.metrics),
            engine: self.engine.clone(),
            plans: Arc::clone(&self.plans),
            counters: Arc::clone(&counters),
            retiring: Arc::clone(&retiring),
            drain: drain_tx,
        };
        let handle = std::thread::spawn(move || shard_loop(worker));
        ShardSlot {
            id,
            ring,
            counters,
            retiring,
            drain: Mutex::new(drain_rx),
            worker: Some(handle),
        }
    }

    /// Number of shards actually running (after `shards: 0` resolves to
    /// the available hardware parallelism, and after any resizes).
    pub fn shards(&self) -> usize {
        self.routing.read().unwrap().slots.len()
    }

    /// The routing-table epoch: bumped by every `add_shard` /
    /// `retire_shard` (and by shutdown). Routing decisions are made and
    /// dispatched under one read lock, so every job is routed and
    /// enqueued within a single epoch.
    pub fn epoch(&self) -> u64 {
        self.routing.read().unwrap().epoch
    }

    /// Grow the pool by one shard; returns its stable id. The new shard
    /// joins the affinity map at the next epoch, so in-flight routing
    /// decisions are unaffected.
    pub fn add_shard(&self) -> usize {
        let slot = self.spawn_slot();
        let id = slot.id;
        let mut rt = self.routing.write().unwrap();
        rt.slots.push(slot);
        rt.epoch += 1;
        id
    }

    /// Shrink the pool by one shard (the most recently added position);
    /// returns the retired shard's stable id, or an error when only one
    /// shard remains.
    ///
    /// Retirement never loses an admitted job: the slot leaves the
    /// routing table first (so no new work can reach it), the retiring
    /// worker finishes its in-flight job and hands every still-queued
    /// job back through its drain channel, and each handed-back job is
    /// re-routed through the current (post-resize) affinity map before
    /// this call returns. Outputs stay bitwise identical to a
    /// fixed-size run — resizing changes scheduling, never numerics.
    ///
    /// Accounting note: the retired shard keeps the `affine` / `stolen`
    /// attribution of jobs it never served; a re-routed job is counted
    /// again at its new home, so routing counters summed across all
    /// shards may exceed `handled` totals after a retirement.
    pub fn retire_shard(&self) -> Result<usize> {
        let slot = {
            let mut rt = self.routing.write().unwrap();
            if rt.slots.len() <= 1 {
                return Err(anyhow!("cannot retire the last shard"));
            }
            let slot = rt.slots.pop().expect("len checked above");
            slot.retiring.store(true, Ordering::Release);
            rt.epoch += 1;
            // Registered before the routing lock drops, so there is no
            // instant at which this shard's counters are in neither the
            // active table nor the draining list — snapshots taken
            // mid-retirement stay complete.
            self.draining.lock().unwrap().push((slot.id, Arc::clone(&slot.counters)));
            slot
        };
        let ShardSlot { id, ring, counters, drain, worker, .. } = slot;
        // Closing the ring wakes the worker; with the retiring flag
        // set it hands queued jobs back instead of serving them.
        ring.close();
        let drain = drain.into_inner().unwrap();
        while let Ok(job) = drain.recv() {
            let weight = job.weight();
            counters.depth.fetch_sub(weight as usize, Ordering::Relaxed);
            let points = job.points();
            let rt = self.routing.read().unwrap();
            if rt.slots.is_empty() {
                // Only reachable if shutdown raced this retirement.
                drop(rt);
                fail_job(job);
                continue;
            }
            let (pos, affine) = rt.route(self.cfg.steal_threshold, points);
            self.dispatch_in(&rt, pos, job, affine, weight);
        }
        if let Some(h) = worker {
            let _ = h.join();
        }
        let elapsed_us = (self.started.elapsed().as_micros() as u64).max(1);
        // Move from draining to retired under the draining lock, so a
        // concurrent snapshot (which takes draining before retired, in
        // this same order) sees the shard in exactly one of the two.
        let mut draining = self.draining.lock().unwrap();
        draining.retain(|(slot_id, _)| *slot_id != id);
        let mut retired = self.retired.lock().unwrap();
        retired.push(stat_of(id, &counters, elapsed_us, true));
        fold_retired(&mut retired);
        Ok(id)
    }

    /// Enqueue `job` (carrying `jobs` requests) on the slot at `pos` —
    /// a position in `rt.slots`, valid for the epoch the caller's read
    /// lock pins — maintaining the queue-depth gauge (in jobs, so a
    /// 16-job batch chunk weighs 16 against the steal threshold) and
    /// the routing counters. If the shard's worker is gone, the job is
    /// answered with a typed [`ServiceError::WorkerGone`] instead of
    /// panicking.
    fn dispatch_in(&self, rt: &RoutingState, pos: usize, job: Job, affine: bool, jobs: u64) {
        let c = &rt.slots[pos].counters;
        let depth = c.depth.fetch_add(jobs as usize, Ordering::Relaxed) + jobs as usize;
        c.max_depth.fetch_max(depth, Ordering::Relaxed);
        if affine {
            c.affine.fetch_add(jobs, Ordering::Relaxed);
        } else {
            c.stolen.fetch_add(jobs, Ordering::Relaxed);
            self.steals.fetch_add(jobs, Ordering::Relaxed);
        }
        // A full ring blocks here (backpressure); `Err` means the ring
        // was closed under us — the worker is gone, fail the job typed.
        if let Err(job) = rt.slots[pos].ring.push(job) {
            c.depth.fetch_sub(jobs as usize, Ordering::Relaxed);
            fail_job(job);
        }
    }

    /// Submit one [`FftRequest`]; the returned channel yields the
    /// result. The QoS degrade level is threaded through dispatch:
    /// affinity routing, queue weights and the serving shard's resident
    /// executor all see the truncated (served) size, so a degraded
    /// request lands on the home shard of the size it actually runs at.
    ///
    /// A request whose effective (post-degrade) size exceeds its pass
    /// ceiling is served by four-step decomposition (see
    /// [`FftCompute::request`]): each stage becomes a coalesced batch
    /// that [`ShardedFftService::request_all`] chunks across the pool,
    /// so one large transform pipelines over every shard. The
    /// orchestration runs on the calling thread and the channel is
    /// already resolved when this returns.
    pub fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        if req.needs_decomposition() {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            return request::serve_staged(self, &self.plans, &self.mp_stats, &self.mp_gate, id, req);
        }
        self.enqueue(req.input, req.level, req.workload)
    }

    /// Submit a set of requests and wait for every result, in
    /// submission order. Same-size Full-level requests within the pass
    /// ceiling coalesce into per-size batch chunks spread across the
    /// pool (see the chunking notes on `enqueue_batch`); degraded or
    /// above-ceiling requests are served individually. Output bits are
    /// identical to sequential [`ShardedFftService::request`] calls.
    pub fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        request::serve_request_all(
            self,
            |inputs, workload| self.enqueue_batch(inputs, workload),
            |input, level, workload| self.enqueue(input, level, workload),
            reqs,
        )
    }

    /// Route and queue one single job at `level` (the unified
    /// [`ShardedFftService::request`] fronts it).
    fn enqueue(
        &self,
        input: JobSlot,
        level: super::qos::DegradeLevel,
        workload: Workload,
    ) -> Receiver<Result<FftResult>> {
        let (reply_tx, reply_rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            kind: JobKind::Single { id, input, reply: reply_tx },
            submitted: Instant::now(),
            level,
            workload,
        };
        let points = job.points();
        let rt = self.routing.read().unwrap();
        if rt.slots.is_empty() {
            drop(rt);
            fail_job(job);
            return reply_rx;
        }
        let (pos, affine) = rt.route(self.cfg.steal_threshold, points);
        self.dispatch_in(&rt, pos, job, affine, 1);
        reply_rx
    }

    /// Batched dispatch across the shard pool
    /// ([`ShardedFftService::request_all`] fronts it):
    /// coalesce `inputs` into per-size groups exactly as the
    /// single-queue pool, then split each group into up to one chunk
    /// per shard (chunks of at least `min_chunk` jobs). The first chunk
    /// follows affinity routing; the rest go straight to the
    /// least-loaded shards, so a homogeneous batch parallelizes
    /// pool-wide at any steal threshold. The whole batch is routed
    /// under one read lock — one epoch — so a concurrent resize cannot
    /// split its view of the pool. Results come back in the original
    /// submission order and are bitwise identical to the single-shard
    /// path. This is also what gives one decomposed large transform its
    /// cross-shard pipeline: every multi-pass stage arrives here as one
    /// same-size group and fans out over the pool.
    fn enqueue_batch(&self, inputs: Vec<JobSlot>, workload: Workload) -> Result<Vec<FftResult>> {
        let n = inputs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let ids: Vec<u64> =
            (0..n).map(|_| self.next_id.fetch_add(1, Ordering::Relaxed)).collect();
        let groups = coalesce_by_size(&inputs);
        let mut inputs: Vec<Option<JobSlot>> = inputs.into_iter().map(Some).collect();
        let mut pending = Vec::new();
        {
            let rt = self.routing.read().unwrap();
            if rt.slots.is_empty() {
                return Err(ServiceError::WorkerGone.into());
            }
            for (points, idxs) in groups {
                let chunks = split_group(&idxs, self.cfg.min_chunk, rt.slots.len());
                let spread = chunks.len() > 1;
                for (ci, chunk) in chunks.into_iter().enumerate() {
                    let batch_ids: Vec<u64> = chunk.iter().map(|&i| ids[i]).collect();
                    let batch_inputs: Vec<JobSlot> = chunk
                        .iter()
                        .map(|&i| inputs[i].take().expect("each input consumed once"))
                        .collect();
                    let (reply_tx, reply_rx) = channel();
                    let job = Job {
                        kind: JobKind::Batch {
                            ids: batch_ids,
                            inputs: batch_inputs,
                            reply: reply_tx,
                        },
                        submitted: Instant::now(),
                        level: super::qos::DegradeLevel::Full,
                        workload,
                    };
                    // The first chunk follows normal affinity routing;
                    // the rest of a split group go straight to the
                    // least-loaded shards — spreading must not depend
                    // on the steal threshold, or a locality-biased
                    // threshold would serialize the whole batch on its
                    // home shard.
                    let (pos, affine) = if spread && ci > 0 {
                        let victim = rt.least_loaded();
                        (victim, victim == rt.affinity(points))
                    } else {
                        rt.route(self.cfg.steal_threshold, points)
                    };
                    self.dispatch_in(&rt, pos, job, affine, chunk.len() as u64);
                    pending.push((chunk, reply_rx));
                }
            }
        }
        collect_batch_results(n, pending)
    }

    /// Submit every input individually and wait for all results in
    /// submission order.
    pub fn run_batch(&self, inputs: Vec<Vec<(f32, f32)>>) -> Result<Vec<FftResult>> {
        let handles: Vec<_> =
            inputs.into_iter().map(|i| self.request(FftRequest::new(i))).collect();
        handles
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::Error::new(ServiceError::WorkerGone))?)
            .collect()
    }

    /// Service metrics including per-shard scheduler counters (active
    /// shards first, then retired shards with frozen final counters —
    /// all keyed by stable id), steal totals, aggregate throughput and
    /// shared plan-cache stats.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plan_cache = self.plans.stats();
        snap.multipass = self.mp_stats.snapshot();
        snap.steals = self.steals.load(Ordering::Relaxed);
        let elapsed_us = (self.started.elapsed().as_micros() as u64).max(1);
        snap.agg_jobs_per_s = snap.served as f64 / (elapsed_us as f64 / 1e6);
        // Lock order matches retire_shard (routing → draining →
        // retired), and the routing read lock is held until the
        // draining list has been captured: a retirement cannot move a
        // shard from the active table to `draining` mid-snapshot, so
        // every shard appears exactly once — active, draining, or
        // retired.
        let rt = self.routing.read().unwrap();
        snap.shards = rt
            .slots
            .iter()
            .map(|s| stat_of(s.id, &s.counters, elapsed_us, false))
            .collect();
        let draining = self.draining.lock().unwrap();
        snap.shards
            .extend(draining.iter().map(|(id, c)| stat_of(*id, c, elapsed_us, true)));
        snap.shards.extend(self.retired.lock().unwrap().iter().cloned());
        drop(draining);
        drop(rt);
        snap
    }

    /// The process-wide plan cache shared by every shard.
    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// The configuration the pool was started with.
    pub fn config(&self) -> &ShardPoolConfig {
        &self.cfg
    }

    /// Close every shard's ring and join the workers (each one serves
    /// its remaining queue before exiting), then join the PJRT server
    /// if one is running.
    fn stop_all(&mut self) {
        let slots = {
            let mut rt = self.routing.write().unwrap();
            rt.epoch += 1;
            std::mem::take(&mut rt.slots)
        };
        let mut handles = Vec::with_capacity(slots.len());
        for slot in slots {
            slot.ring.close(); // remaining jobs drain before the worker exits
            if let Some(h) = slot.worker {
                handles.push(h);
            }
        }
        for h in handles {
            let _ = h.join();
        }
        // The PJRT server thread exits when the last PjrtHandle drops;
        // the workers just released theirs, so the service's own clone
        // (kept for add_shard) must go before the join or it blocks
        // forever.
        self.engine = None;
        for h in std::mem::take(&mut self.pjrt_workers) {
            let _ = h.join();
        }
    }

    /// Drain and stop all shard workers.
    pub fn shutdown(mut self) {
        self.stop_all();
    }

    /// Measured serving capacity of a fresh single-shard simulator pool
    /// for `points`-sized jobs on this host, jobs/s: warm 8 jobs (plan
    /// build + resident executor), then time 32. This is the shared
    /// calibration anchor for the load benches and integration tests,
    /// so "N× one shard's capacity" means the same thing in every file
    /// (and stays meaningful across fast and slow runners).
    pub fn calibrate_single_shard_rps(points: usize) -> Result<f64> {
        let svc = ShardedFftService::start(ShardPoolConfig {
            shards: 1,
            steal_threshold: 0,
            service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
            ..Default::default()
        })?;
        let signal = |seed: u64| -> Vec<(f32, f32)> {
            crate::fft::reference::test_signal(points, seed)
                .iter()
                .map(|c| c.to_f32_pair())
                .collect()
        };
        svc.run_batch((0..8).map(signal).collect())?;
        let t0 = Instant::now();
        svc.run_batch((0..32).map(signal).collect())?;
        let rps = 32.0 / t0.elapsed().as_secs_f64();
        svc.shutdown();
        Ok(rps)
    }
}

impl FftCompute for ShardedFftService {
    fn request(&self, req: FftRequest) -> Receiver<Result<FftResult>> {
        ShardedFftService::request(self, req)
    }

    fn request_all(&self, reqs: Vec<FftRequest>) -> Result<Vec<FftResult>> {
        ShardedFftService::request_all(self, reqs)
    }
}

impl Drop for ShardedFftService {
    fn drop(&mut self) {
        self.stop_all();
    }
}

/// Retired-shard stats are kept individually up to this count; older
/// entries beyond it are folded into one cumulative entry (stable id
/// `usize::MAX`), so a long-running autoscaled deployment that retires
/// shards for months cannot grow snapshots (or `render()`) without
/// bound.
const RETIRED_STATS_CAP: usize = 64;

/// Fold the oldest individual retired entries into the cumulative
/// accumulator (created on first fold, at index 0, `shard: usize::MAX`)
/// until at most [`RETIRED_STATS_CAP`] entries remain. Counter fields
/// add; `occupancy` is meaningless for a merged entry and reports 0.
fn fold_retired(retired: &mut Vec<ShardStat>) {
    while retired.len() > RETIRED_STATS_CAP {
        let oldest = usize::from(retired[0].shard == usize::MAX);
        let s = retired.remove(oldest);
        if retired[0].shard != usize::MAX {
            retired.insert(
                0,
                ShardStat { shard: usize::MAX, retired: true, ..Default::default() },
            );
        }
        let acc = &mut retired[0];
        acc.handled += s.handled;
        acc.batch_jobs += s.batch_jobs;
        acc.affine += s.affine;
        acc.stolen += s.stolen;
        acc.max_queue_depth = acc.max_queue_depth.max(s.max_queue_depth);
        acc.busy_us += s.busy_us;
    }
}

/// Split one same-size group into at most one chunk per shard, each of
/// at least `min_chunk` jobs, so a large homogeneous batch runs
/// pool-wide instead of serializing on its home shard.
fn split_group(idxs: &[usize], min_chunk: usize, shards: usize) -> Vec<Vec<usize>> {
    let chunks = (idxs.len() / min_chunk.max(1)).clamp(1, shards);
    let per = idxs.len().div_ceil(chunks);
    idxs.chunks(per).map(|c| c.to_vec()).collect()
}

/// A point-in-time copy of one shard's counters.
fn stat_of(id: usize, c: &ShardCounters, elapsed_us: u64, retired: bool) -> ShardStat {
    let busy_us = c.busy_us.load(Ordering::Relaxed);
    ShardStat {
        shard: id,
        handled: c.handled.load(Ordering::Relaxed),
        batch_jobs: c.batch_jobs.load(Ordering::Relaxed),
        affine: c.affine.load(Ordering::Relaxed),
        stolen: c.stolen.load(Ordering::Relaxed),
        queue_depth: c.depth.load(Ordering::Relaxed),
        max_queue_depth: c.max_depth.load(Ordering::Relaxed),
        busy_us,
        occupancy: (busy_us as f64 / elapsed_us as f64).min(1.0),
        retired,
    }
}

/// One shard's worker: a private queue feeding one resident simulated
/// SM, serving jobs with exactly the same code as the single-queue
/// pool. The depth gauge counts a job until it is *served* (not merely
/// dequeued), so the router sees in-flight work as load. Once the
/// shard's retiring flag is set, every remaining queued job is handed
/// back through the drain channel for `retire_shard` to re-route.
fn shard_loop(w: ShardWorker) {
    let ShardWorker { id, cfg, ring, metrics, engine, plans, counters, retiring, drain } = w;
    let mut core = Core { id, cfg, plans, execs: HashMap::new(), tick: 0 };
    while let Some(job) = ring.pop() {
        if retiring.load(Ordering::Acquire) {
            // Hand queued work back to the router instead of serving it
            // on a shard that is leaving the pool.
            let _ = drain.send(job);
            continue;
        }
        let jobs = job.weight();
        let is_batch = matches!(job.kind, JobKind::Batch { .. });
        // Count the job *before* serving: replies are sent inside
        // `handle_job`, so a snapshot taken after a caller's `recv`
        // returns must never be behind on these counters.
        counters.handled.fetch_add(jobs, Ordering::Relaxed);
        if is_batch {
            counters.batch_jobs.fetch_add(jobs, Ordering::Relaxed);
        }
        let t0 = Instant::now();
        handle_job(&mut core, &engine, &metrics, job);
        counters
            .busy_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        counters.depth.fetch_sub(jobs as usize, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{self, reference};

    fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
        reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
    }

    fn pool(shards: usize, steal_threshold: usize) -> ShardedFftService {
        ShardedFftService::start(ShardPoolConfig {
            shards,
            steal_threshold,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn sharded_service_end_to_end() {
        let svc = pool(2, 2);
        let results = svc.run_batch((0..8).map(|i| signal(256, i)).collect()).unwrap();
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            let want = reference::fft(&reference::test_signal(256, i as u64));
            let got: Vec<_> = r
                .output
                .iter()
                .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
                .collect();
            assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
        }
        let m = svc.metrics();
        assert_eq!(m.served, 8);
        assert_eq!(m.errors, 0);
        assert_eq!(m.shards.len(), 2);
        assert_eq!(m.shards.iter().map(|s| s.handled).sum::<u64>(), 8);
        assert!(m.agg_jobs_per_s > 0.0);
        svc.shutdown();
    }

    #[test]
    fn auto_shard_count_uses_available_parallelism() {
        let svc = pool(0, 2);
        assert!(svc.shards() >= 1);
        let r = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap().unwrap();
        assert_eq!(r.output.len(), 256);
        svc.shutdown();
    }

    #[test]
    fn split_group_respects_min_chunk_and_shard_count() {
        let idxs: Vec<usize> = (0..64).collect();
        let chunks = split_group(&idxs, 8, 4);
        assert_eq!(chunks.len(), 4, "64 jobs / min_chunk 8 caps at 4 shards");
        assert!(chunks.iter().all(|c| c.len() == 16));
        let small: Vec<usize> = (0..5).collect();
        assert_eq!(split_group(&small, 8, 4).len(), 1, "below min_chunk stays whole");
        let rejoined: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(rejoined, idxs, "chunking preserves order");
    }

    #[test]
    fn degraded_submit_routes_and_serves_at_the_truncated_size() {
        use crate::coordinator::qos::DegradeLevel;
        let svc = pool(2, 2);
        let r = svc
            .request(FftRequest::new(signal(1024, 5)).with_level(DegradeLevel::Half))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(r.output.len(), 512, "half resolution of a 1024-point request");
        // bitwise identical to submitting the truncated signal directly
        let direct = svc
            .request(FftRequest::new(signal(1024, 5)[..512].to_vec()))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(
            r.output.iter().map(|&(a, b)| (a.to_bits(), b.to_bits())).collect::<Vec<_>>(),
            direct.output.iter().map(|&(a, b)| (a.to_bits(), b.to_bits())).collect::<Vec<_>>(),
            "degrade changes dispatch, never numerics"
        );
        svc.shutdown();
    }

    #[test]
    fn large_request_pipelines_stage_batches_across_shards() {
        use crate::fft::multipass::{four_step_reference, MultipassPlan};
        let svc = pool(2, 2);
        // 1024 points over a 64-point ceiling: 32 row jobs + 32 col
        // jobs, each stage one coalesced 32-job group of 32-point jobs.
        let r = svc
            .request(FftRequest::new(signal(1024, 9)).with_max_pass_points(64))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(r.output.len(), 1024);
        let plan = MultipassPlan::new(1024, 64).unwrap();
        let want = four_step_reference(&reference::test_signal(1024, 9), &plan);
        let got: Vec<_> = r
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        assert!(reference::rms_rel_error(&got, &want) < 5.0 * fft::F32_TOL);
        let m = svc.metrics();
        assert_eq!(m.multipass.requests, 1);
        assert_eq!(m.multipass.completed, 1);
        assert_eq!(m.multipass.reserved, 1, "default gate admits the pipelined path");
        assert_eq!(m.multipass.row_jobs, 32);
        assert_eq!(m.multipass.col_jobs, 32);
        // Each stage group splits into per-shard chunks (min_chunk 8,
        // 2 shards -> two 16-job chunks), so one large transform
        // pipelines across the whole pool.
        assert_eq!(m.shards.iter().map(|s| s.batch_jobs).sum::<u64>(), 64);
        for s in &m.shards {
            assert!(
                s.batch_jobs > 0,
                "stage chunks must spread across every shard: {:?}",
                m.shards
            );
        }
        svc.shutdown();
    }

    #[test]
    fn sharded_ntt_requests_are_exact_and_coalesce_per_workload() {
        use crate::fft::field;
        let svc = pool(2, 2);
        // One NTT and one FFT of the same size in one batch: they must
        // stay in separate kernels (per-workload grouping) and the NTT
        // side must match the radix-2 field oracle exactly.
        let elems = field::test_elements(256, 3);
        let reqs = vec![
            FftRequest::ntt(elems.clone()),
            FftRequest::new(signal(256, 3)),
        ];
        let results = svc.request_all(reqs).unwrap();
        let got: Vec<u64> = results[0].output.iter().map(|&w| field::unpack(w)).collect();
        assert_eq!(got, field::ntt(&elems), "sharded NTT output is bit-exact");
        let want = reference::fft(&reference::test_signal(256, 3));
        let fgot: Vec<_> = results[1]
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        assert!(reference::rms_rel_error(&fgot, &want) < fft::F32_TOL);
        svc.shutdown();
    }

    #[test]
    fn bad_size_errors_without_killing_shards() {
        let svc = pool(2, 2);
        let bad = svc.request(FftRequest::new(signal(100, 0))).recv().unwrap();
        assert!(bad.is_err());
        let ok = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap();
        assert!(ok.is_ok());
        assert_eq!(svc.metrics().errors, 1);
        svc.shutdown();
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let svc = pool(2, 2);
        assert!(svc.request_all(Vec::new()).unwrap().is_empty());
        assert_eq!(svc.metrics().served, 0);
        svc.shutdown();
    }

    #[test]
    fn invalid_variant_rejected() {
        let bad = crate::arch::Variant { mem: crate::arch::MemPorts::Qp, vm: true, complex: false };
        let err = ShardedFftService::start(ShardPoolConfig {
            shards: 1,
            service: ServiceConfig { variant: bad, ..Default::default() },
            ..Default::default()
        });
        assert!(err.is_err());
    }

    #[test]
    fn add_and_retire_reshape_the_pool_with_stable_ids() {
        let svc = pool(2, 2);
        assert_eq!(svc.shards(), 2);
        let e0 = svc.epoch();
        let id = svc.add_shard();
        assert_eq!(id, 2, "stable ids are monotonic");
        assert_eq!(svc.shards(), 3);
        assert!(svc.epoch() > e0, "resize bumps the routing epoch");
        let retired = svc.retire_shard().unwrap();
        assert_eq!(retired, 2, "last position retires first");
        assert_eq!(svc.shards(), 2);
        // the pool still serves after the round trip
        let r = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap().unwrap();
        assert_eq!(r.output.len(), 256);
        svc.shutdown();
    }

    #[test]
    fn cannot_retire_the_last_shard() {
        let svc = pool(2, 2);
        svc.retire_shard().unwrap();
        assert_eq!(svc.shards(), 1);
        assert!(svc.retire_shard().is_err());
        assert_eq!(svc.shards(), 1);
        svc.shutdown();
    }

    #[test]
    fn retire_drains_queued_jobs_without_loss() {
        // With 3 shards, fft256 (trailing zeros 8) homes on position 2 —
        // the exact slot retire_shard pops — and a huge steal threshold
        // pins every job there, so retirement must drain a loaded queue.
        let svc = pool(3, 1024);
        let handles: Vec<_> =
            (0..16).map(|i| svc.request(FftRequest::new(signal(256, i)))).collect();
        let retired = svc.retire_shard().unwrap();
        assert_eq!(svc.shards(), 2);
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.recv().expect("reply arrives").unwrap_or_else(|e| {
                panic!("job {i} lost across retirement: {e:#}");
            });
            assert_eq!(r.output.len(), 256);
        }
        let m = svc.metrics();
        assert_eq!(m.served, 16, "every admitted job served");
        assert_eq!(
            m.shards.iter().map(|s| s.handled).sum::<u64>(),
            16,
            "per-shard counts (active + retired) account for every job: {:?}",
            m.shards
        );
        let frozen = m.shards.iter().find(|s| s.retired).expect("retired stat kept");
        assert_eq!(frozen.shard, retired);
        assert_eq!(frozen.queue_depth, 0, "retired shard drained completely");
        svc.shutdown();
    }

    #[test]
    fn retired_stats_fold_beyond_the_cap_without_losing_counts() {
        let n = RETIRED_STATS_CAP + 5;
        let mut retired: Vec<ShardStat> = (0..n)
            .map(|i| ShardStat { shard: i, handled: 2, retired: true, ..Default::default() })
            .collect();
        fold_retired(&mut retired);
        assert_eq!(retired.len(), RETIRED_STATS_CAP);
        assert_eq!(retired[0].shard, usize::MAX, "cumulative entry leads");
        assert!(retired[0].retired);
        assert_eq!(
            retired.iter().map(|s| s.handled).sum::<u64>(),
            2 * n as u64,
            "folding loses no counts"
        );
        let mut few: Vec<ShardStat> = (0..3)
            .map(|i| ShardStat { shard: i, ..Default::default() })
            .collect();
        fold_retired(&mut few);
        assert_eq!(few.len(), 3, "under the cap nothing folds");
        assert!(few.iter().all(|s| s.shard != usize::MAX));
    }

    #[test]
    fn snapshots_tolerate_resize_with_stable_ids() {
        let svc = pool(2, 2);
        svc.request(FftRequest::new(signal(256, 0))).recv().unwrap().unwrap();
        svc.add_shard(); // id 2
        svc.retire_shard().unwrap(); // retires id 2
        svc.add_shard(); // id 3
        svc.request(FftRequest::new(signal(256, 1))).recv().unwrap().unwrap();
        let m = svc.metrics();
        let ids: Vec<usize> = m.shards.iter().map(|s| s.shard).collect();
        assert_eq!(ids.len(), 4, "3 active + 1 retired");
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "no id reuse across resizes: {ids:?}");
        assert!(ids.contains(&3), "non-contiguous ids survive the snapshot");
        assert_eq!(m.shards.iter().filter(|s| s.retired).count(), 1);
        assert_eq!(m.shards.iter().map(|s| s.handled).sum::<u64>(), 2);
        // render must not index by position
        assert!(m.render().contains("[retired]"));
        svc.shutdown();
    }
}
