//! Service metrics: throughput, latency distribution, simulated
//! (virtual) eGPU time, aggregate efficiency, batched-dispatch
//! occupancy, shared plan-cache counters, per-shard scheduler counters,
//! and — for the admission-controlled [`super::server::TrafficServer`]
//! — queue-wait vs service-time latency recorders plus admission /
//! shedding / deadline / priority accounting.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::buffer::{ArenaStats, JobArena};
use crate::fft::cache::CacheStats;
use crate::fft::field::Workload;
use crate::profile::Profile;

/// Latency histogram bucket upper bounds, µs (log-spaced).
pub const LATENCY_BUCKETS_US: [f64; 8] =
    [50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 10_000.0, f64::INFINITY];

/// Number of log₂ buckets in a [`LatencyRecorder`]: bucket `i` counts
/// samples whose bit length in µs is `i`, i.e. values in
/// `[2^(i-1), 2^i)`. 32 buckets cover up to ~2^31 µs (~36 minutes).
pub const LATENCY_LOG_BUCKETS: usize = 32;

/// Lock-free log₂-bucketed latency recorder (µs resolution).
///
/// The traffic frontend records *queue wait* and *service time* into
/// two separate recorders so head-of-line blocking is distinguishable
/// from slow backends. Buckets are powers of two, so percentile
/// estimates are upper bounds accurate to within 2×, which is the
/// right fidelity for p99/p999 gating without a lock on the hot path.
#[derive(Default)]
pub struct LatencyRecorder {
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
    buckets: [AtomicU64; LATENCY_LOG_BUCKETS],
}

impl LatencyRecorder {
    /// Record one sample, in µs.
    pub fn record(&self, us: f64) {
        let v = us.max(0.0) as u64;
        let bucket = if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(LATENCY_LOG_BUCKETS - 1)
        };
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
        self.max_us.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy of the recorder's counters.
    pub fn snapshot(&self) -> LatencyStats {
        let mut buckets = [0u64; LATENCY_LOG_BUCKETS];
        for (out, b) in buckets.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        LatencyStats {
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed) as f64,
            max_us: self.max_us.load(Ordering::Relaxed) as f64,
            buckets,
        }
    }
}

/// A point-in-time copy of a [`LatencyRecorder`].
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    /// Samples recorded.
    pub count: u64,
    /// Sum of all samples, µs.
    pub sum_us: f64,
    /// Largest sample, µs.
    pub max_us: f64,
    /// Log₂ bucket counts (see [`LATENCY_LOG_BUCKETS`]).
    pub buckets: [u64; LATENCY_LOG_BUCKETS],
}

impl LatencyStats {
    /// Mean sample, µs (0 with no samples).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// The samples recorded between `prev` (an earlier snapshot of the
    /// same recorder) and this snapshot, as their own distribution —
    /// the interval view the autoscaler's pressure feed is built on.
    /// `max_us` stays cumulative: a per-interval max is not recoverable
    /// from bucket counts.
    pub fn delta_since(&self, prev: &LatencyStats) -> LatencyStats {
        let mut buckets = [0u64; LATENCY_LOG_BUCKETS];
        for (out, (cur, old)) in
            buckets.iter_mut().zip(self.buckets.iter().zip(&prev.buckets))
        {
            *out = cur.saturating_sub(*old);
        }
        LatencyStats {
            count: self.count.saturating_sub(prev.count),
            sum_us: (self.sum_us - prev.sum_us).max(0.0),
            max_us: self.max_us,
            buckets,
        }
    }

    /// Percentile estimate (upper bound of the covering bucket), µs.
    /// `q` in `[0, 1]`; returns 0 with no samples.
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 { 1.0 } else { (1u64 << i) as f64 };
            }
        }
        self.max_us
    }
}

/// One QoS class's frontend counters, as captured by
/// `TrafficServer::metrics` — the per-class slice of [`ServerStats`].
#[derive(Clone, Debug, Default)]
pub struct ClassStats {
    /// Class name, from the [`super::qos::QosClass`] configuration.
    pub name: String,
    /// Fair-share weight (0 = background class).
    pub weight: u32,
    /// Resolved admission-queue capacity for this class.
    pub capacity: usize,
    /// `request` calls naming this class, admitted or shed.
    pub submitted: u64,
    /// Requests that entered this class's admission queue.
    pub admitted: u64,
    /// Requests served to successful completion.
    pub completed: u64,
    /// Requests rejected at admission (queue full).
    pub shed: u64,
    /// Requests whose deadline expired while queued.
    pub expired: u64,
    /// Requests served to completion but past their deadline.
    pub late: u64,
    /// Requests that failed in the backend.
    pub failed: u64,
    /// Dispatches served at half resolution (the degrade ladder's
    /// per-level accounting).
    pub degraded_half: u64,
    /// Dispatches served at quarter resolution.
    pub degraded_quarter: u64,
    /// Aged promotions of this class's requests ahead of weighted work.
    pub aged: u64,
    /// Peak queue depth observed for this class.
    pub max_queue_depth: usize,
    /// Time from admission to dispatch, this class only.
    pub queue_wait: LatencyStats,
}

impl ClassStats {
    /// Fraction of admitted requests that missed their deadline.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            (self.expired + self.late) as f64 / self.admitted as f64
        }
    }

    /// This class's share of `total_completed` dispatches — what the
    /// WFQ share-conformance checks compare against weight/Σweights.
    pub fn served_fraction(&self, total_completed: u64) -> f64 {
        if total_completed == 0 {
            0.0
        } else {
            self.completed as f64 / total_completed as f64
        }
    }

    /// Total degraded dispatches at any level.
    pub fn degraded(&self) -> u64 {
        self.degraded_half + self.degraded_quarter
    }

    fn interval_since(&self, prev: &ClassStats) -> ClassStats {
        ClassStats {
            name: self.name.clone(),
            weight: self.weight,
            capacity: self.capacity,
            submitted: self.submitted.saturating_sub(prev.submitted),
            admitted: self.admitted.saturating_sub(prev.admitted),
            completed: self.completed.saturating_sub(prev.completed),
            shed: self.shed.saturating_sub(prev.shed),
            expired: self.expired.saturating_sub(prev.expired),
            late: self.late.saturating_sub(prev.late),
            failed: self.failed.saturating_sub(prev.failed),
            degraded_half: self.degraded_half.saturating_sub(prev.degraded_half),
            degraded_quarter: self.degraded_quarter.saturating_sub(prev.degraded_quarter),
            aged: self.aged.saturating_sub(prev.aged),
            max_queue_depth: self.max_queue_depth,
            queue_wait: self.queue_wait.delta_since(&prev.queue_wait),
        }
    }
}

/// One tenant's admission/billing counters, as captured by
/// `TrafficServer::metrics` from the
/// [`super::tenant::TenantRegistry`] — the per-principal slice of the
/// snapshot (empty for servers running without a tenancy layer).
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    /// Tenant name, from the [`super::tenant::TenantSpec`]
    /// configuration.
    pub name: String,
    /// Priority tenant: its queued requests arm the cross-pass
    /// preemption signal.
    pub priority: bool,
    /// `request` calls naming this tenant, admitted or throttled.
    pub submitted: u64,
    /// Requests that passed the token bucket and quota.
    pub admitted: u64,
    /// Requests refused by the token bucket or the job-unit quota
    /// (typed `ServiceError::TenantThrottled`, never queued).
    pub throttled: u64,
    /// Requests served to successful completion.
    pub completed: u64,
    /// Job units billed to completed requests (1 per single-pass
    /// request, the sub-job count for a decomposed one) — the billing
    /// counter.
    pub job_units: u64,
    /// Job units currently admitted but not yet finished (the quota's
    /// live charge).
    pub units_in_flight: u64,
    /// Time from admission to dispatch, this tenant only.
    pub queue_wait: LatencyStats,
}

impl TenantStats {
    /// Fraction of submissions refused by the tenancy layer.
    pub fn throttle_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.throttled as f64 / self.submitted as f64
        }
    }
}

/// Traffic-frontend counters, as captured by
/// `TrafficServer::metrics` (all zeros / empty for services running
/// without an admission layer).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// All `request` calls, whether admitted or shed.
    pub submitted: u64,
    /// Requests that entered an admission queue.
    pub admitted: u64,
    /// Requests that completed with a successful FFT result.
    pub completed: u64,
    /// Requests rejected at admission with `ServiceError::QueueFull`.
    pub shed: u64,
    /// Requests served at reduced resolution by the Degrade policy.
    pub degraded: u64,
    /// Requests whose deadline expired while queued (typed error, never
    /// served).
    pub expired: u64,
    /// Requests served to completion but past their deadline.
    pub late: u64,
    /// Requests that failed in the backend (typed error delivered).
    pub failed: u64,
    /// Completions in class 0 (the legacy "high priority" aggregate).
    pub served_high: u64,
    /// Completions in every other class (legacy "low priority").
    pub served_low: u64,
    /// Low-priority dequeues forced ahead of waiting high-priority work
    /// by the aging rule (the starvation-freedom mechanism firing).
    pub aged: u64,
    /// Peak admission-queue depth (both classes) observed.
    pub max_queue_depth: usize,
    /// Time from admission to dispatch.
    pub queue_wait: LatencyStats,
    /// Time from dispatch to backend completion.
    pub service_time: LatencyStats,
    /// Per-QoS-class counters, in configuration order (empty for
    /// services running without an admission layer).
    pub per_class: Vec<ClassStats>,
}

impl ServerStats {
    /// Fraction of submissions rejected at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted as f64
        }
    }

    /// Fraction of admitted requests that missed their deadline —
    /// expired in queue or served late.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            (self.expired + self.late) as f64 / self.admitted as f64
        }
    }

    /// Every admitted request is accounted for: completed, expired, or
    /// failed with a typed error. Nothing is silently dropped.
    pub fn accounted(&self) -> bool {
        self.completed + self.expired + self.failed == self.admitted
    }

    /// The traffic accumulated between `prev` (an earlier snapshot of
    /// the same frontend) and this snapshot: counter fields subtract,
    /// the latency recorders become interval distributions
    /// ([`LatencyStats::delta_since`]), and `max_queue_depth` stays
    /// cumulative. `shed_rate()` / `deadline_miss_rate()` on the result
    /// are interval rates — the signals the autoscaler reacts to.
    pub fn interval_since(&self, prev: &ServerStats) -> ServerStats {
        ServerStats {
            submitted: self.submitted.saturating_sub(prev.submitted),
            admitted: self.admitted.saturating_sub(prev.admitted),
            completed: self.completed.saturating_sub(prev.completed),
            shed: self.shed.saturating_sub(prev.shed),
            degraded: self.degraded.saturating_sub(prev.degraded),
            expired: self.expired.saturating_sub(prev.expired),
            late: self.late.saturating_sub(prev.late),
            failed: self.failed.saturating_sub(prev.failed),
            served_high: self.served_high.saturating_sub(prev.served_high),
            served_low: self.served_low.saturating_sub(prev.served_low),
            aged: self.aged.saturating_sub(prev.aged),
            max_queue_depth: self.max_queue_depth,
            queue_wait: self.queue_wait.delta_since(&prev.queue_wait),
            service_time: self.service_time.delta_since(&prev.service_time),
            per_class: self
                .per_class
                .iter()
                .enumerate()
                .map(|(i, cur)| match prev.per_class.get(i) {
                    Some(p) => cur.interval_since(p),
                    // a fresh meter starts from ServerStats::default()
                    // (no classes): the whole history is the interval
                    None => cur.clone(),
                })
                .collect(),
        }
    }
}

/// The execution layer's shared counter block: workers call
/// [`Metrics::observe`] per job and consumers read a coherent
/// [`MetricsSnapshot`].
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    served: u64,
    errors: u64,
    by_points: HashMap<usize, u64>,
    by_workload: HashMap<Workload, u64>,
    wall_us_sum: f64,
    wall_us_max: f64,
    latency_hist: [u64; 8],
    /// Accumulated simulated eGPU time (µs at the variant Fmax).
    virtual_us: f64,
    /// Accumulated cycle profile across all simulated jobs.
    profile: Profile,
    /// Coalesced batches served through `request_all`.
    batches: u64,
    /// Jobs served inside those batches.
    batched_jobs: u64,
    /// Largest batch seen.
    max_batch_jobs: u64,
}

impl Metrics {
    /// Record one successfully served job: its workload, (post-degrade)
    /// size, wall latency, and cycle profile when the simulator ran it.
    pub fn observe(
        &self,
        workload: Workload,
        points: usize,
        wall_us: f64,
        profile: Option<&Profile>,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.served += 1;
        *m.by_points.entry(points).or_insert(0) += 1;
        *m.by_workload.entry(workload).or_insert(0) += 1;
        m.wall_us_sum += wall_us;
        m.wall_us_max = m.wall_us_max.max(wall_us);
        let bucket = LATENCY_BUCKETS_US.iter().position(|&b| wall_us <= b).unwrap_or(7);
        m.latency_hist[bucket] += 1;
        if let Some(p) = profile {
            m.virtual_us += p.time_us();
            m.profile += *p;
        }
    }

    /// Record one failed job.
    pub fn observe_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record one completed coalesced batch of `jobs` requests (each
    /// job is additionally observed individually for latency/profile).
    pub fn observe_batch(&self, jobs: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_jobs += jobs as u64;
        m.max_batch_jobs = m.max_batch_jobs.max(jobs as u64);
    }

    /// A coherent copy of the counters. Layer-specific fields
    /// (plan cache, shards, frontend, backends) are zero/empty here —
    /// each service's own `metrics()` fills in the parts it owns.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            served: m.served,
            errors: m.errors,
            by_points: m.by_points.clone(),
            by_workload: m.by_workload.clone(),
            mean_wall_us: if m.served == 0 { 0.0 } else { m.wall_us_sum / m.served as f64 },
            max_wall_us: m.wall_us_max,
            latency_hist: m.latency_hist,
            virtual_us: m.virtual_us,
            aggregate_profile: m.profile,
            batches: m.batches,
            batched_jobs: m.batched_jobs,
            max_batch_jobs: m.max_batch_jobs,
            plan_cache: CacheStats::default(),
            multipass: MultipassSnapshot::default(),
            shards: Vec::new(),
            steals: 0,
            agg_jobs_per_s: 0.0,
            server: ServerStats::default(),
            tenants: Vec::new(),
            backends: Vec::new(),
            arena: JobArena::global().snapshot(),
        }
    }
}

/// Multi-pass (four-step) decomposition counters, as captured by the
/// services that orchestrate large-N requests (all zeros for a stack
/// that never saw a request above the single-pass ceiling).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MultipassSnapshot {
    /// Large requests that entered the four-step decomposition path.
    pub requests: u64,
    /// Decomposed requests served to successful completion.
    pub completed: u64,
    /// Requests that reserved an inflight-multipass slot and had their
    /// stage batches pipelined across the pool.
    pub reserved: u64,
    /// Requests that found no slot free and spilled to strictly
    /// serialized sub-jobs (the no-deadlock admission path).
    pub spilled: u64,
    /// Requests abandoned at the between-pass cooperative preemption
    /// point (deadline expired after stage 1).
    pub preempted: u64,
    /// Between-pass checkpoints at which a request paused to let a
    /// waiting priority tenant's work through (the request still
    /// completes — unlike `preempted`, a yield is not an abandonment).
    pub yielded: u64,
    /// Stage-1 (row FFT) sub-jobs submitted to the executors.
    pub row_jobs: u64,
    /// Stage-2 (column FFT) sub-jobs submitted to the executors.
    pub col_jobs: u64,
}

impl MultipassSnapshot {
    /// Total sub-jobs across both stages.
    pub fn stage_jobs(&self) -> u64 {
        self.row_jobs + self.col_jobs
    }
}

/// One routed backend lane's counters, as captured by
/// `ServiceHandle::metrics` on a routed set (empty for unrouted
/// services). The first entry is always the simulator lane.
#[derive(Clone, Debug, Default)]
pub struct BackendStat {
    /// Lane name (`sim`, `pjrt`, ...).
    pub name: String,
    /// Requests this lane served to completion (excludes calibration
    /// and validation re-serves).
    pub served: u64,
    /// Requests that failed on this lane (alternate-lane failures fall
    /// back to the simulator, but are still counted here).
    pub failed: u64,
    /// Alternate-served results cross-checked against the simulator.
    pub validate_checks: u64,
    /// Cross-checks that disagreed beyond tolerance. Any mismatch
    /// quarantines the lane.
    pub validate_mismatches: u64,
    /// The router no longer sends this lane traffic (a validation
    /// cross-check failed).
    pub quarantined: bool,
    /// Mean measured service time over served requests, µs.
    pub mean_service_us: f64,
}

/// One shard's scheduler counters, as captured by
/// `ShardedFftService::metrics` (all zeros / empty for the unsharded
/// service).
#[derive(Clone, Debug, Default)]
pub struct ShardStat {
    /// Stable shard id: assigned once at spawn and never reused, so a
    /// snapshot taken across `add_shard` / `retire_shard` resizes keys
    /// counters by identity, not by position in the pool. Ids may be
    /// non-contiguous after a resize.
    pub shard: usize,
    /// Jobs processed by this shard — successes *and* errors, counted
    /// at dequeue (unlike the aggregate `served`, which counts only
    /// successful jobs).
    pub handled: u64,
    /// Jobs served through coalesced batch chunks.
    pub batch_jobs: u64,
    /// Jobs that arrived via their size-affinity home route.
    pub affine: u64,
    /// Jobs that arrived via the work-stealing overflow route.
    pub stolen: u64,
    /// Queued + in-flight jobs at snapshot time.
    pub queue_depth: usize,
    /// Peak queue depth observed.
    pub max_queue_depth: usize,
    /// Time spent serving jobs, µs.
    pub busy_us: u64,
    /// Fraction of wall time this shard spent serving (0.0–1.0). For a
    /// retired shard this is frozen at retirement time.
    pub occupancy: f64,
    /// The shard has left the routing table via
    /// `ShardedFftService::retire_shard`. While the retirement is still
    /// draining, snapshots report the shard's *live* counters under
    /// this flag (they may still advance between snapshots); once the
    /// drain completes the counters are frozen final values. Either
    /// way, retired entries keep aggregate accounting (e.g. summing
    /// `handled`) complete across resizes.
    pub retired: bool,
}

/// A coherent point-in-time view of the whole serving stack's
/// counters: execution layer, plan cache, shards, traffic frontend,
/// and routed backends — each layer fills in the parts it owns.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Jobs served to successful completion.
    pub served: u64,
    /// Jobs that failed with an error.
    pub errors: u64,
    /// Served jobs by (post-degrade) transform size.
    pub by_points: HashMap<usize, u64>,
    /// Served jobs by workload (complex-f32 FFT vs Goldilocks NTT) —
    /// how much of the engine's traffic each transform family carried.
    pub by_workload: HashMap<Workload, u64>,
    /// Mean wall latency over served jobs, µs.
    pub mean_wall_us: f64,
    /// Largest wall latency observed, µs.
    pub max_wall_us: f64,
    /// Wall-latency histogram over [`LATENCY_BUCKETS_US`].
    pub latency_hist: [u64; 8],
    /// Accumulated simulated eGPU time (µs at the variant Fmax).
    pub virtual_us: f64,
    /// Accumulated cycle profile across all simulated jobs.
    pub aggregate_profile: Profile,
    /// Coalesced batches served through `request_all`.
    pub batches: u64,
    /// Jobs served inside those batches (`served` counts them too).
    pub batched_jobs: u64,
    /// Largest coalesced batch seen.
    pub max_batch_jobs: u64,
    /// Shared plan-cache counters (filled in by `FftService::metrics`;
    /// `Metrics::snapshot` alone reports zeros).
    pub plan_cache: CacheStats,
    /// Multi-pass decomposition counters (filled in by the services'
    /// `metrics()`; all zeros when no request exceeded the ceiling).
    pub multipass: MultipassSnapshot,
    /// Per-shard scheduler counters (filled in by
    /// `ShardedFftService::metrics`; empty for the unsharded service).
    pub shards: Vec<ShardStat>,
    /// Jobs redirected away from their affine home shard by the
    /// work-stealing overflow rule (sharded service only).
    pub steals: u64,
    /// Aggregate served throughput since service start, jobs/s (sharded
    /// service only; 0.0 otherwise).
    pub agg_jobs_per_s: f64,
    /// Traffic-frontend counters (filled in by `TrafficServer::metrics`;
    /// all-zero for services running without an admission layer).
    pub server: ServerStats,
    /// Per-tenant admission/billing counters, in configuration order
    /// (filled in by `TrafficServer::metrics` when a tenancy layer is
    /// configured; empty otherwise).
    pub tenants: Vec<TenantStats>,
    /// Per-backend routing counters (filled in by
    /// `ServiceHandle::metrics` on a routed set; empty otherwise).
    pub backends: Vec<BackendStat>,
    /// Process-global job-arena counters: slot occupancy plus
    /// lease-hit / lease-miss / release totals (all zeros when no
    /// request payload ever touched the arena).
    pub arena: ArenaStats,
}

impl MetricsSnapshot {
    /// Mean jobs per coalesced batch — the per-batch occupancy.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_jobs as f64 / self.batches as f64
        }
    }

    /// Approximate latency percentile from the histogram.
    pub fn latency_percentile_us(&self, q: f64) -> f64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.latency_hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return LATENCY_BUCKETS_US[i];
            }
        }
        f64::INFINITY
    }

    /// Aggregate FP-efficiency over all simulated work (§6 metric).
    pub fn efficiency_pct(&self) -> f64 {
        if self.aggregate_profile.total() == 0 {
            0.0
        } else {
            self.aggregate_profile.efficiency_pct()
        }
    }

    /// Human-readable multi-line rendering; sections appear only for
    /// the layers that saw traffic.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "served={} errors={} mean_wall={:.1}us max_wall={:.1}us\n",
            self.served, self.errors, self.mean_wall_us, self.max_wall_us
        ));
        let mut pts: Vec<_> = self.by_points.iter().collect();
        pts.sort();
        for (p, c) in pts {
            s.push_str(&format!("  fft{p}: {c} jobs\n"));
        }
        if self.by_workload.len() > 1 || self.by_workload.contains_key(&Workload::Ntt) {
            let count = |w| self.by_workload.get(&w).copied().unwrap_or(0);
            s.push_str(&format!(
                "  workloads: {} fft / {} ntt jobs\n",
                count(Workload::Fft),
                count(Workload::Ntt)
            ));
        }
        if self.virtual_us > 0.0 {
            s.push_str(&format!(
                "  simulated eGPU time: {:.1}us, aggregate efficiency {:.2}%\n",
                self.virtual_us,
                self.efficiency_pct()
            ));
        }
        if self.batches > 0 {
            s.push_str(&format!(
                "  batches: {} ({} jobs, mean occupancy {:.1}, max {})\n",
                self.batches,
                self.batched_jobs,
                self.mean_batch_occupancy(),
                self.max_batch_jobs
            ));
        }
        if self.plan_cache.lookups() > 0 {
            s.push_str(&format!(
                "  plan cache: {}/{} entries, hit rate {:.3} ({} hits / {} misses, \
                 {} evictions, {} lock contentions)\n",
                self.plan_cache.entries,
                self.plan_cache.capacity,
                self.plan_cache.hit_rate(),
                self.plan_cache.hits,
                self.plan_cache.misses,
                self.plan_cache.evictions,
                self.plan_cache.lock_contentions
            ));
        }
        if self.multipass.requests > 0 {
            let mp = &self.multipass;
            s.push_str(&format!(
                "  multipass: {} requests ({} completed, {} preempted, {} yielded), \
                 {} reserved / {} spilled, {} row + {} col sub-jobs\n",
                mp.requests,
                mp.completed,
                mp.preempted,
                mp.yielded,
                mp.reserved,
                mp.spilled,
                mp.row_jobs,
                mp.col_jobs
            ));
        }
        if self.server.submitted > 0 {
            let sv = &self.server;
            s.push_str(&format!(
                "  frontend: {} submitted, {} admitted, {} completed, {} shed \
                 ({:.3}), {} degraded, {} expired + {} late (miss rate {:.3}), \
                 {} aged, peak queue {}\n",
                sv.submitted,
                sv.admitted,
                sv.completed,
                sv.shed,
                sv.shed_rate(),
                sv.degraded,
                sv.expired,
                sv.late,
                sv.deadline_miss_rate(),
                sv.aged,
                sv.max_queue_depth
            ));
            s.push_str(&format!(
                "    queue wait   p50 {:.0}us p90 {:.0}us p99 {:.0}us p999 {:.0}us \
                 (mean {:.0}us, max {:.0}us)\n",
                sv.queue_wait.percentile_us(0.50),
                sv.queue_wait.percentile_us(0.90),
                sv.queue_wait.percentile_us(0.99),
                sv.queue_wait.percentile_us(0.999),
                sv.queue_wait.mean_us(),
                sv.queue_wait.max_us
            ));
            s.push_str(&format!(
                "    service time p50 {:.0}us p90 {:.0}us p99 {:.0}us p999 {:.0}us \
                 (mean {:.0}us, max {:.0}us)\n",
                sv.service_time.percentile_us(0.50),
                sv.service_time.percentile_us(0.90),
                sv.service_time.percentile_us(0.99),
                sv.service_time.percentile_us(0.999),
                sv.service_time.mean_us(),
                sv.service_time.max_us
            ));
            for c in &sv.per_class {
                s.push_str(&format!(
                    "    class {} (w{}, cap {}): {} served ({:.3} share), {} shed, \
                     {} miss ({:.3}), degraded {}+{}, {} aged, queue p99 {:.0}us \
                     (peak {})\n",
                    c.name,
                    c.weight,
                    c.capacity,
                    c.completed,
                    c.served_fraction(sv.completed),
                    c.shed,
                    c.expired + c.late,
                    c.deadline_miss_rate(),
                    c.degraded_half,
                    c.degraded_quarter,
                    c.aged,
                    c.queue_wait.percentile_us(0.99),
                    c.max_queue_depth
                ));
            }
        }
        if !self.tenants.is_empty() {
            s.push_str(&format!("  tenants: {}\n", self.tenants.len()));
            for t in &self.tenants {
                s.push_str(&format!(
                    "    tenant {}{}: {} admitted / {} submitted ({} throttled, \
                     rate {:.3}), {} completed, {} job-units ({} in flight), \
                     queue p99 {:.0}us\n",
                    t.name,
                    if t.priority { " [priority]" } else { "" },
                    t.admitted,
                    t.submitted,
                    t.throttled,
                    t.throttle_rate(),
                    t.completed,
                    t.job_units,
                    t.units_in_flight,
                    t.queue_wait.percentile_us(0.99)
                ));
            }
        }
        if !self.shards.is_empty() {
            s.push_str(&format!(
                "  shards: {} (steals {}, aggregate {:.0} jobs/s)\n",
                self.shards.len(),
                self.steals,
                self.agg_jobs_per_s
            ));
            for sh in &self.shards {
                s.push_str(&format!(
                    "    shard {}{}: handled {} (affine {}, stolen {}), occupancy {:.2}, \
                     queue {} (peak {})\n",
                    sh.shard,
                    if sh.retired { " [retired]" } else { "" },
                    sh.handled,
                    sh.affine,
                    sh.stolen,
                    sh.occupancy,
                    sh.queue_depth,
                    sh.max_queue_depth
                ));
            }
        }
        if !self.backends.is_empty() {
            s.push_str(&format!("  backends: {}\n", self.backends.len()));
            for b in &self.backends {
                s.push_str(&format!(
                    "    {}{}: served {} (failed {}), mean {:.0}us, validate {}/{} \
                     mismatched\n",
                    b.name,
                    if b.quarantined { " [quarantined]" } else { "" },
                    b.served,
                    b.failed,
                    b.mean_service_us,
                    b.validate_mismatches,
                    b.validate_checks
                ));
            }
        }
        if self.arena.lease_hits + self.arena.lease_misses > 0 {
            let a = &self.arena;
            s.push_str(&format!(
                "  arena: {}/{} slots in use (x{} points, high water {}), \
                 {} lease hits / {} misses, {} releases\n",
                a.in_use,
                a.slots,
                a.slot_points,
                a.high_water,
                a.lease_hits,
                a.lease_misses,
                a.releases
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    #[test]
    fn observe_and_snapshot() {
        let m = Metrics::default();
        let mut p = Profile::new(771.0);
        p.record(OpClass::Fp, 771); // 1 us of virtual time
        m.observe(Workload::Fft, 256, 120.0, Some(&p));
        m.observe(Workload::Fft, 256, 80.0, None);
        m.observe_error();
        let s = m.snapshot();
        assert_eq!(s.served, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.by_points[&256], 2);
        assert_eq!(s.by_workload[&Workload::Fft], 2);
        assert!(!s.by_workload.contains_key(&Workload::Ntt));
        assert!((s.mean_wall_us - 100.0).abs() < 1e-9);
        assert!((s.virtual_us - 1.0).abs() < 1e-9);
        assert_eq!(s.efficiency_pct(), 100.0);
    }

    #[test]
    fn percentiles_from_histogram() {
        let m = Metrics::default();
        for _ in 0..99 {
            m.observe(Workload::Fft, 256, 40.0, None);
        }
        m.observe(Workload::Fft, 256, 9000.0, None);
        let s = m.snapshot();
        assert_eq!(s.latency_percentile_us(0.5), 50.0);
        assert_eq!(s.latency_percentile_us(0.999), 10_000.0);
    }

    #[test]
    fn render_contains_counts() {
        let m = Metrics::default();
        m.observe(Workload::Fft, 1024, 10.0, None);
        assert!(m.snapshot().render().contains("fft1024: 1 jobs"));
    }

    /// The per-workload split only renders once NTT traffic exists —
    /// an all-FFT stack keeps its legacy output byte-for-byte.
    #[test]
    fn workload_split_accounting_and_render() {
        let m = Metrics::default();
        m.observe(Workload::Fft, 256, 10.0, None);
        assert!(!m.snapshot().render().contains("workloads:"));
        m.observe(Workload::Ntt, 256, 10.0, None);
        m.observe(Workload::Ntt, 1024, 12.0, None);
        let s = m.snapshot();
        assert_eq!(s.by_workload[&Workload::Fft], 1);
        assert_eq!(s.by_workload[&Workload::Ntt], 2);
        assert_eq!(s.served, 3, "the aggregate keeps counting both workloads");
        assert!(s.render().contains("workloads: 1 fft / 2 ntt jobs"), "{}", s.render());
    }

    #[test]
    fn batch_occupancy_accounting() {
        let m = Metrics::default();
        m.observe_batch(8);
        m.observe_batch(4);
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.batched_jobs, 12);
        assert_eq!(s.max_batch_jobs, 8);
        assert!((s.mean_batch_occupancy() - 6.0).abs() < 1e-12);
        assert!(s.render().contains("mean occupancy 6.0"));
    }

    #[test]
    fn empty_snapshot_reports_zero_occupancy_and_cache() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        assert_eq!(s.plan_cache.lookups(), 0);
        assert_eq!(s.plan_cache.hit_rate(), 0.0);
        assert!(s.shards.is_empty());
        assert!(s.tenants.is_empty());
        assert_eq!(s.steals, 0);
        assert_eq!(s.agg_jobs_per_s, 0.0);
    }

    #[test]
    fn latency_recorder_buckets_and_percentiles() {
        let r = LatencyRecorder::default();
        for _ in 0..90 {
            r.record(12.0); // bit length 4 -> bucket 4, upper bound 16
        }
        for _ in 0..9 {
            r.record(900.0); // bit length 10 -> bucket 10, upper bound 1024
        }
        r.record(60_000.0); // bit length 16 -> bucket 16, upper bound 65536
        let s = r.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.percentile_us(0.50), 16.0);
        assert_eq!(s.percentile_us(0.90), 16.0);
        assert_eq!(s.percentile_us(0.99), 1024.0);
        assert_eq!(s.percentile_us(0.999), 65_536.0);
        assert_eq!(s.max_us, 60_000.0);
        assert!((s.mean_us() - (90.0 * 12.0 + 9.0 * 900.0 + 60_000.0) / 100.0).abs() < 1.0);
    }

    #[test]
    fn latency_recorder_edge_cases() {
        let r = LatencyRecorder::default();
        assert_eq!(r.snapshot().percentile_us(0.99), 0.0);
        assert_eq!(r.snapshot().mean_us(), 0.0);
        r.record(0.0);
        r.record(1e18); // clamps into the last bucket
        let s = r.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.percentile_us(0.0), 1.0);
        assert!(s.percentile_us(1.0) >= (1u64 << (LATENCY_LOG_BUCKETS - 1)) as f64);
    }

    #[test]
    fn latency_delta_isolates_the_interval() {
        let r = LatencyRecorder::default();
        for _ in 0..50 {
            r.record(12.0);
        }
        let first = r.snapshot();
        for _ in 0..10 {
            r.record(900.0);
        }
        let iv = r.snapshot().delta_since(&first);
        assert_eq!(iv.count, 10, "only the new samples");
        assert_eq!(iv.percentile_us(0.50), 1024.0, "interval p50 sees only the slow burst");
        assert!((iv.mean_us() - 900.0).abs() < 1.0);
        let empty = r.snapshot().delta_since(&r.snapshot());
        assert_eq!(empty.count, 0);
        assert_eq!(empty.percentile_us(0.99), 0.0);
    }

    #[test]
    fn server_stats_interval_since_subtracts_counters() {
        let prev = ServerStats {
            submitted: 100,
            admitted: 90,
            completed: 80,
            shed: 10,
            ..Default::default()
        };
        let cur = ServerStats {
            submitted: 150,
            admitted: 130,
            completed: 120,
            shed: 20,
            max_queue_depth: 64,
            ..Default::default()
        };
        let iv = cur.interval_since(&prev);
        assert_eq!(iv.submitted, 50);
        assert_eq!(iv.admitted, 40);
        assert_eq!(iv.completed, 40);
        assert_eq!(iv.shed, 10);
        assert!((iv.shed_rate() - 0.2).abs() < 1e-12, "interval shed rate, not cumulative");
        assert_eq!(iv.max_queue_depth, 64, "peaks stay cumulative");
        let noop = cur.interval_since(&cur);
        assert_eq!(noop.submitted, 0);
        assert_eq!(noop.shed_rate(), 0.0);
    }

    #[test]
    fn retired_shards_render_with_stable_ids() {
        let mut s = Metrics::default().snapshot();
        s.shards = vec![
            ShardStat { shard: 0, handled: 10, ..Default::default() },
            ShardStat { shard: 3, handled: 4, retired: true, ..Default::default() },
        ];
        let out = s.render();
        assert!(out.contains("shard 0: handled 10"), "{out}");
        assert!(out.contains("shard 3 [retired]: handled 4"), "{out}");
    }

    #[test]
    fn server_stats_rates_and_accounting() {
        let mut sv = ServerStats { submitted: 10, admitted: 8, shed: 2, ..Default::default() };
        sv.completed = 6;
        sv.expired = 1;
        sv.failed = 1;
        sv.late = 1;
        assert!((sv.shed_rate() - 0.2).abs() < 1e-12);
        assert!((sv.deadline_miss_rate() - 0.25).abs() < 1e-12);
        assert!(sv.accounted());
        sv.completed = 5;
        assert!(!sv.accounted());
        assert_eq!(ServerStats::default().shed_rate(), 0.0);
        assert_eq!(ServerStats::default().deadline_miss_rate(), 0.0);
    }

    #[test]
    fn class_stats_rates_interval_and_render() {
        let prev = ClassStats {
            name: "gold".into(),
            weight: 5,
            capacity: 64,
            submitted: 10,
            admitted: 8,
            completed: 6,
            shed: 2,
            expired: 1,
            late: 1,
            degraded_half: 1,
            ..Default::default()
        };
        assert!((prev.deadline_miss_rate() - 0.25).abs() < 1e-12);
        assert!((prev.served_fraction(12) - 0.5).abs() < 1e-12);
        assert_eq!(prev.degraded(), 1);
        assert_eq!(ClassStats::default().deadline_miss_rate(), 0.0);
        assert_eq!(ClassStats::default().served_fraction(0), 0.0);

        let cur = ClassStats { submitted: 25, admitted: 20, completed: 15, ..prev.clone() };
        let mut a = ServerStats { per_class: vec![prev], ..Default::default() };
        let b = ServerStats { per_class: vec![cur], ..Default::default() };
        let iv = b.interval_since(&a);
        assert_eq!(iv.per_class[0].submitted, 15);
        assert_eq!(iv.per_class[0].completed, 9);
        assert_eq!(iv.per_class[0].name, "gold");
        // a fresh meter (empty prev) sees the whole history
        a.per_class.clear();
        assert_eq!(b.interval_since(&a).per_class[0].submitted, 25);

        let mut snap = Metrics::default().snapshot();
        snap.server = b;
        snap.server.submitted = 25;
        snap.server.completed = 15;
        let out = snap.render();
        assert!(out.contains("class gold (w5, cap 64)"), "{out}");
    }

    #[test]
    fn multipass_stats_render_only_with_traffic() {
        let mut s = Metrics::default().snapshot();
        assert_eq!(s.multipass, MultipassSnapshot::default());
        assert!(!s.render().contains("multipass:"));
        s.multipass = MultipassSnapshot {
            requests: 3,
            completed: 2,
            reserved: 2,
            spilled: 1,
            preempted: 1,
            yielded: 4,
            row_jobs: 192,
            col_jobs: 384,
        };
        assert_eq!(s.multipass.stage_jobs(), 576);
        let out = s.render();
        assert!(
            out.contains("multipass: 3 requests (2 completed, 1 preempted, 4 yielded)"),
            "{out}"
        );
        assert!(out.contains("2 reserved / 1 spilled"), "{out}");
        assert!(out.contains("192 row + 384 col sub-jobs"), "{out}");
    }

    #[test]
    fn tenant_stats_rates_and_render() {
        let t = TenantStats {
            name: "abuser".into(),
            submitted: 100,
            admitted: 40,
            throttled: 60,
            completed: 38,
            job_units: 38,
            units_in_flight: 2,
            ..Default::default()
        };
        assert!((t.throttle_rate() - 0.6).abs() < 1e-12);
        assert_eq!(TenantStats::default().throttle_rate(), 0.0);

        let mut s = Metrics::default().snapshot();
        assert!(!s.render().contains("tenants:"));
        s.tenants = vec![
            TenantStats { name: "victim".into(), priority: true, ..Default::default() },
            t,
        ];
        let out = s.render();
        assert!(out.contains("tenants: 2"), "{out}");
        assert!(out.contains("tenant victim [priority]:"), "{out}");
        assert!(
            out.contains("tenant abuser: 40 admitted / 100 submitted (60 throttled, rate 0.600)"),
            "{out}"
        );
        assert!(out.contains("38 job-units (2 in flight)"), "{out}");
    }

    #[test]
    fn render_includes_frontend_section() {
        let mut s = Metrics::default().snapshot();
        assert!(!s.render().contains("frontend:"));
        s.server.submitted = 4;
        s.server.admitted = 3;
        s.server.shed = 1;
        let out = s.render();
        assert!(out.contains("frontend: 4 submitted, 3 admitted"), "{out}");
        assert!(out.contains("queue wait"), "{out}");
        assert!(out.contains("service time"), "{out}");
    }

    #[test]
    fn backend_stats_render() {
        let mut s = Metrics::default().snapshot();
        assert!(!s.render().contains("backends:"));
        s.backends = vec![
            BackendStat {
                name: "sim".into(),
                served: 90,
                mean_service_us: 1500.0,
                ..Default::default()
            },
            BackendStat {
                name: "pjrt".into(),
                served: 10,
                failed: 1,
                validate_checks: 5,
                validate_mismatches: 1,
                quarantined: true,
                mean_service_us: 80.0,
                ..Default::default()
            },
        ];
        let out = s.render();
        assert!(out.contains("backends: 2"), "{out}");
        assert!(out.contains("sim: served 90 (failed 0), mean 1500us"), "{out}");
        assert!(out.contains("pjrt [quarantined]: served 10 (failed 1)"), "{out}");
        assert!(out.contains("validate 1/5 mismatched"), "{out}");
    }

    #[test]
    fn shard_stats_render() {
        let mut s = Metrics::default().snapshot();
        s.steals = 3;
        s.agg_jobs_per_s = 1234.0;
        s.shards = vec![
            ShardStat {
                shard: 0,
                handled: 10,
                affine: 8,
                stolen: 2,
                occupancy: 0.5,
                ..Default::default()
            },
            ShardStat { shard: 1, handled: 4, affine: 4, ..Default::default() },
        ];
        let out = s.render();
        assert!(out.contains("shards: 2 (steals 3, aggregate 1234 jobs/s)"), "{out}");
        assert!(out.contains("shard 0: handled 10 (affine 8, stolen 2)"), "{out}");
        assert!(out.contains("shard 1: handled 4"), "{out}");
    }
}
