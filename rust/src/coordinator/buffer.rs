//! Zero-copy job buffers: a slab/arena of fixed-capacity FFT payload
//! buffers ([`JobArena`] leasing [`JobSlot`]s) plus the bounded SPSC
//! [`JobRing`] the sharded dispatcher uses instead of per-shard MPSC
//! channels.
//!
//! The memory discipline is *lease → compute-in-place → reply →
//! release*: admission moves a request's samples into a leased slot
//! once, every layer after that passes the same slot by move (never
//! cloning the payload), the executor writes the transform back into
//! the slot it read from, and the reply hands that slot to the caller.
//! Dropping the slot returns its buffer to the arena free list, so
//! steady-state serving performs zero per-job heap allocations on the
//! lease-hit path. When the arena is exhausted (or a payload exceeds
//! the slot capacity) a lease falls back to an ordinary heap `Vec` —
//! counted as a miss, never an error — so exhaustion degrades
//! gracefully instead of rejecting or deadlocking.

use std::collections::VecDeque;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::fft::multipass::MAX_SINGLE_PASS_POINTS;

/// Slots in the process-global arena ([`JobArena::global`]). 64 slots
/// of 4096 complex points is ~2 MiB resident — enough to cover every
/// in-flight single-pass job across the default service shapes (the
/// frontend's per-class queues and the executors' in-flight window),
/// small enough to pin permanently.
pub const GLOBAL_ARENA_SLOTS: usize = 64;

/// The arena's shared state: the free list plus lease/release counters.
/// Held behind an `Arc` so leased [`JobSlot`]s can find their way home
/// from any thread, in any order, without a registry.
struct ArenaShared {
    /// Capacity of every pooled buffer, in complex points.
    slot_points: usize,
    /// Total pooled buffers (free + leased).
    slots: usize,
    /// Buffers currently at home. Every entry has
    /// `capacity() >= slot_points` and `len() == 0`.
    free: Mutex<Vec<Vec<(f32, f32)>>>,
    /// Leases served from the pool.
    lease_hits: AtomicU64,
    /// Leases that fell back to a heap allocation (pool empty, or the
    /// payload exceeds `slot_points`).
    lease_misses: AtomicU64,
    /// Pooled buffers returned by a dropped slot.
    releases: AtomicU64,
    /// Pooled buffers currently leased out.
    in_use: AtomicUsize,
    /// Peak of `in_use` over the arena's lifetime.
    high_water: AtomicUsize,
}

impl ArenaShared {
    fn release(&self, mut buf: Vec<(f32, f32)>) {
        buf.clear();
        self.releases.fetch_add(1, Ordering::Relaxed);
        self.in_use.fetch_sub(1, Ordering::Relaxed);
        self.free.lock().unwrap().push(buf);
    }
}

/// A point-in-time copy of a [`JobArena`]'s occupancy and lease
/// counters, surfaced in `MetricsSnapshot::arena`. `lease_hits ==
/// jobs_served` over a steady-state window is the zero-allocation
/// proof the hotpath bench asserts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Total pooled buffers (free + leased).
    pub slots: usize,
    /// Capacity of each pooled buffer, in complex points.
    pub slot_points: usize,
    /// Buffers currently at home on the free list.
    pub free_slots: usize,
    /// Buffers currently leased out.
    pub in_use: usize,
    /// Peak simultaneous leases observed.
    pub high_water: usize,
    /// Leases served from the pool (no heap allocation).
    pub lease_hits: u64,
    /// Leases that fell back to a heap allocation.
    pub lease_misses: u64,
    /// Buffers returned to the pool by dropped slots.
    pub releases: u64,
}

/// A slab arena of fixed-capacity `Vec<(f32, f32)>` payload buffers.
/// Cheaply cloneable (it is an `Arc` handle); [`JobArena::global`] is
/// the process-wide instance every service layer leases from.
#[derive(Clone)]
pub struct JobArena {
    shared: Arc<ArenaShared>,
}

impl JobArena {
    /// A new arena of `slots` buffers, each holding up to `slot_points`
    /// complex points. All buffers are allocated up front; the arena
    /// never grows or shrinks.
    pub fn new(slots: usize, slot_points: usize) -> JobArena {
        let free = (0..slots).map(|_| Vec::with_capacity(slot_points)).collect();
        JobArena {
            shared: Arc::new(ArenaShared {
                slot_points,
                slots,
                free: Mutex::new(free),
                lease_hits: AtomicU64::new(0),
                lease_misses: AtomicU64::new(0),
                releases: AtomicU64::new(0),
                in_use: AtomicUsize::new(0),
                high_water: AtomicUsize::new(0),
            }),
        }
    }

    /// The process-global arena: [`GLOBAL_ARENA_SLOTS`] slots sized to
    /// the single-pass ceiling (the largest payload one executor job
    /// carries — larger requests decompose into sub-jobs at or under
    /// it).
    pub fn global() -> &'static JobArena {
        static GLOBAL: OnceLock<JobArena> = OnceLock::new();
        GLOBAL.get_or_init(|| JobArena::new(GLOBAL_ARENA_SLOTS, MAX_SINGLE_PASS_POINTS))
    }

    /// Lease an empty slot able to hold `points` complex points. Served
    /// from the pool when `points` fits a pooled buffer and one is
    /// free (a *hit*); otherwise falls back to a fresh heap buffer (a
    /// *miss*) — never blocks, never fails.
    pub fn lease(&self, points: usize) -> JobSlot {
        if points <= self.shared.slot_points {
            if let Some(buf) = self.shared.free.lock().unwrap().pop() {
                self.shared.lease_hits.fetch_add(1, Ordering::Relaxed);
                let now = self.shared.in_use.fetch_add(1, Ordering::Relaxed) + 1;
                self.shared.high_water.fetch_max(now, Ordering::Relaxed);
                return JobSlot { buf, home: Some(Arc::clone(&self.shared)) };
            }
        }
        self.shared.lease_misses.fetch_add(1, Ordering::Relaxed);
        JobSlot { buf: Vec::with_capacity(points), home: None }
    }

    /// Lease a slot and copy `data` into it — the one memcpy a reused
    /// prototype pays per request (loadgen's steady-state path).
    pub fn lease_copy(&self, data: &[(f32, f32)]) -> JobSlot {
        let mut slot = self.lease(data.len());
        slot.buf.extend_from_slice(data);
        slot
    }

    /// Take ownership of an already-materialized payload. When the
    /// payload fits a free pooled buffer its samples are copied in (a
    /// hit: the caller's allocation is freed now, and the slot recycles
    /// forever after); otherwise the vec itself is adopted heap-backed
    /// (a miss: zero copy, freed on drop).
    pub fn adopt_or_lease(&self, data: Vec<(f32, f32)>) -> JobSlot {
        if data.len() <= self.shared.slot_points {
            if let Some(mut buf) = self.shared.free.lock().unwrap().pop() {
                self.shared.lease_hits.fetch_add(1, Ordering::Relaxed);
                let now = self.shared.in_use.fetch_add(1, Ordering::Relaxed) + 1;
                self.shared.high_water.fetch_max(now, Ordering::Relaxed);
                buf.extend_from_slice(&data);
                return JobSlot { buf, home: Some(Arc::clone(&self.shared)) };
            }
        }
        self.shared.lease_misses.fetch_add(1, Ordering::Relaxed);
        JobSlot { buf: data, home: None }
    }

    /// A point-in-time copy of the arena's counters.
    pub fn snapshot(&self) -> ArenaStats {
        ArenaStats {
            slots: self.shared.slots,
            slot_points: self.shared.slot_points,
            free_slots: self.shared.free.lock().unwrap().len(),
            in_use: self.shared.in_use.load(Ordering::Relaxed),
            high_water: self.shared.high_water.load(Ordering::Relaxed),
            lease_hits: self.shared.lease_hits.load(Ordering::Relaxed),
            lease_misses: self.shared.lease_misses.load(Ordering::Relaxed),
            releases: self.shared.releases.load(Ordering::Relaxed),
        }
    }
}

impl fmt::Debug for JobArena {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.snapshot();
        f.debug_struct("JobArena")
            .field("slots", &s.slots)
            .field("slot_points", &s.slot_points)
            .field("free_slots", &s.free_slots)
            .finish()
    }
}

/// One leased FFT payload buffer: the unit of data movement on the
/// serving path. A slot is either *arena-backed* (its buffer returns
/// to the pool on drop) or *heap-backed* (an adopted or fallback `Vec`,
/// freed on drop) — identical in behavior either way. Derefs to
/// `[(f32, f32)]`, so everything that read the old `Vec` payload reads
/// a slot unchanged.
pub struct JobSlot {
    buf: Vec<(f32, f32)>,
    home: Option<Arc<ArenaShared>>,
}

impl JobSlot {
    /// Shorten the payload to `points` (the degrade-ladder truncation).
    /// No-op when `points >= len()`. Capacity is untouched, so an
    /// arena-backed slot still goes home at full size.
    pub fn truncate(&mut self, points: usize) {
        self.buf.truncate(points);
    }

    /// Replace the payload with `data` in place (the executor's
    /// write-back). Reuses the slot's buffer; only grows it when
    /// `data` exceeds the current capacity.
    pub fn copy_from(&mut self, data: &[(f32, f32)]) {
        self.buf.clear();
        self.buf.extend_from_slice(data);
    }

    /// True when this slot's buffer returns to an arena on drop.
    pub fn arena_backed(&self) -> bool {
        self.home.is_some()
    }

    /// The payload as an owned `Vec`. Heap-backed slots give up their
    /// buffer without copying; arena-backed slots copy out and send
    /// their buffer home.
    pub fn into_vec(mut self) -> Vec<(f32, f32)> {
        if self.home.is_none() {
            std::mem::take(&mut self.buf)
        } else {
            self.buf.clone()
        }
    }
}

impl Drop for JobSlot {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            home.release(std::mem::take(&mut self.buf));
        }
    }
}

impl Deref for JobSlot {
    type Target = [(f32, f32)];
    fn deref(&self) -> &[(f32, f32)] {
        &self.buf
    }
}

impl DerefMut for JobSlot {
    fn deref_mut(&mut self) -> &mut [(f32, f32)] {
        &mut self.buf
    }
}

impl From<Vec<(f32, f32)>> for JobSlot {
    /// Adopt a heap `Vec` as-is: zero copy, no arena involvement, no
    /// lease counted. The staged multi-pass batches use this to wrap
    /// sub-job grids they already own.
    fn from(buf: Vec<(f32, f32)>) -> JobSlot {
        JobSlot { buf, home: None }
    }
}

impl Clone for JobSlot {
    /// A deep, heap-backed copy (clones never contend for pool slots).
    fn clone(&self) -> JobSlot {
        JobSlot { buf: self.buf.clone(), home: None }
    }
}

impl fmt::Debug for JobSlot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JobSlot")
            .field("len", &self.buf.len())
            .field("arena_backed", &self.home.is_some())
            .finish()
    }
}

impl PartialEq for JobSlot {
    fn eq(&self, other: &JobSlot) -> bool {
        self.buf == other.buf
    }
}

impl PartialEq<Vec<(f32, f32)>> for JobSlot {
    fn eq(&self, other: &Vec<(f32, f32)>) -> bool {
        self.buf == *other
    }
}

struct RingState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// A bounded FIFO ring for the dispatcher → shard-worker hop. The
/// steady-state topology is single-producer single-consumer (one
/// dispatcher routes, one worker drains), but the implementation is a
/// mutexed deque, safe under the transient multi-producer bursts the
/// routing table allows during resizes. Unlike an `mpsc` channel, a
/// push moves the job into a pre-sized ring — no per-send heap node.
pub struct JobRing<T> {
    state: Mutex<RingState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> JobRing<T> {
    /// A new ring holding at most `capacity` queued items (minimum 1).
    pub fn new(capacity: usize) -> JobRing<T> {
        let capacity = capacity.max(1);
        JobRing {
            state: Mutex::new(RingState { buf: VecDeque::with_capacity(capacity), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue `item`, blocking while the ring is full. Returns the
    /// item back when the ring has been closed (the producer's signal
    /// to re-route or fail the job).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        while st.buf.len() >= self.capacity && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.buf.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue the oldest item, blocking while the ring is open and
    /// empty. After [`close`](JobRing::close), remaining items drain in
    /// order; `None` means closed *and* empty (the consumer's exit
    /// signal).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the ring: blocked producers fail their push, the consumer
    /// drains what is queued and then sees `None`. Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> fmt::Debug for JobRing<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JobRing")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn lease_hit_reuses_the_pooled_buffer_and_counts() {
        let arena = JobArena::new(2, 16);
        let mut a = arena.lease(8);
        assert!(a.arena_backed());
        a.copy_from(&[(1.0, 2.0); 8]);
        assert_eq!(a.len(), 8);
        let s = arena.snapshot();
        assert_eq!((s.lease_hits, s.lease_misses, s.in_use, s.free_slots), (1, 0, 1, 1));
        drop(a);
        let s = arena.snapshot();
        assert_eq!((s.releases, s.in_use, s.free_slots), (1, 0, 2));
        // the returned buffer comes back empty
        let b = arena.lease(16);
        assert!(b.is_empty() && b.arena_backed());
    }

    #[test]
    fn exhaustion_and_oversize_fall_back_to_heap() {
        let arena = JobArena::new(1, 16);
        let a = arena.lease(4);
        let b = arena.lease(4); // pool exhausted
        let c = arena.lease(64); // over slot capacity
        assert!(a.arena_backed() && !b.arena_backed() && !c.arena_backed());
        let s = arena.snapshot();
        assert_eq!((s.lease_hits, s.lease_misses), (1, 2));
        assert_eq!(s.high_water, 1);
    }

    #[test]
    fn adopt_or_lease_copies_into_a_slot_when_one_is_free() {
        let arena = JobArena::new(1, 16);
        let v = vec![(3.0f32, 4.0f32); 8];
        let a = arena.adopt_or_lease(v.clone());
        assert!(a.arena_backed());
        assert_eq!(&*a, &v[..]);
        // pool now empty: the vec itself is adopted, contents intact
        let b = arena.adopt_or_lease(v.clone());
        assert!(!b.arena_backed());
        assert_eq!(&*b, &v[..]);
        assert_eq!(b.into_vec(), v);
    }

    #[test]
    fn slot_clone_is_heap_backed_and_equal() {
        let arena = JobArena::new(1, 8);
        let a = arena.lease_copy(&[(1.0, 0.0), (2.0, 0.0)]);
        let b = a.clone();
        assert!(!b.arena_backed());
        assert_eq!(a, b);
        assert_eq!(a, vec![(1.0, 0.0), (2.0, 0.0)]);
    }

    #[test]
    fn into_vec_round_trips_and_releases() {
        let arena = JobArena::new(1, 8);
        let a = arena.lease_copy(&[(5.0, 6.0)]);
        assert_eq!(a.into_vec(), vec![(5.0, 6.0)]);
        assert_eq!(arena.snapshot().free_slots, 1, "arena-backed into_vec releases");
        let v: JobSlot = vec![(7.0, 8.0)].into();
        assert_eq!(v.into_vec(), vec![(7.0, 8.0)]);
    }

    #[test]
    fn truncate_shortens_but_keeps_the_slot_home() {
        let arena = JobArena::new(1, 8);
        let mut a = arena.lease_copy(&[(1.0, 0.0); 8]);
        a.truncate(2);
        assert_eq!(a.len(), 2);
        drop(a);
        assert_eq!(arena.snapshot().free_slots, 1);
    }

    #[test]
    fn ring_is_fifo_and_drains_after_close() {
        let ring = JobRing::new(8);
        for i in 0..5 {
            ring.push(i).unwrap();
        }
        ring.close();
        assert_eq!(ring.push(99), Err(99), "push after close returns the item");
        let drained: Vec<i32> = std::iter::from_fn(|| ring.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn full_ring_blocks_the_producer_until_a_pop() {
        let ring = Arc::new(JobRing::new(2));
        ring.push(1).unwrap();
        ring.push(2).unwrap();
        let r2 = Arc::clone(&ring);
        let producer = thread::spawn(move || r2.push(3));
        thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(ring.len(), 2, "third push must be blocked");
        assert_eq!(ring.pop(), Some(1));
        assert_eq!(producer.join().unwrap(), Ok(()));
        assert_eq!(ring.pop(), Some(2));
        assert_eq!(ring.pop(), Some(3));
    }

    #[test]
    fn spsc_order_is_preserved_across_threads() {
        let ring = Arc::new(JobRing::new(4));
        let producer = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for i in 0..1000u32 {
                    ring.push(i).unwrap();
                }
                ring.close();
            })
        };
        let consumer = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = ring.pop() {
                    got.push(v);
                }
                got
            })
        };
        producer.join().unwrap();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..1000u32).collect::<Vec<_>>());
    }
}
