//! SLO-driven shard autoscaler: capacity follows traffic.
//!
//! "A Statically and Dynamically Scalable Soft GPGPU" (arXiv:2401.04261)
//! argues that a soft GPGPU approaches IP-core efficiency only when its
//! compute-unit count is sized to the workload — and that the sizing
//! should be *dynamic*. Our serving stack measures exactly the demand
//! signals that paper proposes reacting to: queue depth, shed rate and
//! deadline misses, per [`PressureSample`]. This module closes the
//! loop: an [`AutoscaleController`] consumes the traffic frontend's
//! periodic pressure feed and grows or shrinks the shard pool of the
//! running [`super::ShardedFftService`] against an SLO target.
//!
//! The control law ([`ControllerCore::decide`]) is deliberately simple
//! and fully unit-testable:
//!
//! * **scale up** (one shard) when the interval shed rate exceeds
//!   [`AutoscalePolicy::max_shed_rate`] or the interval queue-wait p99
//!   exceeds `target_p99_ms * scale_up_threshold`, the pool is below
//!   `max_shards`, and `scale_up_cooldown` has elapsed since the last
//!   resize;
//! * **scale down** (one shard) when nothing was shed, the queue-wait
//!   p99 is below `target_p99_ms * scale_down_threshold`, the
//!   admission queue is shallow, the pool is above `min_shards`, and
//!   `scale_down_cooldown` has elapsed — so the pool drains back to
//!   `min_shards` when traffic goes away;
//! * **hold** otherwise.
//!
//! The SLO targets *queue wait*, not service time: adding shards
//! removes queueing, while per-job service time is a property of the
//! workload — gating on it would make the controller chase a signal it
//! cannot move. Cooldowns are asymmetric by default (scale up fast,
//! scale down slowly) so a bursty workload does not thrash the pool.
//!
//! Shutdown order matters: [`AutoscaleController::stop`] first (it
//! holds a clone of the server's service handle), then
//! `TrafficServer::shutdown`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::server::{PressureSample, ServiceHandle, TrafficServer};

/// The SLO target and actuation limits for one controller.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// The pool never shrinks below this many shards.
    pub min_shards: usize,
    /// The pool never grows beyond this many shards. Must not exceed
    /// the server's `ServerConfig::dispatchers` (the backend in-flight
    /// bound) — shards beyond it can never receive concurrent work, so
    /// [`AutoscaleController::spawn`] rejects such a pairing.
    pub max_shards: usize,
    /// SLO: interval queue-wait p99 target, milliseconds.
    pub target_p99_ms: f64,
    /// SLO: maximum tolerable interval shed rate (fraction of
    /// submissions rejected at admission).
    pub max_shed_rate: f64,
    /// Scale up once the interval p99 exceeds `target_p99_ms` times
    /// this factor (1.0 = react exactly at the SLO; below 1.0 reacts
    /// early, leaving headroom).
    pub scale_up_threshold: f64,
    /// Scale down only while the interval p99 is below `target_p99_ms`
    /// times this factor (and nothing is being shed).
    pub scale_down_threshold: f64,
    /// Minimum time between a resize and the next scale-up.
    pub scale_up_cooldown: Duration,
    /// Minimum time between a resize and the next scale-down (longer
    /// than the scale-up cooldown by default: grow fast, shrink slow).
    pub scale_down_cooldown: Duration,
    /// Pressure-feed sampling interval.
    pub interval: Duration,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_shards: 1,
            // Capped at ServerConfig::default()'s dispatcher count so
            // the two defaults compose on any host — raise both
            // together for wider pools.
            max_shards: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .min(4),
            target_p99_ms: 10.0,
            max_shed_rate: 0.01,
            scale_up_threshold: 1.0,
            scale_down_threshold: 0.25,
            scale_up_cooldown: Duration::from_millis(250),
            scale_down_cooldown: Duration::from_secs(2),
            interval: Duration::from_millis(50),
        }
    }
}

impl AutoscalePolicy {
    pub fn validate(&self) -> Result<()> {
        if self.min_shards == 0 {
            return Err(anyhow!("min_shards must be at least 1"));
        }
        if self.max_shards < self.min_shards {
            return Err(anyhow!(
                "max_shards ({}) must be >= min_shards ({})",
                self.max_shards,
                self.min_shards
            ));
        }
        if self.target_p99_ms <= 0.0 {
            return Err(anyhow!("target_p99_ms must be positive"));
        }
        if !(0.0..=1.0).contains(&self.max_shed_rate) {
            return Err(anyhow!("max_shed_rate must be in [0, 1]"));
        }
        if self.scale_down_threshold >= self.scale_up_threshold {
            return Err(anyhow!(
                "scale_down_threshold ({}) must be below scale_up_threshold ({}) \
                 or the controller oscillates",
                self.scale_down_threshold,
                self.scale_up_threshold
            ));
        }
        if self.interval.is_zero() {
            return Err(anyhow!("interval must be positive"));
        }
        Ok(())
    }
}

/// What the control law decided for one sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    Up,
    Down,
    Hold,
}

/// The pure control law: policy + cooldown state, no threads, no
/// service — fully unit-testable by feeding synthetic samples.
pub struct ControllerCore {
    policy: AutoscalePolicy,
    /// Last resize (initialized to construction time, so the first
    /// action waits out a full cooldown — a freshly started controller
    /// never reacts to an empty first interval).
    last_resize: Instant,
}

impl ControllerCore {
    pub fn new(policy: AutoscalePolicy) -> Self {
        ControllerCore { policy, last_resize: Instant::now() }
    }

    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }

    /// Decide on one sample, given the current shard count. Returning
    /// `Up`/`Down` records the resize for cooldown purposes — the
    /// caller is expected to apply it.
    pub fn decide(&mut self, s: &PressureSample, shards: usize) -> ScaleAction {
        let p99_ms = s.queue_p99_us / 1e3;
        let since_resize = s.at.checked_duration_since(self.last_resize).unwrap_or_default();
        let overloaded = s.shed_rate > self.policy.max_shed_rate
            || p99_ms > self.policy.target_p99_ms * self.policy.scale_up_threshold;
        if overloaded {
            if shards < self.policy.max_shards && since_resize >= self.policy.scale_up_cooldown {
                self.last_resize = s.at;
                return ScaleAction::Up;
            }
            return ScaleAction::Hold;
        }
        let underloaded = s.shed == 0
            && p99_ms < self.policy.target_p99_ms * self.policy.scale_down_threshold
            && s.queue_depth <= shards;
        if underloaded
            && shards > self.policy.min_shards
            && since_resize >= self.policy.scale_down_cooldown
        {
            self.last_resize = s.at;
            return ScaleAction::Down;
        }
        ScaleAction::Hold
    }
}

/// One applied resize, for the log.
#[derive(Clone, Debug)]
pub struct AutoscaleEvent {
    /// Seconds since the controller started.
    pub at_s: f64,
    pub from_shards: usize,
    pub to_shards: usize,
    /// Human-readable trigger (which SLO signal fired, with values).
    pub reason: String,
}

/// One observed sample, for shards-over-time reporting.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleSample {
    /// Seconds since the controller started.
    pub at_s: f64,
    /// Shard count *after* any action this tick applied.
    pub shards: usize,
    pub queue_depth: usize,
    pub shed_rate: f64,
    /// Interval queue-wait p99, milliseconds.
    pub queue_p99_ms: f64,
    pub action: ScaleAction,
}

/// Everything a controller run observed and did.
#[derive(Clone, Debug, Default)]
pub struct AutoscaleLog {
    pub samples: Vec<AutoscaleSample>,
    pub events: Vec<AutoscaleEvent>,
}

impl AutoscaleLog {
    /// `(seconds, shards)` per tick — the bench's shards-over-time
    /// series.
    pub fn shards_over_time(&self) -> Vec<(f64, usize)> {
        self.samples.iter().map(|s| (s.at_s, s.shards)).collect()
    }

    /// Seconds from `from_s` until the first subsequent sample meeting
    /// both SLO thresholds (shed rate and queue-wait p99), or `None`
    /// if the run never recovered.
    pub fn recovery_after_s(&self, from_s: f64, policy: &AutoscalePolicy) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.at_s >= from_s
                    && s.shed_rate <= policy.max_shed_rate
                    && s.queue_p99_ms <= policy.target_p99_ms
            })
            .map(|s| s.at_s - from_s)
    }

    pub fn render(&self) -> String {
        let ups = self.events.iter().filter(|e| e.to_shards > e.from_shards).count();
        let downs = self.events.len() - ups;
        let span = self.samples.last().map(|s| s.at_s).unwrap_or(0.0);
        let mut s = format!(
            "autoscale: {} scale-up(s), {} scale-down(s) over {:.1}s ({} samples)\n",
            ups,
            downs,
            span,
            self.samples.len()
        );
        for e in &self.events {
            s.push_str(&format!(
                "  t={:>6.2}s  {} -> {} shards  ({})\n",
                e.at_s, e.from_shards, e.to_shards, e.reason
            ));
        }
        if !self.samples.is_empty() {
            let series = self
                .samples
                .iter()
                .map(|p| p.shards.to_string())
                .collect::<Vec<_>>()
                .join(" ");
            s.push_str(&format!("  shards over time: {series}\n"));
        }
        s
    }
}

/// The running feedback controller: a thread consuming the server's
/// pressure feed and resizing the sharded backend against the policy.
pub struct AutoscaleController {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<AutoscaleLog>>,
}

impl AutoscaleController {
    /// Start a controller over `server`'s backend. Fails when the
    /// policy is invalid or the server does not wrap the sharded
    /// (resizable) service.
    pub fn spawn(server: &TrafficServer, policy: AutoscalePolicy) -> Result<Self> {
        policy.validate()?;
        let service = server.service();
        if service.as_sharded().is_none() {
            return Err(anyhow!(
                "autoscaling requires ServiceHandle::Sharded (the pool service is not resizable)"
            ));
        }
        // The dispatcher pool bounds backend in-flight work, so shards
        // beyond it add zero capacity: scaling past it would weld the
        // pool at max with the SLO never recovering.
        let dispatchers = server.config().dispatchers;
        if policy.max_shards > dispatchers {
            return Err(anyhow!(
                "max_shards ({}) exceeds the server's dispatcher count ({}): shards \
                 beyond the in-flight bound add no capacity — raise \
                 ServerConfig::dispatchers or lower max_shards",
                policy.max_shards,
                dispatchers
            ));
        }
        let feed = server.pressure_feed(policy.interval);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || controller_loop(feed, service, policy, stop2));
        Ok(AutoscaleController { stop, thread: Some(thread) })
    }

    /// Stop the controller and return everything it observed and did.
    /// This drops the controller's service handle, so call it *before*
    /// `TrafficServer::shutdown`.
    pub fn stop(mut self) -> AutoscaleLog {
        self.stop.store(true, Ordering::Release);
        self.thread
            .take()
            .map(|t| t.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for AutoscaleController {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn controller_loop(
    feed: std::sync::mpsc::Receiver<PressureSample>,
    service: Arc<ServiceHandle>,
    policy: AutoscalePolicy,
    stop: Arc<AtomicBool>,
) -> AutoscaleLog {
    let started = Instant::now();
    let target_ms = policy.target_p99_ms;
    let max_shed = policy.max_shed_rate;
    let mut core = ControllerCore::new(policy.clone());
    let mut log = AutoscaleLog::default();
    let sharded = service.as_sharded().expect("validated in spawn");
    while !stop.load(Ordering::Acquire) {
        let sample = match feed.recv_timeout(policy.interval) {
            Ok(s) => s,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let shards = sharded.shards();
        let action = core.decide(&sample, shards);
        let at_s = sample.at.checked_duration_since(started).unwrap_or_default().as_secs_f64();
        let p99_ms = sample.queue_p99_us / 1e3;
        let after = match action {
            ScaleAction::Up => {
                sharded.add_shard();
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards + 1,
                    reason: format!(
                        "shed rate {:.3} (SLO {:.3}), queue p99 {:.1}ms (SLO {:.1}ms)",
                        sample.shed_rate, max_shed, p99_ms, target_ms
                    ),
                });
                shards + 1
            }
            ScaleAction::Down => match sharded.retire_shard() {
                Ok(_) => {
                    log.events.push(AutoscaleEvent {
                        at_s,
                        from_shards: shards,
                        to_shards: shards - 1,
                        reason: format!(
                            "idle: no shedding, queue p99 {:.1}ms well under {:.1}ms SLO",
                            p99_ms, target_ms
                        ),
                    });
                    shards - 1
                }
                Err(_) => shards, // raced shutdown; nothing to do
            },
            ScaleAction::Hold => shards,
        };
        log.samples.push(AutoscaleSample {
            at_s,
            shards: after,
            queue_depth: sample.queue_depth,
            shed_rate: sample.shed_rate,
            queue_p99_ms: p99_ms,
            action,
        });
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 10.0,
            max_shed_rate: 0.05,
            scale_up_threshold: 1.0,
            scale_down_threshold: 0.25,
            scale_up_cooldown: Duration::from_millis(100),
            scale_down_cooldown: Duration::from_millis(400),
            interval: Duration::from_millis(25),
        }
    }

    fn sample(
        at: Instant,
        shed_rate: f64,
        queue_p99_us: f64,
        queue_depth: usize,
    ) -> PressureSample {
        PressureSample {
            at,
            queue_depth,
            submitted: 100,
            completed: 90,
            shed: if shed_rate > 0.0 { (shed_rate * 100.0) as u64 } else { 0 },
            expired: 0,
            shed_rate,
            deadline_miss_rate: 0.0,
            queue_p99_us,
            service_p99_us: 500.0,
        }
    }

    #[test]
    fn policy_validation_rejects_nonsense() {
        assert!(policy().validate().is_ok());
        assert!(AutoscalePolicy { min_shards: 0, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { max_shards: 1, min_shards: 2, ..policy() }
            .validate()
            .is_err());
        assert!(AutoscalePolicy { target_p99_ms: 0.0, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { max_shed_rate: 1.5, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy {
            scale_down_threshold: 1.0,
            scale_up_threshold: 1.0,
            ..policy()
        }
        .validate()
        .is_err());
        assert!(AutoscalePolicy { interval: Duration::ZERO, ..policy() }.validate().is_err());
    }

    #[test]
    fn shedding_triggers_scale_up_after_cooldown() {
        let mut core = ControllerCore::new(policy());
        let t0 = Instant::now();
        // inside the initial cooldown: held even under pressure
        assert_eq!(core.decide(&sample(t0, 0.5, 100.0, 32), 1), ScaleAction::Hold);
        let t1 = t0 + Duration::from_millis(150);
        assert_eq!(core.decide(&sample(t1, 0.5, 100.0, 32), 1), ScaleAction::Up);
        // immediately after: cooldown holds the next step
        let t2 = t1 + Duration::from_millis(25);
        assert_eq!(core.decide(&sample(t2, 0.5, 100.0, 32), 2), ScaleAction::Hold);
        let t3 = t1 + Duration::from_millis(150);
        assert_eq!(core.decide(&sample(t3, 0.5, 100.0, 32), 2), ScaleAction::Up);
    }

    #[test]
    fn p99_breach_triggers_scale_up_without_shedding() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // 15ms interval queue p99 > 10ms SLO, zero shed
        assert_eq!(core.decide(&sample(t, 0.0, 15_000.0, 8), 2), ScaleAction::Up);
    }

    #[test]
    fn max_shards_caps_growth() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t, 0.9, 90_000.0, 64), 4), ScaleAction::Hold);
    }

    #[test]
    fn idle_scales_down_to_min_and_no_further() {
        let mut core = ControllerCore::new(policy());
        let t1 = Instant::now() + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t1, 0.0, 100.0, 0), 3), ScaleAction::Down);
        // scale-down cooldown holds the next shrink
        let t2 = t1 + Duration::from_millis(100);
        assert_eq!(core.decide(&sample(t2, 0.0, 100.0, 0), 2), ScaleAction::Hold);
        let t3 = t1 + Duration::from_millis(500);
        assert_eq!(core.decide(&sample(t3, 0.0, 100.0, 0), 2), ScaleAction::Down);
        let t4 = t3 + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t4, 0.0, 100.0, 0), 1), ScaleAction::Hold, "at min");
    }

    #[test]
    fn healthy_midband_holds() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // p99 at 5ms: above the 2.5ms scale-down band, below the 10ms SLO
        assert_eq!(core.decide(&sample(t, 0.0, 5_000.0, 2), 2), ScaleAction::Hold);
    }

    #[test]
    fn deep_queue_blocks_scale_down() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // p99 looks calm but a backlog is sitting in admission
        assert_eq!(core.decide(&sample(t, 0.0, 100.0, 64), 3), ScaleAction::Hold);
    }

    #[test]
    fn log_reports_recovery_and_series() {
        let pol = policy();
        let log = AutoscaleLog {
            samples: vec![
                AutoscaleSample {
                    at_s: 0.1,
                    shards: 1,
                    queue_depth: 50,
                    shed_rate: 0.4,
                    queue_p99_ms: 40.0,
                    action: ScaleAction::Hold,
                },
                AutoscaleSample {
                    at_s: 0.2,
                    shards: 2,
                    queue_depth: 30,
                    shed_rate: 0.2,
                    queue_p99_ms: 20.0,
                    action: ScaleAction::Up,
                },
                AutoscaleSample {
                    at_s: 0.3,
                    shards: 3,
                    queue_depth: 2,
                    shed_rate: 0.0,
                    queue_p99_ms: 2.0,
                    action: ScaleAction::Up,
                },
            ],
            events: vec![AutoscaleEvent {
                at_s: 0.2,
                from_shards: 1,
                to_shards: 2,
                reason: "shed rate 0.400".into(),
            }],
        };
        assert_eq!(log.shards_over_time(), vec![(0.1, 1), (0.2, 2), (0.3, 3)]);
        let rec = log.recovery_after_s(0.1, &pol).expect("recovered");
        assert!((rec - 0.2).abs() < 1e-9, "first compliant sample at 0.3s");
        assert!(log.recovery_after_s(0.35, &pol).is_none(), "no sample after 0.35s");
        let out = log.render();
        assert!(out.contains("1 -> 2 shards"), "{out}");
        assert!(out.contains("shards over time: 1 2 3"), "{out}");
    }
}
