//! SLO-driven shard autoscaler: capacity follows traffic.
//!
//! "A Statically and Dynamically Scalable Soft GPGPU" (arXiv:2401.04261)
//! argues that a soft GPGPU approaches IP-core efficiency only when its
//! compute-unit count is sized to the workload — and that the sizing
//! should be *dynamic*. Our serving stack measures exactly the demand
//! signals that paper proposes reacting to: queue depth, shed rate and
//! deadline misses, per [`PressureSample`]. This module closes the
//! loop: an [`AutoscaleController`] consumes the traffic frontend's
//! periodic pressure feed and grows or shrinks the shard pool of the
//! running [`super::ShardedFftService`] against an SLO target.
//!
//! The control law ([`ControllerCore::decide`]) is deliberately simple
//! and fully unit-testable:
//!
//! * **scale up** (one shard) when the interval shed rate exceeds
//!   [`AutoscalePolicy::max_shed_rate`] or the interval queue-wait p99
//!   exceeds `target_p99_ms * scale_up_threshold`, the pool is below
//!   `max_shards`, and `scale_up_cooldown` has elapsed since the last
//!   resize;
//! * **scale down** (one shard) when nothing was shed, the queue-wait
//!   p99 is below `target_p99_ms * scale_down_threshold`, the
//!   admission queue is shallow, the pool is above `min_shards`, and
//!   `scale_down_cooldown` has elapsed — so the pool drains back to
//!   `min_shards` when traffic goes away;
//! * **hold** otherwise.
//!
//! **The degrade lever.** With [`AutoscalePolicy::max_degrade`] above
//! `Full`, [`ControllerCore::decide_qos`] extends the law with the
//! frontend's resolution ladder, modelling the cost of both levers: a
//! degrade step *halves per-request service cost* (the transform
//! shrinks by 2×), takes effect immediately, and costs quality but no
//! hardware; a shard adds one shard's fixed capacity, costs hardware,
//! and persists. The law therefore reaches for resolution first and
//! capacity second:
//!
//! * under overload, **degrade** one step (after the short
//!   `degrade_cooldown`) while the ladder has depth left — a burst is
//!   served coarser instead of triggering a shard add;
//! * if overload *persists* after the ladder budget is spent, **scale
//!   up** exactly as before — the sustained-demand lever;
//! * once the pressure clears, **restore** resolution one step at a
//!   time (after `restore_cooldown`) before any scale-down — so a
//!   scaled-up pool returns to `Full` resolution, and only then sheds
//!   idle shards. Restore uses its own band — p99 below *half* the
//!   overload trigger with nothing shed — looser than the scale-down
//!   band, because a restore step roughly doubles per-request cost
//!   (half-trigger headroom absorbs it) and a workload that settles
//!   mid-band must not be pinned at reduced resolution.
//!
//! **The swap lever (third actuator).** With
//! [`AutoscalePolicy::swap_service_p99_ms`] positive and the server
//! wrapping a routed backend set ([`super::BackendSet`]), an
//! overloaded interval whose *service-time* p99 exceeds the threshold
//! first pins the router to its measured-fastest lane
//! ([`super::RouteMode::Fastest`]) — service time is the one latency
//! component that neither shards nor resolution can move, so swapping
//! the backend is tried before either. The swap is one-shot per
//! overload episode (re-arming only after release), costs nothing and
//! is instant; when the SLO is calm again the pin is released back to
//! load-balanced routing before resolution is restored.
//!
//! Every decision (including degrade/restore and swap/release steps)
//! lands in the [`AutoscaleLog`] with the operating level before and
//! after.
//!
//! The SLO targets *queue wait*, not service time: adding shards
//! removes queueing, while per-job service time is a property of the
//! workload — gating on it would make the controller chase a signal it
//! cannot move. Cooldowns are asymmetric by default (scale up fast,
//! scale down slowly) so a bursty workload does not thrash the pool.
//!
//! Shutdown order matters: [`AutoscaleController::stop`] first (it
//! holds a clone of the server's service handle), then
//! `TrafficServer::shutdown`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::backend::RouteMode;
use super::qos::DegradeLevel;
use super::server::{DegradeControl, PressureSample, ServiceHandle, TrafficServer};

/// The SLO target and actuation limits for one controller.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// The pool never shrinks below this many shards.
    pub min_shards: usize,
    /// The pool never grows beyond this many shards. Must not exceed
    /// the server's `ServerConfig::dispatchers` (the backend in-flight
    /// bound) — shards beyond it can never receive concurrent work, so
    /// [`AutoscaleController::spawn`] rejects such a pairing.
    pub max_shards: usize,
    /// SLO: interval queue-wait p99 target, milliseconds.
    pub target_p99_ms: f64,
    /// SLO: maximum tolerable interval shed rate (fraction of
    /// submissions rejected at admission).
    pub max_shed_rate: f64,
    /// Scale up once the interval p99 exceeds `target_p99_ms` times
    /// this factor (1.0 = react exactly at the SLO; below 1.0 reacts
    /// early, leaving headroom).
    pub scale_up_threshold: f64,
    /// Scale down only while the interval p99 is below `target_p99_ms`
    /// times this factor (and nothing is being shed).
    pub scale_down_threshold: f64,
    /// Minimum time between a resize and the next scale-up.
    pub scale_up_cooldown: Duration,
    /// Minimum time between a resize and the next scale-down (longer
    /// than the scale-up cooldown by default: grow fast, shrink slow).
    pub scale_down_cooldown: Duration,
    /// Pressure-feed sampling interval.
    pub interval: Duration,
    /// Deepest operating degrade level the controller may set. `Full`
    /// (the default) disables the degrade lever entirely, preserving
    /// the shard-only control law.
    pub max_degrade: DegradeLevel,
    /// Minimum time between actions and the next degrade step. Must
    /// not exceed `scale_up_cooldown` when the lever is enabled:
    /// degrading is the cheap, instant lever, so it reacts at least as
    /// fast as a shard add — which is what lets a short burst be served
    /// coarser without any resize.
    pub degrade_cooldown: Duration,
    /// Minimum time between actions and the next resolution-restore
    /// step once the SLO is healthy again.
    pub restore_cooldown: Duration,
    /// Swap-before-scale: when positive, an overloaded interval whose
    /// *service-time* p99 exceeds this many milliseconds first pins the
    /// routed backend set to its measured-fastest lane before any
    /// degrade or resize — service time is the one latency component
    /// shards and resolution cannot move, and only a faster backend
    /// can. Requires the server to wrap a routed set
    /// ([`AutoscaleController::spawn`] rejects the pairing otherwise).
    /// `0.0` (the default) disables the swap actuator.
    pub swap_service_p99_ms: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_shards: 1,
            // Capped at ServerConfig::default()'s dispatcher count so
            // the two defaults compose on any host — raise both
            // together for wider pools.
            max_shards: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .min(4),
            target_p99_ms: 10.0,
            max_shed_rate: 0.01,
            scale_up_threshold: 1.0,
            scale_down_threshold: 0.25,
            scale_up_cooldown: Duration::from_millis(250),
            scale_down_cooldown: Duration::from_secs(2),
            interval: Duration::from_millis(50),
            max_degrade: DegradeLevel::Full,
            degrade_cooldown: Duration::from_millis(100),
            restore_cooldown: Duration::from_millis(500),
            swap_service_p99_ms: 0.0,
        }
    }
}

impl AutoscalePolicy {
    /// Reject configurations the control law cannot run safely on
    /// (inverted bounds, thresholds that oscillate, cooldowns that
    /// invert the lever ordering).
    pub fn validate(&self) -> Result<()> {
        if self.min_shards == 0 {
            return Err(anyhow!("min_shards must be at least 1"));
        }
        if self.max_shards < self.min_shards {
            return Err(anyhow!(
                "max_shards ({}) must be >= min_shards ({})",
                self.max_shards,
                self.min_shards
            ));
        }
        if self.target_p99_ms <= 0.0 {
            return Err(anyhow!("target_p99_ms must be positive"));
        }
        if !(0.0..=1.0).contains(&self.max_shed_rate) {
            return Err(anyhow!("max_shed_rate must be in [0, 1]"));
        }
        if self.scale_down_threshold >= self.scale_up_threshold {
            return Err(anyhow!(
                "scale_down_threshold ({}) must be below scale_up_threshold ({}) \
                 or the controller oscillates",
                self.scale_down_threshold,
                self.scale_up_threshold
            ));
        }
        if self.interval.is_zero() {
            return Err(anyhow!("interval must be positive"));
        }
        if self.swap_service_p99_ms < 0.0 {
            return Err(anyhow!("swap_service_p99_ms must be non-negative (0 disables)"));
        }
        if self.max_degrade != DegradeLevel::Full
            && self.degrade_cooldown > self.scale_up_cooldown
        {
            return Err(anyhow!(
                "degrade_cooldown ({:?}) must not exceed scale_up_cooldown ({:?}): \
                 degrading is the cheap lever and must react at least as fast as a \
                 shard add",
                self.degrade_cooldown,
                self.scale_up_cooldown
            ));
        }
        if self.max_degrade != DegradeLevel::Full
            && self.restore_cooldown > self.scale_down_cooldown
        {
            return Err(anyhow!(
                "restore_cooldown ({:?}) must not exceed scale_down_cooldown ({:?}): \
                 resolution must be restorable before capacity is retired, or a \
                 still-degraded pool could shed the shards its effective capacity \
                 depends on",
                self.restore_cooldown,
                self.scale_down_cooldown
            ));
        }
        Ok(())
    }
}

/// What the shard-only control law decided for one sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add one shard.
    Up,
    /// Retire one shard.
    Down,
    /// No change this sample.
    Hold,
}

/// What the degrade-aware control law decided for one sample: shard
/// actions, the two resolution-ladder actions, and the two
/// backend-routing actions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosAction {
    /// Add one shard (the durable capacity lever).
    ScaleUp,
    /// Retire one shard.
    ScaleDown,
    /// Step the operating level one rung deeper (halves per-request
    /// service cost — the burst lever).
    Degrade,
    /// Step the operating level one rung back toward full resolution.
    Restore,
    /// Pin the routed backend set to its measured-fastest lane (the
    /// swap-before-scale lever, fired on service-time pressure).
    SwapBackend,
    /// Release the backend pin back to load-balanced routing.
    ReleaseBackend,
    /// No change this sample.
    Hold,
}

/// The pure control law: policy + cooldown state, no threads, no
/// service — fully unit-testable by feeding synthetic samples.
pub struct ControllerCore {
    policy: AutoscalePolicy,
    /// Last applied action (initialized to construction time, so the
    /// first action waits out a full cooldown — a freshly started
    /// controller never reacts to an empty first interval).
    last_action: Instant,
    /// The swap actuator has fired and not yet been released: the swap
    /// is one-shot per overload episode.
    swapped: bool,
}

impl ControllerCore {
    /// A fresh control-law core over `policy`, with cooldown state
    /// starting at construction time.
    pub fn new(policy: AutoscalePolicy) -> Self {
        ControllerCore { policy, last_action: Instant::now(), swapped: false }
    }

    /// The policy this core decides against.
    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }

    /// The shard-only law: decide on one sample, given the current
    /// shard count, ignoring the degrade lever (equivalent to
    /// [`ControllerCore::decide_qos`] with the lever disabled and the
    /// level at `Full`). Returning `Up`/`Down` records the action for
    /// cooldown purposes — the caller is expected to apply it.
    pub fn decide(&mut self, s: &PressureSample, shards: usize) -> ScaleAction {
        match self.decide_inner(s, shards, DegradeLevel::Full, DegradeLevel::Full, false) {
            QosAction::ScaleUp => ScaleAction::Up,
            QosAction::ScaleDown => ScaleAction::Down,
            _ => ScaleAction::Hold,
        }
    }

    /// The degrade-aware law: decide on one sample given the current
    /// shard count *and* operating degrade level. Cost model: a degrade
    /// step halves per-request service cost instantly at zero
    /// provisioning cost (quality is the price), so it is tried first
    /// on overload; a shard adds fixed capacity and is the durable
    /// lever once the ladder budget (`max_degrade`) is spent. When
    /// healthy, resolution is restored before any shard is retired.
    ///
    /// With [`AutoscalePolicy::swap_service_p99_ms`] positive, the law
    /// gains a swap-before-scale step: an overloaded interval whose
    /// service-time p99 exceeds the threshold returns
    /// [`QosAction::SwapBackend`] before any degrade or resize (once
    /// per overload episode), and a calm interval releases the pin
    /// ([`QosAction::ReleaseBackend`]) before restoring resolution.
    pub fn decide_qos(
        &mut self,
        s: &PressureSample,
        shards: usize,
        level: DegradeLevel,
    ) -> QosAction {
        let swap = self.policy.swap_service_p99_ms > 0.0;
        self.decide_inner(s, shards, level, self.policy.max_degrade, swap)
    }

    fn decide_inner(
        &mut self,
        s: &PressureSample,
        shards: usize,
        level: DegradeLevel,
        max_degrade: DegradeLevel,
        swap_enabled: bool,
    ) -> QosAction {
        let p99_ms = s.queue_p99_us / 1e3;
        let since = s.at.checked_duration_since(self.last_action).unwrap_or_default();
        let overloaded = s.shed_rate > self.policy.max_shed_rate
            || p99_ms > self.policy.target_p99_ms * self.policy.scale_up_threshold;
        if overloaded {
            // Swap before scale: service time is the one component of
            // latency that shards and resolution cannot move, so when
            // it is what breaches, try the free lever — a faster
            // backend — first. One-shot until released.
            if swap_enabled
                && !self.swapped
                && s.service_p99_us / 1e3 > self.policy.swap_service_p99_ms
                && since >= self.policy.degrade_cooldown
            {
                self.swapped = true;
                self.last_action = s.at;
                return QosAction::SwapBackend;
            }
            if level < max_degrade && since >= self.policy.degrade_cooldown {
                self.last_action = s.at;
                return QosAction::Degrade;
            }
            if shards < self.policy.max_shards && since >= self.policy.scale_up_cooldown {
                self.last_action = s.at;
                return QosAction::ScaleUp;
            }
            return QosAction::Hold;
        }
        // Restore has its own, looser band than scale-down: a restore
        // step roughly doubles per-request cost, so it is safe once the
        // p99 sits below half the overload trigger — and without the
        // looser band, a workload that settles mid-band after a burst
        // would be served at reduced resolution forever despite ample
        // SLO headroom (the tight scale-down band exists to avoid
        // capacity thrash, not to gate quality).
        let calm = s.shed == 0
            && p99_ms < 0.5 * self.policy.target_p99_ms * self.policy.scale_up_threshold;
        // Release the backend pin first: routing returns to
        // load-balanced before resolution (and then capacity) recover,
        // mirroring the overload ordering in reverse.
        if calm && swap_enabled && self.swapped && since >= self.policy.restore_cooldown {
            self.swapped = false;
            self.last_action = s.at;
            return QosAction::ReleaseBackend;
        }
        if calm && level > DegradeLevel::Full && since >= self.policy.restore_cooldown {
            self.last_action = s.at;
            return QosAction::Restore;
        }
        let healthy = s.shed == 0
            && p99_ms < self.policy.target_p99_ms * self.policy.scale_down_threshold
            && s.queue_depth <= shards;
        if healthy && shards > self.policy.min_shards && since >= self.policy.scale_down_cooldown {
            self.last_action = s.at;
            return QosAction::ScaleDown;
        }
        QosAction::Hold
    }
}

/// One applied action (resize or degrade-ladder step), for the log.
#[derive(Clone, Debug)]
pub struct AutoscaleEvent {
    /// Seconds since the controller started.
    pub at_s: f64,
    /// Shard count before the action.
    pub from_shards: usize,
    /// Shard count after the action (equal to `from_shards` for ladder
    /// and routing steps).
    pub to_shards: usize,
    /// Operating degrade level before the action (equal to `to_level`
    /// for pure resizes, as the shard counts are for pure ladder
    /// steps).
    pub from_level: DegradeLevel,
    /// Operating degrade level after the action.
    pub to_level: DegradeLevel,
    /// Human-readable trigger (which SLO signal fired, with values).
    pub reason: String,
}

/// One observed sample, for shards/level-over-time reporting.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleSample {
    /// Seconds since the controller started.
    pub at_s: f64,
    /// Shard count *after* any action this tick applied.
    pub shards: usize,
    /// Operating degrade level *after* any action this tick applied.
    pub level: DegradeLevel,
    /// Admitted-but-undispatched requests at sample time.
    pub queue_depth: usize,
    /// Interval shed fraction.
    pub shed_rate: f64,
    /// Interval queue-wait p99, milliseconds.
    pub queue_p99_ms: f64,
    /// What the control law decided this tick.
    pub action: QosAction,
}

/// Everything a controller run observed and did.
#[derive(Clone, Debug, Default)]
pub struct AutoscaleLog {
    /// One entry per pressure-feed tick.
    pub samples: Vec<AutoscaleSample>,
    /// One entry per applied action (resize, ladder or routing step).
    pub events: Vec<AutoscaleEvent>,
}

impl AutoscaleLog {
    /// `(seconds, shards)` per tick — the bench's shards-over-time
    /// series.
    pub fn shards_over_time(&self) -> Vec<(f64, usize)> {
        self.samples.iter().map(|s| (s.at_s, s.shards)).collect()
    }

    /// Seconds from `from_s` until the first subsequent sample meeting
    /// both SLO thresholds (shed rate and queue-wait p99), or `None`
    /// if the run never recovered.
    pub fn recovery_after_s(&self, from_s: f64, policy: &AutoscalePolicy) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.at_s >= from_s
                    && s.shed_rate <= policy.max_shed_rate
                    && s.queue_p99_ms <= policy.target_p99_ms
            })
            .map(|s| s.at_s - from_s)
    }

    /// Applied degrade steps (operating level deepened).
    pub fn degrades(&self) -> usize {
        self.events.iter().filter(|e| e.to_level > e.from_level).count()
    }

    /// Applied restore steps (operating level moved back toward Full).
    pub fn restores(&self) -> usize {
        self.events.iter().filter(|e| e.to_level < e.from_level).count()
    }

    /// Applied scale-ups.
    pub fn scale_ups(&self) -> usize {
        self.events.iter().filter(|e| e.to_shards > e.from_shards).count()
    }

    /// Human-readable event/series report of the run.
    pub fn render(&self) -> String {
        let ups = self.scale_ups();
        let downs = self.events.iter().filter(|e| e.to_shards < e.from_shards).count();
        let span = self.samples.last().map(|s| s.at_s).unwrap_or(0.0);
        let mut s = format!(
            "autoscale: {} scale-up(s), {} scale-down(s), {} degrade(s), {} restore(s) \
             over {:.1}s ({} samples)\n",
            ups,
            downs,
            self.degrades(),
            self.restores(),
            span,
            self.samples.len()
        );
        for e in &self.events {
            if e.from_level != e.to_level {
                s.push_str(&format!(
                    "  t={:>6.2}s  level {} -> {}  ({})\n",
                    e.at_s, e.from_level, e.to_level, e.reason
                ));
            } else if e.from_shards != e.to_shards {
                s.push_str(&format!(
                    "  t={:>6.2}s  {} -> {} shards  ({})\n",
                    e.at_s, e.from_shards, e.to_shards, e.reason
                ));
            } else {
                // neither shards nor level moved: a routing step
                s.push_str(&format!("  t={:>6.2}s  routing  ({})\n", e.at_s, e.reason));
            }
        }
        if !self.samples.is_empty() {
            let series = self
                .samples
                .iter()
                .map(|p| p.shards.to_string())
                .collect::<Vec<_>>()
                .join(" ");
            s.push_str(&format!("  shards over time: {series}\n"));
            if self.samples.iter().any(|p| p.level != DegradeLevel::Full) {
                let levels = self
                    .samples
                    .iter()
                    .map(|p| p.level.shift().to_string())
                    .collect::<Vec<_>>()
                    .join(" ");
                s.push_str(&format!("  degrade shift over time: {levels}\n"));
            }
        }
        s
    }
}

/// The running feedback controller: a thread consuming the server's
/// pressure feed and resizing the sharded backend against the policy.
pub struct AutoscaleController {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<AutoscaleLog>>,
}

impl AutoscaleController {
    /// Start a controller over `server`'s backend. Fails when the
    /// policy is invalid or the server does not wrap the sharded
    /// (resizable) service.
    pub fn spawn(server: &TrafficServer, policy: AutoscalePolicy) -> Result<Self> {
        policy.validate()?;
        let service = server.service();
        if service.as_sharded().is_none() {
            return Err(anyhow!(
                "autoscaling requires ServiceHandle::Sharded (the pool service is not resizable)"
            ));
        }
        if policy.swap_service_p99_ms > 0.0 && service.as_routed().is_none() {
            return Err(anyhow!(
                "swap_service_p99_ms is set but the server does not wrap a routed \
                 backend set (ServiceHandle::Routed) — the swap actuator has nothing \
                 to drive"
            ));
        }
        // The dispatcher pool bounds backend in-flight work, so shards
        // beyond it add zero capacity: scaling past it would weld the
        // pool at max with the SLO never recovering.
        let dispatchers = server.config().dispatchers;
        if policy.max_shards > dispatchers {
            return Err(anyhow!(
                "max_shards ({}) exceeds the server's dispatcher count ({}): shards \
                 beyond the in-flight bound add no capacity — raise \
                 ServerConfig::dispatchers or lower max_shards",
                policy.max_shards,
                dispatchers
            ));
        }
        let feed = server.pressure_feed(policy.interval);
        let control = server.degrade_control();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread =
            std::thread::spawn(move || controller_loop(feed, service, control, policy, stop2));
        Ok(AutoscaleController { stop, thread: Some(thread) })
    }

    /// Stop the controller and return everything it observed and did.
    /// This drops the controller's service handle, so call it *before*
    /// `TrafficServer::shutdown`.
    pub fn stop(mut self) -> AutoscaleLog {
        self.stop.store(true, Ordering::Release);
        self.thread
            .take()
            .map(|t| t.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for AutoscaleController {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn controller_loop(
    feed: std::sync::mpsc::Receiver<PressureSample>,
    service: Arc<ServiceHandle>,
    control: DegradeControl,
    policy: AutoscalePolicy,
    stop: Arc<AtomicBool>,
) -> AutoscaleLog {
    let started = Instant::now();
    let target_ms = policy.target_p99_ms;
    let max_shed = policy.max_shed_rate;
    let mut core = ControllerCore::new(policy.clone());
    let mut log = AutoscaleLog::default();
    let sharded = service.as_sharded().expect("validated in spawn");
    let routed = service.as_routed();
    while !stop.load(Ordering::Acquire) {
        let sample = match feed.recv_timeout(policy.interval) {
            Ok(s) => s,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let shards = sharded.shards();
        let level = control.get();
        let action = core.decide_qos(&sample, shards, level);
        let at_s = sample.at.checked_duration_since(started).unwrap_or_default().as_secs_f64();
        let p99_ms = sample.queue_p99_us / 1e3;
        let overload_reason = || {
            format!(
                "shed rate {:.3} (SLO {:.3}), queue p99 {:.1}ms (SLO {:.1}ms)",
                sample.shed_rate, max_shed, p99_ms, target_ms
            )
        };
        let (shards_after, level_after) = match action {
            QosAction::ScaleUp => {
                sharded.add_shard();
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards + 1,
                    from_level: level,
                    to_level: level,
                    reason: overload_reason(),
                });
                (shards + 1, level)
            }
            QosAction::ScaleDown => match sharded.retire_shard() {
                Ok(_) => {
                    log.events.push(AutoscaleEvent {
                        at_s,
                        from_shards: shards,
                        to_shards: shards - 1,
                        from_level: level,
                        to_level: level,
                        reason: format!(
                            "idle: no shedding, queue p99 {:.1}ms well under {:.1}ms SLO",
                            p99_ms, target_ms
                        ),
                    });
                    (shards - 1, level)
                }
                Err(_) => (shards, level), // raced shutdown; nothing to do
            },
            QosAction::Degrade => {
                let to = control.deepen(policy.max_degrade);
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards,
                    from_level: level,
                    to_level: to,
                    reason: format!(
                        "{} — degrading instead of adding a shard",
                        overload_reason()
                    ),
                });
                (shards, to)
            }
            QosAction::Restore => {
                let to = control.restore();
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards,
                    from_level: level,
                    to_level: to,
                    reason: format!(
                        "healthy: queue p99 {p99_ms:.1}ms under {target_ms:.1}ms SLO — \
                         restoring resolution"
                    ),
                });
                (shards, to)
            }
            QosAction::SwapBackend => {
                if let Some(set) = routed {
                    set.set_mode(RouteMode::Fastest);
                }
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards,
                    from_level: level,
                    to_level: level,
                    reason: format!(
                        "service p99 {:.1}ms over swap threshold {:.1}ms — pinning the \
                         fastest backend",
                        sample.service_p99_us / 1e3,
                        policy.swap_service_p99_ms
                    ),
                });
                (shards, level)
            }
            QosAction::ReleaseBackend => {
                if let Some(set) = routed {
                    set.set_mode(RouteMode::Balance);
                }
                log.events.push(AutoscaleEvent {
                    at_s,
                    from_shards: shards,
                    to_shards: shards,
                    from_level: level,
                    to_level: level,
                    reason: format!(
                        "healthy: queue p99 {p99_ms:.1}ms under {target_ms:.1}ms SLO — \
                         releasing the backend pin"
                    ),
                });
                (shards, level)
            }
            QosAction::Hold => (shards, level),
        };
        log.samples.push(AutoscaleSample {
            at_s,
            shards: shards_after,
            level: level_after,
            queue_depth: sample.queue_depth,
            shed_rate: sample.shed_rate,
            queue_p99_ms: p99_ms,
            action,
        });
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 10.0,
            max_shed_rate: 0.05,
            scale_up_threshold: 1.0,
            scale_down_threshold: 0.25,
            scale_up_cooldown: Duration::from_millis(100),
            scale_down_cooldown: Duration::from_millis(400),
            interval: Duration::from_millis(25),
            ..Default::default()
        }
    }

    fn sample(
        at: Instant,
        shed_rate: f64,
        queue_p99_us: f64,
        queue_depth: usize,
    ) -> PressureSample {
        PressureSample {
            at,
            queue_depth,
            submitted: 100,
            completed: 90,
            shed: if shed_rate > 0.0 { (shed_rate * 100.0) as u64 } else { 0 },
            expired: 0,
            shed_rate,
            deadline_miss_rate: 0.0,
            queue_p99_us,
            service_p99_us: 500.0,
            operating_level: DegradeLevel::Full,
        }
    }

    #[test]
    fn policy_validation_rejects_nonsense() {
        assert!(policy().validate().is_ok());
        assert!(AutoscalePolicy { min_shards: 0, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { max_shards: 1, min_shards: 2, ..policy() }
            .validate()
            .is_err());
        assert!(AutoscalePolicy { target_p99_ms: 0.0, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy { max_shed_rate: 1.5, ..policy() }.validate().is_err());
        assert!(AutoscalePolicy {
            scale_down_threshold: 1.0,
            scale_up_threshold: 1.0,
            ..policy()
        }
        .validate()
        .is_err());
        assert!(AutoscalePolicy { interval: Duration::ZERO, ..policy() }.validate().is_err());
    }

    #[test]
    fn shedding_triggers_scale_up_after_cooldown() {
        let mut core = ControllerCore::new(policy());
        let t0 = Instant::now();
        // inside the initial cooldown: held even under pressure
        assert_eq!(core.decide(&sample(t0, 0.5, 100.0, 32), 1), ScaleAction::Hold);
        let t1 = t0 + Duration::from_millis(150);
        assert_eq!(core.decide(&sample(t1, 0.5, 100.0, 32), 1), ScaleAction::Up);
        // immediately after: cooldown holds the next step
        let t2 = t1 + Duration::from_millis(25);
        assert_eq!(core.decide(&sample(t2, 0.5, 100.0, 32), 2), ScaleAction::Hold);
        let t3 = t1 + Duration::from_millis(150);
        assert_eq!(core.decide(&sample(t3, 0.5, 100.0, 32), 2), ScaleAction::Up);
    }

    #[test]
    fn p99_breach_triggers_scale_up_without_shedding() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // 15ms interval queue p99 > 10ms SLO, zero shed
        assert_eq!(core.decide(&sample(t, 0.0, 15_000.0, 8), 2), ScaleAction::Up);
    }

    #[test]
    fn max_shards_caps_growth() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t, 0.9, 90_000.0, 64), 4), ScaleAction::Hold);
    }

    #[test]
    fn idle_scales_down_to_min_and_no_further() {
        let mut core = ControllerCore::new(policy());
        let t1 = Instant::now() + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t1, 0.0, 100.0, 0), 3), ScaleAction::Down);
        // scale-down cooldown holds the next shrink
        let t2 = t1 + Duration::from_millis(100);
        assert_eq!(core.decide(&sample(t2, 0.0, 100.0, 0), 2), ScaleAction::Hold);
        let t3 = t1 + Duration::from_millis(500);
        assert_eq!(core.decide(&sample(t3, 0.0, 100.0, 0), 2), ScaleAction::Down);
        let t4 = t3 + Duration::from_secs(1);
        assert_eq!(core.decide(&sample(t4, 0.0, 100.0, 0), 1), ScaleAction::Hold, "at min");
    }

    #[test]
    fn healthy_midband_holds() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // p99 at 5ms: above the 2.5ms scale-down band, below the 10ms SLO
        assert_eq!(core.decide(&sample(t, 0.0, 5_000.0, 2), 2), ScaleAction::Hold);
    }

    #[test]
    fn deep_queue_blocks_scale_down() {
        let mut core = ControllerCore::new(policy());
        let t = Instant::now() + Duration::from_secs(1);
        // p99 looks calm but a backlog is sitting in admission
        assert_eq!(core.decide(&sample(t, 0.0, 100.0, 64), 3), ScaleAction::Hold);
    }

    #[test]
    fn log_reports_recovery_and_series() {
        let pol = policy();
        let sam = |at_s, shards, level, queue_depth, shed_rate, queue_p99_ms, action| {
            AutoscaleSample { at_s, shards, level, queue_depth, shed_rate, queue_p99_ms, action }
        };
        let log = AutoscaleLog {
            samples: vec![
                sam(0.1, 1, DegradeLevel::Full, 50, 0.4, 40.0, QosAction::Hold),
                sam(0.2, 2, DegradeLevel::Half, 30, 0.2, 20.0, QosAction::ScaleUp),
                sam(0.3, 3, DegradeLevel::Full, 2, 0.0, 2.0, QosAction::ScaleUp),
            ],
            events: vec![
                AutoscaleEvent {
                    at_s: 0.15,
                    from_shards: 1,
                    to_shards: 1,
                    from_level: DegradeLevel::Full,
                    to_level: DegradeLevel::Half,
                    reason: "shed rate 0.400 — degrading".into(),
                },
                AutoscaleEvent {
                    at_s: 0.2,
                    from_shards: 1,
                    to_shards: 2,
                    from_level: DegradeLevel::Half,
                    to_level: DegradeLevel::Half,
                    reason: "shed rate 0.400".into(),
                },
                AutoscaleEvent {
                    at_s: 0.25,
                    from_shards: 2,
                    to_shards: 2,
                    from_level: DegradeLevel::Half,
                    to_level: DegradeLevel::Full,
                    reason: "healthy — restoring resolution".into(),
                },
            ],
        };
        assert_eq!(log.shards_over_time(), vec![(0.1, 1), (0.2, 2), (0.3, 3)]);
        assert_eq!((log.scale_ups(), log.degrades(), log.restores()), (1, 1, 1));
        let rec = log.recovery_after_s(0.1, &pol).expect("recovered");
        assert!((rec - 0.2).abs() < 1e-9, "first compliant sample at 0.3s");
        assert!(log.recovery_after_s(0.35, &pol).is_none(), "no sample after 0.35s");
        let out = log.render();
        let head = "1 scale-up(s), 0 scale-down(s), 1 degrade(s), 1 restore(s)";
        assert!(out.contains(head), "{out}");
        assert!(out.contains("1 -> 2 shards"), "{out}");
        assert!(out.contains("level full -> half"), "{out}");
        assert!(out.contains("level half -> full"), "{out}");
        assert!(out.contains("shards over time: 1 2 3"), "{out}");
        assert!(out.contains("degrade shift over time: 0 1 0"), "{out}");
    }

    #[test]
    fn degrade_cooldown_must_not_exceed_scale_up_cooldown_when_enabled() {
        let bad = AutoscalePolicy {
            max_degrade: DegradeLevel::Quarter,
            degrade_cooldown: Duration::from_secs(1),
            scale_up_cooldown: Duration::from_millis(100),
            ..policy()
        };
        assert!(bad.validate().is_err());
        // with the lever disabled the same cooldowns are fine
        assert!(AutoscalePolicy { max_degrade: DegradeLevel::Full, ..bad }.validate().is_ok());
    }

    #[test]
    fn restore_cooldown_must_not_exceed_scale_down_cooldown_when_enabled() {
        // otherwise a healthy-but-still-degraded pool could retire the
        // shards its effective capacity depends on before restoring
        let bad = AutoscalePolicy {
            max_degrade: DegradeLevel::Quarter,
            restore_cooldown: Duration::from_secs(5),
            scale_down_cooldown: Duration::from_secs(1),
            ..policy()
        };
        assert!(bad.validate().is_err());
        assert!(AutoscalePolicy { max_degrade: DegradeLevel::Full, ..bad }.validate().is_ok());
    }

    fn qos_policy() -> AutoscalePolicy {
        AutoscalePolicy {
            max_degrade: DegradeLevel::Quarter,
            degrade_cooldown: Duration::from_millis(50),
            restore_cooldown: Duration::from_millis(50),
            ..policy()
        }
    }

    #[test]
    fn overload_degrades_down_the_ladder_before_scaling_up() {
        // the crossover law: Half, then Quarter, and only with the
        // ladder spent does a sustained overload add a shard
        let mut core = ControllerCore::new(qos_policy());
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(200);
        let over = |t| sample(t, 0.5, 90_000.0, 64);
        assert_eq!(core.decide_qos(&over(t1), 1, DegradeLevel::Full), QosAction::Degrade);
        let t2 = t1 + Duration::from_millis(25);
        assert_eq!(
            core.decide_qos(&over(t2), 1, DegradeLevel::Half),
            QosAction::Hold,
            "degrade cooldown"
        );
        let t3 = t1 + Duration::from_millis(60);
        assert_eq!(core.decide_qos(&over(t3), 1, DegradeLevel::Half), QosAction::Degrade);
        let t4 = t3 + Duration::from_millis(60);
        assert_eq!(
            core.decide_qos(&over(t4), 1, DegradeLevel::Quarter),
            QosAction::Hold,
            "ladder spent, scale-up cooldown (100ms) not yet elapsed"
        );
        let t5 = t3 + Duration::from_millis(150);
        assert_eq!(
            core.decide_qos(&over(t5), 1, DegradeLevel::Quarter),
            QosAction::ScaleUp,
            "sustained overload reaches for capacity once the ladder is spent"
        );
    }

    #[test]
    fn mid_band_load_still_restores_resolution() {
        // p99 at 4ms: above the 2.5ms scale-down band, below half the
        // 10ms overload trigger — a degraded pool must not be pinned at
        // reduced resolution just because it never goes fully idle
        let mut core = ControllerCore::new(qos_policy());
        let t1 = Instant::now() + Duration::from_secs(1);
        let mid = |t| sample(t, 0.0, 4_000.0, 2);
        assert_eq!(core.decide_qos(&mid(t1), 2, DegradeLevel::Half), QosAction::Restore);
        // ...but the same band never sheds capacity, and at Full it holds
        let t2 = t1 + Duration::from_secs(1);
        assert_eq!(core.decide_qos(&mid(t2), 2, DegradeLevel::Full), QosAction::Hold);
        // above half the trigger (6ms), restore waits for more headroom
        let t3 = t2 + Duration::from_secs(1);
        let warm = sample(t3, 0.0, 6_000.0, 2);
        assert_eq!(core.decide_qos(&warm, 2, DegradeLevel::Half), QosAction::Hold);
    }

    #[test]
    fn healthy_restores_resolution_before_scaling_down() {
        let mut core = ControllerCore::new(qos_policy());
        let t1 = Instant::now() + Duration::from_secs(1);
        let calm = |t| sample(t, 0.0, 100.0, 0);
        assert_eq!(
            core.decide_qos(&calm(t1), 3, DegradeLevel::Quarter),
            QosAction::Restore,
            "resolution comes back before shards go away"
        );
        let t2 = t1 + Duration::from_millis(60);
        assert_eq!(core.decide_qos(&calm(t2), 3, DegradeLevel::Half), QosAction::Restore);
        let t3 = t2 + Duration::from_millis(450);
        assert_eq!(
            core.decide_qos(&calm(t3), 3, DegradeLevel::Full),
            QosAction::ScaleDown,
            "only a Full-resolution healthy pool sheds capacity"
        );
    }

    fn swap_policy() -> AutoscalePolicy {
        AutoscalePolicy { swap_service_p99_ms: 1.0, ..qos_policy() }
    }

    fn sample_svc(
        at: Instant,
        shed_rate: f64,
        queue_p99_us: f64,
        service_p99_us: f64,
    ) -> PressureSample {
        PressureSample { service_p99_us, ..sample(at, shed_rate, queue_p99_us, 32) }
    }

    #[test]
    fn negative_swap_threshold_rejected() {
        assert!(AutoscalePolicy { swap_service_p99_ms: -1.0, ..policy() }
            .validate()
            .is_err());
        assert!(swap_policy().validate().is_ok());
    }

    #[test]
    fn swap_fires_once_then_degrade_and_releases_on_calm() {
        let mut core = ControllerCore::new(swap_policy());
        let t0 = Instant::now();
        // overloaded with a 5ms service p99 over the 1ms swap threshold:
        // the free lever fires first
        let t1 = t0 + Duration::from_millis(200);
        assert_eq!(
            core.decide_qos(&sample_svc(t1, 0.5, 90_000.0, 5_000.0), 1, DegradeLevel::Full),
            QosAction::SwapBackend
        );
        // overload persists: the swap is one-shot, so the ladder is next
        let t2 = t1 + Duration::from_millis(60);
        assert_eq!(
            core.decide_qos(&sample_svc(t2, 0.5, 90_000.0, 5_000.0), 1, DegradeLevel::Full),
            QosAction::Degrade
        );
        // calm again: the pin is released before resolution is restored
        let t3 = t2 + Duration::from_millis(60);
        assert_eq!(
            core.decide_qos(&sample_svc(t3, 0.0, 100.0, 200.0), 1, DegradeLevel::Half),
            QosAction::ReleaseBackend
        );
        let t4 = t3 + Duration::from_millis(60);
        assert_eq!(
            core.decide_qos(&sample_svc(t4, 0.0, 100.0, 200.0), 1, DegradeLevel::Half),
            QosAction::Restore
        );
    }

    #[test]
    fn swap_requires_service_time_pressure() {
        // overloaded, but the 0.5ms service p99 is under the 1ms swap
        // threshold: queueing is the problem, not the backend — the
        // ladder (then capacity) handles it
        let mut core = ControllerCore::new(swap_policy());
        let t1 = Instant::now() + Duration::from_millis(200);
        assert_eq!(
            core.decide_qos(&sample_svc(t1, 0.5, 90_000.0, 500.0), 1, DegradeLevel::Full),
            QosAction::Degrade
        );
    }

    #[test]
    fn routing_events_render_without_fake_resizes() {
        let log = AutoscaleLog {
            samples: Vec::new(),
            events: vec![AutoscaleEvent {
                at_s: 0.5,
                from_shards: 2,
                to_shards: 2,
                from_level: DegradeLevel::Full,
                to_level: DegradeLevel::Full,
                reason: "pinning the fastest backend".into(),
            }],
        };
        let out = log.render();
        assert!(out.contains("routing  (pinning the fastest backend)"), "{out}");
        assert!(!out.contains("2 -> 2 shards"), "{out}");
    }

    #[test]
    fn decide_qos_with_lever_disabled_matches_the_shard_only_law() {
        let t1 = Instant::now() + Duration::from_secs(1);
        let cases = [
            sample(t1, 0.5, 100.0, 32),
            sample(t1, 0.0, 15_000.0, 8),
            sample(t1, 0.0, 100.0, 0),
            sample(t1, 0.0, 5_000.0, 2),
        ];
        for (i, s) in cases.iter().enumerate() {
            let mut a = ControllerCore::new(policy());
            let mut b = ControllerCore::new(policy());
            let plain = a.decide(s, 2);
            let qos = b.decide_qos(s, 2, DegradeLevel::Full);
            let mapped = match qos {
                QosAction::ScaleUp => ScaleAction::Up,
                QosAction::ScaleDown => ScaleAction::Down,
                _ => ScaleAction::Hold,
            };
            assert_eq!(plain, mapped, "case {i}");
        }
    }
}
