//! QoS scheduling core: N traffic classes under weighted fair queueing,
//! earliest-deadline-first ordering within each class, an aging rule
//! for background classes, and a floor-clamped degrade ladder.
//!
//! This module is the pure heart of the traffic frontend — no threads,
//! no channels, time injected through every call — so every scheduling
//! invariant the serving layer depends on is provable by the property
//! suite in `rust/tests/proptests.rs`:
//!
//! * **Weighted fair queueing** across classes is deficit round-robin
//!   ([`QosScheduler::pop`]): each positive-weight class in rotation
//!   receives a quantum equal to its weight and serves one request per
//!   unit of deficit, so under sustained saturation class `c` receives
//!   a `weight_c / Σ weights` share of dispatches, exact to within one
//!   round.
//! * **EDF within a class**: a pop takes the queued request with the
//!   earliest absolute deadline (ties broken by admission order;
//!   deadline-less requests come after all deadlined peers, in FIFO
//!   order). When every request in a class carries the same *relative*
//!   deadline, absolute-deadline order equals arrival order, so EDF
//!   degenerates to the FIFO the two-class server used — which is what
//!   keeps the legacy configuration's dispatch order reproducible. The
//!   one exception is an aging promotion (below), which dispatches the
//!   aged request itself.
//! * **Aging** protects *background* classes (weight 0, excluded from
//!   the fair-share rotation): once a background class's oldest waiter
//!   has waited [`QosScheduler::aging`], that *request* wins the next
//!   dispatch slot ahead of all weighted work — the bound is
//!   per-request, so a deadline-less request cannot starve behind a
//!   stream of deadlined peers in its own class. Positive-weight
//!   classes need no aging — DRR already guarantees each non-empty
//!   class a quantum every rotation, which is the N-class
//!   starvation-freedom bound. The legacy two-priority server is the
//!   special case `[{high, weight 1}, {low, weight 0}]`: high strictly
//!   first, low promoted by aging, low drains when high is idle.
//! * **The degrade ladder** (`Full → Half → Quarter`) maps admission
//!   pressure (or a controller decision) to a resolution level; the
//!   [`DegradeLadder`] clamps every request to the deepest level whose
//!   truncated transform still has at least `min_points` samples, so
//!   degradation can never emit an unservable (or uselessly small)
//!   design point. `min_points` is the radix/variant-aware floor: use
//!   [`DegradeLadder::for_radix`] to keep every degraded transform a
//!   legal pass shape for the deployed radix.
//!
//! The tenancy layer ([`super::tenant`]) composes *over* these classes:
//! its per-tenant token buckets and [`UnitQuota`] in-flight caps run
//! before [`QosScheduler::try_enqueue`], so a throttled tenant's
//! request never occupies class-queue capacity and the fair-share /
//! EDF / aging invariants above only ever see conforming traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

/// Resolution level of the degrade ladder. `Ord` follows depth:
/// `Full < Half < Quarter`, so `a.max(b)` is "the more degraded of the
/// two" — which is how admission merges the queue-pressure level with
/// the controller's operating level.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DegradeLevel {
    /// Serve the request at its submitted size.
    #[default]
    Full,
    /// Serve the first half of the input (one right-shift of the size).
    Half,
    /// Serve the first quarter of the input (two right-shifts).
    Quarter,
}

impl DegradeLevel {
    /// Right-shift applied to the transform size at this level.
    pub fn shift(self) -> u32 {
        match self {
            DegradeLevel::Full => 0,
            DegradeLevel::Half => 1,
            DegradeLevel::Quarter => 2,
        }
    }

    /// Relative per-request service cost at this level (a degrade step
    /// halves the transform size, and therefore roughly halves the
    /// backend time) — the controller's cost model for the degrade
    /// lever.
    pub fn cost_factor(self) -> f64 {
        1.0 / (1u32 << self.shift()) as f64
    }

    /// One step deeper on the ladder (saturates at `Quarter`).
    pub fn deeper(self) -> DegradeLevel {
        match self {
            DegradeLevel::Full => DegradeLevel::Half,
            _ => DegradeLevel::Quarter,
        }
    }

    /// One step back toward full resolution (saturates at `Full`).
    pub fn shallower(self) -> DegradeLevel {
        match self {
            DegradeLevel::Quarter => DegradeLevel::Half,
            _ => DegradeLevel::Full,
        }
    }

    /// Stable wire encoding for the shared atomic operating level.
    pub fn as_u8(self) -> u8 {
        self.shift() as u8
    }

    /// Inverse of [`DegradeLevel::as_u8`] (out-of-range clamps to
    /// `Quarter`).
    pub fn from_u8(v: u8) -> DegradeLevel {
        match v {
            0 => DegradeLevel::Full,
            1 => DegradeLevel::Half,
            _ => DegradeLevel::Quarter,
        }
    }
}

impl std::fmt::Display for DegradeLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DegradeLevel::Full => write!(f, "full"),
            DegradeLevel::Half => write!(f, "half"),
            DegradeLevel::Quarter => write!(f, "quarter"),
        }
    }
}

impl std::str::FromStr for DegradeLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_lowercase().as_str() {
            "full" => Ok(DegradeLevel::Full),
            "half" => Ok(DegradeLevel::Half),
            "quarter" => Ok(DegradeLevel::Quarter),
            other => Err(anyhow!("unknown degrade level `{other}` (full|half|quarter)")),
        }
    }
}

/// The floor-clamped degrade ladder: requests are never truncated below
/// `min_points` samples, whatever level pressure (or the controller)
/// asks for.
#[derive(Clone, Copy, Debug)]
pub struct DegradeLadder {
    /// Smallest truncated transform size any degrade level may produce.
    pub min_points: usize,
}

impl DegradeLadder {
    /// A ladder whose floor keeps every degraded transform a legal
    /// design point for `radix` (two full passes: `radix²` points) —
    /// the radix/variant-aware construction.
    pub fn for_radix(radix: usize) -> DegradeLadder {
        DegradeLadder { min_points: (radix * radix).max(4) }
    }

    /// The deepest level not deeper than `requested` whose truncated
    /// size stays at or above the floor. `Full` is always allowed, even
    /// for inputs already below the floor.
    pub fn clamp(&self, requested: DegradeLevel, points: usize) -> DegradeLevel {
        let mut level = requested;
        while level != DegradeLevel::Full && (points >> level.shift()) < self.min_points {
            level = level.shallower();
        }
        level
    }

    /// Clamp and resolve: `(effective level, truncated point count)`.
    pub fn apply(&self, requested: DegradeLevel, points: usize) -> (DegradeLevel, usize) {
        let level = self.clamp(requested, points);
        (level, points >> level.shift())
    }
}

/// One traffic class of the QoS frontend.
#[derive(Clone, Debug)]
pub struct QosClass {
    /// Class name, as reported in metrics and load reports.
    pub name: String,
    /// Fair-share weight. Positive weights share dispatch slots in
    /// proportion (deficit round-robin); weight 0 marks a *background*
    /// class, served only when every weighted queue is empty or via the
    /// aging rule — exactly the legacy low-priority semantics.
    pub weight: u32,
    /// Bounded admission-queue capacity for this class. Defaults to
    /// [`DEFAULT_CLASS_CAPACITY`]; override with
    /// [`QosClass::with_capacity`]. A capacity of `0` is rejected at
    /// server start — every class must be able to admit work.
    pub capacity: usize,
    /// Deadline applied to this class's requests when the submission
    /// carries none (falls back to `ServerConfig::default_deadline`).
    pub deadline_default: Option<Duration>,
}

/// Default per-class admission-queue capacity, used by
/// [`QosClass::new`] when no explicit capacity is set. Matches the
/// shared `ServerConfig::queue_capacity` default the 0.3.0 surface
/// used, so configurations that never touched capacity behave
/// identically under the per-class scheme.
pub const DEFAULT_CLASS_CAPACITY: usize = 64;

impl QosClass {
    /// A class with the given name and fair-share weight; capacity
    /// starts at [`DEFAULT_CLASS_CAPACITY`] and the default deadline
    /// falls back to server-level settings.
    pub fn new(name: &str, weight: u32) -> QosClass {
        QosClass {
            name: name.into(),
            weight,
            capacity: DEFAULT_CLASS_CAPACITY,
            deadline_default: None,
        }
    }

    /// Builder: set an explicit per-class admission-queue capacity.
    pub fn with_capacity(mut self, capacity: usize) -> QosClass {
        self.capacity = capacity;
        self
    }

    /// Builder: set the class's default relative deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> QosClass {
        self.deadline_default = Some(deadline);
        self
    }
}

/// The legacy two-priority configuration: `high` (weight 1) strictly
/// before `low` (weight 0, promoted by aging) — class indices 0 and 1
/// match the old `Priority::High` / `Priority::Low`.
pub fn default_two_class() -> Vec<QosClass> {
    vec![QosClass::new("high", 1), QosClass::new("low", 0)]
}

/// A lock-free in-flight job-unit cap — the quota half of the tenancy
/// layer's two admission levers (the token bucket bounds *rate*; this
/// bounds *outstanding work*). Units are charged at admission with
/// [`UnitQuota::try_charge`] and given back with [`UnitQuota::release`]
/// when the request finishes (completed, expired, failed, or shed
/// downstream), so the in-flight total can never drift upward.
///
/// `None` means unlimited: every charge succeeds but the in-flight
/// count is still tracked for metrics.
#[derive(Debug)]
pub struct UnitQuota {
    limit: Option<u64>,
    in_flight: AtomicU64,
}

impl UnitQuota {
    /// A quota capping in-flight units at `limit` (`None` = unlimited).
    pub fn new(limit: Option<u64>) -> UnitQuota {
        UnitQuota { limit, in_flight: AtomicU64::new(0) }
    }

    /// The configured cap.
    pub fn limit(&self) -> Option<u64> {
        self.limit
    }

    /// Units currently charged (admitted but not yet released).
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Atomically charge `units` if the cap allows: `true` admits,
    /// `false` leaves the count untouched. A request costing more
    /// units than the whole cap can never charge successfully — even
    /// from idle — so admission surfaces it as a throttle immediately
    /// instead of letting it wait forever for room that cannot exist.
    pub fn try_charge(&self, units: u64) -> bool {
        let mut cur = self.in_flight.load(Ordering::Acquire);
        loop {
            if let Some(limit) = self.limit {
                if cur.saturating_add(units) > limit {
                    return false;
                }
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + units,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Return `units` to the quota (saturating: releasing more than is
    /// charged clamps at zero rather than underflowing).
    pub fn release(&self, units: u64) {
        let mut cur = self.in_flight.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(units);
            match self.in_flight.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// One admitted-but-not-yet-dispatched request, as the scheduler core
/// sees it. The payload is opaque so the core stays thread-free and
/// property-testable with plain values.
pub struct Queued<T> {
    /// Admission sequence number (monotonic, scheduler-wide): the EDF
    /// tiebreak and the FIFO order for deadline-less requests.
    pub seq: u64,
    /// Index of the class this request was admitted into.
    pub class: usize,
    /// Absolute deadline, if the submission (or class default) set one.
    pub deadline: Option<Instant>,
    /// Admission instant, as injected by the caller — the aging clock.
    pub enqueued: Instant,
    /// The caller's opaque request payload.
    pub payload: T,
}

/// A dispatched request plus whether the aging rule promoted it ahead
/// of waiting weighted work.
pub struct Popped<T> {
    /// The dispatched request.
    pub item: Queued<T>,
    /// `true` when the aging rule jumped this request ahead of queued
    /// weighted work.
    pub aged: bool,
}

/// The N-class scheduler: bounded per-class queues, deficit round-robin
/// across positive-weight classes, EDF within a class, aging for
/// background (weight-0) classes. All time is injected, so behaviour is
/// a pure function of the call sequence.
///
/// **Complexity note:** per-class queues are plain `Vec`s, so a pop
/// scans O(class depth) under the admission lock (EDF min, oldest
/// waiter). At the capacities this frontend supports (hundreds of
/// queued requests per class) that scan is tens of nanoseconds per
/// entry — noise next to the µs-to-ms service time of a single FFT —
/// and it keeps the core trivially auditable for the property suite.
/// If per-class caps ever grow by orders of magnitude, swap the `Vec`
/// for a `BinaryHeap` keyed on `(deadline, seq)` plus an arrival-order
/// index for the aging scan.
///
/// ```
/// use std::time::{Duration, Instant};
///
/// use egpu_fft::coordinator::{QosClass, QosScheduler};
///
/// // The legacy two-priority shape: high (weight 1) strictly before
/// // low (weight 0, background).
/// let classes = vec![QosClass::new("high", 1), QosClass::new("low", 0)];
/// let mut sched: QosScheduler<&str> =
///     QosScheduler::new(classes, vec![16, 16], Duration::from_secs(1));
///
/// let now = Instant::now();
/// sched.try_enqueue(1, None, now, "background").unwrap();
/// sched.try_enqueue(0, None, now, "urgent").unwrap();
///
/// // Weighted work wins the slot; background drains afterwards.
/// assert_eq!(sched.pop(now).unwrap().item.payload, "urgent");
/// assert_eq!(sched.pop(now).unwrap().item.payload, "background");
/// assert!(sched.is_empty());
/// ```
pub struct QosScheduler<T> {
    classes: Vec<QosClass>,
    caps: Vec<usize>,
    queues: Vec<Vec<Queued<T>>>,
    deficit: Vec<u32>,
    /// Indices of positive-weight classes, in configuration order (the
    /// DRR rotation) — and of background classes (weight 0).
    weighted: Vec<usize>,
    background: Vec<usize>,
    cursor: usize,
    aging: Duration,
    next_seq: u64,
}

impl<T> QosScheduler<T> {
    /// `caps` are the per-class admission capacities (one per class,
    /// usually each class's own [`QosClass::capacity`]); `aging` is the
    /// background-class promotion threshold.
    pub fn new(classes: Vec<QosClass>, caps: Vec<usize>, aging: Duration) -> QosScheduler<T> {
        assert_eq!(classes.len(), caps.len(), "one capacity per class");
        let weighted: Vec<usize> = (0..classes.len()).filter(|&c| classes[c].weight > 0).collect();
        let background: Vec<usize> =
            (0..classes.len()).filter(|&c| classes[c].weight == 0).collect();
        let n = classes.len();
        QosScheduler {
            classes,
            caps,
            queues: (0..n).map(|_| Vec::new()).collect(),
            deficit: vec![0; n],
            weighted,
            background,
            cursor: 0,
            aging,
            next_seq: 0,
        }
    }

    /// The configured classes, in index order.
    pub fn classes(&self) -> &[QosClass] {
        &self.classes
    }

    /// Resolved admission capacity of `class`.
    pub fn capacity(&self, class: usize) -> usize {
        self.caps[class]
    }

    /// Number of requests currently queued in `class`.
    pub fn depth(&self, class: usize) -> usize {
        self.queues[class].len()
    }

    /// Total queued requests across every class.
    pub fn total_depth(&self) -> usize {
        self.queues.iter().map(Vec::len).sum()
    }

    /// `true` when no class has queued work.
    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(Vec::is_empty)
    }

    /// Admit one request into its class queue. Fails with the class's
    /// capacity when the queue is full (the caller applies its
    /// admission policy: block, shed, or degrade-then-shed).
    pub fn try_enqueue(
        &mut self,
        class: usize,
        deadline: Option<Instant>,
        now: Instant,
        payload: T,
    ) -> std::result::Result<u64, usize> {
        let cap = self.caps[class];
        if self.queues[class].len() >= cap {
            return Err(cap);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queues[class].push(Queued { seq, class, deadline, enqueued: now, payload });
        Ok(seq)
    }

    /// Dispatch the next request: an aged background request first,
    /// then deficit round-robin over the weighted classes, then
    /// background drain when no weighted work is queued. Within the
    /// chosen class the pop is always EDF.
    pub fn pop(&mut self, now: Instant) -> Option<Popped<T>> {
        // 1. Aging: a background class whose oldest waiter has waited
        // past the threshold wins the slot (oldest waiter first among
        // several classes), and the promotion dispatches that oldest
        // waiter *itself* — not the class's EDF-min. The bound protects
        // the individual request: were the promotion to serve the
        // EDF-min instead, a deadline-less request could starve forever
        // behind a steady stream of deadlined peers. EDF ordering
        // therefore holds between promotions; an aged pop is the
        // explicit, counted exception. Counted as a promotion only when
        // weighted work was actually jumped.
        if let Some(class) = self.aged_background(now) {
            let aged = self.weighted.iter().any(|&w| !self.queues[w].is_empty());
            let item = self.pop_oldest(class).expect("aged class is non-empty");
            return Some(Popped { item, aged });
        }
        // 2. Deficit round-robin across positive-weight classes: the
        // cursor class serves one request per unit of deficit and the
        // rotation advances when its quantum (== weight) is spent, so
        // saturated classes split slots in weight proportion.
        for _ in 0..self.weighted.len() {
            let class = self.weighted[self.cursor % self.weighted.len()];
            if self.queues[class].is_empty() {
                self.deficit[class] = 0;
                self.cursor = (self.cursor + 1) % self.weighted.len();
                continue;
            }
            if self.deficit[class] == 0 {
                self.deficit[class] = self.classes[class].weight;
            }
            self.deficit[class] -= 1;
            if self.deficit[class] == 0 {
                self.cursor = (self.cursor + 1) % self.weighted.len();
            }
            let item = self.pop_edf(class).expect("checked non-empty");
            return Some(Popped { item, aged: false });
        }
        // 3. No weighted work: drain background classes, oldest waiter
        // first (not a promotion — nothing was jumped).
        let class = self
            .background
            .iter()
            .copied()
            .filter(|&c| !self.queues[c].is_empty())
            .min_by_key(|&c| self.oldest(c).expect("filtered non-empty"))?;
        let item = self.pop_edf(class).expect("chosen non-empty");
        Some(Popped { item, aged: false })
    }

    /// Enqueue instant of the class's oldest waiter.
    fn oldest(&self, class: usize) -> Option<Instant> {
        self.queues[class].iter().map(|q| q.enqueued).min()
    }

    /// The background class owed an aged promotion, if any (oldest
    /// waiter past the aging threshold; oldest first on ties).
    fn aged_background(&self, now: Instant) -> Option<usize> {
        self.background
            .iter()
            .copied()
            .filter_map(|c| self.oldest(c).map(|t| (c, t)))
            .filter(|&(_, t)| now.checked_duration_since(t).unwrap_or_default() >= self.aging)
            .min_by_key(|&(_, t)| t)
            .map(|(c, _)| c)
    }

    /// EDF pop: earliest absolute deadline first, admission order as
    /// the tiebreak, deadline-less requests after all deadlined peers
    /// (in admission order).
    fn pop_edf(&mut self, class: usize) -> Option<Queued<T>> {
        let queue = &self.queues[class];
        let idx = queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| (q.deadline.is_none(), q.deadline, q.seq))
            .map(|(i, _)| i)?;
        Some(self.queues[class].swap_remove(idx))
    }

    /// Oldest-waiter pop: the request the aging bound protects. Used
    /// only for aging promotions — see [`QosScheduler::pop`].
    fn pop_oldest(&mut self, class: usize) -> Option<Queued<T>> {
        let queue = &self.queues[class];
        let idx = queue
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| (q.enqueued, q.seq))
            .map(|(i, _)| i)?;
        Some(self.queues[class].swap_remove(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(specs: &[(&str, u32)], cap: usize, aging: Duration) -> QosScheduler<u64> {
        let classes: Vec<QosClass> = specs.iter().map(|&(n, w)| QosClass::new(n, w)).collect();
        let caps = vec![cap; classes.len()];
        QosScheduler::new(classes, caps, aging)
    }

    #[test]
    fn legacy_two_class_pop_order_is_preserved() {
        // the PR 3 frontend: high strictly before low, low drains when
        // high is empty, aged low jumps waiting high work
        let aging = Duration::from_secs(3600);
        let mut s = sched(&[("high", 1), ("low", 0)], 16, aging);
        let t0 = Instant::now();
        s.try_enqueue(1, None, t0, 100).unwrap();
        s.try_enqueue(0, None, t0, 1).unwrap();
        s.try_enqueue(0, None, t0, 2).unwrap();
        let p = s.pop(t0).unwrap();
        assert_eq!((p.item.class, p.item.payload, p.aged), (0, 1, false));
        let p = s.pop(t0).unwrap();
        assert_eq!((p.item.class, p.item.payload), (0, 2));
        let p = s.pop(t0).unwrap();
        assert_eq!((p.item.class, p.item.payload, p.aged), (1, 100, false), "low drains");
        assert!(s.pop(t0).is_none());
    }

    #[test]
    fn aged_background_jumps_weighted_work_and_is_counted() {
        let aging = Duration::from_millis(10);
        let mut s = sched(&[("high", 1), ("low", 0)], 16, aging);
        let t0 = Instant::now();
        s.try_enqueue(1, None, t0, 100).unwrap();
        s.try_enqueue(0, None, t0, 1).unwrap();
        let later = t0 + Duration::from_millis(50);
        let p = s.pop(later).unwrap();
        assert_eq!((p.item.class, p.aged), (1, true), "aged low jumps waiting high");
        let p = s.pop(later).unwrap();
        assert_eq!((p.item.class, p.aged), (0, false));
    }

    #[test]
    fn aged_promotion_serves_the_oldest_waiter_not_the_edf_min() {
        // the aging bound is per-request: a deadline-less background
        // request must not be starved by later-arriving deadlined peers
        let aging = Duration::from_millis(10);
        let mut s = sched(&[("high", 1), ("low", 0)], 16, aging);
        let t0 = Instant::now();
        s.try_enqueue(1, None, t0, 100).unwrap(); // the starvation candidate
        let later = t0 + Duration::from_millis(50);
        // deadlined peers keep arriving and would win any EDF pop
        s.try_enqueue(1, Some(later + Duration::from_millis(1)), later, 200).unwrap();
        s.try_enqueue(0, None, later, 1).unwrap();
        let p = s.pop(later).unwrap();
        assert_eq!(
            (p.item.class, p.item.payload, p.aged),
            (1, 100, true),
            "the aged request itself is dispatched"
        );
        // with the aged request served, EDF resumes for the peers
        let p = s.pop(later).unwrap();
        assert_eq!((p.item.class, p.item.payload), (0, 1), "weighted work next");
    }

    #[test]
    fn aged_pop_without_weighted_work_is_not_a_promotion() {
        let mut s = sched(&[("high", 1), ("low", 0)], 16, Duration::from_millis(1));
        let t0 = Instant::now();
        s.try_enqueue(1, None, t0, 7).unwrap();
        let p = s.pop(t0 + Duration::from_secs(1)).unwrap();
        assert_eq!((p.item.class, p.aged), (1, false), "nothing was jumped");
    }

    #[test]
    fn drr_shares_follow_weights_under_saturation() {
        let weights = [(("gold", 5u32)), ("silver", 3), ("bronze", 1)];
        let mut s = sched(&weights, 1024, Duration::from_secs(3600));
        let t0 = Instant::now();
        // keep every queue saturated while popping
        let mut served = [0u64; 3];
        for round in 0..900u64 {
            for c in 0..3 {
                while s.depth(c) < 8 {
                    s.try_enqueue(c, None, t0, round).unwrap();
                }
            }
            let p = s.pop(t0).unwrap();
            served[p.item.class] += 1;
        }
        let total: u64 = served.iter().sum();
        for (c, &(_, w)) in weights.iter().enumerate() {
            let frac = served[c] as f64 / total as f64;
            let want = w as f64 / 9.0;
            assert!(
                (frac - want).abs() < 0.02,
                "class {c}: share {frac:.3} vs weight share {want:.3}"
            );
        }
    }

    #[test]
    fn edf_orders_within_a_class() {
        let mut s = sched(&[("rt", 1)], 16, Duration::from_secs(3600));
        let t0 = Instant::now();
        let d = |ms: u64| Some(t0 + Duration::from_millis(ms));
        s.try_enqueue(0, d(50), t0, 1).unwrap();
        s.try_enqueue(0, d(10), t0, 2).unwrap();
        s.try_enqueue(0, None, t0, 3).unwrap();
        s.try_enqueue(0, d(30), t0, 4).unwrap();
        let order: Vec<u64> = (0..4).map(|_| s.pop(t0).unwrap().item.payload).collect();
        assert_eq!(order, vec![2, 4, 1, 3], "earliest deadline first, None last");
    }

    #[test]
    fn capacity_bounds_each_class_independently() {
        let mut s = sched(&[("a", 1), ("b", 1)], 2, Duration::ZERO);
        let t0 = Instant::now();
        assert!(s.try_enqueue(0, None, t0, 1).is_ok());
        assert!(s.try_enqueue(0, None, t0, 2).is_ok());
        assert_eq!(s.try_enqueue(0, None, t0, 3).unwrap_err(), 2, "class a full");
        assert!(s.try_enqueue(1, None, t0, 4).is_ok(), "class b unaffected");
        assert_eq!(s.depth(0), 2);
        assert_eq!(s.depth(1), 1);
        assert_eq!(s.total_depth(), 3);
    }

    #[test]
    fn builder_default_capacity_matches_retired_shared_default() {
        // The 0.3.0 surface derived unset class capacities from a shared
        // `ServerConfig::queue_capacity` defaulting to 64. The builder
        // default must reproduce that, so untouched configurations keep
        // their old admission bounds across the 0.4.0 migration.
        assert_eq!(DEFAULT_CLASS_CAPACITY, 64);
        assert_eq!(QosClass::new("b", 1).capacity, DEFAULT_CLASS_CAPACITY);
        assert_eq!(QosClass::new("a", 2).with_capacity(7).capacity, 7, "explicit wins");
        for c in default_two_class() {
            assert_eq!(c.capacity, DEFAULT_CLASS_CAPACITY, "legacy two-class default");
        }
    }

    #[test]
    fn unit_quota_charges_to_the_cap_and_releases() {
        let q = UnitQuota::new(Some(10));
        assert_eq!(q.limit(), Some(10));
        assert!(q.try_charge(6));
        assert!(q.try_charge(4));
        assert!(!q.try_charge(1), "cap reached");
        assert_eq!(q.in_flight(), 10);
        q.release(4);
        assert!(q.try_charge(3));
        assert_eq!(q.in_flight(), 9);
    }

    #[test]
    fn unit_quota_oversized_charge_never_succeeds() {
        let q = UnitQuota::new(Some(4));
        assert!(!q.try_charge(5), "bigger than the whole cap, even from idle");
        assert_eq!(q.in_flight(), 0, "failed charge leaves nothing behind");
    }

    #[test]
    fn unit_quota_unlimited_tracks_but_never_denies() {
        let q = UnitQuota::new(None);
        assert!(q.try_charge(u64::MAX / 2));
        assert!(q.try_charge(17));
        assert_eq!(q.in_flight(), u64::MAX / 2 + 17);
    }

    #[test]
    fn unit_quota_release_saturates_at_zero() {
        let q = UnitQuota::new(Some(8));
        assert!(q.try_charge(3));
        q.release(100);
        assert_eq!(q.in_flight(), 0, "no underflow");
        assert!(q.try_charge(8), "full cap available again");
    }

    #[test]
    fn ladder_clamps_at_the_floor_and_resolves_sizes() {
        let ladder = DegradeLadder { min_points: 256 };
        assert_eq!(ladder.apply(DegradeLevel::Quarter, 4096), (DegradeLevel::Quarter, 1024));
        assert_eq!(ladder.apply(DegradeLevel::Quarter, 1024), (DegradeLevel::Quarter, 256));
        assert_eq!(ladder.apply(DegradeLevel::Quarter, 512), (DegradeLevel::Half, 256));
        assert_eq!(ladder.apply(DegradeLevel::Quarter, 256), (DegradeLevel::Full, 256));
        assert_eq!(ladder.apply(DegradeLevel::Half, 128), (DegradeLevel::Full, 128), "tiny ok");
        assert_eq!(DegradeLadder::for_radix(16).min_points, 256, "radix-aware floor");
    }

    #[test]
    fn level_encoding_round_trips_and_orders_by_depth() {
        for l in [DegradeLevel::Full, DegradeLevel::Half, DegradeLevel::Quarter] {
            assert_eq!(DegradeLevel::from_u8(l.as_u8()), l);
        }
        assert!(DegradeLevel::Full < DegradeLevel::Half);
        assert!(DegradeLevel::Half < DegradeLevel::Quarter);
        assert_eq!(DegradeLevel::Full.deeper(), DegradeLevel::Half);
        assert_eq!(DegradeLevel::Quarter.deeper(), DegradeLevel::Quarter);
        assert_eq!(DegradeLevel::Quarter.shallower(), DegradeLevel::Half);
        assert_eq!(DegradeLevel::Full.shallower(), DegradeLevel::Full);
        assert_eq!(DegradeLevel::Quarter.cost_factor(), 0.25);
        assert_eq!("half".parse::<DegradeLevel>().unwrap(), DegradeLevel::Half);
        assert!("third".parse::<DegradeLevel>().is_err());
    }
}
