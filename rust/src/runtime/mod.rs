//! PJRT runtime: load the AOT-compiled JAX FFT artifacts and execute
//! them from the rust request path (Python never runs at serving time).
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. One compiled executable per FFT
//! size, cached for the life of the engine.
//!
//! The engine binds to the vendored `xla` crate, which is not on
//! crates.io; it is compiled only with the `pjrt` cargo feature. The
//! default build substitutes a stub whose [`spawn_pjrt_server`] fails
//! with a descriptive error, so the coordinator's `Simulator` backend
//! (and every test/bench that does not need PJRT) builds and runs in
//! a plain CI environment.

/// The FFT sizes with AOT artifacts (see python/compile/aot.py).
pub const ARTIFACT_SIZES: [usize; 3] = [256, 1024, 4096];

pub use imp::*;

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    /// A PJRT-backed FFT engine: the "fast numeric path" of the service.
    pub struct PjrtFftEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        exes: Mutex<HashMap<usize, xla::PjRtLoadedExecutable>>,
    }

    impl PjrtFftEngine {
        /// Create a CPU PJRT client and lazily compile artifacts from
        /// `dir` (typically `artifacts/`).
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(PjrtFftEngine {
                client,
                dir: dir.as_ref().to_path_buf(),
                exes: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn artifact_path(&self, points: usize) -> PathBuf {
            self.dir.join(format!("fft{points}.hlo.txt"))
        }

        /// Compile (and cache) the executable for one FFT size.
        pub fn ensure_compiled(&self, points: usize) -> Result<()> {
            let mut exes = self.exes.lock().unwrap();
            if exes.contains_key(&points) {
                return Ok(());
            }
            let path = self.artifact_path(points);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling fft{points}"))?;
            exes.insert(points, exe);
            Ok(())
        }

        /// Whether an artifact file exists for this size.
        pub fn has_artifact(&self, points: usize) -> bool {
            self.artifact_path(points).exists()
        }

        /// Execute the AOT FFT on an interleaved (re, im) signal.
        pub fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
            let points = input.len();
            self.ensure_compiled(points)?;
            let exes = self.exes.lock().unwrap();
            let exe = exes.get(&points).unwrap();

            let re: Vec<f32> = input.iter().map(|&(r, _)| r).collect();
            let im: Vec<f32> = input.iter().map(|&(_, i)| i).collect();
            let lit_re = xla::Literal::vec1(&re);
            let lit_im = xla::Literal::vec1(&im);
            let result = exe
                .execute::<xla::Literal>(&[lit_re, lit_im])?[0][0]
                .to_literal_sync()?;
            // lowered with return_tuple=True -> a 2-tuple (yr, yi)
            let (out_re, out_im) = result.to_tuple2()?;
            let yr = out_re.to_vec::<f32>()?;
            let yi = out_im.to_vec::<f32>()?;
            if yr.len() != points {
                return Err(anyhow!("artifact returned {} points, expected {points}", yr.len()));
            }
            Ok(yr.into_iter().zip(yi).collect())
        }
    }

    // -----------------------------------------------------------------
    // Threaded front-end: the xla crate's PJRT client is !Send (Rc
    // inside), so multi-threaded callers (the coordinator's worker pool)
    // talk to a dedicated PJRT thread through channels.

    struct PjrtReq {
        input: Vec<(f32, f32)>,
        reply: std::sync::mpsc::Sender<Result<Vec<(f32, f32)>>>,
    }

    /// Cloneable, `Send` handle to a PJRT server thread.
    #[derive(Clone)]
    pub struct PjrtHandle {
        tx: std::sync::mpsc::Sender<PjrtReq>,
    }

    impl PjrtHandle {
        /// Blocking FFT round-trip through the PJRT thread.
        pub fn fft(&self, input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
            let (reply, rx) = std::sync::mpsc::channel();
            self.tx
                .send(PjrtReq { input: input.to_vec(), reply })
                .map_err(|_| anyhow!("PJRT server thread gone"))?;
            rx.recv().map_err(|_| anyhow!("PJRT server dropped reply"))?
        }
    }

    /// Spawn the dedicated PJRT thread; the engine is created inside it
    /// and startup errors are reported synchronously. The thread exits
    /// when the last [`PjrtHandle`] is dropped.
    pub fn spawn_pjrt_server(
        dir: impl AsRef<Path>,
    ) -> Result<(PjrtHandle, std::thread::JoinHandle<()>)> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = std::sync::mpsc::channel::<PjrtReq>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let engine = match PjrtFftEngine::new(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(engine.fft(&req.input));
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("PJRT server thread died during startup"))??;
        Ok((PjrtHandle { tx }, join))
    }

    #[cfg(test)]
    mod tests {
        use super::super::ARTIFACT_SIZES;
        use super::*;
        use crate::fft::reference;
        use crate::fft::Cpx;

        fn engine() -> Option<PjrtFftEngine> {
            // artifacts are produced by `make artifacts`; tests skip (but
            // scream) when they are missing
            let eng = PjrtFftEngine::new("artifacts").ok()?;
            if ARTIFACT_SIZES.iter().all(|&n| eng.has_artifact(n)) {
                Some(eng)
            } else {
                eprintln!("WARNING: artifacts/ missing — run `make artifacts`");
                None
            }
        }

        #[test]
        fn pjrt_fft_matches_reference() {
            let Some(eng) = engine() else { return };
            for n in ARTIFACT_SIZES {
                let sig = reference::test_signal(n, 99);
                let input: Vec<(f32, f32)> = sig.iter().map(|c| c.to_f32_pair()).collect();
                let out = eng.fft(&input).unwrap();
                let got: Vec<Cpx> = out
                    .iter()
                    .map(|&(r, i)| Cpx::new(r as f64, i as f64))
                    .collect();
                let err = reference::rms_rel_error(&got, &reference::fft(&sig));
                assert!(err < 1e-4, "n={n}: rms {err:e}");
            }
        }

        #[test]
        fn executable_cache_reused() {
            let Some(eng) = engine() else { return };
            let sig: Vec<(f32, f32)> = vec![(1.0, 0.0); 256];
            eng.fft(&sig).unwrap();
            eng.fft(&sig).unwrap(); // second call hits the cache
            assert_eq!(eng.exes.lock().unwrap().len(), 1);
        }

        #[test]
        fn missing_artifact_is_an_error() {
            let eng = PjrtFftEngine::new("artifacts").unwrap();
            let sig: Vec<(f32, f32)> = vec![(0.0, 0.0); 128]; // no fft128 artifact
            assert!(eng.fft(&sig).is_err());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    /// Stub handle compiled without the `pjrt` feature: the type exists
    /// so the coordinator's plumbing type-checks, but no instance can be
    /// created ([`spawn_pjrt_server`] always fails).
    #[derive(Clone)]
    pub struct PjrtHandle {
        _unconstructible: std::convert::Infallible,
    }

    impl PjrtHandle {
        pub fn fft(&self, _input: &[(f32, f32)]) -> Result<Vec<(f32, f32)>> {
            match self._unconstructible {}
        }
    }

    /// Always fails: the build does not include the PJRT engine.
    pub fn spawn_pjrt_server(
        _dir: impl AsRef<Path>,
    ) -> Result<(PjrtHandle, std::thread::JoinHandle<()>)> {
        Err(anyhow!(
            "PJRT support not compiled in: rebuild with `--features pjrt` \
             and a vendored `xla` crate to use the Pjrt/Validate backends"
        ))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_spawn_reports_missing_feature() {
            let err = spawn_pjrt_server("artifacts").err().expect("stub must fail");
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
