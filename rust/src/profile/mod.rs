//! Cycle profiles — the unit of reporting in the paper's Tables 1–3.
//!
//! A [`Profile`] accumulates cycles per [`OpClass`] while a program runs
//! on the simulated SM, then derives the paper's metrics:
//!
//! * `Time (µs)` = total cycles / Fmax,
//! * `Efficiency %` = (FP + 2×Complex) / total — each complex-FU op
//!   performs two MAC-class operations on its dual-DSP datapath (§6),
//! * `Memory %` = (Load + Store + StoreVM) / total,
//! * `Effective efficiency %` additionally credits INT ops that perform
//!   FP-equivalent work (§6.1: 20.5 % vs 19.13 % for radix-8 DP 4096).

use crate::isa::OpClass;
use std::fmt;
use std::ops::{Add, AddAssign};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Profile {
    /// Cycles per op class, indexed by [`OpClass::index`].
    pub cycles: [u64; 9],
    /// Subset of INT cycles that perform FP-equivalent work (§3.1/§6.1).
    pub int_fp_work_cycles: u64,
    /// Dynamic instruction count (instructions issued, not cycles).
    pub instructions: u64,
    /// Clock frequency used for `time_us` (variant-dependent).
    pub fmax_mhz: f64,
}

impl Profile {
    pub fn new(fmax_mhz: f64) -> Self {
        Profile { fmax_mhz, ..Default::default() }
    }

    pub fn record(&mut self, class: OpClass, cycles: u64) {
        self.cycles[class.index()] += cycles;
    }

    pub fn get(&self, class: OpClass) -> u64 {
        self.cycles[class.index()]
    }

    /// Total cycles across all classes — the paper's `Total` row.
    pub fn total(&self) -> u64 {
        self.cycles.iter().sum()
    }

    /// Wall-clock time in microseconds at the variant's Fmax.
    pub fn time_us(&self) -> f64 {
        self.total() as f64 / self.fmax_mhz
    }

    /// FP-utilization efficiency (§6): complex-FU cycles count double.
    pub fn efficiency_pct(&self) -> f64 {
        let useful = self.get(OpClass::Fp) + 2 * self.get(OpClass::Complex);
        100.0 * useful as f64 / self.total() as f64
    }

    /// §6.1's refinement: credit INT ops that implement FP work.
    pub fn effective_efficiency_pct(&self) -> f64 {
        let useful =
            self.get(OpClass::Fp) + 2 * self.get(OpClass::Complex) + self.int_fp_work_cycles;
        100.0 * useful as f64 / self.total() as f64
    }

    /// Fraction of cycles spent on shared-memory accesses.
    pub fn memory_pct(&self) -> f64 {
        let mem =
            self.get(OpClass::Load) + self.get(OpClass::Store) + self.get(OpClass::StoreVm);
        100.0 * mem as f64 / self.total() as f64
    }

    /// Achieved FP throughput in GFLOP/s given the op count of the
    /// transform (used for the Table 6 / roofline comparisons).
    pub fn gflops(&self, flops: f64) -> f64 {
        flops / (self.time_us() * 1e3)
    }
}

impl Add for Profile {
    type Output = Profile;
    fn add(self, rhs: Profile) -> Profile {
        let mut out = self;
        out += rhs;
        out
    }
}

impl AddAssign for Profile {
    fn add_assign(&mut self, rhs: Profile) {
        for (c, r) in self.cycles.iter_mut().zip(rhs.cycles.iter()) {
            *c += r;
        }
        self.int_fp_work_cycles += rhs.int_fp_work_cycles;
        self.instructions += rhs.instructions;
        if self.fmax_mhz == 0.0 {
            self.fmax_mhz = rhs.fmax_mhz;
        }
    }
}

impl fmt::Display for Profile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for class in OpClass::ALL {
            let c = self.get(class);
            if c > 0 {
                writeln!(f, "{:<12} {:>10}", class.name(), c)?;
            }
        }
        writeln!(f, "{:<12} {:>10}", "Total", self.total())?;
        writeln!(f, "Time (us)    {:>10.2}", self.time_us())?;
        writeln!(f, "Efficiency % {:>10.2}", self.efficiency_pct())?;
        write!(f, "Memory %     {:>10.2}", self.memory_pct())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reconstruct the radix-4 / 4096-pt / eGPU-DP column of Table 1
    /// and check every derived metric against the paper.
    #[test]
    fn table1_dp_column_metrics() {
        let mut p = Profile::new(771.0);
        p.record(OpClass::Fp, 13440);
        p.record(OpClass::Int, 2880);
        p.record(OpClass::Load, 19968);
        p.record(OpClass::Store, 49152);
        p.record(OpClass::Immediate, 1287);
        p.record(OpClass::Branch, 90);
        assert_eq!(p.total(), 86817);
        assert!((p.time_us() - 112.60).abs() < 0.01);
        assert!((p.efficiency_pct() - 15.48).abs() < 0.01);
        assert!((p.memory_pct() - 79.61).abs() < 0.01);
    }

    /// VM+Complex column: complex cycles count double in efficiency.
    #[test]
    fn table1_vm_complex_column_metrics() {
        let mut p = Profile::new(771.0);
        p.record(OpClass::Fp, 7680);
        p.record(OpClass::Complex, 2880);
        p.record(OpClass::Int, 2880);
        p.record(OpClass::Load, 19968);
        p.record(OpClass::Store, 16384);
        p.record(OpClass::StoreVm, 8192);
        p.record(OpClass::Immediate, 1287);
        p.record(OpClass::Branch, 90);
        assert_eq!(p.total(), 59361);
        assert!((p.time_us() - 76.99).abs() < 0.01);
        assert!((p.efficiency_pct() - 22.64).abs() < 0.01);
        assert!((p.memory_pct() - 75.04).abs() < 0.01);
    }

    /// §6.1: radix-8 DP efficiency rises from 19.13 % to 20.5 % when the
    /// 288 INT cycles doing FP work are credited.
    #[test]
    fn effective_efficiency_radix8() {
        let mut p = Profile::new(771.0);
        p.record(OpClass::Fp, 11840);
        p.record(OpClass::Int, 3296);
        p.record(OpClass::Load, 13568);
        p.record(OpClass::Store, 32768);
        p.record(OpClass::Immediate, 328);
        p.record(OpClass::Branch, 96);
        p.int_fp_work_cycles = 288 * 3; // 288 per §6.1 scaled: see note
        // paper: 61896 total, 19.13 % base
        assert!((p.efficiency_pct() - 19.13).abs() < 0.05);
        assert!(p.effective_efficiency_pct() > p.efficiency_pct());
    }

    #[test]
    fn qp_fmax_slows_time_not_efficiency() {
        let mut dp = Profile::new(771.0);
        dp.record(OpClass::Fp, 100);
        dp.record(OpClass::Store, 100);
        let mut qp = dp;
        qp.fmax_mhz = 600.0;
        assert_eq!(dp.efficiency_pct(), qp.efficiency_pct());
        assert!(qp.time_us() > dp.time_us());
    }

    #[test]
    fn merge() {
        let mut a = Profile::new(771.0);
        a.record(OpClass::Fp, 10);
        let mut b = Profile::new(771.0);
        b.record(OpClass::Fp, 5);
        b.record(OpClass::Load, 7);
        b.instructions = 3;
        a += b;
        assert_eq!(a.get(OpClass::Fp), 15);
        assert_eq!(a.get(OpClass::Load), 7);
        assert_eq!(a.instructions, 3);
    }
}
