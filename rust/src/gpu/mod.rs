//! Commercial-GPGPU cuFFT efficiency model (Table 6 / §7).
//!
//! The paper's GPU rows are themselves *quoted from Nvidia's published
//! cuFFT performance data* [21] — the authors did not run an A100. We
//! keep both: the published efficiencies (the comparison target) and a
//! first-principles roofline model that explains them.
//!
//! Model: small/medium single-batch C2C FP32 FFTs on a big GPU are
//! global-memory-bandwidth bound — the kernel reads the input once and
//! writes the output once (8 bytes per direction per point), while the
//! arithmetic is only `5·N·log2 N` flops. The achievable FP efficiency
//! is therefore
//!
//! ```text
//! eff ≈ (5·log2 N · BW_eff) / (16 · peak_flops)
//! ```
//!
//! with `BW_eff` the achieved fraction of peak HBM bandwidth (the one
//! calibration constant per device, fit to the published cuFFT points).

/// A GPU device model for the Table 6 comparison.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub name: &'static str,
    /// Peak FP32 throughput in GFLOP/s.
    pub peak_gflops: f64,
    /// Peak HBM bandwidth in GB/s.
    pub peak_bw_gbs: f64,
    /// Achieved fraction of peak bandwidth in cuFFT (calibrated).
    pub bw_fraction: f64,
    /// Die size in mm² (the paper's normalization, §2).
    pub die_mm2: f64,
    /// Published cuFFT efficiencies for 256 / 1024 / 4096 points [21],
    /// as tabulated in the paper's Table 6.
    pub published_eff_pct: [(usize, f64); 3],
}

/// Nvidia A100-40G (§2: 19.5 TFLOPs peak, 826 mm²).
pub const A100: GpuModel = GpuModel {
    name: "A100",
    peak_gflops: 19500.0,
    peak_bw_gbs: 1555.0,
    bw_fraction: 1.08, // cuFFT slightly exceeds naive stream BW (L2 reuse)
    die_mm2: 826.0,
    published_eff_pct: [(256, 21.0), (1024, 27.0), (4096, 33.0)],
};

/// Nvidia V100 (shown "for interest" in Table 6).
pub const V100: GpuModel = GpuModel {
    name: "V100",
    peak_gflops: 15700.0,
    peak_bw_gbs: 900.0,
    bw_fraction: 1.00,
    die_mm2: 815.0,
    published_eff_pct: [(256, 15.0), (1024, 18.0), (4096, 21.0)],
};

impl GpuModel {
    /// Roofline-modelled cuFFT FP efficiency (percent) at size `n`.
    pub fn modeled_eff_pct(&self, n: usize) -> f64 {
        let log2n = (n as f64).log2();
        let bw = self.peak_bw_gbs * self.bw_fraction;
        100.0 * (5.0 * log2n * bw) / (16.0 * self.peak_gflops)
    }

    /// Published cuFFT efficiency (percent), if tabulated for `n`.
    pub fn published_eff_pct(&self, n: usize) -> Option<f64> {
        self.published_eff_pct
            .iter()
            .find(|&&(pts, _)| pts == n)
            .map(|&(_, e)| e)
    }

    /// Modelled single-batch transform time in µs at size `n`
    /// (bandwidth-bound: 16 bytes per complex point round trip).
    pub fn transform_time_us(&self, n: usize) -> f64 {
        let bytes = 16.0 * n as f64;
        bytes / (self.peak_bw_gbs * self.bw_fraction * 1e3)
    }

    /// Achieved GFLOP/s at size `n` under the model.
    pub fn achieved_gflops(&self, n: usize) -> f64 {
        self.peak_gflops * self.modeled_eff_pct(n) / 100.0
    }
}

/// §2's density argument: FP32 TFLOPs/mm² is similar between the
/// Agilex AGF022 (9.6 TFLOPs, mid-range die) and the A100 (19.5
/// TFLOPs, 826 mm²), making *efficiency* the fair comparison metric.
pub fn density_comparison() -> (f64, f64) {
    let agilex_tflops = 9.6;
    let agilex_mm2 = 400.0; // mid-range: "significantly smaller" than 826
    let a100 = A100.peak_gflops / 1e3 / A100.die_mm2;
    (agilex_tflops / agilex_mm2, a100)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The roofline model must land within ~2 efficiency points of
    /// every published cuFFT number the paper quotes.
    #[test]
    fn model_matches_published_table6() {
        for gpu in [A100, V100] {
            for (n, published) in gpu.published_eff_pct {
                let modeled = gpu.modeled_eff_pct(n);
                assert!(
                    (modeled - published).abs() < 2.0,
                    "{} n={n}: model {modeled:.1} vs published {published}",
                    gpu.name
                );
            }
        }
    }

    #[test]
    fn efficiency_grows_with_size() {
        // more flops per byte as N grows -> higher efficiency
        assert!(A100.modeled_eff_pct(4096) > A100.modeled_eff_pct(256));
        assert!(V100.modeled_eff_pct(4096) > V100.modeled_eff_pct(256));
    }

    #[test]
    fn a100_beats_v100() {
        for n in [256, 1024, 4096] {
            assert!(A100.modeled_eff_pct(n) > V100.modeled_eff_pct(n));
        }
    }

    #[test]
    fn transform_time_sane() {
        // 4096 points ≈ 65 KB round trip over ~1.6 TB/s ≈ 0.04 µs of
        // pure streaming (the real kernel adds launch overhead; the
        // absolute-time comparison is not the paper's metric)
        let t = A100.transform_time_us(4096);
        assert!(t > 0.01 && t < 1.0);
    }

    /// §2: similar FP32 density per mm² between Agilex and A100.
    #[test]
    fn density_similar() {
        let (fpga, gpu) = density_comparison();
        let ratio = fpga / gpu;
        assert!(ratio > 0.5 && ratio < 2.0, "density ratio {ratio}");
    }
}
