//! The SM's banked shared memory (§4 of the paper).
//!
//! Physically the eGPU shared memory is four parallel M20K banks. A
//! coherent `sts` writes the same word into all four banks (which is why
//! DP mode has only one logical write port: the write is broadcast). A
//! `save_bank` write stores **only** into the bank owned by the issuing
//! SP (SP index mod 4), quadrupling write bandwidth but leaving the
//! other three banks stale at that location. Reads always come from the
//! reading SP's own bank, so a `save_bank`-written word is only valid
//! when the next reader's SP index is congruent (mod 4) to the writer's.
//!
//! Modelling all four banks explicitly means a *mis-scheduled* virtual
//! bank write produces genuinely wrong numerics — the same failure mode
//! as the real hardware — which our FFT validation tests would catch.

use thiserror::Error;

pub const NUM_BANKS: usize = 4;

#[derive(Debug, Error, PartialEq, Eq)]
pub enum MemError {
    #[error("shared-memory address {addr} out of bounds ({words} words)")]
    OutOfBounds { addr: i64, words: usize },
    #[error("incoherent read at {addr}: banks differ (bank values {values:?})")]
    Incoherent { addr: usize, values: [u32; NUM_BANKS] },
}

#[derive(Clone, Debug)]
pub struct SharedMem {
    words: usize,
    banks: [Vec<u32>; NUM_BANKS],
}

impl SharedMem {
    pub fn new(words: usize) -> Self {
        SharedMem {
            words,
            banks: std::array::from_fn(|_| vec![0u32; words]),
        }
    }

    pub fn words(&self) -> usize {
        self.words
    }

    #[inline]
    fn check(&self, addr: i64) -> Result<usize, MemError> {
        if addr < 0 || addr as usize >= self.words {
            Err(MemError::OutOfBounds { addr, words: self.words })
        } else {
            Ok(addr as usize)
        }
    }

    /// Read as seen by scalar processor `sp` (bank = sp mod 4).
    #[inline]
    pub fn read(&self, sp: usize, addr: i64) -> Result<u32, MemError> {
        let a = self.check(addr)?;
        Ok(self.banks[sp % NUM_BANKS][a])
    }

    /// Coherent store (`sts`): broadcast into all four banks.
    #[inline]
    pub fn write_coherent(&mut self, addr: i64, value: u32) -> Result<(), MemError> {
        let a = self.check(addr)?;
        for bank in &mut self.banks {
            bank[a] = value;
        }
        Ok(())
    }

    /// `save_bank` store from scalar processor `sp`: only that SP's bank
    /// is written; the other three now hold stale data at `addr`.
    #[inline]
    pub fn write_bank(&mut self, sp: usize, addr: i64, value: u32) -> Result<(), MemError> {
        let a = self.check(addr)?;
        self.banks[sp % NUM_BANKS][a] = value;
        Ok(())
    }

    /// Host-side preload (input data, twiddle tables): coherent fill.
    /// Bulk slice copies per bank (this is on the coordinator's serving
    /// path — §Perf).
    pub fn host_fill(&mut self, base: usize, data: &[u32]) -> Result<(), MemError> {
        let end = base.checked_add(data.len()).ok_or(MemError::OutOfBounds {
            addr: i64::MAX,
            words: self.words,
        })?;
        if end > self.words {
            return Err(MemError::OutOfBounds { addr: end as i64 - 1, words: self.words });
        }
        for bank in &mut self.banks {
            bank[base..end].copy_from_slice(data);
        }
        Ok(())
    }

    /// Host-side readback that *requires* coherence — the natural way to
    /// read final FFT results (the last pass must use a coherent store).
    pub fn host_read_coherent(&self, base: usize, len: usize) -> Result<Vec<u32>, MemError> {
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            let a = self.check((base + i) as i64)?;
            let values: [u32; NUM_BANKS] = std::array::from_fn(|b| self.banks[b][a]);
            if values.iter().any(|&v| v != values[0]) {
                return Err(MemError::Incoherent { addr: a, values });
            }
            out.push(values[0]);
        }
        Ok(out)
    }

    /// Readback from one bank without the coherence check (debugging).
    pub fn host_read_bank(&self, bank: usize, base: usize, len: usize) -> Vec<u32> {
        self.banks[bank % NUM_BANKS][base..base + len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coherent_write_visible_to_all_sps() {
        let mut m = SharedMem::new(64);
        m.write_coherent(10, 0xdead_beef).unwrap();
        for sp in 0..16 {
            assert_eq!(m.read(sp, 10).unwrap(), 0xdead_beef);
        }
    }

    #[test]
    fn bank_write_visible_only_to_congruent_sps() {
        let mut m = SharedMem::new(64);
        m.write_coherent(5, 1).unwrap();
        // SP 6 writes via save_bank -> bank 2.
        m.write_bank(6, 5, 99).unwrap();
        for sp in 0..16 {
            let expect = if sp % 4 == 2 { 99 } else { 1 };
            assert_eq!(m.read(sp, 5).unwrap(), expect, "sp {sp}");
        }
    }

    /// The paper's mapping: "memory bank 1 maps to SP 1, 5, 9 and 13"
    /// (1-indexed); in 0-indexed terms bank b serves SPs b, b+4, b+8, b+12.
    #[test]
    fn paper_bank_mapping() {
        let mut m = SharedMem::new(8);
        for b in 0..4u32 {
            m.write_bank(b as usize, 0, b + 100).unwrap();
        }
        for sp in 0..16 {
            assert_eq!(m.read(sp, 0).unwrap(), (sp as u32 % 4) + 100);
        }
    }

    #[test]
    fn incoherent_read_detected() {
        let mut m = SharedMem::new(8);
        m.write_coherent(3, 7).unwrap();
        m.write_bank(1, 3, 8).unwrap();
        let err = m.host_read_coherent(3, 1).unwrap_err();
        assert!(matches!(err, MemError::Incoherent { addr: 3, .. }));
        // Re-writing coherently heals it.
        m.write_coherent(3, 9).unwrap();
        assert_eq!(m.host_read_coherent(3, 1).unwrap(), vec![9]);
    }

    #[test]
    fn bounds_checked() {
        let mut m = SharedMem::new(16);
        assert!(m.read(0, 16).is_err());
        assert!(m.read(0, -1).is_err());
        assert!(m.write_coherent(16, 0).is_err());
        assert!(m.write_bank(0, 16, 0).is_err());
    }
}
