//! Cycle-accurate eGPU streaming-multiprocessor simulator.
//!
//! Functional semantics and cycle accounting in one pass. The SIMT
//! execution model is the paper's: one SM of 16 SPs; an instruction
//! issues for `wavefront = threads/16` consecutive cycles (one thread
//! per SP per cycle); results emerge `pipeline_depth` (8) cycles after
//! issue, so RAW hazards only stall (as NOP cycles) when the wavefront
//! is shallower than the pipeline — exactly the §6 observation that
//! "hazards are hidden completely if the wavefront depth is greater
//! than 8".
//!
//! Memory port contention (§4/§6):
//! * `lds`   — 16 SPs share 4 read ports → 4× wavefront cycles,
//! * `sts`   — 1 write port (DP) → 16×; 2 ports (QP) → 8×,
//! * `save_bank` — 4 virtual write ports → 4× (DP+VM only).
//!
//! The simulator also *executes* every instruction on real f32/u32
//! data, so a program's numerical output can be validated against an
//! FFT oracle — including the stale-bank semantics of `save_bank`.

pub mod exec;
pub mod sharedmem;

pub use exec::FftExecutor;

use crate::arch::{SmConfig, Variant};
use crate::isa::{Inst, OpClass, Program, Reg};
use crate::profile::Profile;
use sharedmem::{MemError, SharedMem};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum SimError {
    #[error(transparent)]
    Mem(#[from] MemError),
    #[error("program uses register r{max} but the variant has {budget} regs/thread")]
    RegBudget { max: Reg, budget: usize },
    #[error("divergent branch at pc {pc}: bnz predicate not uniform across threads")]
    DivergentBranch { pc: usize },
    #[error("save_bank executed on a variant without virtual-bank support ({variant})")]
    VmUnsupported { variant: String },
    #[error("branch target {target} out of range at pc {pc}")]
    BadBranchTarget { pc: usize, target: usize },
    #[error("program ran past the end without halt")]
    RanOffEnd,
    #[error("instruction budget exceeded ({0} issued) — runaway program?")]
    Runaway(u64),
    #[error("active thread count {active} exceeds configured {threads}")]
    TooManyThreads { active: usize, threads: usize },
}

/// Upper bound on dynamically issued instructions (runaway protection).
const MAX_ISSUED: u64 = 50_000_000;

pub struct Sm {
    pub cfg: SmConfig,
    /// Flat register file: `regs[t * regs_per_thread + r]`.
    pub regs: Vec<u32>,
    pub smem: SharedMem,
    /// Coefficient cache: one (re, im) pair per thread (§5).
    coeff: Vec<[f32; 2]>,
    coeff_enabled: bool,
}

impl Sm {
    pub fn new(cfg: SmConfig) -> Self {
        Sm {
            regs: vec![0u32; cfg.threads * cfg.regs_per_thread],
            smem: SharedMem::new(cfg.smem_words),
            coeff: vec![[0.0, 0.0]; cfg.threads],
            coeff_enabled: false,
            cfg,
        }
    }

    /// Preload R0 of every thread with its thread index (Figure 2:
    /// "R0 contains the thread number").
    pub fn seed_thread_ids(&mut self) {
        let rpt = self.cfg.regs_per_thread;
        for t in 0..self.cfg.threads {
            self.regs[t * rpt] = t as u32;
        }
    }

    #[inline]
    fn reg(&self, t: usize, r: Reg) -> u32 {
        self.regs[t * self.cfg.regs_per_thread + r as usize]
    }

    #[inline]
    fn set_reg(&mut self, t: usize, r: Reg, v: u32) {
        self.regs[t * self.cfg.regs_per_thread + r as usize] = v;
    }

    #[inline]
    fn regf(&self, t: usize, r: Reg) -> f32 {
        f32::from_bits(self.reg(t, r))
    }

    #[inline]
    fn set_regf(&mut self, t: usize, r: Reg, v: f32) {
        self.set_reg(t, r, v.to_bits());
    }

    /// Issue-duration in cycles for one instruction at wavefront `w`.
    fn duration(&self, inst: &Inst, w: u64) -> u64 {
        let n_sp = self.cfg.n_sp as u64;
        match inst.class() {
            OpClass::Fp | OpClass::Int | OpClass::Immediate | OpClass::Nop => w,
            OpClass::Complex => match inst {
                // Clock-gate toggles are scalar control writes.
                Inst::CoeffEn | Inst::CoeffDis => 1,
                _ => w,
            },
            OpClass::Load => w * (n_sp / self.cfg.variant.load_ports() as u64),
            OpClass::Store => w * (n_sp / self.cfg.variant.store_ports() as u64),
            OpClass::StoreVm => w * (n_sp / self.cfg.variant.store_vm_ports() as u64),
            // Uniform scalar control: one slot plus a pipeline drain.
            OpClass::Branch => 1 + self.cfg.pipeline_depth as u64,
        }
    }

    /// Run `program` over the first `active` threads; returns the cycle
    /// profile. Register/memory state persists across calls (an SM can
    /// run several dependent kernels over the same shared memory).
    pub fn run(&mut self, program: &Program, active: usize) -> Result<Profile, SimError> {
        if active > self.cfg.threads {
            return Err(SimError::TooManyThreads { active, threads: self.cfg.threads });
        }
        let max_reg = program.max_reg();
        if (max_reg as usize) >= self.cfg.regs_per_thread {
            return Err(SimError::RegBudget { max: max_reg, budget: self.cfg.regs_per_thread });
        }

        let w = self.cfg.wavefront(active) as u64;
        let pipe = self.cfg.pipeline_depth as u64;
        let mut profile = Profile::new(self.cfg.variant.fmax_mhz());

        // Warp-level scoreboard: cycle at which each register (and the
        // coefficient cache) becomes readable.
        let mut ready = vec![0u64; self.cfg.regs_per_thread];
        let mut coeff_ready = 0u64;

        let mut clock: u64 = 0;
        let mut pc: usize = 0;
        let mut issued: u64 = 0;

        loop {
            let inst = *program.insts.get(pc).ok_or(SimError::RanOffEnd)?;
            issued += 1;
            if issued > MAX_ISSUED {
                return Err(SimError::Runaway(issued));
            }

            // RAW hazard: stall until every source is ready.
            let mut start = clock;
            for src in inst.srcs() {
                start = start.max(ready[src as usize]);
            }
            if matches!(inst, Inst::MulReal { .. } | Inst::MulImag { .. }) {
                start = start.max(coeff_ready);
            }
            if start > clock {
                profile.record(OpClass::Nop, start - clock);
                clock = start;
            }

            let dur = self.duration(&inst, w);
            profile.record(inst.class(), dur);
            if inst.is_fp_work() {
                profile.int_fp_work_cycles += dur;
            }
            profile.instructions += 1;

            // Result-ready time: last thread's result emerges a pipeline
            // depth after its (possibly port-stretched) issue slot.
            if let Some(d) = inst.dst() {
                ready[d as usize] = clock + dur.saturating_sub(w) + pipe;
            }

            // ---- functional semantics ----
            // §Perf: the arms below walk the flat register file with a
            // running thread-base index instead of per-access
            // `t * regs_per_thread` multiplies (EXPERIMENTS.md §Perf).
            let rpt = self.cfg.regs_per_thread;

            /// FP / INT register-register binop over all active threads.
            macro_rules! binop {
                ($d:ident, $a:ident, $b:ident, |$va:ident, $vb:ident| $body:expr) => {{
                    let (d, a, b) = ($d as usize, $a as usize, $b as usize);
                    let mut base = 0usize;
                    for _ in 0..active {
                        let $va = self.regs[base + a];
                        let $vb = self.regs[base + b];
                        self.regs[base + d] = $body;
                        base += rpt;
                    }
                }};
            }
            /// Unary / immediate-operand op over all active threads.
            macro_rules! unop {
                ($d:ident, $a:ident, |$va:ident| $body:expr) => {{
                    let (d, a) = ($d as usize, $a as usize);
                    let mut base = 0usize;
                    for _ in 0..active {
                        let $va = self.regs[base + a];
                        self.regs[base + d] = $body;
                        base += rpt;
                    }
                }};
            }
            #[inline(always)]
            fn fp(bits: u32) -> f32 {
                f32::from_bits(bits)
            }

            let mut next_pc = pc + 1;
            match inst {
                Inst::FAdd { d, a, b } => binop!(d, a, b, |x, y| (fp(x) + fp(y)).to_bits()),
                Inst::FSub { d, a, b } => binop!(d, a, b, |x, y| (fp(x) - fp(y)).to_bits()),
                Inst::FMul { d, a, b } => binop!(d, a, b, |x, y| (fp(x) * fp(y)).to_bits()),
                Inst::IAdd { d, a, b } => binop!(d, a, b, |x, y| x.wrapping_add(y)),
                Inst::ISub { d, a, b } => binop!(d, a, b, |x, y| x.wrapping_sub(y)),
                Inst::IXor { d, a, b } => binop!(d, a, b, |x, y| x ^ y),
                Inst::IAnd { d, a, b } => binop!(d, a, b, |x, y| x & y),
                Inst::IOr { d, a, b } => binop!(d, a, b, |x, y| x | y),
                Inst::IAddI { d, a, imm } => unop!(d, a, |x| x.wrapping_add(imm as u32)),
                Inst::IAndI { d, a, imm } => unop!(d, a, |x| x & imm),
                Inst::IXorI { d, a, imm, .. } => unop!(d, a, |x| x ^ imm),
                Inst::IShlI { d, a, sh } => unop!(d, a, |x| x << sh),
                Inst::IShrI { d, a, sh } => unop!(d, a, |x| x >> sh),
                Inst::Mov { d, a, .. } => unop!(d, a, |x| x),
                Inst::Ldi { d, imm } => {
                    let d = d as usize;
                    let mut base = 0usize;
                    for _ in 0..active {
                        self.regs[base + d] = imm;
                        base += rpt;
                    }
                }
                Inst::LdiF { d, imm } => {
                    let (d, bits) = (d as usize, imm.to_bits());
                    let mut base = 0usize;
                    for _ in 0..active {
                        self.regs[base + d] = bits;
                        base += rpt;
                    }
                }
                Inst::Lds { d, addr, offset } => {
                    let (d, addr) = (d as usize, addr as usize);
                    let n_sp = self.cfg.n_sp;
                    let (mut base, mut sp) = (0usize, 0usize);
                    for _ in 0..active {
                        let a = self.regs[base + addr] as i64 + offset as i64;
                        let v = self.smem.read(sp, a)?;
                        self.regs[base + d] = v;
                        base += rpt;
                        sp += 1;
                        if sp == n_sp {
                            sp = 0;
                        }
                    }
                }
                Inst::Sts { addr, offset, s } => {
                    let (addr, s) = (addr as usize, s as usize);
                    let mut base = 0usize;
                    for _ in 0..active {
                        let a = self.regs[base + addr] as i64 + offset as i64;
                        self.smem.write_coherent(a, self.regs[base + s])?;
                        base += rpt;
                    }
                }
                Inst::StsBank { addr, offset, s } => {
                    if !self.cfg.variant.vm {
                        return Err(SimError::VmUnsupported {
                            variant: self.cfg.variant.name(),
                        });
                    }
                    let (addr, s) = (addr as usize, s as usize);
                    let n_sp = self.cfg.n_sp;
                    let (mut base, mut sp) = (0usize, 0usize);
                    for _ in 0..active {
                        let a = self.regs[base + addr] as i64 + offset as i64;
                        self.smem.write_bank(sp, a, self.regs[base + s])?;
                        base += rpt;
                        sp += 1;
                        if sp == n_sp {
                            sp = 0;
                        }
                    }
                }
                Inst::LodCoeff { re, im } => {
                    coeff_ready = clock + pipe;
                    let (re, im) = (re as usize, im as usize);
                    let mut base = 0usize;
                    for t in 0..active {
                        self.coeff[t] = [fp(self.regs[base + re]), fp(self.regs[base + im])];
                        base += rpt;
                    }
                }
                Inst::MulReal { d, a, b } => {
                    let (d, a, b) = (d as usize, a as usize, b as usize);
                    let mut base = 0usize;
                    for t in 0..active {
                        let [cr, ci] = self.coeff[t];
                        let v = fp(self.regs[base + a]) * cr - fp(self.regs[base + b]) * ci;
                        self.regs[base + d] = v.to_bits();
                        base += rpt;
                    }
                }
                Inst::MulImag { d, a, b } => {
                    let (d, a, b) = (d as usize, a as usize, b as usize);
                    let mut base = 0usize;
                    for t in 0..active {
                        let [cr, ci] = self.coeff[t];
                        let v = fp(self.regs[base + a]) * ci + fp(self.regs[base + b]) * cr;
                        self.regs[base + d] = v.to_bits();
                        base += rpt;
                    }
                }
                Inst::CoeffEn => self.coeff_enabled = true,
                Inst::CoeffDis => self.coeff_enabled = false,
                Inst::Bar | Inst::Nop => {}
                Inst::Bnz { a, target } => {
                    if target >= program.insts.len() {
                        return Err(SimError::BadBranchTarget { pc, target });
                    }
                    let first = self.reg(0, a) != 0;
                    for t in 1..active {
                        if (self.reg(t, a) != 0) != first {
                            return Err(SimError::DivergentBranch { pc });
                        }
                    }
                    if first {
                        next_pc = target;
                    }
                }
                Inst::Halt => {
                    // clock advanced below
                    break;
                }
            }

            clock += dur;
            pc = next_pc;
        }
        Ok(profile)
    }

    pub fn variant(&self) -> Variant {
        self.cfg.variant
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{SmConfig, Variant};
    use crate::isa::asm::assemble;

    fn cfg(variant: Variant, threads: usize) -> SmConfig {
        SmConfig {
            variant,
            n_sp: 16,
            pipeline_depth: 8,
            smem_words: 1024,
            threads,
            regs_per_thread: 32,
        }
    }

    fn sm(variant: Variant, threads: usize) -> Sm {
        let mut sm = Sm::new(cfg(variant, threads));
        sm.seed_thread_ids();
        sm
    }

    #[test]
    fn fadd_per_thread_semantics() {
        let mut sm = sm(Variant::DP, 32);
        let p = assemble("t", "ldif r1, 1.5\nldif r2, 2.25\nfadd r3, r1, r2\nhalt").unwrap();
        sm.run(&p, 32).unwrap();
        for t in 0..32 {
            assert_eq!(sm.regf(t, 3), 3.75);
        }
    }

    #[test]
    fn thread_ids_seeded_in_r0() {
        let mut sm = sm(Variant::DP, 64);
        let p = assemble("t", "ishli r1, r0, 1\nhalt").unwrap();
        sm.run(&p, 64).unwrap();
        for t in 0..64 {
            assert_eq!(sm.reg(t, 1), 2 * t as u32);
        }
    }

    /// ALU instruction at wavefront 4 (64 threads / 16 SP) costs 4 cycles;
    /// a load costs 16 (4 read ports); a DP store costs 64 (1 port).
    #[test]
    fn cycle_costs_dp() {
        let mut sm = sm(Variant::DP, 64);
        // independent instructions, no hazards
        let p = assemble(
            "t",
            "ishli r1, r0, 1\nldi r2, 0\nlds r3, [r2+0]\nsts [r1+0], r0\nhalt",
        )
        .unwrap();
        let prof = sm.run(&p, 64).unwrap();
        assert_eq!(prof.get(OpClass::Int), 4);
        assert_eq!(prof.get(OpClass::Immediate), 4);
        assert_eq!(prof.get(OpClass::Load), 16);
        assert_eq!(prof.get(OpClass::Store), 64);
    }

    #[test]
    fn cycle_costs_qp_store_halves() {
        let mut sm_qp = sm(Variant::QP, 64);
        let p = assemble("t", "ishli r1, r0, 1\nsts [r1+0], r0\nhalt").unwrap();
        let prof = sm_qp.run(&p, 64).unwrap();
        assert_eq!(prof.get(OpClass::Store), 32); // 2 write ports
        assert_eq!(prof.fmax_mhz, 600.0);
    }

    #[test]
    fn cycle_costs_vm_store() {
        let mut s = sm(Variant::DP_VM, 64);
        let p = assemble("t", "ishli r1, r0, 1\nsave_bank [r1+0], r0\nhalt").unwrap();
        let prof = s.run(&p, 64).unwrap();
        assert_eq!(prof.get(OpClass::StoreVm), 16); // 4 virtual ports
    }

    #[test]
    fn save_bank_rejected_without_vm() {
        let mut s = sm(Variant::DP, 16);
        let p = assemble("t", "save_bank [r0+0], r0\nhalt").unwrap();
        assert!(matches!(s.run(&p, 16), Err(SimError::VmUnsupported { .. })));
    }

    /// §6: "hazards are hidden completely if the wavefront depth is
    /// greater than 8" — dependent back-to-back FP ops produce no NOPs at
    /// wavefront 16, but stall (8 - w) cycles at wavefront 4.
    #[test]
    fn hazard_nops_only_below_pipeline_depth() {
        for (threads, expect_nop) in [(256usize, 0u64), (64, 4), (16, 7)] {
            let mut s = sm(Variant::DP, threads);
            let p = assemble("t", "ldif r1, 1.0\nfadd r2, r1, r1\nfadd r3, r2, r2\nhalt")
                .unwrap();
            let prof = s.run(&p, threads).unwrap();
            // two dependent edges: ldi->fadd and fadd->fadd
            assert_eq!(prof.get(OpClass::Nop), 2 * expect_nop, "threads={threads}");
        }
    }

    /// Independent instructions interleaved between dependent ones cover
    /// part of the latency, shrinking the stall.
    #[test]
    fn independent_work_hides_latency() {
        let threads = 64; // wavefront 4
        let mut s = sm(Variant::DP, threads);
        let p = assemble(
            "t",
            "ldif r1, 1.0\nldi r4, 7\nfadd r2, r1, r1\nhalt", // 1 indep op between
        )
        .unwrap();
        let prof = s.run(&p, threads).unwrap();
        // gap to dependent = 2 issues * 4 cycles = 8 >= pipeline -> 0 NOPs
        assert_eq!(prof.get(OpClass::Nop), 0);
    }

    /// The §5 complex-multiply sequence computes the right numbers.
    #[test]
    fn complex_fu_sequence() {
        let mut s = sm(Variant::DP_COMPLEX, 16);
        // (r8 + i r9) * (r30 + i r31) with values (1+2i) * (3+4i) = -5+10i
        let p = assemble(
            "t",
            "coeff_en
             ldif r8, 1.0
             ldif r9, 2.0
             ldif r30, 3.0
             ldif r31, 4.0
             lod_coeff r30, r31
             mul_real r6, r8, r9
             mul_imag r7, r8, r9
             coeff_dis
             halt",
        )
        .unwrap();
        let prof = s.run(&p, 16).unwrap();
        for t in 0..16 {
            assert_eq!(s.regf(t, 6), -5.0);
            assert_eq!(s.regf(t, 7), 10.0);
        }
        // 3 wavefront-wide complex ops + 2 scalar gate toggles
        assert_eq!(prof.get(OpClass::Complex), 3 + 2);
    }

    /// save_bank leaves stale banks: reading from a non-congruent SP
    /// returns the old value (the real failure mode of mis-scheduled VM).
    #[test]
    fn save_bank_stale_visibility() {
        let mut s = sm(Variant::DP_VM, 16);
        s.smem.host_fill(0, &vec![77u32; 16]).unwrap();
        // each thread writes its id to word t via save_bank, then reads
        // word (t+1) mod 16 — neighbouring SP differs by 1 mod 4 -> stale.
        let p = assemble(
            "t",
            "save_bank [r0+0], r0
             iaddi r1, r0, 1
             iandi r1, r1, 0xf
             lds r2, [r1+0]
             halt",
        )
        .unwrap();
        s.run(&p, 16).unwrap();
        for t in 0..16 {
            assert_eq!(s.reg(t, 2), 77, "thread {t} must see the stale value");
        }
    }

    #[test]
    fn bnz_uniform_loop_and_divergence() {
        let mut s = sm(Variant::DP, 16);
        let p = assemble(
            "t",
            "ldi r1, 3\nldi r2, 0\ntop:\niaddi r2, r2, 5\niaddi r1, r1, -1\nbnz r1, top\nhalt",
        )
        .unwrap();
        let prof = s.run(&p, 16).unwrap();
        assert_eq!(s.reg(0, 2), 15);
        assert!(prof.get(OpClass::Branch) >= 3 * 9);

        // divergent predicate -> error
        let mut s = sm(Variant::DP, 16);
        let p = assemble("t", "mov r1, r0\nbnz r1, 0\nhalt").unwrap();
        assert!(matches!(s.run(&p, 16), Err(SimError::DivergentBranch { .. })));
    }

    #[test]
    fn reg_budget_enforced() {
        let mut s = sm(Variant::DP, 16);
        let p = assemble("t", "mov r31, r0\nhalt").unwrap();
        assert!(s.run(&p, 16).is_ok());
        let p = assemble("t", "mov r32, r0\nhalt").unwrap();
        assert!(matches!(s.run(&p, 16), Err(SimError::RegBudget { .. })));
    }

    #[test]
    fn int_fp_work_cycles_tracked() {
        let mut s = sm(Variant::DP, 32);
        let src = "ldif r1, 1.0\nixori r2, r1, 0x80000000\nhalt";
        let mut p = assemble("t", src).unwrap();
        // tag the xor as FP work (codegen does this directly)
        if let Inst::IXorI { ref mut fp_work, .. } = p.insts[1] {
            *fp_work = true;
        }
        let prof = s.run(&p, 32).unwrap();
        assert_eq!(prof.int_fp_work_cycles, 2); // wavefront 2
        assert_eq!(s.regf(0, 2), -1.0);
    }
}
