//! Resident FFT executor: an SM bound to one prebuilt, shared program.
//!
//! The serving path creates one executor per (core, size) and pays the
//! setup — SM allocation, thread-id seeding and the twiddle-table
//! upload — exactly once; each request is then only a data fill, a run
//! and a readback. The program arrives as an `Arc<FftProgram>` from the
//! shared [`crate::fft::cache::PlanCache`], so no plan, schedule or
//! twiddle table is ever rebuilt per call.

use std::sync::Arc;

use super::Sm;
use crate::arch::SmConfig;
use crate::fft::{self, FftError, FftProgram, FftRun};

pub struct FftExecutor {
    sm: Sm,
    program: Arc<FftProgram>,
    runs: u64,
}

impl FftExecutor {
    /// Bind `program` to a fresh SM: seed thread ids and upload the
    /// precomputed twiddle image once.
    pub fn new(cfg: SmConfig, program: Arc<FftProgram>) -> Result<Self, FftError> {
        let mut sm = Sm::new(cfg);
        sm.seed_thread_ids();
        fft::load_twiddles(&mut sm, &program)?;
        Ok(FftExecutor { sm, program, runs: 0 })
    }

    /// The shared program this executor runs.
    pub fn program(&self) -> &Arc<FftProgram> {
        &self.program
    }

    /// Transform size handled per run.
    pub fn points(&self) -> usize {
        self.program.plan.points
    }

    /// FFTs served by this resident executor since it was bound — the
    /// per-SM amortization counter (setup cost ÷ `runs`).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Run one FFT: load the input, execute, read back natural order.
    pub fn run(&mut self, input: &[(f32, f32)]) -> Result<FftRun, FftError> {
        if input.len() != self.program.plan.points {
            return Err(FftError::BadInput {
                got: input.len(),
                want: self.program.plan.points,
            });
        }
        fft::load_data(&mut self.sm, &self.program, input)?;
        let profile = self.sm.run(&self.program.program, self.program.plan.threads)?;
        let output = fft::read_output(&self.sm, &self.program)?;
        self.runs += 1;
        Ok(FftRun { output, profile })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Variant;
    use crate::fft::reference;

    fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
        reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
    }

    /// The resident executor must be bit-for-bit the one-shot path: the
    /// same program over the same data on a deterministic SM.
    #[test]
    fn executor_matches_one_shot_run_fft_bitwise() {
        let cfg = SmConfig::for_radix(Variant::DP_VM_COMPLEX, 4);
        let fp = Arc::new(fft::generate(&cfg, 256, 4).unwrap());
        let mut ex = FftExecutor::new(cfg, Arc::clone(&fp)).unwrap();
        for seed in 0..4u64 {
            let input = signal(256, seed);
            let resident = ex.run(&input).unwrap();
            let oneshot = fft::run_fft(&fp, &cfg, &input).unwrap();
            let a: Vec<(u32, u32)> =
                resident.output.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect();
            let b: Vec<(u32, u32)> =
                oneshot.output.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect();
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(resident.profile.total(), oneshot.profile.total());
        }
    }

    /// Re-running the same input must be deterministic even though SM
    /// register/memory state persists between runs.
    #[test]
    fn repeated_runs_are_deterministic() {
        let cfg = SmConfig::for_radix(Variant::DP, 16);
        let fp = Arc::new(fft::generate(&cfg, 1024, 16).unwrap());
        let mut ex = FftExecutor::new(cfg, fp).unwrap();
        assert_eq!(ex.runs(), 0);
        let input = signal(1024, 42);
        let first = ex.run(&input).unwrap();
        let second = ex.run(&input).unwrap();
        assert_eq!(first.output, second.output);
        assert_eq!(ex.runs(), 2, "amortization counter tracks served FFTs");
    }

    #[test]
    fn wrong_length_rejected() {
        let cfg = SmConfig::for_radix(Variant::DP, 4);
        let fp = Arc::new(fft::generate(&cfg, 256, 4).unwrap());
        let mut ex = FftExecutor::new(cfg, fp).unwrap();
        assert_eq!(ex.points(), 256);
        assert!(matches!(
            ex.run(&signal(128, 0)),
            Err(FftError::BadInput { got: 128, want: 256 })
        ));
    }
}
