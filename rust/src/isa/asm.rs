//! Text assembler for the eGPU ISA.
//!
//! Accepts the PTX-like syntax that [`Inst`](super::Inst)'s `Display`
//! impl emits (so listings round-trip), plus labels for branches:
//!
//! ```text
//! ; radix-2 butterfly
//! loop:
//!   lds   r4, [r2+0]
//!   fadd  r6, r4, r5
//!   sts   [r2+0], r6
//!   bnz   r3, loop
//!   halt
//! ```

use super::{Inst, Program, Reg};
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum AsmError {
    #[error("line {line}: unknown mnemonic `{mnemonic}`")]
    UnknownMnemonic { line: usize, mnemonic: String },
    #[error("line {line}: bad operand `{operand}`: {reason}")]
    BadOperand { line: usize, operand: String, reason: String },
    #[error("line {line}: expected {expected} operands, got {got}")]
    Arity { line: usize, expected: usize, got: usize },
    #[error("undefined label `{0}`")]
    UndefinedLabel(String),
    #[error("duplicate label `{0}`")]
    DuplicateLabel(String),
}

/// Assemble source text into a [`Program`].
pub fn assemble(name: &str, src: &str) -> Result<Program, AsmError> {
    // First pass: strip comments, collect labels.
    let mut labels: HashMap<String, usize> = HashMap::new();
    let mut lines: Vec<(usize, String)> = Vec::new(); // (source line no, text)
    let mut idx = 0usize;
    for (ln, raw) in src.lines().enumerate() {
        let text = raw.split(';').next().unwrap_or("").trim();
        if text.is_empty() {
            continue;
        }
        if let Some(label) = text.strip_suffix(':') {
            let label = label.trim().to_string();
            if labels.insert(label.clone(), idx).is_some() {
                return Err(AsmError::DuplicateLabel(label));
            }
            continue;
        }
        lines.push((ln + 1, text.to_string()));
        idx += 1;
    }

    // Second pass: parse instructions.
    let mut insts = Vec::with_capacity(lines.len());
    for (ln, text) in &lines {
        insts.push(parse_line(*ln, text, &labels)?);
    }
    Ok(Program::new(name, insts))
}

fn parse_line(
    line: usize,
    text: &str,
    labels: &HashMap<String, usize>,
) -> Result<Inst, AsmError> {
    let (mnemonic, rest) = match text.find(char::is_whitespace) {
        Some(pos) => (&text[..pos], text[pos..].trim()),
        None => (text, ""),
    };
    let m = mnemonic.to_ascii_lowercase();
    let ops: Vec<&str> = if rest.is_empty() {
        vec![]
    } else {
        rest.split(',').map(str::trim).collect()
    };

    let arity = |n: usize| -> Result<(), AsmError> {
        if ops.len() != n {
            Err(AsmError::Arity { line, expected: n, got: ops.len() })
        } else {
            Ok(())
        }
    };
    let reg = |s: &str| parse_reg(line, s);
    let mem = |s: &str| parse_mem(line, s);

    let inst = match m.as_str() {
        "fadd" | "fsub" | "fmul" | "iadd" | "isub" | "ixor" | "iand" | "ior" | "mul_real"
        | "mul_imag" => {
            arity(3)?;
            let d = reg(ops[0])?;
            let a = reg(ops[1])?;
            let b = reg(ops[2])?;
            match m.as_str() {
                "fadd" => Inst::FAdd { d, a, b },
                "fsub" => Inst::FSub { d, a, b },
                "fmul" => Inst::FMul { d, a, b },
                "iadd" => Inst::IAdd { d, a, b },
                "isub" => Inst::ISub { d, a, b },
                "ixor" => Inst::IXor { d, a, b },
                "iand" => Inst::IAnd { d, a, b },
                "ior" => Inst::IOr { d, a, b },
                "mul_real" => Inst::MulReal { d, a, b },
                _ => Inst::MulImag { d, a, b },
            }
        }
        "iaddi" => {
            arity(3)?;
            Inst::IAddI { d: reg(ops[0])?, a: reg(ops[1])?, imm: parse_int(line, ops[2])? as i32 }
        }
        "iandi" => {
            arity(3)?;
            Inst::IAndI {
                d: reg(ops[0])?,
                a: reg(ops[1])?,
                imm: parse_int(line, ops[2])? as u32,
            }
        }
        "ixori" => {
            arity(3)?;
            Inst::IXorI {
                d: reg(ops[0])?,
                a: reg(ops[1])?,
                imm: parse_int(line, ops[2])? as u32,
                fp_work: false,
            }
        }
        "ishli" | "ishri" => {
            arity(3)?;
            let sh = parse_int(line, ops[2])? as u8;
            let (d, a) = (reg(ops[0])?, reg(ops[1])?);
            if m == "ishli" {
                Inst::IShlI { d, a, sh }
            } else {
                Inst::IShrI { d, a, sh }
            }
        }
        "mov" => {
            arity(2)?;
            Inst::Mov { d: reg(ops[0])?, a: reg(ops[1])?, fp_work: false }
        }
        "ldi" => {
            arity(2)?;
            Inst::Ldi { d: reg(ops[0])?, imm: parse_int(line, ops[1])? as u32 }
        }
        "ldif" => {
            arity(2)?;
            let v: f32 = ops[1].parse().map_err(|_| AsmError::BadOperand {
                line,
                operand: ops[1].into(),
                reason: "expected f32 literal".into(),
            })?;
            Inst::LdiF { d: reg(ops[0])?, imm: v }
        }
        "lds" => {
            arity(2)?;
            let (addr, offset) = mem(ops[1])?;
            Inst::Lds { d: reg(ops[0])?, addr, offset }
        }
        "sts" | "save_bank" => {
            arity(2)?;
            let (addr, offset) = mem(ops[0])?;
            let s = reg(ops[1])?;
            if m == "sts" {
                Inst::Sts { addr, offset, s }
            } else {
                Inst::StsBank { addr, offset, s }
            }
        }
        "lod_coeff" => {
            arity(2)?;
            Inst::LodCoeff { re: reg(ops[0])?, im: reg(ops[1])? }
        }
        "coeff_en" => Inst::CoeffEn,
        "coeff_dis" => Inst::CoeffDis,
        "bar" => Inst::Bar,
        "bnz" => {
            arity(2)?;
            let a = reg(ops[0])?;
            let target = match labels.get(ops[1]) {
                Some(&t) => t,
                None => ops[1]
                    .parse::<usize>()
                    .map_err(|_| AsmError::UndefinedLabel(ops[1].to_string()))?,
            };
            Inst::Bnz { a, target }
        }
        "nop" => Inst::Nop,
        "halt" => Inst::Halt,
        _ => return Err(AsmError::UnknownMnemonic { line, mnemonic: mnemonic.into() }),
    };
    Ok(inst)
}

fn parse_reg(line: usize, s: &str) -> Result<Reg, AsmError> {
    let body = s
        .strip_prefix('r')
        .or_else(|| s.strip_prefix('R'))
        .ok_or_else(|| AsmError::BadOperand {
            line,
            operand: s.into(),
            reason: "expected register rN".into(),
        })?;
    body.parse::<Reg>().map_err(|_| AsmError::BadOperand {
        line,
        operand: s.into(),
        reason: "bad register number".into(),
    })
}

/// Parse `[rN+off]` / `[rN-off]` / `[rN]`.
fn parse_mem(line: usize, s: &str) -> Result<(Reg, i32), AsmError> {
    let bad = |reason: &str| AsmError::BadOperand {
        line,
        operand: s.into(),
        reason: reason.into(),
    };
    let inner = s
        .strip_prefix('[')
        .and_then(|t| t.strip_suffix(']'))
        .ok_or_else(|| bad("expected [rN+off]"))?;
    if let Some(pos) = inner.rfind(['+', '-']) {
        if pos > 0 {
            let r = parse_reg(line, inner[..pos].trim())?;
            let off: i32 = inner[pos..]
                .replace('+', "")
                .trim()
                .parse()
                .map_err(|_| bad("bad offset"))?;
            return Ok((r, off));
        }
    }
    Ok((parse_reg(line, inner.trim())?, 0))
}

fn parse_int(line: usize, s: &str) -> Result<i64, AsmError> {
    let t = s.trim();
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t),
    };
    let v = if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16)
    } else {
        t.parse::<i64>()
    }
    .map_err(|_| AsmError::BadOperand {
        line,
        operand: s.into(),
        reason: "bad integer".into(),
    })?;
    Ok(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    #[test]
    fn paper_complex_sequence_assembles() {
        // The exact fragment from §5 of the paper (lowercased mnemonics).
        let src = "
            lod_coeff r30, r31 ; load tw_real, tw_imag into cache
            mul_real  r6, r8, r9
            mul_imag  r7, r8, r9
            halt
        ";
        let p = assemble("cmul", src).unwrap();
        assert_eq!(p.insts.len(), 4);
        assert_eq!(p.insts[0], Inst::LodCoeff { re: 30, im: 31 });
        assert_eq!(p.insts[1], Inst::MulReal { d: 6, a: 8, b: 9 });
        assert_eq!(p.insts[2], Inst::MulImag { d: 7, a: 8, b: 9 });
        assert_eq!(p.class_histogram()[OpClass::Complex.index()], 3);
    }

    #[test]
    fn memory_operands() {
        let p = assemble(
            "m",
            "lds r4, [r2+16]\nsts [r2-3], r7\nsave_bank [r9], r1\nhalt",
        )
        .unwrap();
        assert_eq!(p.insts[0], Inst::Lds { d: 4, addr: 2, offset: 16 });
        assert_eq!(p.insts[1], Inst::Sts { addr: 2, offset: -3, s: 7 });
        assert_eq!(p.insts[2], Inst::StsBank { addr: 9, offset: 0, s: 1 });
    }

    #[test]
    fn labels_resolve() {
        let src = "
            ldi r1, 4
        top:
            iaddi r1, r1, -1
            bnz r1, top
            halt
        ";
        let p = assemble("loop", src).unwrap();
        assert_eq!(p.insts[2], Inst::Bnz { a: 1, target: 1 });
    }

    #[test]
    fn duplicate_label_rejected() {
        let err = assemble("d", "x:\nnop\nx:\nhalt").unwrap_err();
        assert!(matches!(err, AsmError::DuplicateLabel(_)));
    }

    #[test]
    fn unknown_mnemonic_rejected() {
        let err = assemble("u", "frobnicate r1, r2").unwrap_err();
        assert!(matches!(err, AsmError::UnknownMnemonic { .. }));
    }

    #[test]
    fn hex_and_negative_immediates() {
        let p = assemble("i", "ldi r1, 0x8000_0000\nhalt");
        // underscore not supported -> error is fine; without underscore:
        assert!(p.is_err() || p.is_ok());
        let p = assemble("i", "ldi r1, 0x80000000\niaddi r2, r1, -6\nhalt").unwrap();
        assert_eq!(p.insts[0], Inst::Ldi { d: 1, imm: 0x8000_0000 });
        assert_eq!(p.insts[1], Inst::IAddI { d: 2, a: 1, imm: -6 });
    }

    #[test]
    fn display_round_trips() {
        let insts = vec![
            Inst::FAdd { d: 1, a: 2, b: 3 },
            Inst::IShlI { d: 4, a: 1, sh: 3 },
            Inst::Lds { d: 5, addr: 4, offset: 12 },
            Inst::Sts { addr: 4, offset: 1, s: 5 },
            Inst::StsBank { addr: 4, offset: 0, s: 5 },
            Inst::LodCoeff { re: 30, im: 31 },
            Inst::MulReal { d: 6, a: 8, b: 9 },
            Inst::Ldi { d: 7, imm: 0xff },
            Inst::Bar,
            Inst::Halt,
        ];
        let src: String = insts.iter().map(|i| format!("{i}\n")).collect();
        let p = assemble("rt", &src).unwrap();
        assert_eq!(p.insts, insts);
    }
}
