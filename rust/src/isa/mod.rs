//! The eGPU instruction set.
//!
//! A PTX-like SIMT ISA modelled on the paper's published fragments
//! (`LOD_COEFF R30, R31; MUL_REAL R6, R8, R9; ...`) and on the
//! architectural description in [Langhammer & Constantinides, FPGA'24].
//! Every instruction belongs to exactly one [`OpClass`]; the profiler
//! (Tables 1–3 of the paper) accounts cycles per class.
//!
//! Register operands are per-thread register-file indices (`R0` is
//! preloaded with the thread id, as in Figure 2 of the paper). Memory
//! operands address the SM's shared memory in 32-bit words.

pub mod asm;

use std::fmt;

/// Per-thread register index.
pub type Reg = u16;

/// Cycle-accounting class, one row group of the paper's Tables 1–3.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash, PartialOrd, Ord)]
pub enum OpClass {
    /// Real floating-point ALU op (add/sub/mul).
    Fp,
    /// Complex functional-unit op (`lod_coeff`, `mul_real`, `mul_imag`):
    /// a sum-of-two-multipliers datapath built from two DSP blocks.
    Complex,
    /// Integer ALU op (add/sub/logic/shift/move).
    Int,
    /// Shared-memory read (4 read ports).
    Load,
    /// Shared-memory coherent write (1 port DP, 2 ports QP).
    Store,
    /// `save_bank` virtual-banked write (4 virtual ports).
    StoreVm,
    /// Immediate load into a register.
    Immediate,
    /// Uniform control flow (pass barrier / branch).
    Branch,
    /// Explicit or hazard-inserted stall.
    Nop,
}

impl OpClass {
    pub const ALL: [OpClass; 9] = [
        OpClass::Fp,
        OpClass::Complex,
        OpClass::Int,
        OpClass::Load,
        OpClass::Store,
        OpClass::StoreVm,
        OpClass::Immediate,
        OpClass::Branch,
        OpClass::Nop,
    ];

    pub fn index(self) -> usize {
        match self {
            OpClass::Fp => 0,
            OpClass::Complex => 1,
            OpClass::Int => 2,
            OpClass::Load => 3,
            OpClass::Store => 4,
            OpClass::StoreVm => 5,
            OpClass::Immediate => 6,
            OpClass::Branch => 7,
            OpClass::Nop => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Fp => "FP OP",
            OpClass::Complex => "Complex OP",
            OpClass::Int => "INT OP",
            OpClass::Load => "Load",
            OpClass::Store => "Store",
            OpClass::StoreVm => "StoreVM",
            OpClass::Immediate => "Immediate",
            OpClass::Branch => "Branch",
            OpClass::Nop => "NOP",
        }
    }
}

/// One eGPU instruction.
///
/// `FpWork` tagging: some INT-class instructions perform work that is
/// arithmetically part of the FFT (e.g. a multiply by `-j` implemented as
/// a move + sign-flip XOR, §3.1 of the paper). They carry `fp_work = true`
/// so the profiler can report the paper's §6.1 "effective efficiency".
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Inst {
    // ---- real FP (OpClass::Fp) ----
    FAdd { d: Reg, a: Reg, b: Reg },
    FSub { d: Reg, a: Reg, b: Reg },
    FMul { d: Reg, a: Reg, b: Reg },

    // ---- integer / move (OpClass::Int) ----
    IAdd { d: Reg, a: Reg, b: Reg },
    ISub { d: Reg, a: Reg, b: Reg },
    IXor { d: Reg, a: Reg, b: Reg },
    IAnd { d: Reg, a: Reg, b: Reg },
    IOr { d: Reg, a: Reg, b: Reg },
    /// `d = a + imm` (sign-extended immediate operand, still INT class).
    IAddI { d: Reg, a: Reg, imm: i32 },
    /// `d = a & imm`.
    IAndI { d: Reg, a: Reg, imm: u32 },
    /// `d = a ^ imm`; with `imm = 0x8000_0000` this is the paper's
    /// integer FP-negate (§3.1), tagged as FP work.
    IXorI { d: Reg, a: Reg, imm: u32, fp_work: bool },
    /// `d = a << sh`.
    IShlI { d: Reg, a: Reg, sh: u8 },
    /// `d = a >> sh` (logical).
    IShrI { d: Reg, a: Reg, sh: u8 },
    /// Register move; `fp_work` when it realizes a trivial complex
    /// rotation (multiply by ±1/±j), per Table 4 of the paper.
    Mov { d: Reg, a: Reg, fp_work: bool },

    // ---- immediate (OpClass::Immediate) ----
    Ldi { d: Reg, imm: u32 },
    /// Load an f32 constant (encoding convenience; same class/cost as Ldi).
    LdiF { d: Reg, imm: f32 },

    // ---- shared memory ----
    /// `d = smem[a + offset]` (word-addressed).
    Lds { d: Reg, addr: Reg, offset: i32 },
    /// Coherent store: `smem[a + offset] = s` in all four banks.
    Sts { addr: Reg, offset: i32, s: Reg },
    /// `save_bank`: virtual-banked store; writes only the bank belonging
    /// to the issuing SP (SP index mod 4). 4× write bandwidth, but the
    /// other three banks hold stale data at this location (§4).
    StsBank { addr: Reg, offset: i32, s: Reg },

    // ---- complex functional unit (OpClass::Complex) ----
    /// Load (tw_re, tw_im) from registers into the per-thread
    /// coefficient cache (circular buffer indexed by thread id, §5).
    LodCoeff { re: Reg, im: Reg },
    /// `d = a*tw_re - b*tw_im` (sum-of-two-multipliers datapath).
    MulReal { d: Reg, a: Reg, b: Reg },
    /// `d = a*tw_im + b*tw_re`.
    MulImag { d: Reg, a: Reg, b: Reg },
    /// Enable / disable the coefficient-cache clock (power gating, §5).
    CoeffEn,
    CoeffDis,

    // ---- control (OpClass::Branch / Nop) ----
    /// Pass barrier: uniform scalar control op separating FFT passes
    /// (drains the pipeline; costed as a taken branch).
    Bar,
    /// Uniform branch: taken when the (required-uniform) register is
    /// non-zero in all threads. `target` is an absolute instruction index.
    Bnz { a: Reg, target: usize },
    Nop,
    Halt,
}

impl Inst {
    pub fn class(&self) -> OpClass {
        use Inst::*;
        match self {
            FAdd { .. } | FSub { .. } | FMul { .. } => OpClass::Fp,
            IAdd { .. } | ISub { .. } | IXor { .. } | IAnd { .. } | IOr { .. }
            | IAddI { .. } | IAndI { .. } | IXorI { .. } | IShlI { .. } | IShrI { .. }
            | Mov { .. } => OpClass::Int,
            Ldi { .. } | LdiF { .. } => OpClass::Immediate,
            Lds { .. } => OpClass::Load,
            Sts { .. } => OpClass::Store,
            StsBank { .. } => OpClass::StoreVm,
            LodCoeff { .. } | MulReal { .. } | MulImag { .. } | CoeffEn | CoeffDis => {
                OpClass::Complex
            }
            Bar | Bnz { .. } | Halt => OpClass::Branch,
            Nop => OpClass::Nop,
        }
    }

    /// INT-class instruction that performs FP-equivalent work (§6.1).
    pub fn is_fp_work(&self) -> bool {
        matches!(
            self,
            Inst::IXorI { fp_work: true, .. } | Inst::Mov { fp_work: true, .. }
        )
    }

    /// Destination register written by this instruction, if any.
    pub fn dst(&self) -> Option<Reg> {
        use Inst::*;
        match *self {
            FAdd { d, .. } | FSub { d, .. } | FMul { d, .. } | IAdd { d, .. }
            | ISub { d, .. } | IXor { d, .. } | IAnd { d, .. } | IOr { d, .. }
            | IAddI { d, .. } | IAndI { d, .. } | IXorI { d, .. } | IShlI { d, .. }
            | IShrI { d, .. } | Mov { d, .. } | Ldi { d, .. } | LdiF { d, .. }
            | Lds { d, .. } | MulReal { d, .. } | MulImag { d, .. } => Some(d),
            _ => None,
        }
    }

    /// Source registers read by this instruction (up to 3).
    pub fn srcs(&self) -> impl Iterator<Item = Reg> {
        use Inst::*;
        let (a, b, c): (Option<Reg>, Option<Reg>, Option<Reg>) = match *self {
            FAdd { a, b, .. } | FSub { a, b, .. } | FMul { a, b, .. } | IAdd { a, b, .. }
            | ISub { a, b, .. } | IXor { a, b, .. } | IAnd { a, b, .. }
            | IOr { a, b, .. } => (Some(a), Some(b), None),
            IAddI { a, .. } | IAndI { a, .. } | IXorI { a, .. } | IShlI { a, .. }
            | IShrI { a, .. } | Mov { a, .. } => (Some(a), None, None),
            Lds { addr, .. } => (Some(addr), None, None),
            Sts { addr, s, .. } | StsBank { addr, s, .. } => (Some(addr), Some(s), None),
            LodCoeff { re, im } => (Some(re), Some(im), None),
            // mul_real/mul_imag also read the coefficient cache; that
            // dependency is tracked separately by the simulator.
            MulReal { a, b, .. } | MulImag { a, b, .. } => (Some(a), Some(b), None),
            Bnz { a, .. } => (Some(a), None, None),
            _ => (None, None, None),
        };
        [a, b, c].into_iter().flatten()
    }

    /// Highest register index referenced (for register-budget checks).
    pub fn max_reg(&self) -> Option<Reg> {
        self.dst().into_iter().chain(self.srcs()).max()
    }
}

impl fmt::Display for Inst {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Inst::*;
        match *self {
            FAdd { d, a, b } => write!(f, "fadd r{d}, r{a}, r{b}"),
            FSub { d, a, b } => write!(f, "fsub r{d}, r{a}, r{b}"),
            FMul { d, a, b } => write!(f, "fmul r{d}, r{a}, r{b}"),
            IAdd { d, a, b } => write!(f, "iadd r{d}, r{a}, r{b}"),
            ISub { d, a, b } => write!(f, "isub r{d}, r{a}, r{b}"),
            IXor { d, a, b } => write!(f, "ixor r{d}, r{a}, r{b}"),
            IAnd { d, a, b } => write!(f, "iand r{d}, r{a}, r{b}"),
            IOr { d, a, b } => write!(f, "ior r{d}, r{a}, r{b}"),
            IAddI { d, a, imm } => write!(f, "iaddi r{d}, r{a}, {imm}"),
            IAndI { d, a, imm } => write!(f, "iandi r{d}, r{a}, {imm:#x}"),
            IXorI { d, a, imm, fp_work } => {
                write!(f, "ixori r{d}, r{a}, {imm:#x}{}", flag(fp_work))
            }
            IShlI { d, a, sh } => write!(f, "ishli r{d}, r{a}, {sh}"),
            IShrI { d, a, sh } => write!(f, "ishri r{d}, r{a}, {sh}"),
            Mov { d, a, fp_work } => write!(f, "mov r{d}, r{a}{}", flag(fp_work)),
            Ldi { d, imm } => write!(f, "ldi r{d}, {imm:#x}"),
            LdiF { d, imm } => write!(f, "ldif r{d}, {imm:?}"),
            Lds { d, addr, offset } => write!(f, "lds r{d}, [r{addr}+{offset}]"),
            Sts { addr, offset, s } => write!(f, "sts [r{addr}+{offset}], r{s}"),
            StsBank { addr, offset, s } => write!(f, "save_bank [r{addr}+{offset}], r{s}"),
            LodCoeff { re, im } => write!(f, "lod_coeff r{re}, r{im}"),
            MulReal { d, a, b } => write!(f, "mul_real r{d}, r{a}, r{b}"),
            MulImag { d, a, b } => write!(f, "mul_imag r{d}, r{a}, r{b}"),
            CoeffEn => write!(f, "coeff_en"),
            CoeffDis => write!(f, "coeff_dis"),
            Bar => write!(f, "bar"),
            Bnz { a, target } => write!(f, "bnz r{a}, {target}"),
            Nop => write!(f, "nop"),
            Halt => write!(f, "halt"),
        }
    }
}

fn flag(fp_work: bool) -> &'static str {
    if fp_work {
        " ;fp"
    } else {
        ""
    }
}

/// An assembled eGPU program: a flat instruction sequence ending in `halt`.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub name: String,
    pub insts: Vec<Inst>,
}

impl Program {
    pub fn new(name: impl Into<String>, insts: Vec<Inst>) -> Self {
        Self { name: name.into(), insts }
    }

    /// Number of instructions (including the trailing `halt`).
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Highest register index used; must be < regs-per-thread.
    pub fn max_reg(&self) -> Reg {
        self.insts.iter().filter_map(|i| i.max_reg()).max().unwrap_or(0)
    }

    /// Instruction count per op class (static, not cycles).
    pub fn class_histogram(&self) -> [usize; 9] {
        let mut h = [0usize; 9];
        for i in &self.insts {
            h[i.class().index()] += 1;
        }
        h
    }

    /// Disassembly listing (round-trips through [`asm::assemble`]).
    pub fn listing(&self) -> String {
        let mut s = String::with_capacity(self.insts.len() * 24);
        s.push_str(&format!("; program: {}\n", self.name));
        for (idx, inst) in self.insts.iter().enumerate() {
            s.push_str(&format!("{idx:6}  {inst}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_class_mapping_matches_paper_rows() {
        assert_eq!(Inst::FAdd { d: 1, a: 2, b: 3 }.class(), OpClass::Fp);
        assert_eq!(Inst::MulReal { d: 6, a: 8, b: 9 }.class(), OpClass::Complex);
        assert_eq!(Inst::LodCoeff { re: 30, im: 31 }.class(), OpClass::Complex);
        assert_eq!(Inst::Mov { d: 1, a: 2, fp_work: false }.class(), OpClass::Int);
        assert_eq!(Inst::Lds { d: 1, addr: 2, offset: 0 }.class(), OpClass::Load);
        assert_eq!(Inst::Sts { addr: 2, offset: 0, s: 1 }.class(), OpClass::Store);
        assert_eq!(
            Inst::StsBank { addr: 2, offset: 0, s: 1 }.class(),
            OpClass::StoreVm
        );
        assert_eq!(Inst::Ldi { d: 1, imm: 0 }.class(), OpClass::Immediate);
        assert_eq!(Inst::Bar.class(), OpClass::Branch);
        assert_eq!(Inst::Nop.class(), OpClass::Nop);
    }

    #[test]
    fn fp_work_tagging() {
        let neg = Inst::IXorI { d: 1, a: 2, imm: 0x8000_0000, fp_work: true };
        assert!(neg.is_fp_work());
        assert_eq!(neg.class(), OpClass::Int);
        let mov = Inst::Mov { d: 1, a: 2, fp_work: true };
        assert!(mov.is_fp_work());
        let plain = Inst::Mov { d: 1, a: 2, fp_work: false };
        assert!(!plain.is_fp_work());
    }

    #[test]
    fn dst_and_srcs() {
        let i = Inst::FAdd { d: 4, a: 5, b: 6 };
        assert_eq!(i.dst(), Some(4));
        assert_eq!(i.srcs().collect::<Vec<_>>(), vec![5, 6]);
        let s = Inst::Sts { addr: 2, offset: 1, s: 7 };
        assert_eq!(s.dst(), None);
        assert_eq!(s.srcs().collect::<Vec<_>>(), vec![2, 7]);
        assert_eq!(s.max_reg(), Some(7));
    }

    #[test]
    fn class_index_is_dense_permutation() {
        let mut seen = [false; 9];
        for c in OpClass::ALL {
            assert!(!seen[c.index()]);
            seen[c.index()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn program_histogram_and_max_reg() {
        let p = Program::new(
            "t",
            vec![
                Inst::Ldi { d: 3, imm: 1 },
                Inst::FAdd { d: 9, a: 3, b: 3 },
                Inst::Halt,
            ],
        );
        let h = p.class_histogram();
        assert_eq!(h[OpClass::Fp.index()], 1);
        assert_eq!(h[OpClass::Immediate.index()], 1);
        assert_eq!(h[OpClass::Branch.index()], 1);
        assert_eq!(p.max_reg(), 9);
    }
}
