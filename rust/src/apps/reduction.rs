//! Parallel sum-reduction on the eGPU (§4's second VM-friendly
//! workload).
//!
//! Two phases, the standard GPU shape:
//! 1. **serial accumulation** — each of the T threads strides through
//!    the input (`x[t + k·T]`) and accumulates a private partial sum in
//!    a register, then writes it to a scratch vector;
//! 2. **tree reduction** — log2(T) halving passes over the scratch
//!    vector (`s[t] += s[t + len/2]`).
//!
//! Virtual-bank eligibility mirrors the FFT analysis: pass writes go to
//! `s[t]` (same SP re-reads them, trivially congruent) while the other
//! operand comes from `s[t + len/2]` — congruent mod 4 exactly when
//! `len/2 % 4 == 0`, so `save_bank` applies to every tree pass except
//! the last two, which store coherently (and the final result must be
//! coherent for host readback anyway). The generator derives this rule
//! per pass and the banked-memory simulator *proves* it by executing.

use crate::arch::SmConfig;
use crate::fft::plan::PlanError;
use crate::isa::{Inst, Program, Reg};
use crate::profile::Profile;
use crate::sim::{SimError, Sm};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum ReductionError {
    #[error(transparent)]
    Plan(#[from] PlanError),
    #[error(transparent)]
    Sim(#[from] SimError),
    #[error("input length {got} does not match program size {want}")]
    BadInput { got: usize, want: usize },
}

// register map
const R_TID: Reg = 0;
const R_ACC: Reg = 2;
const R_VAL: Reg = 3;
const R_SADDR: Reg = 4;

/// A generated reduction program.
#[derive(Clone, Debug)]
pub struct ReductionProgram {
    pub program: Program,
    pub n: usize,
    pub threads: usize,
    /// Scratch vector base (word address).
    pub scratch_base: usize,
}

/// Generate the sum-reduction of `n` f32 values for `cfg`'s variant.
pub fn generate(cfg: &SmConfig, n: usize) -> Result<ReductionProgram, PlanError> {
    assert!(n.is_power_of_two() && n >= 32);
    let threads = cfg.threads.min(n / 2).min(256);
    let scratch_base = n; // words: input n + scratch threads
    if scratch_base + threads > cfg.smem_words {
        return Err(PlanError::TooLarge { need: scratch_base + threads, have: cfg.smem_words });
    }

    let mut code: Vec<Inst> = Vec::new();
    // phase 1: serial accumulation x[t + k·T], k = 0..n/T
    code.push(Inst::Lds { d: R_ACC, addr: R_TID, offset: 0 });
    let per_thread = n / threads;
    for k in 1..per_thread {
        code.push(Inst::Lds { d: R_VAL, addr: R_TID, offset: (k * threads) as i32 });
        code.push(Inst::FAdd { d: R_ACC, a: R_ACC, b: R_VAL });
    }
    // scratch store: s[t] = acc; banked iff the first tree read is
    // congruent (threads/2 % 4 == 0 — always true for threads ≥ 32)
    code.push(Inst::IAddI { d: R_SADDR, a: R_TID, imm: scratch_base as i32 });
    push_store(&mut code, cfg, threads / 2 % 4 == 0, R_SADDR, 0, R_ACC);
    code.push(Inst::Bar);

    // phase 2: tree passes over scratch. All threads execute (SIMT);
    // threads beyond len/2 write garbage into the dead upper half,
    // which is never read again — the classic divergence-free shape.
    let mut len = threads;
    while len >= 2 {
        let half = len / 2;
        code.push(Inst::Lds { d: R_ACC, addr: R_SADDR, offset: 0 });
        code.push(Inst::Lds { d: R_VAL, addr: R_SADDR, offset: half as i32 });
        code.push(Inst::FAdd { d: R_ACC, a: R_ACC, b: R_VAL });
        // next pass reads s[t] (same SP) and s[t + half/2]: banked
        // write is safe iff half/2 ≡ 0 (mod 4); the final pass (len=2)
        // must be coherent for host readback.
        let vm_ok = len > 2 && (half / 2) % 4 == 0;
        push_store(&mut code, cfg, vm_ok, R_SADDR, 0, R_ACC);
        code.push(Inst::Bar);
        len = half;
    }
    code.push(Inst::Halt);

    let program = crate::fft::sched::schedule(
        &Program::new(format!("reduce{n}-{}", cfg.variant.name()), code),
        cfg.pipeline_depth,
    );
    Ok(ReductionProgram { program, n, threads, scratch_base })
}

fn push_store(code: &mut Vec<Inst>, cfg: &SmConfig, vm_ok: bool, addr: Reg, off: i32, s: Reg) {
    if cfg.variant.vm && vm_ok {
        code.push(Inst::StsBank { addr, offset: off, s });
    } else {
        code.push(Inst::Sts { addr, offset: off, s });
    }
}

/// Run the reduction; returns (sum, profile).
pub fn run(
    rp: &ReductionProgram,
    cfg: &SmConfig,
    input: &[f32],
) -> Result<(f32, Profile), ReductionError> {
    if input.len() != rp.n {
        return Err(ReductionError::BadInput { got: input.len(), want: rp.n });
    }
    let mut sm = Sm::new(*cfg);
    sm.seed_thread_ids();
    let words: Vec<u32> = input.iter().map(|v| v.to_bits()).collect();
    sm.smem.host_fill(0, &words).map_err(SimError::from)?;
    let profile = sm.run(&rp.program, rp.threads)?;
    let out = sm.smem.host_read_coherent(rp.scratch_base, 1).map_err(SimError::from)?;
    Ok((f32::from_bits(out[0]), profile))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Variant;
    use crate::isa::OpClass;

    fn cfg(variant: Variant) -> SmConfig {
        SmConfig::for_radix(variant, 4)
    }

    fn signal(n: usize, seed: u64) -> Vec<f32> {
        crate::fft::reference::test_signal(n, seed)
            .iter()
            .map(|c| c.re as f32)
            .collect()
    }

    #[test]
    fn sums_correctly_all_variants() {
        for n in [256usize, 1024, 8192] {
            let input = signal(n, n as u64);
            let want: f64 = input.iter().map(|&v| v as f64).sum();
            for v in Variant::ALL6 {
                let c = cfg(v);
                let rp = generate(&c, n).unwrap();
                let (got, _) = run(&rp, &c, &input).unwrap();
                // tree summation is MORE accurate than the serial oracle;
                // tolerance covers both orders
                assert!(
                    (got as f64 - want).abs() < 1e-2 + want.abs() * 1e-4,
                    "{n}/{v}: {got} vs {want}"
                );
            }
        }
    }

    /// §4: the banked write accelerates reduction — VM spends fewer
    /// store cycles than DP for the same program shape.
    #[test]
    fn vm_reduces_store_cycles() {
        let n = 4096;
        let input = signal(n, 1);
        let c_dp = cfg(Variant::DP);
        let c_vm = cfg(Variant::DP_VM);
        let (_, p_dp) = run(&generate(&c_dp, n).unwrap(), &c_dp, &input).unwrap();
        let (_, p_vm) = run(&generate(&c_vm, n).unwrap(), &c_vm, &input).unwrap();
        let dp_stores = p_dp.get(OpClass::Store);
        let vm_stores = p_vm.get(OpClass::Store) + p_vm.get(OpClass::StoreVm);
        assert!(
            vm_stores < dp_stores,
            "vm {vm_stores} !< dp {dp_stores}"
        );
        assert!(p_vm.total() < p_dp.total());
        // most tree passes are bank-eligible
        assert!(p_vm.get(OpClass::StoreVm) > 0);
    }

    /// The eligibility rule is load-bearing: banked writes on the final
    /// passes would produce a wrong sum. Prove the simulator would
    /// catch it by checking coherence demand at readback.
    #[test]
    fn final_store_must_be_coherent() {
        let n = 1024;
        let c = cfg(Variant::DP_VM);
        let rp = generate(&c, n).unwrap();
        // the last tree store in the generated code is a coherent sts
        let last_store = rp
            .program
            .insts
            .iter()
            .rev()
            .find(|i| matches!(i, Inst::Sts { .. } | Inst::StsBank { .. }))
            .unwrap();
        assert!(matches!(last_store, Inst::Sts { .. }));
    }

    #[test]
    fn profile_scales_with_n() {
        let c = cfg(Variant::DP);
        let input_small = signal(1024, 2);
        let input_big = signal(8192, 2);
        let (_, p_small) = run(&generate(&c, 1024).unwrap(), &c, &input_small).unwrap();
        let (_, p_big) = run(&generate(&c, 8192).unwrap(), &c, &input_big).unwrap();
        // load instructions: serial phase n/256 + tree 2·log2(256) = 16
        // -> (32+16)/(4+16) = 2.4× at 8× the data (the tree is fixed)
        let ratio = p_big.get(OpClass::Load) as f64 / p_small.get(OpClass::Load) as f64;
        assert!((2.2..=2.6).contains(&ratio), "load ratio {ratio}");
    }
}
