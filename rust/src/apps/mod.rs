//! Software-defined workloads beyond the FFT — the paper's central
//! argument is that a soft *processor* runs arbitrary algorithms with
//! no reconfiguration, and §4 names reduction as another beneficiary of
//! the virtual-banked memory ("many algorithms can use this approach").

pub mod reduction;
