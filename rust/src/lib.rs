//! # egpu-fft
//!
//! A reproduction of *"Soft GPGPU versus IP cores: Quantifying and
//! Reducing the Performance Gap"* (Langhammer & Constantinides, 2024).
//!
//! The crate contains:
//!
//! * [`isa`] — the eGPU SIMT instruction set and a text assembler;
//! * [`arch`] — the six eGPU variants (DP/QP × VM × Complex) and SM
//!   configuration;
//! * [`sim`] — a cycle-accurate, *numerically executing* SM simulator
//!   (banked shared memory with true `save_bank` staleness, coefficient
//!   cache, hazard model);
//! * [`fft`] — FFT program generators for radices 2/4/8/16 and sizes
//!   256–4096, plus a reference transform;
//! * [`profile`] / [`report`] — the paper's per-op-class accounting and
//!   the renderers for Tables 1–6 and Figures 2/4;
//! * [`ipcore`] — the streaming FFT IP-core comparison model (Table 5);
//! * [`gpu`] — the V100/A100 cuFFT efficiency model (Table 6);
//! * [`floorplan`] — footprint-normalized cost comparison (Figure 4);
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX FFT
//!   artifacts (the numerical oracle on the request path);
//! * [`coordinator`] — an async FFT service scheduling jobs over a pool
//!   of simulated eGPU cores and the PJRT fast path.

pub mod apps;
pub mod arch;
pub mod coordinator;
pub mod fft;
pub mod floorplan;
pub mod gpu;
pub mod ipcore;
pub mod isa;
pub mod profile;
pub mod report;
pub mod runtime;
pub mod sim;

pub use arch::{MemPorts, SmConfig, Variant};
pub use profile::Profile;
