//! # egpu-fft
//!
//! A reproduction of *"Soft GPGPU versus IP cores: Quantifying and
//! Reducing the Performance Gap"* (Langhammer & Constantinides, 2024).
//!
//! The crate contains:
//!
//! * [`isa`] — the eGPU SIMT instruction set and a text assembler;
//! * [`arch`] — the six eGPU variants (DP/QP × VM × Complex) and SM
//!   configuration;
//! * [`sim`] — a cycle-accurate, *numerically executing* SM simulator
//!   (banked shared memory with true `save_bank` staleness, coefficient
//!   cache, hazard model);
//! * [`fft`] — FFT program generators for radices 2/4/8/16 and sizes
//!   256–4096, a reference transform, and the shared
//!   [`fft::cache::PlanCache`] memoizing generated programs
//!   (plan + schedule + twiddle image) behind `Arc`s with LRU eviction
//!   and hit/miss counters;
//! * [`profile`] / [`report`] — the paper's per-op-class accounting and
//!   the renderers for Tables 1–6 and Figures 2/4;
//! * [`ipcore`] — the streaming FFT IP-core comparison model (Table 5);
//! * [`gpu`] — the V100/A100 cuFFT efficiency model (Table 6);
//! * [`floorplan`] — footprint-normalized cost comparison (Figure 4);
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX FFT
//!   artifacts (the numerical oracle on the request path);
//! * [`coordinator`] — an FFT service scheduling jobs over a pool of
//!   simulated eGPU cores and the PJRT fast path. Requests go through
//!   `request` (one `FftRequest`, one queue hop) or `request_all`
//!   (same-size requests coalesced onto one worker, amortizing the
//!   plan-cache lookup, the resident SM and the queue traffic across
//!   the batch); transforms past the 4096-point single-pass ceiling
//!   are decomposed four-step style into staged row/column batches;
//!   `MetricsSnapshot` reports latency, batch occupancy and the
//!   plan-cache hit rate. [`coordinator::ShardedFftService`] scales the
//!   pool out multi-core: one queue per shard, size-affinity routing
//!   with work-stealing overflow, batch chunking, and per-shard
//!   occupancy/queue/steal metrics — all shards sharing the one plan
//!   cache. [`coordinator::TrafficServer`] is the admission-controlled
//!   front door over either service: bounded queues with block / shed /
//!   degrade backpressure, two priority classes with an aging rule,
//!   per-request deadlines, and separate queue-wait vs service-time
//!   latency histograms; [`coordinator::loadgen`] drives it with
//!   open-loop Poisson or burst traffic (`egpu-fft loadtest`) and every
//!   failure is a typed [`coordinator::ServiceError`].
//!   [`coordinator::BackendSet`] adds multi-backend routing on top: a
//!   measured per-backend cost model picks the simulator or the PJRT
//!   fast path per request, a sampled fraction of fast-path results is
//!   cross-checked against the simulator, and the autoscale controller
//!   can pin the fastest lane under service-time pressure
//!   (`egpu-fft serve --backends sim,pjrt`).
//!
//! The PJRT fast path compiles only with the `pjrt` cargo feature
//! (it binds the vendored `xla` crate); the default build substitutes
//! a stub whose server spawn fails gracefully, so the simulator
//! backend works in any environment.

pub mod apps;
pub mod arch;
#[deny(missing_docs)]
pub mod coordinator;
pub mod fft;
pub mod floorplan;
pub mod gpu;
pub mod ipcore;
pub mod isa;
pub mod profile;
pub mod report;
pub mod runtime;
pub mod sim;

pub use arch::{MemPorts, SmConfig, Variant};
pub use profile::Profile;
