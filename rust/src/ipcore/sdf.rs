//! Behavioural radix-2 single-path delay-feedback (R2SDF) streaming
//! FFT — the architecture family of the Intel FFT IP the paper compares
//! against (§2: "Most of the current FPGA FFT IP cores are streaming").
//!
//! One complex sample enters per clock; log2(N) butterfly stages with
//! feedback delay lines of N/2, N/4, …, 1 produce one (bit-reversed)
//! output sample per clock after a latency of N−1 cycles. This
//! simulator validates the [`IpCore`](super::IpCore) model's two load-
//! bearing claims: throughput is exactly one transform per N cycles,
//! and the arithmetic is correct.

use crate::fft::twiddle::{twiddle, Cpx};

struct Stage {
    /// Feedback delay-line depth.
    d: usize,
    /// Cycle counter within the 2·d block.
    c: usize,
    buf: Vec<Cpx>,
    head: usize,
    /// W_{2d}^i for the fed-back differences.
    tw: Vec<Cpx>,
}

impl Stage {
    fn new(d: usize) -> Self {
        Stage {
            d,
            c: 0,
            buf: vec![Cpx::ZERO; d],
            head: 0,
            tw: (0..d).map(|i| twiddle(2 * d, i)).collect(),
        }
    }

    /// Process one sample; always emits one sample (garbage during the
    /// initial fill, like the real hardware before its latency).
    fn process(&mut self, x: Cpx) -> Cpx {
        let out;
        if self.c < self.d {
            // fill phase: emit stored differences from the previous
            // block while delaying the incoming first half
            out = self.buf[self.head];
            self.buf[self.head] = x;
        } else {
            // butterfly phase: sum flows downstream, twiddled
            // difference is fed back into the delay line
            let a = self.buf[self.head];
            out = a + x;
            self.buf[self.head] = (a - x) * self.tw[self.c - self.d];
        }
        self.head = (self.head + 1) % self.d;
        self.c = (self.c + 1) % (2 * self.d);
        out
    }
}

pub struct StreamingSdf {
    n: usize,
    stages: Vec<Stage>,
    /// Total samples pushed (for latency bookkeeping).
    cycles: usize,
    /// Butterfly operations actually performed (utilization audit).
    butterflies: usize,
}

impl StreamingSdf {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2);
        let stages = (0..n.trailing_zeros()).map(|s| Stage::new(n >> (s + 1))).collect();
        StreamingSdf { n, stages, cycles: 0, butterflies: 0 }
    }

    /// Output latency in cycles: the accumulated delay-line depth.
    pub fn latency(&self) -> usize {
        self.n - 1
    }

    /// Push one sample through the whole pipeline (one clock).
    pub fn push(&mut self, x: Cpx) -> Cpx {
        let mut v = x;
        for s in &mut self.stages {
            if s.c >= s.d {
                self.butterflies += 1;
            }
            v = s.process(v);
        }
        self.cycles += 1;
        v
    }

    /// Stream several frames through back-to-back (one sample per
    /// cycle, no gaps — the §2 streaming property), then flush; returns
    /// each frame's transform in natural order. Must be called on a
    /// freshly-aligned pipeline (cycles = 0).
    pub fn transform_frames(&mut self, frames: &[&[Cpx]]) -> Vec<Vec<Cpx>> {
        assert_eq!(self.cycles, 0, "pipeline must be frame-aligned");
        let lat = self.latency();
        let total = frames.len() * self.n;
        let mut raw = Vec::with_capacity(total);
        let mut pushed = 0usize;
        while raw.len() < total {
            let x = if pushed < total {
                frames[pushed / self.n][pushed % self.n]
            } else {
                Cpx::ZERO // flush
            };
            pushed += 1;
            let y = self.push(x);
            if self.cycles - 1 >= lat {
                raw.push(y);
            }
        }
        // outputs appear bit-reversed within each frame
        let bits = self.n.trailing_zeros();
        raw.chunks_exact(self.n)
            .map(|chunk| {
                let mut out = vec![Cpx::ZERO; self.n];
                for (i, v) in chunk.iter().enumerate() {
                    let r = (i as u32).reverse_bits() >> (32 - bits);
                    out[r as usize] = *v;
                }
                out
            })
            .collect()
    }

    /// Transform a single frame on a fresh pipeline.
    pub fn transform(&mut self, frame: &[Cpx]) -> Vec<Cpx> {
        assert_eq!(frame.len(), self.n);
        self.transform_frames(&[frame]).pop().unwrap()
    }

    /// Fraction of cycles each butterfly unit was busy so far.
    pub fn butterfly_utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.butterflies as f64 / (self.cycles * self.stages.len()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference;

    #[test]
    fn two_point_exact() {
        let mut sdf = StreamingSdf::new(2);
        let frame = vec![Cpx::new(3.0, 1.0), Cpx::new(1.0, -2.0)];
        let out = sdf.transform(&frame);
        assert!((out[0] - Cpx::new(4.0, -1.0)).abs() < 1e-12);
        assert!((out[1] - Cpx::new(2.0, 3.0)).abs() < 1e-12);
    }

    #[test]
    fn matches_reference_fft() {
        for n in [4usize, 16, 64, 256, 1024, 4096] {
            let sig = reference::test_signal(n, 11);
            let mut sdf = StreamingSdf::new(n);
            let got = sdf.transform(&sig);
            let want = reference::fft(&sig);
            let err = reference::rms_rel_error(&got, &want);
            assert!(err < 1e-10, "n={n} err={err}");
        }
    }

    /// §2: after the latency, output streams at the input rate — the
    /// second back-to-back frame costs exactly N more cycles.
    #[test]
    fn back_to_back_frames_stream() {
        let n = 256;
        let a = reference::test_signal(n, 1);
        let b = reference::test_signal(n, 2);
        let mut sdf = StreamingSdf::new(n);
        let ys = sdf.transform_frames(&[&a, &b]);
        assert!(reference::rms_rel_error(&ys[0], &reference::fft(&a)) < 1e-10);
        assert!(reference::rms_rel_error(&ys[1], &reference::fft(&b)) < 1e-10);
        // the latency is paid once; each additional frame costs N cycles
        assert_eq!(sdf.cycles, 2 * n + sdf.latency());
    }

    /// Each stage's butterfly works every other half-block: 50 %
    /// arithmetic utilization is inherent to SDF (the §2.1 point that
    /// the IP reads/processes/writes simultaneously, not that every
    /// adder is always busy).
    #[test]
    fn butterfly_utilization_half() {
        let n = 1024;
        let mut sdf = StreamingSdf::new(n);
        let sig = reference::test_signal(n, 3);
        let frames: Vec<&[_]> = (0..8).map(|_| sig.as_slice()).collect();
        sdf.transform_frames(&frames);
        let u = sdf.butterfly_utilization();
        assert!((u - 0.5).abs() < 0.05, "utilization {u}");
    }
}
