//! Streaming FFT IP-core model (the paper's §7 / Table 5 comparator).
//!
//! The paper compares the eGPU against the Intel streaming FP32 FFT IP
//! cores [13]: single-stream pipelined architectures (radix-2² SDF
//! style, cf. Garrido's survey [10]) that accept one complex sample per
//! clock and, after a pipeline latency, emit one transformed sample per
//! clock. Throughput is therefore `N / Fmax` per transform by
//! construction (§2), which is what Table 5 reports.
//!
//! Two parts:
//! * [`IpCore`] — the resource/performance model with the paper's
//!   tabulated ALM/M20K/DSP counts (Table 5 is our calibration data);
//! * [`StreamingSdf`] — a behavioural single-delay-feedback simulator
//!   that actually streams samples through log2(N) butterfly stages,
//!   validating that the modelled architecture computes a correct FFT
//!   and exhibits the modelled cycle behaviour.

pub mod sdf;

pub use sdf::StreamingSdf;

/// Fmax of the streaming FFT IP used in the paper's comparison; Table
/// 5's 0.50 µs for a 256-point transform implies ~512 MHz streaming.
pub const IP_FMAX_MHZ: f64 = 512.0;

/// Resource/performance model of one streaming FP32 FFT IP instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IpCore {
    pub points: usize,
    pub alm: u32,
    pub registers: u32,
    pub m20k: u32,
    pub dsp: u32,
    /// Transform time in µs (streaming: N samples at Fmax).
    pub time_us: f64,
}

impl IpCore {
    /// The paper's Table 5 design points (Intel streaming FP32 FFT on
    /// Agilex). These figures are the calibration anchors; sizes in
    /// between are produced by [`IpCore::model`]. The paper's 4096-point
    /// time cell is smudged in the source; `N / 512 MHz ≈ 8.0 µs` is
    /// used, consistent with the other two rows.
    pub fn paper(points: usize) -> Option<IpCore> {
        let (alm, registers, m20k, dsp, time_us) = match points {
            256 => (12842, 23284, 62, 32, 0.50),
            1024 => (15350, 25859, 93, 40, 1.84),
            4096 => (18227, 31283, 126, 48, 8.00),
            _ => return None,
        };
        Some(IpCore { points, alm, registers, m20k, dsp, time_us })
    }

    /// Analytic model for any power-of-two size: a radix-2² SDF needs
    /// log2(N) butterfly stages; ALMs grow with stage count, delay-line
    /// memory with N, and DSPs with the number of complex multipliers
    /// (one per radix-2² stage pair). Coefficients are fits through the
    /// three Table 5 anchors.
    pub fn model(points: usize) -> IpCore {
        assert!(points.is_power_of_two() && points >= 16);
        if let Some(ip) = Self::paper(points) {
            return ip;
        }
        let stages = points.trailing_zeros() as f64;
        let alm = (2200.0 + 1331.0 * stages) as u32;
        let registers = (11000.0 + 1680.0 * stages) as u32;
        // M20K fit through the anchors: 16·stages − 66 (62/94/126 at
        // 256/1024/4096 vs the paper's 62/93/126)
        let m20k = ((16.0 * stages - 66.0).max(4.0)) as u32;
        let dsp = 8 * (points.trailing_zeros() as u32).div_ceil(2);
        IpCore {
            points,
            alm,
            registers,
            m20k,
            dsp,
            time_us: points as f64 / IP_FMAX_MHZ,
        }
    }

    /// Streaming throughput in transforms/second (back-to-back frames).
    pub fn transforms_per_sec(&self) -> f64 {
        1e6 / self.time_us
    }

    /// Pipeline latency in cycles before the first output sample: the
    /// accumulated delay-line depth (≈ N) plus per-stage arithmetic
    /// latency.
    pub fn latency_cycles(&self) -> usize {
        self.points + 12 * self.points.trailing_zeros() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_points() {
        let ip = IpCore::paper(256).unwrap();
        assert_eq!((ip.alm, ip.m20k, ip.dsp), (12842, 62, 32));
        assert_eq!(ip.time_us, 0.50);
        let ip = IpCore::paper(1024).unwrap();
        assert_eq!((ip.alm, ip.m20k, ip.dsp), (15350, 93, 40));
        let ip = IpCore::paper(4096).unwrap();
        assert_eq!(ip.alm, 18227);
        assert!(IpCore::paper(2048).is_none());
    }

    #[test]
    fn streaming_throughput_is_n_over_fmax() {
        // §2: "Throughput performance is easily calculated as the
        // dataset size divided by the clock frequency."
        let ip = IpCore::paper(256).unwrap();
        let implied_fmax_mhz = ip.points as f64 / ip.time_us;
        assert!((implied_fmax_mhz - 512.0).abs() < 1.0);
        assert!((ip.transforms_per_sec() - 2.0e6).abs() < 1e3);
    }

    #[test]
    fn model_interpolates_between_anchors() {
        let ip = IpCore::model(2048);
        let lo = IpCore::paper(1024).unwrap();
        let hi = IpCore::paper(4096).unwrap();
        assert!(ip.alm > lo.alm && ip.alm < hi.alm);
        assert!(ip.m20k > lo.m20k && ip.m20k < hi.m20k);
        assert!(ip.dsp >= lo.dsp && ip.dsp <= hi.dsp);
        assert!(ip.time_us > lo.time_us && ip.time_us < hi.time_us);
    }

    #[test]
    fn model_alm_fit_close_to_anchors() {
        for n in [256usize, 1024, 4096] {
            let anchor = IpCore::paper(n).unwrap().alm as f64;
            let stages = n.trailing_zeros() as f64;
            let fit = 2200.0 + 1331.0 * stages;
            assert!((fit - anchor).abs() / anchor < 0.15, "n={n} fit={fit}");
        }
    }

    #[test]
    fn latency_reasonable() {
        let ip = IpCore::paper(4096).unwrap();
        assert!(ip.latency_cycles() > 4096);
        assert!(ip.latency_cycles() < 2 * 4096 + 200);
    }
}
