//! eGPU architectural variants and SM configuration.
//!
//! The paper evaluates six variants (§6): the standard DP memory
//! (4R-1W, 771 MHz), the QP memory (4R-2W, 600 MHz), the virtually
//! banked memory (4R-4W via `save_bank`), the complex functional unit,
//! and their combinations. `VM` is not supported together with `QP`
//! ("all memory ports are available for all memory accesses").

use std::fmt;

/// Shared-memory write-port style.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum MemPorts {
    /// M20K dual-port mode: 4 read ports, 1 write port, 771 MHz.
    Dp,
    /// M20K quad-port mode: 4 read ports, 2 write ports, 600 MHz,
    /// half the M20K count.
    Qp,
}

/// One of the six eGPU variants of §6 (or any consistent combination).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Variant {
    pub mem: MemPorts,
    /// Virtual 4R-4W banked memory (`save_bank`), §4.
    pub vm: bool,
    /// Complex functional units + coefficient cache, §5.
    pub complex: bool,
}

impl Variant {
    pub const DP: Variant = Variant { mem: MemPorts::Dp, vm: false, complex: false };
    pub const DP_VM: Variant = Variant { mem: MemPorts::Dp, vm: true, complex: false };
    pub const DP_COMPLEX: Variant = Variant { mem: MemPorts::Dp, vm: false, complex: true };
    pub const DP_VM_COMPLEX: Variant = Variant { mem: MemPorts::Dp, vm: true, complex: true };
    pub const QP: Variant = Variant { mem: MemPorts::Qp, vm: false, complex: false };
    pub const QP_COMPLEX: Variant = Variant { mem: MemPorts::Qp, vm: false, complex: true };

    /// The six variants in the paper's table column order.
    pub const ALL6: [Variant; 6] = [
        Variant::DP,
        Variant::DP_VM,
        Variant::DP_COMPLEX,
        Variant::DP_VM_COMPLEX,
        Variant::QP,
        Variant::QP_COMPLEX,
    ];

    /// A QP memory exposes every port for every access; the virtual
    /// banking scheme is meaningless there (§6).
    pub fn is_valid(&self) -> bool {
        !(self.vm && self.mem == MemPorts::Qp)
    }

    /// Achieved clock frequency on Agilex (§6): the QP memory mode
    /// limits the SM to 600 MHz; all other variants close at 771 MHz.
    pub fn fmax_mhz(&self) -> f64 {
        match self.mem {
            MemPorts::Dp => 771.0,
            MemPorts::Qp => 600.0,
        }
    }

    /// Shared-memory write ports visible to a coherent `sts`.
    pub fn store_ports(&self) -> usize {
        match self.mem {
            MemPorts::Dp => 1,
            MemPorts::Qp => 2,
        }
    }

    /// Read ports (4 in every variant: the memory is built from four
    /// banks read in parallel).
    pub fn load_ports(&self) -> usize {
        4
    }

    /// Virtual write ports seen by `save_bank`.
    pub fn store_vm_ports(&self) -> usize {
        4
    }

    pub fn name(&self) -> String {
        let mut s = String::from("eGPU-");
        s.push_str(match self.mem {
            MemPorts::Dp => "DP",
            MemPorts::Qp => "QP",
        });
        if self.vm {
            s.push_str("-VM");
        }
        if self.complex {
            s.push_str("-Complex");
        }
        s
    }

    /// FPGA resource inventory (§6 / Table 5): the DP eGPU required
    /// 8801 ALMs, 192 M20Ks and 32 DSP Blocks; QP halves the M20Ks;
    /// the complex unit adds one DSP block per SP with no footprint
    /// change; VM adds negligible soft logic.
    pub fn resources(&self) -> Resources {
        let m20k = match self.mem {
            MemPorts::Dp => 192,
            MemPorts::Qp => 96,
        };
        let dsp = if self.complex { 48 } else { 32 };
        Resources { alm: 8801, registers: 15109, m20k, dsp }
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// FPGA resource counts (Agilex: ALMs, ALM registers, M20K memory
/// blocks, DSP blocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resources {
    pub alm: u32,
    pub registers: u32,
    pub m20k: u32,
    pub dsp: u32,
}

/// Full SM configuration for a simulation run.
#[derive(Clone, Copy, Debug)]
pub struct SmConfig {
    pub variant: Variant,
    /// Scalar processors per SM (16 in every reported eGPU).
    pub n_sp: usize,
    /// Execution pipeline depth in cycles (8; hazards are fully hidden
    /// once the wavefront depth reaches this, §6).
    pub pipeline_depth: usize,
    /// Shared memory size in 32-bit words (64 KB = 16384 words in §6).
    pub smem_words: usize,
    /// Threads resident in the SM for this launch.
    pub threads: usize,
    /// Registers per thread (32 for the radix-4 runs, 64 for radix-8/16).
    pub regs_per_thread: usize,
}

impl SmConfig {
    /// The paper's FFT-test configuration for a given radix (§6):
    /// radix-4 → 1024 threads × 32 registers; radix-8/16 → 512 × 64.
    pub fn for_radix(variant: Variant, radix: usize) -> Self {
        let (threads, regs) = if radix <= 4 { (1024, 32) } else { (512, 64) };
        SmConfig {
            variant,
            n_sp: 16,
            pipeline_depth: 8,
            smem_words: 64 * 1024 / 4,
            threads,
            regs_per_thread: regs,
        }
    }

    /// Wavefront depth for `active` threads: the number of cycles each
    /// instruction is run for (§5: "the number of cycles that each
    /// instruction is run for in the current thread initialization").
    pub fn wavefront(&self, active: usize) -> usize {
        active.div_ceil(self.n_sp).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names_match_paper() {
        let names: Vec<String> = Variant::ALL6.iter().map(|v| v.name()).collect();
        assert_eq!(
            names,
            vec![
                "eGPU-DP",
                "eGPU-DP-VM",
                "eGPU-DP-Complex",
                "eGPU-DP-VM-Complex",
                "eGPU-QP",
                "eGPU-QP-Complex",
            ]
        );
    }

    #[test]
    fn qp_vm_is_invalid() {
        let v = Variant { mem: MemPorts::Qp, vm: true, complex: false };
        assert!(!v.is_valid());
        assert!(Variant::ALL6.iter().all(|v| v.is_valid()));
    }

    #[test]
    fn fmax_matches_paper() {
        assert_eq!(Variant::DP.fmax_mhz(), 771.0);
        assert_eq!(Variant::QP.fmax_mhz(), 600.0);
        assert_eq!(Variant::DP_VM_COMPLEX.fmax_mhz(), 771.0);
    }

    #[test]
    fn ports() {
        assert_eq!(Variant::DP.store_ports(), 1);
        assert_eq!(Variant::QP.store_ports(), 2);
        assert_eq!(Variant::DP.load_ports(), 4);
        assert_eq!(Variant::DP_VM.store_vm_ports(), 4);
    }

    #[test]
    fn resources_match_section6() {
        let r = Variant::DP.resources();
        assert_eq!((r.alm, r.m20k, r.dsp), (8801, 192, 32));
        assert_eq!(Variant::QP.resources().m20k, 96);
        assert_eq!(Variant::DP_COMPLEX.resources().dsp, 48);
        // Footprint (ALM) unchanged by the complex/VM features (§6).
        assert_eq!(Variant::DP_COMPLEX.resources().alm, Variant::DP.resources().alm);
    }

    #[test]
    fn paper_configs() {
        let c4 = SmConfig::for_radix(Variant::DP, 4);
        assert_eq!((c4.threads, c4.regs_per_thread), (1024, 32));
        let c16 = SmConfig::for_radix(Variant::DP, 16);
        assert_eq!((c16.threads, c16.regs_per_thread), (512, 64));
        // 64 KB shared memory = 16384 words; 32K registers across SPs.
        assert_eq!(c4.smem_words, 16384);
        assert_eq!(c4.threads * c4.regs_per_thread, 32 * 1024);
        assert_eq!(c16.threads * c16.regs_per_thread, 32 * 1024);
    }

    #[test]
    fn wavefront_depth_formula() {
        // §6: wavefront = points / (16 × radix).
        let c = SmConfig::for_radix(Variant::DP, 4);
        assert_eq!(c.wavefront(4096 / 4), 64);
        assert_eq!(c.wavefront(256 / 4), 4);
        let c8 = SmConfig::for_radix(Variant::DP, 8);
        assert_eq!(c8.wavefront(4096 / 8), 32);
        // radix-16, 256 points: 16 threads -> wavefront clamps to 1.
        assert_eq!(c8.wavefront(16), 1);
    }
}
