//! Renderers for the paper's Tables 1–6 and Figures 2 & 4.
//!
//! Each `table*`/`figure*` function *regenerates* its artifact — the
//! profile tables run the full simulation campaign — and returns both
//! structured data and a Markdown rendering, so the same entry points
//! back the CLI, the integration tests and the benchmark harness.

use crate::arch::{SmConfig, Variant};
use crate::fft::{self, FftError, FftPlan};
use crate::floorplan::{self, PackingStyle};
use crate::gpu::{A100, V100};
use crate::ipcore::IpCore;
use crate::isa::OpClass;
use crate::profile::Profile;

/// Sizes the paper reports per radix (Tables 1–3).
pub fn paper_sizes(radix: usize) -> &'static [usize] {
    match radix {
        4 => &[4096, 1024, 256],
        8 => &[4096, 512],
        16 => &[4096, 1024, 256],
        _ => &[4096, 1024, 256],
    }
}

/// One profiled design point: (points, variant) → profile.
#[derive(Clone, Debug)]
pub struct ProfileTable {
    pub radix: usize,
    /// Per size: the six variant profiles in paper column order
    /// (`None` where the design point is not supported/meaningful,
    /// e.g. VM columns for FFTs with no bank-eligible pass).
    pub rows: Vec<(usize, Vec<Option<Profile>>)>,
}

/// Run the simulation campaign behind Table 1 (radix 4), Table 2
/// (radix 8) or Table 3 (radix 16).
pub fn profile_table(radix: usize) -> Result<ProfileTable, FftError> {
    profile_table_for(radix, paper_sizes(radix))
}

pub fn profile_table_for(radix: usize, sizes: &[usize]) -> Result<ProfileTable, FftError> {
    let mut rows = Vec::new();
    for &points in sizes {
        let mut cols = Vec::new();
        for v in Variant::ALL6 {
            cols.push(run_point(points, radix, v)?);
        }
        rows.push((points, cols));
    }
    Ok(ProfileTable { radix, rows })
}

/// Simulate one design point (validating numerics as a side effect);
/// `None` for VM variants where no pass is bank-eligible (the paper
/// leaves those cells blank).
pub fn run_point(
    points: usize,
    radix: usize,
    variant: Variant,
) -> Result<Option<Profile>, FftError> {
    let cfg = SmConfig::for_radix(variant, radix);
    if variant.vm {
        let plan = FftPlan::new(points, radix, cfg.threads)?;
        if !plan.passes.iter().any(|p| p.vm_eligible) {
            return Ok(None);
        }
    }
    let (profile, err) = fft::validate(&cfg, points, radix, 0x5EED)?;
    assert!(err < fft::F32_TOL, "numerics broken at {points}/{radix}/{variant}: {err}");
    Ok(Some(profile))
}

const ROW_CLASSES: [OpClass; 9] = [
    OpClass::Fp,
    OpClass::Complex,
    OpClass::Int,
    OpClass::Load,
    OpClass::Store,
    OpClass::StoreVm,
    OpClass::Immediate,
    OpClass::Branch,
    OpClass::Nop,
];

impl ProfileTable {
    pub fn render_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "### Radix-{} FFT Profiling — Cycles per Operation and Performance\n\n",
            self.radix
        ));
        s.push_str("| Points | Type | ");
        for v in Variant::ALL6 {
            s.push_str(&format!("{} | ", v.name().trim_start_matches("eGPU-")));
        }
        s.push('\n');
        s.push_str(&format!("|---|---|{}\n", "---|".repeat(6)));
        for (points, cols) in &self.rows {
            let cell = |f: &dyn Fn(&Profile) -> String| -> Vec<String> {
                cols.iter()
                    .map(|c| c.as_ref().map(|p| f(p)).unwrap_or_else(|| "-".into()))
                    .collect()
            };
            for class in ROW_CLASSES {
                let vals = cell(&|p: &Profile| {
                    let v = p.get(class);
                    if v == 0 { "-".into() } else { v.to_string() }
                });
                if vals.iter().all(|v| v == "-") {
                    continue;
                }
                s.push_str(&format!(
                    "| {points} | {} | {} |\n",
                    class.name(),
                    vals.join(" | ")
                ));
            }
            for (label, f) in [
                ("Total", &(|p: &Profile| p.total().to_string()) as &dyn Fn(&Profile) -> String),
                ("Time (us)", &|p: &Profile| format!("{:.2}", p.time_us())),
                ("Efficiency %", &|p: &Profile| format!("{:.2}", p.efficiency_pct())),
                ("Memory %", &|p: &Profile| format!("{:.2}", p.memory_pct())),
            ] {
                s.push_str(&format!("| {points} | {label} | {} |\n", cell(f).join(" | ")));
            }
        }
        s
    }

    /// Best (highest) efficiency across variants for a given size.
    pub fn best_efficiency(&self, points: usize) -> Option<f64> {
        self.rows.iter().find(|(p, _)| *p == points).map(|(_, cols)| {
            cols.iter()
                .flatten()
                .map(|p| p.efficiency_pct())
                .fold(f64::MIN, f64::max)
        })
    }

    /// Best (lowest) time across variants for a given size, µs.
    pub fn best_time_us(&self, points: usize) -> Option<f64> {
        self.rows.iter().find(|(p, _)| *p == points).map(|(_, cols)| {
            cols.iter()
                .flatten()
                .map(|p| p.time_us())
                .fold(f64::MAX, f64::min)
        })
    }
}

// ---------------------------------------------------------------------
// Table 4: radix-8 butterfly op breakdown

/// One row of the Table 4 analogue.
#[derive(Clone, Debug)]
pub struct Table4Row {
    pub stage: &'static str,
    pub operation: &'static str,
    pub ops: usize,
    pub cycles: u64,
    pub running_fp: u64,
    pub running_int: u64,
}

/// Reproduce Table 4 for the 4096-point radix-8 FFT (512 threads,
/// wavefront 32): per-stage operation counts of one butterfly pass plus
/// the seven twiddle multiplies, with running FP/INT cycle totals.
/// Derived from the same §3.1 classification the code generator uses;
/// a test asserts consistency with the generated program.
pub fn table4() -> Vec<Table4Row> {
    let wavefront = 32u64; // 4096 / (16 × 8)
    let mut rows: Vec<(&'static str, &'static str, usize, bool)> = Vec::new();
    // stage 1: 4 cadd + 4 csub (16 FP), rotations W8^{0..3}
    rows.push(("1", "Add/Sub", 16, true));
    rows.push(("1", "Cplx (W8^1, equal-coeff)", 4, true));
    rows.push(("1", "Neg INT (W8^2 = -j)", 1, false));
    rows.push(("1", "Cplx (W8^3, equal-coeff)", 4, true));
    // stages 2+3: two radix-4 DIF kernels, 16 FP each
    rows.push(("2", "Add/Sub (DFT4 even)", 16, true));
    rows.push(("3", "Add/Sub (DFT4 odd)", 16, true));
    // twiddles: 7 full complex multiplies
    rows.push(("Complex", "Complex (x7 twiddles)", 42, true));
    let mut out = Vec::new();
    let (mut fp, mut int) = (0u64, 0u64);
    for (stage, op, ops, is_fp) in rows {
        let cycles = ops as u64 * wavefront;
        if is_fp {
            fp += cycles;
        } else {
            int += cycles;
        }
        out.push(Table4Row {
            stage,
            operation: op,
            ops,
            cycles,
            running_fp: fp,
            running_int: int,
        });
    }
    out
}

pub fn render_table4() -> String {
    let mut s = String::from(
        "### Radix-8 Butterfly (4096-pt, wavefront 32)\n\n\
         | Pass | Operation | Ops | Cycles | Running FP | Running INT |\n\
         |---|---|---|---|---|---|\n",
    );
    for r in table4() {
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            r.stage, r.operation, r.ops, r.cycles, r.running_fp, r.running_int
        ));
    }
    s.push_str(
        "\nNote: the paper's `Move` rows (in-register reordering) are folded \
         into store addressing by our code generator; W8^3 uses the §3.1 \
         equal-coefficient form where Table 4 spends a full 6-op multiply.\n",
    );
    s
}

// ---------------------------------------------------------------------
// Table 5: eGPU vs FFT IP core

#[derive(Clone, Debug)]
pub struct Table5Row {
    pub points: usize,
    pub ip: IpCore,
    pub egpu_time_us: f64,
    pub egpu_resources: crate::arch::Resources,
    /// Raw performance ratio (IP is this many times faster).
    pub perf_ratio: f64,
    /// Performance-area product ratio after footprint normalization.
    pub normalized_ratio: f64,
}

/// Regenerate Table 5: the eGPU (best radix-16-family time per size,
/// from the Table 3 campaign) against the streaming FFT IP cores.
pub fn table5() -> Result<Vec<Table5Row>, FftError> {
    let t3 = profile_table(16)?;
    let egpu_res = Variant::DP.resources();
    let egpu_fp = floorplan::footprint_alm_eq(&egpu_res, PackingStyle::Columnar);
    let mut rows = Vec::new();
    for points in [256usize, 1024, 4096] {
        let ip = IpCore::paper(points).unwrap();
        let ip_res = crate::arch::Resources {
            alm: ip.alm,
            registers: ip.registers,
            m20k: ip.m20k,
            dsp: ip.dsp,
        };
        let ip_fp = floorplan::footprint_alm_eq(&ip_res, PackingStyle::Wrapped);
        let egpu_time = t3.best_time_us(points).unwrap();
        let perf_ratio = egpu_time / ip.time_us;
        let normalized_ratio = perf_ratio * (egpu_fp / ip_fp);
        rows.push(Table5Row {
            points,
            ip,
            egpu_time_us: egpu_time,
            egpu_resources: egpu_res,
            perf_ratio,
            normalized_ratio,
        });
    }
    Ok(rows)
}

pub fn render_table5(rows: &[Table5Row]) -> String {
    let mut s = String::from(
        "### eGPU vs. FFT IP Core\n\n\
         | FFT Size | IP time | IP ALM/Regs | IP M20K | IP DSP | eGPU time | eGPU ALM/Regs | eGPU M20K | eGPU DSP | Ratio (Perf) | Ratio (Normalized) |\n\
         |---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {:.2}us | {}/{} | {} | {} | {:.2}us | {}/{} | {} | {} | {:.1} | {:.1} |\n",
            r.points,
            r.ip.time_us,
            r.ip.alm,
            r.ip.registers,
            r.ip.m20k,
            r.ip.dsp,
            r.egpu_time_us,
            r.egpu_resources.alm,
            r.egpu_resources.registers,
            r.egpu_resources.m20k,
            r.egpu_resources.dsp,
            r.perf_ratio,
            r.normalized_ratio,
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Table 6: FFT efficiency, eGPU vs A100/V100

#[derive(Clone, Debug)]
pub struct Table6Row {
    pub points: usize,
    pub egpu_eff_pct: f64,
    pub v100_published: f64,
    pub v100_modeled: f64,
    pub a100_published: f64,
    pub a100_modeled: f64,
}

/// Regenerate Table 6: our measured best eGPU efficiency per size (max
/// over radices and the 771 MHz variant family) against the published
/// and roofline-modelled cuFFT efficiencies.
pub fn table6() -> Result<Vec<Table6Row>, FftError> {
    let mut rows = Vec::new();
    for points in [256usize, 1024, 4096] {
        let mut best = f64::MIN;
        for radix in [4usize, 8, 16] {
            if points == 512 || (radix == 8 && points != 4096 && points != 512) {
                continue;
            }
            for v in Variant::ALL6 {
                if let Some(p) = run_point(points, radix, v)? {
                    best = best.max(p.efficiency_pct());
                }
            }
        }
        rows.push(Table6Row {
            points,
            egpu_eff_pct: best,
            v100_published: V100.published_eff_pct(points).unwrap(),
            v100_modeled: V100.modeled_eff_pct(points),
            a100_published: A100.published_eff_pct(points).unwrap(),
            a100_modeled: A100.modeled_eff_pct(points),
        });
    }
    Ok(rows)
}

pub fn render_table6(rows: &[Table6Row]) -> String {
    let mut s = String::from(
        "### FFT Efficiency — A100 vs eGPU\n\n\
         | GPU | 256 points | 1024 points | 4096 points |\n|---|---|---|---|\n",
    );
    let fmt_row = |name: &str, f: &dyn Fn(&Table6Row) -> f64| -> String {
        let cells: Vec<String> = rows.iter().map(|r| format!("{:.0}%", f(r))).collect();
        format!("| {name} | {} |\n", cells.join(" | "))
    };
    s.push_str(&fmt_row("eGPU (measured)", &|r| r.egpu_eff_pct));
    s.push_str(&fmt_row("V100 (published)", &|r| r.v100_published));
    s.push_str(&fmt_row("V100 (roofline model)", &|r| r.v100_modeled));
    s.push_str(&fmt_row("A100 (published)", &|r| r.a100_published));
    s.push_str(&fmt_row("A100 (roofline model)", &|r| r.a100_modeled));
    s
}

// ---------------------------------------------------------------------
// Figure 2: data indexes per pass (radix-4, 256 points)

/// Render the Figure 2 analogue: for each of the first `n_passes`
/// passes of the 256-point radix-4 FFT, the data indexes held by
/// threads 0..`n_threads` (R0 = thread id, then the 4 kernel indexes).
pub fn figure2(n_threads: usize, n_passes: usize) -> Result<String, FftError> {
    let plan = FftPlan::new(256, 4, 1024)?;
    let mut s = String::from("Figure 2: data indexes per pass (radix-4, 256 points)\n");
    for (pi, pass) in plan.passes.iter().take(n_passes).enumerate() {
        s.push_str(&format!("\nPass {}:\n", pi + 1));
        let hdr: Vec<String> = (0..n_threads).map(|t| format!("T{t}")).collect();
        s.push_str(&format!("      {}\n", hdr.join("\t")));
        for k in 0..pass.radix {
            let row: Vec<String> = (0..n_threads)
                .map(|t| format!("i{:03}", pass.kernel_base(t) + k * pass.stride))
                .collect();
            s.push_str(&format!("  R{}: {}\n", k + 1, row.join("\t")));
        }
    }
    Ok(s)
}

/// Figure 4 (delegates to the floorplan model).
pub fn figure4() -> String {
    let ip = IpCore::paper(4096).unwrap();
    let ip_res = crate::arch::Resources {
        alm: ip.alm,
        registers: ip.registers,
        m20k: ip.m20k,
        dsp: ip.dsp,
    };
    floorplan::render_figure4(&Variant::DP.resources(), &ip_res)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 2's exact values from the paper: pass 1 T0 = {0,64,128,192};
    /// pass 2 T16 = {64,80,96,112}; pass 3 T0 = {0,4,8,12}.
    #[test]
    fn figure2_matches_paper() {
        let fig = figure2(32, 3).unwrap();
        assert!(fig.contains("i000"));
        let plan = FftPlan::new(256, 4, 1024).unwrap();
        let p2 = &plan.passes[1];
        assert_eq!(
            (0..4).map(|k| p2.kernel_base(16) + k * p2.stride).collect::<Vec<_>>(),
            vec![64, 80, 96, 112]
        );
        let p3 = &plan.passes[2];
        assert_eq!(
            (0..4).map(|k| p3.kernel_base(0) + k * p3.stride).collect::<Vec<_>>(),
            vec![0, 4, 8, 12]
        );
    }

    /// Table 4 audit totals must agree with the generated radix-8
    /// program: FP cycles per butterfly+twiddle = what codegen emits.
    #[test]
    fn table4_consistent_with_codegen() {
        let rows = table4();
        let last = rows.last().unwrap();
        // per-pass FP ops: kernel 56 + twiddles 42 = 98 (× wavefront 32)
        assert_eq!(last.running_fp, 98 * 32);
        // generated program: 4 passes, last without twiddles
        let cfg = SmConfig::for_radix(Variant::DP, 8);
        let f = fft::generate(&cfg, 4096, 8).unwrap();
        let h = f.program.class_histogram();
        assert_eq!(h[OpClass::Fp.index()] as u64 * 32, 3 * last.running_fp + 56 * 32);
    }

    #[test]
    fn table6_shapes_hold() {
        let rows = table6().unwrap();
        // efficiency grows with size for the eGPU (paper: 25/27/36)
        assert!(rows[2].egpu_eff_pct > rows[0].egpu_eff_pct);
        for r in &rows {
            // eGPU is in the A100's published efficiency band (paper's
            // §8 claim; our radix-16 4096 cells sit a few points below
            // the paper's — see EXPERIMENTS.md on the Table 3 VM-store
            // discrepancy)
            assert!(
                r.egpu_eff_pct > r.a100_published - 6.0,
                "{}: egpu {:.1} vs a100 {:.1}",
                r.points,
                r.egpu_eff_pct,
                r.a100_published
            );
            // and clearly beats the V100
            assert!(r.egpu_eff_pct > r.v100_published);
        }
    }

    #[test]
    fn markdown_renders() {
        let t = profile_table_for(4, &[256]).unwrap();
        let md = t.render_markdown();
        assert!(md.contains("FP OP"));
        assert!(md.contains("Efficiency %"));
        assert!(render_table4().contains("Running FP"));
    }
}
