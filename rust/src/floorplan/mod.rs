//! Footprint-normalized cost comparison (§7, §8, Figure 4).
//!
//! The paper argues that cataloguing ALM/M20K/DSP counts separately
//! understates a design's true cost: a placed-and-routed core occupies
//! a *footprint*, and embedded blocks inside that footprint that the
//! design does not use are unreachable to the rest of the system
//! ("If an unused DSP Block is surrounded by logic, it will not be
//! otherwise available to other circuits"). The normalized comparison
//! in Table 5 is therefore based on floorplan area, and Figure 4 shows
//! that the 4K FFT IP core's floorplan is about twice the eGPU's.
//!
//! Model: each resource type is converted to ALM-equivalent silicon
//! area (Agilex column pitch ratios), and a *wrap factor* accounts for
//! the unreachable embedded blocks inside logic-wrapped IP layouts.

use crate::arch::Resources;

/// ALM-equivalent area of one M20K block (column pitch ≈ a dozen ALMs).
pub const M20K_ALM_EQ: f64 = 12.0;
/// ALM-equivalent area of one DSP block.
pub const DSP_ALM_EQ: f64 = 30.0;
/// Packing overhead of a logic-wrapped fixed-function core whose
/// embedded columns become unreachable to other logic (calibrated so
/// the 4K FFT IP footprint is ~2× the eGPU, per Figure 4).
pub const WRAP_FACTOR: f64 = 1.15;

/// Agilex AGF022-class device totals, for utilization percentages
/// (§1: one eGPU ≈ 1 % of a mid-range FPGA).
pub const DEVICE_ALM: f64 = 782_000.0;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PackingStyle {
    /// Regular, column-aligned layout (the eGPU: "packs efficiently
    /// into the FPGA ... with a minimum (or none) of design-tool
    /// constraints").
    Columnar,
    /// Logic wrapped around embedded blocks (the FFT IP, Figure 4
    /// right), paying [`WRAP_FACTOR`].
    Wrapped,
}

/// ALM-equivalent floorplan footprint of a design.
pub fn footprint_alm_eq(r: &Resources, style: PackingStyle) -> f64 {
    let raw = r.alm as f64 + r.m20k as f64 * M20K_ALM_EQ + r.dsp as f64 * DSP_ALM_EQ;
    match style {
        PackingStyle::Columnar => raw,
        PackingStyle::Wrapped => raw * WRAP_FACTOR,
    }
}

/// Fraction of a mid-range device consumed.
pub fn device_fraction(r: &Resources, style: PackingStyle) -> f64 {
    footprint_alm_eq(r, style) / DEVICE_ALM
}

/// Render the Figure 4 comparison: two boxes whose widths scale with
/// footprint, annotated with resources.
pub fn render_figure4(egpu: &Resources, ip: &Resources) -> String {
    let fe = footprint_alm_eq(egpu, PackingStyle::Columnar);
    let fi = footprint_alm_eq(ip, PackingStyle::Wrapped);
    let scale = 48.0 / fi.max(fe);
    let we = (fe * scale).round() as usize;
    let wi = (fi * scale).round() as usize;
    let boxline = |w: usize, c: char| -> String { std::iter::repeat(c).take(w).collect() };
    let mut s = String::new();
    s.push_str("Figure 4: floorplan footprint, eGPU (left) vs 4K streaming FP FFT IP (right)\n\n");
    s.push_str(&format!(
        "  +{}+      +{}+\n",
        boxline(we, '-'),
        boxline(wi, '-')
    ));
    let body = |label: String, w: usize| format!("|{label:^w$}|");
    s.push_str(&format!(
        "  {}      {}\n",
        body("eGPU".into(), we),
        body("FFT-4K IP".into(), wi)
    ));
    s.push_str(&format!(
        "  {}      {}\n",
        body(format!("{} ALM", egpu.alm), we),
        body(format!("{} ALM", ip.alm), wi)
    ));
    s.push_str(&format!(
        "  {}      {}\n",
        body(format!("{} M20K/{} DSP", egpu.m20k, egpu.dsp), we),
        body(format!("{} M20K/{} DSP (wrapped)", ip.m20k, ip.dsp), wi)
    ));
    s.push_str(&format!(
        "  +{}+      +{}+\n\n",
        boxline(we, '-'),
        boxline(wi, '-')
    ));
    s.push_str(&format!(
        "  footprint: {:.0} vs {:.0} ALM-eq  (ratio {:.2}x; device fraction {:.1}% vs {:.1}%)\n",
        fe,
        fi,
        fi / fe,
        100.0 * fe / DEVICE_ALM,
        100.0 * fi / DEVICE_ALM,
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Variant;
    use crate::ipcore::IpCore;

    fn ip_resources(n: usize) -> Resources {
        let ip = IpCore::paper(n).unwrap();
        Resources { alm: ip.alm, registers: ip.registers, m20k: ip.m20k, dsp: ip.dsp }
    }

    /// Figure 4 / §7: "the FFT IP core is twice the cost of the eGPU".
    #[test]
    fn ip_4k_footprint_about_twice_egpu() {
        let egpu = Variant::DP.resources();
        let fe = footprint_alm_eq(&egpu, PackingStyle::Columnar);
        let fi = footprint_alm_eq(&ip_resources(4096), PackingStyle::Wrapped);
        let ratio = fi / fe;
        assert!((1.8..=2.2).contains(&ratio), "footprint ratio {ratio}");
    }

    /// §1/§8: the eGPU occupies ~1–2 % of a mid-range device.
    #[test]
    fn egpu_is_one_to_two_percent_of_device() {
        let f = device_fraction(&Variant::DP.resources(), PackingStyle::Columnar);
        assert!((0.01..=0.02).contains(&f), "device fraction {f}");
    }

    /// The complex-FU variant adds DSPs but not footprint beyond the
    /// sector already consumed (§5/§6): raw ALM-eq grows slightly, but
    /// stays within the same sector budget (< 7 %).
    #[test]
    fn complex_variant_footprint_stable() {
        let base = footprint_alm_eq(&Variant::DP.resources(), PackingStyle::Columnar);
        let cplx = footprint_alm_eq(&Variant::DP_COMPLEX.resources(), PackingStyle::Columnar);
        assert!((cplx - base) / base < 0.07);
    }

    #[test]
    fn figure4_renders() {
        let fig = render_figure4(&Variant::DP.resources(), &ip_resources(4096));
        assert!(fig.contains("eGPU"));
        assert!(fig.contains("FFT-4K IP"));
        assert!(fig.contains("ratio"));
    }
}
