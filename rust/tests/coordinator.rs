//! Service-level integration tests: routing, concurrency, failure
//! injection, metrics, and sim↔PJRT agreement through the coordinator.

use egpu_fft::arch::Variant;
use egpu_fft::coordinator::{cross_error, Backend, FftRequest, FftService, ServiceConfig};
use egpu_fft::fft::{self, reference};

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/fft256.hlo.txt").exists();
    if !ok {
        eprintln!("WARNING: artifacts/ missing — run `make artifacts`; skipping PJRT test");
    }
    ok
}

#[test]
fn concurrent_submitters() {
    let svc = std::sync::Arc::new(
        FftService::start(ServiceConfig { cores: 4, ..Default::default() }).unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let svc = std::sync::Arc::clone(&svc);
        joins.push(std::thread::spawn(move || {
            for i in 0..8u64 {
                let r = svc.request(FftRequest::new(signal(256, t * 100 + i))).recv().unwrap().unwrap();
                assert_eq!(r.output.len(), 256);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(svc.metrics().served, 32);
}

#[test]
fn every_variant_serves() {
    for variant in Variant::ALL6 {
        let svc = FftService::start(ServiceConfig {
            cores: 1,
            variant,
            radix: 16,
            ..Default::default()
        })
        .unwrap();
        let r = svc.request(FftRequest::new(signal(1024, 5))).recv().unwrap().unwrap();
        let err = cross_error(
            &r.output,
            &reference::fft(&reference::test_signal(1024, 5))
                .iter()
                .map(|c| c.to_f32_pair())
                .collect::<Vec<_>>(),
        );
        assert!(err < fft::F32_TOL, "{variant}: {err}");
        svc.shutdown();
    }
}

/// Failure injection: a stream with malformed sizes interleaved — every
/// bad job errors, every good job still completes, counts are exact.
#[test]
fn failure_injection_mixed_stream() {
    let svc = FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap();
    let mut pending = Vec::new();
    let mut expect_err = 0;
    let mut expect_ok = 0;
    for i in 0..20u64 {
        let n = match i % 5 {
            0 => 100,                   // not a power of two
            1 => 8192 * 4,              // exceeds shared memory
            _ => 256,
        };
        if n == 256 {
            expect_ok += 1;
        } else {
            expect_err += 1;
        }
        pending.push(svc.request(FftRequest::new(signal(n, i))));
    }
    let (mut ok, mut err) = (0, 0);
    for p in pending {
        match p.recv().unwrap() {
            Ok(r) => {
                assert_eq!(r.output.len(), 256);
                ok += 1;
            }
            Err(_) => err += 1,
        }
    }
    assert_eq!((ok, err), (expect_ok, expect_err));
    let m = svc.metrics();
    assert_eq!(m.served, expect_ok);
    assert_eq!(m.errors, expect_err);
}

#[test]
fn metrics_accumulate_virtual_time_and_efficiency() {
    let svc = FftService::start(ServiceConfig {
        cores: 2,
        variant: Variant::DP_VM_COMPLEX,
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..6).map(|i| signal(1024, i)).collect()).unwrap();
    let m = svc.metrics();
    // 6 × ~12.6 us of virtual time
    assert!((60.0..=100.0).contains(&m.virtual_us), "{}", m.virtual_us);
    assert!((20.0..=35.0).contains(&m.efficiency_pct()), "{}", m.efficiency_pct());
    svc.shutdown();
}

#[test]
fn shutdown_drains_cleanly() {
    let svc = FftService::start(ServiceConfig { cores: 3, ..Default::default() }).unwrap();
    let handles: Vec<_> = (0..12).map(|i| svc.request(FftRequest::new(signal(256, i)))).collect();
    // results must all arrive even if we shut down right after
    let results: Vec<_> = handles.into_iter().map(|h| h.recv().unwrap()).collect();
    svc.shutdown();
    assert!(results.iter().all(|r| r.is_ok()));
}

#[test]
fn pjrt_and_sim_agree_through_the_service() {
    if !have_artifacts() {
        return;
    }
    let sim = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let pjrt = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Pjrt,
        ..Default::default()
    })
    .unwrap();
    for n in [256usize, 1024, 4096] {
        let input = signal(n, 1234);
        let a = sim.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
        let b = pjrt.request(FftRequest::new(input)).recv().unwrap().unwrap();
        let err = cross_error(&a.output, &b.output);
        assert!(err < fft::F32_TOL, "n={n}: {err}");
    }
}

/// Backpressure sanity: a burst far larger than the worker count
/// completes without deadlock and preserves per-job ids.
#[test]
fn large_burst_completes() {
    let svc = FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap();
    let results = svc
        .run_batch((0..100).map(|i| signal(256, i)).collect())
        .unwrap();
    assert_eq!(results.len(), 100);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.id, i as u64);
    }
}
