//! Batched-dispatch integration tests: `request_all` vs sequential
//! `request` (bitwise identity and reference numerics), steady-state
//! plan-cache behaviour, occupancy metrics, and LRU eviction through
//! the running service.

use egpu_fft::coordinator::{Backend, FftRequest, FftService, ServiceConfig};
use egpu_fft::fft::{self, reference};

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn service(cores: usize) -> FftService {
    FftService::start(ServiceConfig {
        cores,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

/// The acceptance property: a batch submission produces *bitwise* the
/// same outputs as the same inputs served one at a time, and both
/// match the reference transform.
#[test]
fn request_all_bitwise_identical_to_sequential_requests() {
    let seeds: Vec<u64> = (0..8).map(|i| 1000 + i).collect();
    let inputs: Vec<_> = seeds.iter().map(|&s| signal(256, s)).collect();

    let svc = service(1);
    let sequential: Vec<Vec<(f32, f32)>> = inputs
        .iter()
        .map(|input| svc.request(FftRequest::new(input.clone())).recv().unwrap().unwrap().output)
        .collect();
    svc.shutdown();

    let svc = service(1);
    let batched = svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();
    svc.shutdown();

    assert_eq!(batched.len(), sequential.len());
    for ((b, seq), &seed) in batched.iter().zip(&sequential).zip(&seeds) {
        assert_eq!(bits(&b.output), bits(seq), "seed {seed}");
        // both paths must also be *correct*, not merely consistent
        let got: Vec<fft::Cpx> = b
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        let want = reference::fft(&reference::test_signal(256, seed));
        let err = reference::rms_rel_error(&got, &want);
        assert!(err < fft::F32_TOL, "seed {seed}: rms {err:e}");
    }
}

/// Steady-state batch workload: one plan build, then every batch hits
/// the shared cache — the acceptance bar is a hit rate above 0.9.
#[test]
fn plan_cache_hit_rate_exceeds_090_in_steady_state() {
    let svc = service(1);
    let rounds = 16u64;
    for round in 0..rounds {
        let inputs: Vec<_> = (0..8).map(|i| signal(1024, round * 8 + i)).collect();
        let results = svc.request_all(inputs.into_iter().map(FftRequest::new).collect()).unwrap();
        assert_eq!(results.len(), 8);
    }
    let m = svc.metrics();
    assert_eq!(m.served, rounds * 8);
    assert_eq!(m.batches, rounds);
    assert_eq!(m.batched_jobs, rounds * 8);
    assert_eq!(m.max_batch_jobs, 8);
    assert!((m.mean_batch_occupancy() - 8.0).abs() < 1e-9);
    assert_eq!(m.plan_cache.misses, 1, "one size on one core builds once");
    assert!(
        m.plan_cache.hit_rate() > 0.9,
        "steady-state hit rate {:.3} (hits {} / misses {})",
        m.plan_cache.hit_rate(),
        m.plan_cache.hits,
        m.plan_cache.misses
    );
    svc.shutdown();
}

/// A mixed-size batch is coalesced into one batch job per distinct
/// size; results come back in submission order with monotonic ids.
#[test]
fn mixed_size_batch_preserves_order_and_coalesces_by_size() {
    let svc = service(2);
    let sizes = [256usize, 1024, 256, 4096, 1024, 256];
    let inputs: Vec<_> = sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| signal(n, i as u64))
        .collect();
    let results = svc.request_all(inputs.into_iter().map(FftRequest::new).collect()).unwrap();
    assert_eq!(results.len(), sizes.len());
    for (r, &n) in results.iter().zip(&sizes) {
        assert_eq!(r.output.len(), n);
        assert!(r.profile.is_some());
    }
    for w in results.windows(2) {
        assert!(w[0].id < w[1].id, "ids follow submission order");
    }
    let m = svc.metrics();
    assert_eq!(m.served, 6);
    assert_eq!(m.batches, 3, "one coalesced batch per distinct size");
    assert_eq!(m.batched_jobs, 6);
    assert_eq!(m.max_batch_jobs, 3, "three 256-point jobs share a batch");
    svc.shutdown();
}

/// All jobs in a same-size batch share one worker core; the profile is
/// reported per job exactly as in the sequential path.
#[test]
fn batch_runs_on_a_single_core() {
    let svc = service(4);
    let results = svc
        .request_all((0..6).map(|i| FftRequest::new(signal(512, i))).collect())
        .unwrap();
    let cores: Vec<usize> = results.iter().map(|r| r.core).collect();
    assert!(cores.iter().all(|&c| c == cores[0]), "cores {cores:?}");
    svc.shutdown();
}

#[test]
fn batch_with_bad_size_errors_without_killing_the_service() {
    let svc = service(1);
    assert!(svc.request_all(vec![signal(100, 0); 3].into_iter().map(FftRequest::new).collect()).is_err());
    let m = svc.metrics();
    assert_eq!(m.errors, 3, "per-job error granularity, as the sequential path");
    assert_eq!(m.served, 0);
    assert_eq!((m.batches, m.batched_jobs), (1, 3));
    // the worker survives and keeps serving
    let ok = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap();
    assert!(ok.is_ok());
    svc.shutdown();
}

#[test]
fn empty_batch_is_a_no_op() {
    let svc = service(1);
    let results = svc.request_all(Vec::new()).unwrap();
    assert!(results.is_empty());
    let m = svc.metrics();
    assert_eq!((m.served, m.batches), (0, 0));
    svc.shutdown();
}

/// Cycling more sizes than the cache holds forces LRU eviction; the
/// service keeps serving correct results while plans are rebuilt.
#[test]
fn plan_cache_lru_eviction_through_the_service() {
    let svc = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Simulator,
        plan_cache_capacity: 2,
        ..Default::default()
    })
    .unwrap();
    for n in [256usize, 1024, 4096, 256, 1024, 4096] {
        let results = svc.request_all(vec![FftRequest::new(signal(n, 0))]).unwrap();
        assert_eq!(results[0].output.len(), n);
    }
    let pc = svc.metrics().plan_cache;
    assert_eq!(pc.entries, 2);
    assert_eq!(pc.capacity, 2);
    assert_eq!(pc.misses, 6, "cycling 3 sizes through 2 slots rebuilds every time");
    assert!(pc.evictions >= 4, "evictions {}", pc.evictions);
    svc.shutdown();
}
