//! End-to-end NTT integration: the ISSUE's acceptance bar is *exact*
//! integer equality — a Goldilocks NTT request submitted through the
//! full stack (traffic frontend → QoS class queue → tenancy → sharded
//! dispatch → four-step decomposition where needed → host field kernel)
//! must reproduce the naive O(N²) modular DFT bit for bit. Floating
//! tolerances never appear in this file: any defect anywhere in the
//! pack/unpack plumbing, the root tables, or the orchestration shows up
//! as a hard integer mismatch, not a drifting RMS.

use std::time::Duration;

use egpu_fft::coordinator::{
    AdmissionPolicy, Backend, FftRequest, FftService, QosClass, ServerConfig, ServiceConfig,
    ServiceHandle, ShardPoolConfig, ShardedFftService, TenantSpec, TrafficServer, Workload,
};
use egpu_fft::fft::field;

/// Deterministic non-trivial field elements (the shared xorshift64*
/// driver behind the field module's own oracle tests).
fn elements(points: usize, seed: u64) -> Vec<u64> {
    field::test_elements(points, seed)
}

/// Decode a served wire payload back to field elements.
fn unpack(out: &[(f32, f32)]) -> Vec<u64> {
    out.iter().map(|&w| field::unpack(w)).collect()
}

fn sharded_server(shards: usize, cfg: ServerConfig) -> TrafficServer {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    TrafficServer::start(ServiceHandle::Sharded(svc), cfg).unwrap()
}

/// Single-pass sizes through the full frontend, against the naive
/// modular DFT: 256, 1024 and 4096 points, each under a QoS class and
/// a tenant so admission, tenancy and sharded dispatch are all in the
/// serving path. Equality is exact.
#[test]
fn single_pass_ntt_matches_the_naive_modular_dft_exactly() {
    let server = sharded_server(
        2,
        ServerConfig {
            classes: vec![QosClass::new("rt", 4).with_capacity(64), QosClass::new("bulk", 1)],
            policy: AdmissionPolicy::Shed,
            dispatchers: 2,
            tenants: vec![TenantSpec::new("prover", 1e9, 1_000_000)],
            ..Default::default()
        },
    );
    for (i, points) in [256usize, 1024, 4096].into_iter().enumerate() {
        let input = elements(points, 0xA0 + i as u64);
        let served = server
            .request(FftRequest::ntt(input.clone()).with_class(i % 2).with_tenant(0))
            .unwrap()
            .recv()
            .unwrap()
            .expect("NTT served through the frontend");
        assert_eq!(served.result.output.len(), points);
        assert_eq!(
            unpack(&served.result.output),
            field::dft_naive(&input),
            "{points}-point NTT must equal the O(N²) modular DFT exactly"
        );
    }
    let snap = server.metrics();
    assert_eq!(snap.by_workload.get(&Workload::Ntt).copied().unwrap_or(0), 3);
    assert_eq!(snap.tenants[0].completed, 3);
    server.shutdown();
}

/// The four-step path is not a second algorithm: a 4096-point request
/// forced to decompose at a 256-point pass ceiling must produce the
/// same integers as the single-pass answer — and both must equal the
/// standalone host kernel.
#[test]
fn decomposed_ntt_equals_its_single_pass_answer_bitwise() {
    let svc = FftService::start(ServiceConfig {
        cores: 2,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let input = elements(4096, 77);
    let single = svc
        .request(FftRequest::ntt(input.clone()))
        .recv()
        .unwrap()
        .expect("single-pass NTT");
    let staged = svc
        .request(FftRequest::ntt(input.clone()).with_max_pass_points(256))
        .recv()
        .unwrap()
        .expect("decomposed NTT");
    let want = field::ntt(&input);
    assert_eq!(unpack(&single.output), want);
    assert_eq!(
        unpack(&staged.output),
        want,
        "64×64 four-step decomposition changes scheduling, never integers"
    );
    // 4096 splits 64 × 64 under the 256 ceiling: 64 row + 64 col jobs
    assert_eq!(svc.metrics().multipass.stage_jobs(), 128, "the staged run actually decomposed");
    svc.shutdown();
}

/// The ISSUE's large-N acceptance case: a 65536-point NTT decomposes as
/// 256 × 256 through the traffic frontend (tenancy billing the true
/// 512-unit cost) and must match the host radix-2 kernel exactly. The
/// naive oracle is O(N²) and unusable at this size; exactness of the
/// fast kernel against the naive DFT is established at 256–4096 by the
/// field module's own tests, so transitivity carries the oracle here.
#[test]
fn multipass_ntt_through_the_traffic_server_is_exact() {
    let server = sharded_server(
        2,
        ServerConfig {
            // 65536 points = 256 + 256 = 512 admission units
            classes: vec![QosClass::new("only", 1).with_capacity(1024)],
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            tenants: vec![TenantSpec::new("prover", 1e9, 1_000_000)],
            ..Default::default()
        },
    );
    let input = elements(65_536, 91);
    let served = server
        .request(FftRequest::ntt(input.clone()).with_class(0).with_tenant(0))
        .unwrap()
        .recv()
        .unwrap()
        .expect("decomposed NTT served through the frontend");
    assert_eq!(served.result.output.len(), 65_536);
    assert_eq!(
        unpack(&served.result.output),
        field::ntt(&input),
        "65536-point four-step NTT must match the host kernel exactly"
    );
    let snap = server.metrics();
    assert!(snap.multipass.requests >= 1);
    assert_eq!(snap.multipass.stage_jobs(), 512, "256 row jobs + 256 column jobs");
    assert_eq!(snap.tenants[0].job_units, 512, "decomposed NTT bills its true cost");
    assert_eq!(snap.tenants[0].units_in_flight, 0);
    server.shutdown();
}

/// QoS degradation applies to NTT payloads exactly as to FFT ones: a
/// Half-level request serves the power-of-two prefix — and the answer
/// is the exact transform of that prefix, because each `(f32, f32)`
/// slot is one bit-packed element, so truncation is element-aligned.
#[test]
fn degraded_ntt_serves_the_exact_transform_of_the_prefix() {
    use egpu_fft::coordinator::DegradeLevel;

    let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let input = elements(2048, 13);
    let r = svc
        .request(FftRequest::ntt(input.clone()).with_level(DegradeLevel::Half))
        .recv()
        .unwrap()
        .expect("degraded NTT");
    assert_eq!(r.output.len(), 1024, "half resolution of a 2048-element request");
    assert_eq!(
        unpack(&r.output),
        field::ntt(&input[..1024]),
        "degrade truncates elements, then transforms exactly"
    );
    svc.shutdown();
}

/// A deadline expiring at the between-pass checkpoint kills a
/// decomposed NTT with the same typed error the FFT path reports — the
/// orchestration above the kernel is genuinely workload-blind.
#[test]
fn decomposed_ntt_honors_the_between_pass_deadline() {
    use egpu_fft::coordinator::ServiceError;

    let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let err = svc
        .request(FftRequest::ntt(elements(65_536, 5)).with_deadline(Duration::from_millis(1)))
        .recv()
        .unwrap()
        .expect_err("a 1ms deadline cannot survive the first 256-job stage");
    match err.downcast_ref::<ServiceError>() {
        Some(ServiceError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(svc.metrics().multipass.preempted >= 1);
    svc.shutdown();
}
