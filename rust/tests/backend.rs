//! Integration tests for multi-backend routing: the measured cost
//! model steers traffic to the faster lane, validation spot-checks
//! catch a corrupted fast path (counter + quarantine + the simulator's
//! answer), and a sim-only routed set is bitwise identical to the
//! unrouted service.
//!
//! Timing-sensitive assertions calibrate against a measured simulator
//! service time instead of assuming one, so they hold on slow CI hosts
//! and under parallel test execution.

use std::time::Duration;

use egpu_fft::coordinator::{
    cross_error, AutoscaleController, AutoscalePolicy, BackendSet, BackendSetConfig,
    FftBackend, FftRequest, FftService, ServerConfig, ServiceConfig,
    ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::{self, reference, Cpx};

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

/// An honest fast lane: the f64 reference transform, which is both
/// orders of magnitude faster than the cycle-accurate simulator and
/// numerically within [`fft::F32_TOL`] of it.
struct Oracle;

impl Oracle {
    fn transform(input: &[(f32, f32)]) -> Vec<(f32, f32)> {
        let cpx: Vec<Cpx> =
            input.iter().map(|&(r, i)| Cpx::new(r as f64, i as f64)).collect();
        reference::fft(&cpx).iter().map(|c| c.to_f32_pair()).collect()
    }
}

impl FftBackend for Oracle {
    fn name(&self) -> &str {
        "oracle"
    }

    fn fft(&self, input: &[(f32, f32)]) -> anyhow::Result<Vec<(f32, f32)>> {
        Ok(Oracle::transform(input))
    }
}

/// A correct but artificially slow lane, for forcing the router away.
struct Slow {
    sleep: Duration,
}

impl FftBackend for Slow {
    fn name(&self) -> &str {
        "slow"
    }

    fn fft(&self, input: &[(f32, f32)]) -> anyhow::Result<Vec<(f32, f32)>> {
        std::thread::sleep(self.sleep);
        Ok(Oracle::transform(input))
    }
}

/// A fast lane that silently corrupts one output sample — what the
/// validation spot-check exists to catch.
struct Corrupt;

impl FftBackend for Corrupt {
    fn name(&self) -> &str {
        "corrupt"
    }

    fn fft(&self, input: &[(f32, f32)]) -> anyhow::Result<Vec<(f32, f32)>> {
        let mut out = Oracle::transform(input);
        out[0].0 += 1000.0;
        Ok(out)
    }
}

fn sim_pool(cores: usize) -> ServiceHandle {
    ServiceHandle::Pool(
        FftService::start(ServiceConfig { cores, ..Default::default() }).unwrap(),
    )
}

#[test]
fn router_sends_at_least_90pct_to_the_measured_faster_lane() {
    let mut set = BackendSet::new(
        sim_pool(1),
        BackendSetConfig { calibrate_sizes: vec![256], ..Default::default() },
    )
    .unwrap();
    set.register("oracle", Box::new(Oracle), 4).unwrap();
    set.calibrate().unwrap();
    let inputs: Vec<_> = (0..100).map(|i| signal(256, i)).collect();
    let results = set.run_batch(inputs, 4).unwrap();
    assert_eq!(results.len(), 100);
    let stats = set.stats();
    assert_eq!(stats[1].name, "oracle");
    assert!(
        stats[1].served >= 90,
        "oracle lane served {}/100 (sim {})",
        stats[1].served,
        stats[0].served
    );
    assert_eq!(stats[0].served + stats[1].served, 100, "every request lands on a lane");
    // routed results are still correct transforms
    let want = Oracle::transform(&signal(256, 0));
    assert!(cross_error(&results[0].output, &want) < fft::F32_TOL);
    set.shutdown();
}

#[test]
fn forced_slow_lane_loses_traffic_to_the_simulator() {
    // Measure the simulator's own service time first, so "slow" is
    // slow relative to it on any host.
    let probe = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let mut sim_us: f64 = 0.0;
    for seed in 0..3 {
        let r = probe.run_batch(vec![signal(256, seed)]).unwrap();
        sim_us = sim_us.max(r[0].wall_us);
    }
    probe.shutdown();
    let sleep = Duration::from_secs_f64((sim_us * 20.0).max(10_000.0) / 1e6);

    let mut set = BackendSet::new(
        sim_pool(1),
        BackendSetConfig {
            calibrate_sizes: vec![256],
            calibrate_samples: 1,
            ..Default::default()
        },
    )
    .unwrap();
    set.register("slow", Box::new(Slow { sleep }), 1).unwrap();
    set.calibrate().unwrap();
    let results = set.run_batch((0..30).map(|i| signal(256, i)).collect(), 2).unwrap();
    assert_eq!(results.len(), 30);
    let stats = set.stats();
    assert!(
        stats[0].served >= 27,
        "sim kept {}/30 against a 20x-slower lane (slow lane {})",
        stats[0].served,
        stats[1].served
    );
    set.shutdown();
}

#[test]
fn validate_mismatch_counts_quarantines_and_returns_the_simulator_result() {
    let mut set = BackendSet::new(
        sim_pool(1),
        BackendSetConfig {
            validate_fraction: 1.0,
            calibrate_sizes: vec![256],
            ..Default::default()
        },
    )
    .unwrap();
    set.register("corrupt", Box::new(Corrupt), 4).unwrap();
    set.calibrate().unwrap();

    let input = signal(256, 9);
    let served = set.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
    let stats = set.stats();
    assert_eq!(stats[1].name, "corrupt");
    assert!(stats[1].validate_checks >= 1);
    assert_eq!(stats[1].validate_mismatches, 1, "the corruption was caught");
    assert!(stats[1].quarantined, "a mismatching lane is quarantined");
    assert_eq!(stats[1].served, 0, "a caught mismatch is not a serve");

    // The caller received the simulator's answer: re-serving the same
    // input (now quarantined, so sim takes it) is bitwise identical.
    let again = set.request(FftRequest::new(input)).recv().unwrap().unwrap();
    assert_eq!(bits(&served.output), bits(&again.output));

    // Quarantine holds: all subsequent traffic is simulator-served.
    for i in 0..5 {
        set.request(FftRequest::new(signal(256, 100 + i))).recv().unwrap().unwrap();
    }
    let stats = set.stats();
    assert_eq!(stats[1].served, 0);
    assert_eq!(stats[0].served, 6, "re-serve plus five follow-ups, all on sim");
    set.shutdown();
}

#[test]
fn sim_only_routed_set_is_bitwise_identical_to_the_unrouted_service() {
    let cfg = ServiceConfig { cores: 1, ..Default::default() };
    let direct = FftService::start(cfg.clone()).unwrap();
    let want = direct.run_batch(vec![signal(1024, 3)]).unwrap();
    direct.shutdown();

    // No alternates, no calibration: every request takes the simulator
    // path unchanged.
    let set = BackendSet::new(
        ServiceHandle::Pool(FftService::start(cfg).unwrap()),
        BackendSetConfig::default(),
    )
    .unwrap();
    let got = set.request(FftRequest::new(signal(1024, 3))).recv().unwrap().unwrap();
    assert_eq!(bits(&want[0].output), bits(&got.output));
    set.shutdown();
}

#[test]
fn traffic_server_over_a_routed_set_serves_and_reports_backend_stats() {
    let sim = ServiceHandle::Sharded(
        ShardedFftService::start(ShardPoolConfig { shards: 2, ..Default::default() }).unwrap(),
    );
    let mut set = BackendSet::new(
        sim,
        BackendSetConfig { calibrate_sizes: vec![256], ..Default::default() },
    )
    .unwrap();
    set.register("oracle", Box::new(Oracle), 4).unwrap();
    set.calibrate().unwrap();
    let server =
        TrafficServer::start(ServiceHandle::Routed(set), ServerConfig::default()).unwrap();
    let replies: Vec<_> = (0..20)
        .filter_map(|i| server.request(FftRequest::new(signal(256, i))).ok())
        .collect();
    let served = replies.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    assert_eq!(served, 20);
    let snap = server.metrics();
    assert_eq!(snap.backends.len(), 2, "sim lane plus the oracle lane");
    assert_eq!(snap.backends[0].name, "sim");
    let total: u64 = snap.backends.iter().map(|b| b.served).sum();
    assert_eq!(total, 20);
    assert!(
        snap.backends[1].served >= 18,
        "oracle lane took the traffic: {:?}",
        snap.backends[1].served
    );
    assert!(snap.render().contains("backends: 2"), "{}", snap.render());
    server.shutdown();
}

#[test]
fn autoscale_swap_requires_a_routed_service_and_accepts_one() {
    let policy = AutoscalePolicy { swap_service_p99_ms: 1.0, ..Default::default() };

    let inner = ServiceHandle::Sharded(
        ShardedFftService::start(ShardPoolConfig { shards: 1, ..Default::default() }).unwrap(),
    );
    let server = TrafficServer::start(inner, ServerConfig::default()).unwrap();
    let err = AutoscaleController::spawn(&server, policy.clone())
        .err()
        .expect("a sharded-only server cannot drive the swap actuator");
    assert!(err.to_string().contains("routed"), "{err}");
    server.shutdown();

    let sharded = ServiceHandle::Sharded(
        ShardedFftService::start(ShardPoolConfig { shards: 1, ..Default::default() }).unwrap(),
    );
    let set = BackendSet::new(sharded, BackendSetConfig::default()).unwrap();
    let server =
        TrafficServer::start(ServiceHandle::Routed(set), ServerConfig::default()).unwrap();
    let controller = AutoscaleController::spawn(&server, policy).unwrap();
    controller.stop();
    server.shutdown();
}
