//! Integration tests for the traffic frontend: backpressure policies
//! (block / shed / degrade), per-request deadlines, priority aging
//! (starvation freedom), full accounting (no request is ever silently
//! dropped), drain-on-shutdown, and the open-loop load generator.
//!
//! Timing-sensitive assertions calibrate against a measured service
//! time instead of assuming one, so they hold on slow CI hosts and
//! under parallel test execution.

use std::time::Duration;

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, ArrivalPattern, Backend, DegradeLevel,
    FftRequest, FftService, LoadgenConfig, ServerConfig, ServiceConfig, ServiceError,
    ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn pool_server(cores: usize, cfg: ServerConfig) -> TrafficServer {
    let inner = ServiceHandle::Pool(
        FftService::start(ServiceConfig {
            cores,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap(),
    );
    TrafficServer::start(inner, cfg).unwrap()
}

/// A request in class 0 of the default two-class configuration ("high").
fn high(input: Vec<(f32, f32)>) -> FftRequest {
    FftRequest::new(input).with_class(0)
}

/// A request in class 1 of the default two-class configuration ("low").
fn low(input: Vec<(f32, f32)>) -> FftRequest {
    FftRequest::new(input).with_class(1)
}

/// Warm the server on `points` and measure one steady-state service
/// time, µs (plan build and executor residency already paid).
fn calibrate_service_us(server: &TrafficServer, points: usize) -> f64 {
    let mut last = 0.0;
    for seed in 0..2 {
        let rx = server.request(high(signal(points, seed))).unwrap();
        last = rx.recv().unwrap().unwrap().service_us;
    }
    last
}

#[test]
fn shed_policy_returns_typed_queue_full_and_accounts_everything() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(2)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    let input = signal(1024, 0);
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..40 {
        match server.request(high(input.clone())) {
            Ok(rx) => admitted.push(rx),
            Err(ServiceError::QueueFull { capacity }) => {
                assert_eq!(capacity, 2);
                shed += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(shed >= 1, "40 instant submissions into a capacity-2 queue must shed");
    // every admitted request is answered with a real result
    for rx in admitted {
        let served = rx.recv().expect("reply delivered").expect("served");
        assert_eq!(served.result.output.len(), 1024);
        assert!(served.queue_us >= 0.0 && served.service_us > 0.0);
    }
    let sv = server.metrics().server;
    assert_eq!(sv.submitted, 40);
    assert_eq!(sv.admitted + sv.shed, sv.submitted);
    assert_eq!(sv.shed, shed);
    assert!(sv.accounted(), "admitted == completed + expired + failed: {sv:?}");
    assert!(sv.queue_wait.count > 0 && sv.service_time.count > 0);
    server.shutdown();
}

#[test]
fn block_policy_serves_every_request_without_shedding() {
    let server = pool_server(
        2,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(2)).collect(),
            policy: AdmissionPolicy::Block,
            dispatchers: 2,
            ..Default::default()
        },
    );
    let handles: Vec<_> = (0..12)
        .map(|i| server.request(high(signal(256, i))).expect("block policy never sheds"))
        .collect();
    for rx in handles {
        assert!(rx.recv().unwrap().is_ok());
    }
    let sv = server.metrics().server;
    assert_eq!(sv.shed, 0);
    assert_eq!(sv.completed, 12);
    assert!(sv.accounted());
    server.shutdown();
}

#[test]
fn queued_deadline_expiry_surfaces_typed_error_without_serving() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(16)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    // occupy the single dispatcher with a slow job, then queue two
    // requests whose deadline is long past by the time it finishes
    let slow = server.request(high(signal(4096, 0))).unwrap();
    let doomed: Vec<_> = (0..2)
        .map(|i| {
            let req = high(signal(256, i)).with_deadline(Duration::from_micros(1));
            server.request(req).unwrap()
        })
        .collect();
    assert!(slow.recv().unwrap().is_ok());
    for rx in doomed {
        match rx.recv().unwrap() {
            Err(ServiceError::DeadlineExceeded { waited_us }) => assert!(waited_us > 0.0),
            other => panic!("want DeadlineExceeded, got {other:?}"),
        }
    }
    let sv = server.metrics().server;
    assert_eq!(sv.expired, 2);
    assert_eq!(sv.completed, 1);
    assert!(sv.accounted());
    assert!(sv.deadline_miss_rate() > 0.0);
    server.shutdown();
}

#[test]
fn late_service_is_delivered_but_flagged_and_counted() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(16)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    // a deadline at a third of the measured service time expires while
    // the job is *in service*: it was dispatchable, but finishes late
    let service_us = calibrate_service_us(&server, 4096);
    let req =
        high(signal(4096, 9)).with_deadline(Duration::from_secs_f64(service_us / 3.0 * 1e-6));
    let served = server.request(req).unwrap().recv().unwrap().unwrap();
    assert!(served.deadline_missed, "served past its deadline must be flagged");
    assert_eq!(served.result.output.len(), 4096);
    let sv = server.metrics().server;
    assert_eq!(sv.late, 1);
    assert_eq!(sv.expired, 0);
    assert!(sv.deadline_miss_rate() > 0.0);
    server.shutdown();
}

#[test]
fn aged_low_priority_is_served_while_high_backlog_remains() {
    let aging = Duration::from_millis(10);
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(8192)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            aging,
            ..Default::default()
        },
    );
    // build a high-priority backlog worth ~400ms of service, so an
    // unaged low-priority request would wait far beyond the bound
    let service_us = calibrate_service_us(&server, 1024);
    let n_high = ((400_000.0 / service_us).ceil() as usize).clamp(50, 2000);
    let input = signal(1024, 1);
    let highs: Vec<_> = (0..n_high)
        .map(|_| server.request(high(input.clone())).expect("capacity is ample"))
        .collect();
    let t0 = std::time::Instant::now();
    let served_low = server
        .request(low(signal(1024, 2)))
        .unwrap()
        .recv()
        .unwrap()
        .expect("low priority request must complete");
    let low_latency = t0.elapsed();
    let backlog_left = server.queue_depth();
    assert!(
        backlog_left > 0,
        "low priority finished only after the whole high backlog drained \
         (n_high={n_high}, low waited {low_latency:?}): starvation"
    );
    assert!(
        served_low.queue_us < 200_000.0,
        "aging bound is 10ms + one service time; low waited {:.0}us with {} highs queued",
        served_low.queue_us,
        backlog_left
    );
    let sv = server.metrics().server;
    assert!(sv.aged >= 1, "the aging rule must have promoted the low request");
    assert_eq!(sv.served_low, 1);
    drop(highs); // receivers may be dropped; the server still serves and counts
    server.shutdown();
}

#[test]
fn degrade_policy_walks_the_ladder_under_pressure_and_sheds_at_the_limit() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(8)).collect(),
            policy: AdmissionPolicy::Degrade,
            dispatchers: 1,
            min_degraded_points: 256,
            ..Default::default()
        },
    );
    // occupy the dispatcher so the queue actually fills
    let slow = server.request(high(signal(4096, 0))).unwrap();
    let input = signal(1024, 3);
    let mut handles = Vec::new();
    let mut shed = 0u64;
    for _ in 0..12 {
        match server.request(high(input.clone())) {
            Ok(rx) => handles.push(rx),
            Err(ServiceError::QueueFull { .. }) => shed += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(shed >= 1, "beyond class capacity the Degrade policy sheds with a typed error");
    assert!(slow.recv().unwrap().is_ok());
    let (mut halves, mut quarters) = (0u64, 0u64);
    for rx in handles {
        let served = rx.recv().unwrap().unwrap();
        // the served length always matches the reported ladder level
        assert_eq!(served.result.output.len(), 1024 >> served.level.shift());
        assert_eq!(served.degraded, served.level != DegradeLevel::Full);
        match served.level {
            DegradeLevel::Full => {}
            DegradeLevel::Half => halves += 1,
            DegradeLevel::Quarter => quarters += 1,
        }
    }
    assert!(halves >= 1, "requests admitted past half capacity serve at Half");
    assert!(quarters >= 1, "requests admitted past 3/4 capacity serve at Quarter");
    let sv = server.metrics().server;
    assert_eq!(sv.degraded, halves + quarters);
    assert_eq!(sv.per_class[0].degraded_half, halves);
    assert_eq!(sv.per_class[0].degraded_quarter, quarters);
    assert!(sv.accounted());
    server.shutdown();
}

#[test]
fn degraded_output_matches_reference_fft_of_truncated_signal() {
    // fill the queue to the degrade region deterministically: capacity
    // 1 means every admission happens at depth >= 3*cap/4 == 0, i.e. at
    // the deepest ladder level the floor allows (1024 -> Quarter: 256
    // points, exactly the min_degraded_points floor)
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(1)).collect(),
            policy: AdmissionPolicy::Degrade,
            dispatchers: 1,
            min_degraded_points: 256,
            ..Default::default()
        },
    );
    let served = server.request(high(signal(1024, 7))).unwrap().recv().unwrap().unwrap();
    assert!(served.degraded);
    assert_eq!(served.level, DegradeLevel::Quarter);
    assert_eq!(served.result.output.len(), 256);
    let truncated: Vec<_> = reference::test_signal(1024, 7)[..256].to_vec();
    let want = reference::fft(&truncated);
    let got: Vec<_> = served
        .result
        .output
        .iter()
        .map(|&(re, im)| egpu_fft::fft::Cpx::new(re as f64, im as f64))
        .collect();
    assert!(reference::rms_rel_error(&got, &want) < egpu_fft::fft::F32_TOL);

    // a 512-point request floor-clamps to Half (512 >> 2 < 256)
    let served = server.request(high(signal(512, 8))).unwrap().recv().unwrap().unwrap();
    assert_eq!(served.level, DegradeLevel::Half, "ladder floor-clamps at min_points");
    assert_eq!(served.result.output.len(), 256);
    server.shutdown();
}

#[test]
fn shutdown_drains_every_admitted_request() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(16)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    let handles: Vec<_> =
        (0..6).map(|i| server.request(high(signal(256, i))).unwrap()).collect();
    server.shutdown();
    for rx in handles {
        let served = rx.recv().expect("admitted request answered during drain");
        assert!(served.is_ok(), "drained request served: {served:?}");
    }
}

#[test]
fn drop_without_shutdown_still_drains_admitted_requests() {
    let server = pool_server(1, ServerConfig::default());
    let rx = server.request(high(signal(256, 0))).unwrap();
    drop(server); // Drop closes admission and joins dispatchers
    assert!(rx.recv().expect("drained on drop").is_ok());
}

#[test]
fn loadgen_accounts_every_request_open_loop() {
    let inner = ServiceHandle::Sharded(
        ShardedFftService::start(ShardPoolConfig {
            shards: 2,
            service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    );
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(32)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            pattern: ArrivalPattern::Poisson,
            rate_hz: 2000.0,
            duration: Duration::from_millis(500),
            sizes: vec![256, 1024],
            deadline: Some(Duration::from_millis(10)),
            high_fraction: 0.5,
            seed: 1,
            ..Default::default()
        },
    );
    assert!(report.submitted > 100, "open loop kept submitting: {report:?}");
    assert!(report.completed > 0);
    assert_eq!(report.lost, 0, "no reply channel may die unanswered");
    assert!(report.accounted, "submitted == completed + shed + expired + failed");
    assert!(report.achieved_rps > 0.0 && report.offered_rps > 0.0);
    let sv = server.metrics().server;
    assert!(sv.accounted());
    // histogram sanity: percentiles are monotone in q
    assert!(sv.service_time.percentile_us(0.5) <= sv.service_time.percentile_us(0.99));
    assert!(sv.queue_wait.percentile_us(0.5) <= sv.queue_wait.percentile_us(0.999));
    let json = report.to_json();
    assert!(json.contains("\"deadline_miss_rate\""));
    server.shutdown();
}

#[test]
fn burst_pattern_stresses_the_queue_harder_than_poisson() {
    let mk = || {
        let inner = ServiceHandle::Pool(
            FftService::start(ServiceConfig { cores: 2, ..Default::default() }).unwrap(),
        );
        TrafficServer::start(
            inner,
            ServerConfig {
                classes: default_two_class().into_iter().map(|c| c.with_capacity(16)).collect(),
                policy: AdmissionPolicy::Shed,
                dispatchers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let cfg = |pattern| LoadgenConfig {
        pattern,
        rate_hz: 3000.0,
        duration: Duration::from_millis(400),
        burst_size: 64,
        sizes: vec![1024],
        deadline: None,
        seed: 5,
        ..Default::default()
    };
    let srv = mk();
    let burst = loadgen::run(&srv, &cfg(ArrivalPattern::Burst));
    srv.shutdown();
    assert!(burst.accounted);
    assert!(
        burst.shed + burst.completed == burst.submitted,
        "with no deadline every request is served or shed: {burst:?}"
    );
    let srv = mk();
    let poisson = loadgen::run(&srv, &cfg(ArrivalPattern::Poisson));
    srv.shutdown();
    assert!(poisson.accounted);
    // both overload the service; the burst pattern must shed at least
    // as it delivers 64-deep arrival spikes into a 16-slot queue
    assert!(burst.shed > 0, "64-request bursts into a 16-slot queue must shed: {burst:?}");
}
