//! Integration tests for the zero-copy hot path: arena-backed job
//! buffers and the SPSC shard rings.
//!
//! The contract under test, end to end:
//!
//! * **Bitwise identity** — a request whose payload arrives in a leased
//!   [`JobSlot`] produces exactly the same bits as the same payload
//!   submitted through `FftRequest::new(Vec)`, on the pool service, the
//!   sharded service, and a routed [`BackendSet`].
//! * **Graceful exhaustion** — an arena out of free slots falls back to
//!   heap-backed slots: requests are never rejected and the service
//!   never deadlocks, the misses just show up in [`ArenaStats`].
//! * **Ring semantics** — [`JobRing`] is FIFO, blocks producers instead
//!   of dropping when full, and drains completely after `close`.
//! * **Lossless resize** — retiring a shard mid-burst loses no queued
//!   job: every submitted request still gets its (correct) answer.

use egpu_fft::coordinator::{
    Backend, BackendSet, BackendSetConfig, FftRequest, FftService, JobArena, JobRing, JobSlot,
    ServiceConfig, ServiceHandle, ShardPoolConfig, ShardedFftService,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

fn pool(cores: usize) -> FftService {
    FftService::start(ServiceConfig {
        cores,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap()
}

fn sharded(shards: usize) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

/// The slot path must be bitwise identical to the Vec path on every
/// service shape — zero-copy is a plumbing change, not a numeric one.
#[test]
fn slot_requests_match_vec_requests_bitwise() {
    let inputs: Vec<Vec<(f32, f32)>> = (0..6).map(|i| signal(512, 40 + i)).collect();

    // Reference outputs through the plain Vec constructor, pool service.
    let svc = pool(1);
    let want: Vec<Vec<(u32, u32)>> = inputs
        .iter()
        .map(|x| {
            let r = svc.request(FftRequest::new(x.clone())).recv().unwrap().unwrap();
            bits(&r.output)
        })
        .collect();
    svc.shutdown();

    // Pool, slot path.
    let svc = pool(2);
    for (x, w) in inputs.iter().zip(&want) {
        let slot = JobArena::global().lease_copy(x);
        let r = svc.request(FftRequest::with_input_slot(slot)).recv().unwrap().unwrap();
        assert_eq!(bits(&r.output), *w, "pool slot path diverged");
    }
    svc.shutdown();

    // Sharded, slot path.
    let svc = sharded(2);
    for (x, w) in inputs.iter().zip(&want) {
        let slot = JobArena::global().lease_copy(x);
        let r = svc.request(FftRequest::with_input_slot(slot)).recv().unwrap().unwrap();
        assert_eq!(bits(&r.output), *w, "sharded slot path diverged");
    }
    svc.shutdown();

    // Routed (no alternates registered: the pure simulator route).
    let set = BackendSet::new(ServiceHandle::Pool(pool(1)), BackendSetConfig::default()).unwrap();
    for (x, w) in inputs.iter().zip(&want) {
        let slot = JobArena::global().lease_copy(x);
        let r = set.request(FftRequest::with_input_slot(slot)).recv().unwrap().unwrap();
        assert_eq!(bits(&r.output), *w, "routed slot path diverged");
    }
    set.shutdown();
}

/// A dedicated arena with fewer slots than in-flight payloads must fall
/// back to heap-backed slots — never reject, never deadlock — and the
/// fallbacks must be visible as lease misses.
#[test]
fn arena_exhaustion_falls_back_to_heap_and_serves_everything() {
    let arena = JobArena::new(2, 1024);
    let input = signal(1024, 3);

    // Hold more leased slots than the arena owns, all at once.
    let slots: Vec<JobSlot> = (0..10).map(|_| arena.lease_copy(&input)).collect();
    let s = arena.snapshot();
    assert_eq!(s.lease_hits, 2, "only the pooled slots are hits");
    assert_eq!(s.lease_misses, 8, "the overflow leases are heap fallbacks");
    assert_eq!(s.in_use, 2, "heap fallbacks do not occupy arena slots");
    for slot in &slots {
        assert_eq!(&slot[..], &input[..], "fallback slots carry the same payload");
    }

    // All ten serve concurrently and come back identical.
    let svc = pool(2);
    let want = {
        let r = svc.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
        bits(&r.output)
    };
    let pending: Vec<_> = slots
        .into_iter()
        .map(|slot| svc.request(FftRequest::with_input_slot(slot)))
        .collect();
    for rx in pending {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(bits(&r.output), want, "exhaustion path changed the numerics");
    }
    svc.shutdown();

    // Every pooled slot came home.
    let s = arena.snapshot();
    assert_eq!(s.in_use, 0, "all arena slots released after the replies dropped");
}

/// FIFO order through the ring, including across a blocking producer,
/// and complete drain after close.
#[test]
fn job_ring_is_fifo_and_drains_after_close() {
    // Single-threaded FIFO.
    let ring: JobRing<u64> = JobRing::new(8);
    for v in 0..8 {
        ring.push(v).unwrap();
    }
    for v in 0..8 {
        assert_eq!(ring.pop(), Some(v), "FIFO order");
    }

    // A producer past capacity blocks until the consumer makes room,
    // and order is still FIFO end to end.
    let ring = std::sync::Arc::new(JobRing::<u64>::new(4));
    let producer = {
        let ring = ring.clone();
        std::thread::spawn(move || {
            for v in 0..64u64 {
                // push blocks while the ring is full; Err means closed,
                // which must not happen mid-stream
                ring.push(v).expect("ring closed under the producer");
            }
            ring.close();
        })
    };
    let mut got = Vec::new();
    while let Some(v) = ring.pop() {
        got.push(v);
    }
    producer.join().unwrap();
    assert_eq!(got, (0..64).collect::<Vec<u64>>(), "blocking producer kept FIFO order");

    // After close, pushes fail and hand the item back.
    assert_eq!(ring.push(99), Err(99));
    assert_eq!(ring.pop(), None, "drained ring stays empty after close");
}

/// Retiring a shard while a burst is in flight must lose nothing: the
/// retiring worker drains its ring and the pool re-routes the drained
/// jobs, so every request is answered, correctly.
#[test]
fn retire_under_load_loses_no_jobs() {
    let svc = sharded(2);
    let inputs: Vec<Vec<(f32, f32)>> = (0..48).map(|i| signal(256, 70 + i)).collect();
    let want: Vec<Vec<(u32, u32)>> = {
        let reference = pool(1);
        let w = inputs
            .iter()
            .map(|x| {
                let r = reference.request(FftRequest::new(x.clone())).recv().unwrap().unwrap();
                bits(&r.output)
            })
            .collect();
        reference.shutdown();
        w
    };

    let pending: Vec<_> = inputs
        .iter()
        .map(|x| {
            let slot = JobArena::global().lease_copy(x);
            svc.request(FftRequest::with_input_slot(slot))
        })
        .collect();
    // Retire one shard while the burst is queued/in flight.
    svc.retire_shard().unwrap();
    assert_eq!(svc.shards(), 1);

    for (rx, w) in pending.into_iter().zip(&want) {
        let r = rx.recv().expect("reply channel alive").expect("job served");
        assert_eq!(bits(&r.output), *w, "post-retire output diverged");
    }
    svc.shutdown();
}
