//! Table regression: our regenerated Tables 1–6 against the paper's
//! published cells — exact where the architecture fully determines the
//! count (loads/stores), bounded deltas where the paper's hand-written
//! assembly differs from our generated code (see EXPERIMENTS.md).

use egpu_fft::arch::Variant;
use egpu_fft::isa::OpClass;
use egpu_fft::profile::Profile;
use egpu_fft::report::{self, ProfileTable};

fn cell<'t>(t: &'t ProfileTable, points: usize, variant_idx: usize) -> &'t Profile {
    t.rows
        .iter()
        .find(|(p, _)| *p == points)
        .unwrap()
        .1[variant_idx]
        .as_ref()
        .unwrap()
}

fn within(pct: f64, got: f64, paper: f64, what: &str) {
    let delta = 100.0 * (got - paper).abs() / paper;
    assert!(delta <= pct, "{what}: got {got}, paper {paper} ({delta:.1}% off)");
}

/// Table 1 (radix-4): loads/stores exact; totals/time/efficiency within
/// 12 % (our generated FP streams are slightly leaner than the paper's
/// hand assembly).
#[test]
fn table1_against_paper() {
    let t = report::profile_table_for(4, &[4096, 1024, 256]).unwrap();
    // -- exact memory-system counts, 4096 points --
    let dp = cell(&t, 4096, 0);
    assert_eq!(dp.get(OpClass::Load), 19968);
    assert_eq!(dp.get(OpClass::Store), 49152);
    let vm = cell(&t, 4096, 1);
    assert_eq!(vm.get(OpClass::Store), 16384);
    assert_eq!(vm.get(OpClass::StoreVm), 8192);
    let qp = cell(&t, 4096, 4);
    assert_eq!(qp.get(OpClass::Store), 24576);
    // -- bounded metric deltas --
    within(12.0, dp.total() as f64, 86817.0, "T1 DP total");
    within(12.0, dp.time_us(), 112.60, "T1 DP time");
    within(12.0, dp.efficiency_pct(), 15.48, "T1 DP efficiency");
    within(12.0, cell(&t, 4096, 3).efficiency_pct(), 22.64, "T1 VM+C efficiency");
    // 1024 points
    let dp1k = cell(&t, 1024, 0);
    assert_eq!(dp1k.get(OpClass::Load), 4096);
    assert_eq!(dp1k.get(OpClass::Store), 10240);
    within(12.0, dp1k.time_us(), 23.40, "T1 1024 DP time");
    // 256 points: NOPs present in DP, fewer after the complex variant
    let dp256 = cell(&t, 256, 0);
    assert!(dp256.get(OpClass::Nop) > 0);
    assert_eq!(dp256.get(OpClass::Store), 2048);
}

/// Table 2 (radix-8): loads exact (the §6 twiddle-arithmetic check),
/// FP within 6 % (Table 4's recipe, minus the folded moves).
#[test]
fn table2_against_paper() {
    let t = report::profile_table_for(8, &[4096, 512]).unwrap();
    let dp = cell(&t, 4096, 0);
    assert_eq!(dp.get(OpClass::Load), 13568); // paper: 13568 exactly
    assert_eq!(dp.get(OpClass::Store), 32768);
    within(6.0, dp.get(OpClass::Fp) as f64, 11840.0, "T2 FP");
    within(10.0, dp.total() as f64, 61896.0, "T2 DP total");
    within(10.0, dp.efficiency_pct(), 19.13, "T2 DP efficiency");
    let vm = cell(&t, 4096, 1);
    assert_eq!(vm.get(OpClass::StoreVm), 4096);
    assert_eq!(vm.get(OpClass::Store), 16384);
    within(10.0, vm.efficiency_pct(), 23.87, "T2 VM efficiency");
    // complex column: complex-FU op count exact (3 passes × 7 × 3 × 32)
    let cx = cell(&t, 4096, 2);
    assert_eq!(cx.get(OpClass::Complex), 2016 + 2); // +2: coeff_en/dis
    within(10.0, cx.get(OpClass::Fp) as f64, 7808.0, "T2 complex FP");
}

/// Table 3 (radix-16): loads exact; the paper's 4096 VM/QP store cells
/// appear swapped (see EXPERIMENTS.md) — our model gives VM 16384+2048
/// and QP 12288, the consistent assignment.
#[test]
fn table3_against_paper() {
    let t = report::profile_table_for(16, &[4096, 1024]).unwrap();
    let dp = cell(&t, 4096, 0);
    assert_eq!(dp.get(OpClass::Load), 9984); // paper: 9984 exactly
    assert_eq!(dp.get(OpClass::Store), 24576);
    let vm = cell(&t, 4096, 1);
    assert_eq!(vm.get(OpClass::StoreVm), 2048); // paper: 2048
    assert_eq!(vm.get(OpClass::Store), 16384); // paper QP cell (swap)
    let qp = cell(&t, 4096, 4);
    assert_eq!(qp.get(OpClass::Store), 12288); // paper VM cell (swap)
    within(12.0, dp.efficiency_pct(), 25.18, "T3 DP efficiency");
    // 1024 mixed radix: paper 4096 + 512
    let vm1k = cell(&t, 1024, 1);
    assert_eq!(vm1k.get(OpClass::Store), 4096);
    assert_eq!(vm1k.get(OpClass::StoreVm), 512);
    within(12.0, cell(&t, 1024, 0).time_us(), 15.51, "T3 1024 DP time");
}

/// Table 5: the headline — IP core is ~6-7× faster raw but only ~3×
/// after footprint normalization at 4096 points.
#[test]
fn table5_against_paper() {
    let rows = report::table5().unwrap();
    let r4096 = rows.iter().find(|r| r.points == 4096).unwrap();
    assert!((4.0..=9.0).contains(&r4096.perf_ratio), "{}", r4096.perf_ratio);
    assert!(
        (2.0..=4.0).contains(&r4096.normalized_ratio),
        "normalized {}",
        r4096.normalized_ratio
    );
    let r256 = rows.iter().find(|r| r.points == 256).unwrap();
    assert!((4.0..=8.0).contains(&r256.perf_ratio));
    // IP resources are the paper's exact figures
    assert_eq!(r256.ip.alm, 12842);
    assert_eq!(r4096.ip.m20k, 126);
}

/// Table 6: the eGPU matches or beats the A100's published cuFFT
/// efficiency at every size, and clearly beats the V100 (§8).
#[test]
fn table6_against_paper() {
    let rows = report::table6().unwrap();
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert!(
            r.egpu_eff_pct >= r.a100_published - 2.0,
            "{}: egpu {:.1} vs A100 {:.1}",
            r.points,
            r.egpu_eff_pct,
            r.a100_published
        );
        assert!(r.egpu_eff_pct > r.v100_published + 3.0);
        // the roofline model reproduces the published GPU numbers
        assert!((r.a100_modeled - r.a100_published).abs() < 2.0);
        assert!((r.v100_modeled - r.v100_published).abs() < 2.0);
    }
    // efficiency rises with size (paper: 25/27/36; ours: ~23/28/34)
    assert!(rows[0].egpu_eff_pct < rows[1].egpu_eff_pct);
    assert!(rows[1].egpu_eff_pct < rows[2].egpu_eff_pct);
}

/// Figure 2 regression: the exact indexes printed in the paper.
#[test]
fn figure2_against_paper() {
    let fig = report::figure2(32, 3).unwrap();
    // Pass 1 row 2 starts i064 i065 i066 ...
    assert!(fig.contains("i064\ti065\ti066"));
    // Pass 3 T0 = 0,4,8,12 -> rows contain i000/i004/i008/i012 columns
    assert!(fig.contains("i012"));
}

/// Figure 4 regression: ~2× footprint, both cores in the 1–4 % device
/// range (§8: "both ... occupy in the range of 1%-2% of the FPGA").
#[test]
fn figure4_against_paper() {
    let fig = report::figure4();
    let ratio: f64 = fig
        .split("ratio ")
        .nth(1)
        .unwrap()
        .split('x')
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert!((1.8..=2.2).contains(&ratio), "ratio {ratio}");
}

/// The six variants' resource table (§6 prose).
#[test]
fn resources_against_paper() {
    let dp = Variant::DP.resources();
    assert_eq!((dp.alm, dp.m20k, dp.dsp), (8801, 192, 32));
    assert_eq!(Variant::QP.resources().m20k, 96); // "about half"
}
