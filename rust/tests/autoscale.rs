//! Autoscaler integration tests — the ISSUE's acceptance criteria:
//!
//! (a) under a load-generator step overload the controller scales up
//!     within its cooldown budget and shed-rate / queue-p99 recover
//!     below the SLO thresholds;
//! (b) scale-down retires shards without dropping any admitted job;
//! (c) outputs remain bitwise identical to a fixed-size
//!     `ShardedFftService` run across a resize.
//!
//! Offered rates are calibrated against this host's measured
//! single-shard capacity so the step means the same thing on fast and
//! slow runners.

use std::time::Duration;

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, AutoscaleController, AutoscalePolicy, Backend,
    FftRequest, FftService, LoadgenConfig, ServerConfig, ServiceConfig, ServiceHandle,
    ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

fn sharded(shards: usize) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

/// Measured single-shard fft1024 serving capacity, jobs/s (shared
/// library helper — the same anchor the benches calibrate with).
fn single_shard_rps() -> f64 {
    ShardedFftService::calibrate_single_shard_rps(1024).unwrap()
}

/// (a) A step overload onto a one-shard pool: the controller must grow
/// the pool within its cooldown budget, and by the end of the run the
/// interval shed rate and queue-wait p99 must sit back below the SLO.
#[test]
fn step_overload_scales_up_and_recovers_below_slo() {
    let policy = AutoscalePolicy {
        min_shards: 1,
        max_shards: 4,
        target_p99_ms: 50.0,
        max_shed_rate: 0.05,
        scale_up_cooldown: Duration::from_millis(100),
        scale_down_cooldown: Duration::from_secs(30), // never down in this test
        interval: Duration::from_millis(25),
        ..Default::default()
    };
    let base_rps = single_shard_rps();
    let svc = sharded(1);
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let controller = AutoscaleController::spawn(&server, policy.clone()).unwrap();

    // 1.4x one shard's capacity: an overload one shard cannot serve and
    // a four-shard pool absorbs comfortably.
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 1.4 * base_rps,
            duration: Duration::from_millis(2500),
            sizes: vec![1024],
            deadline: None,
            ..Default::default()
        },
    );
    assert!(report.accounted, "every request answered");
    assert_eq!(report.lost, 0);

    let handle = server.service();
    let final_shards = handle.as_sharded().unwrap().shards();
    drop(handle);
    let log = controller.stop();

    assert!(
        final_shards > 1,
        "controller must grow the pool under overload (stayed at {final_shards}):\n{}",
        log.render()
    );
    let first_up = log
        .events
        .iter()
        .find(|e| e.to_shards > e.from_shards)
        .unwrap_or_else(|| panic!("no scale-up event:\n{}", log.render()));
    assert!(
        first_up.at_s <= 1.0,
        "first scale-up at {:.2}s exceeds the cooldown budget (100ms cooldown, \
         25ms interval):\n{}",
        first_up.at_s,
        log.render()
    );

    // SLO recovery: by the last quarter of the run the interval shed
    // rate and queue-wait p99 are back under the thresholds.
    let span = log.samples.last().map(|s| s.at_s).unwrap_or(0.0);
    let tail: Vec<_> = log.samples.iter().filter(|s| s.at_s >= 0.75 * span).collect();
    assert!(!tail.is_empty(), "controller observed the end of the run");
    let mean_shed = tail.iter().map(|s| s.shed_rate).sum::<f64>() / tail.len() as f64;
    let mean_p99 = tail.iter().map(|s| s.queue_p99_ms).sum::<f64>() / tail.len() as f64;
    assert!(
        mean_shed <= policy.max_shed_rate,
        "shed rate did not recover: {mean_shed:.3} > {:.3} SLO\n{}",
        policy.max_shed_rate,
        log.render()
    );
    assert!(
        mean_p99 <= policy.target_p99_ms,
        "queue p99 did not recover: {mean_p99:.1}ms > {:.1}ms SLO\n{}",
        policy.target_p99_ms,
        log.render()
    );
    server.shutdown();
}

/// (b) Scale-down under light sustained traffic: the pool shrinks from
/// its over-provisioned start and every admitted request is still
/// answered — retirement re-routes queued work, it never drops it.
#[test]
fn scale_down_under_light_load_drops_no_jobs() {
    let base_rps = single_shard_rps();
    let svc = sharded(4);
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let controller = AutoscaleController::spawn(
        &server,
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 50.0,
            max_shed_rate: 0.05,
            scale_up_cooldown: Duration::from_millis(100),
            scale_down_cooldown: Duration::from_millis(200),
            interval: Duration::from_millis(20),
            ..Default::default()
        },
    )
    .unwrap();

    // ~10% of one shard's capacity: four shards are gross
    // over-provisioning, so the controller should shed capacity.
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: (0.1 * base_rps).max(20.0),
            duration: Duration::from_millis(2000),
            sizes: vec![1024],
            deadline: None,
            ..Default::default()
        },
    );
    assert!(report.accounted, "every request answered across resizes");
    assert_eq!(report.lost, 0, "no reply channel dropped");
    assert_eq!(report.shed, 0, "light load never sheds");
    assert_eq!(report.failed, 0, "no job failed across retirements");

    let handle = server.service();
    let final_shards = handle.as_sharded().unwrap().shards();
    let snap = handle.metrics();
    drop(handle);
    let log = controller.stop();

    assert!(
        final_shards < 4,
        "idle capacity must be retired (still at {final_shards}):\n{}",
        log.render()
    );
    assert!(final_shards >= 1);
    let downs = log.events.iter().filter(|e| e.to_shards < e.from_shards).count();
    assert!(downs >= 1, "scale-down events logged:\n{}", log.render());
    // retired shards keep their final counters in the snapshot, so
    // per-shard accounting still covers every served job
    assert_eq!(
        snap.shards.iter().map(|s| s.handled).sum::<u64>(),
        snap.served + snap.errors,
        "active + retired shard counters account for every job: {:?}",
        snap.shards
    );
    assert_eq!(snap.shards.iter().filter(|s| s.retired).count(), 4 - final_shards);
    server.shutdown();
}

/// (c) Bitwise identity across a resize: a pool that grows and shrinks
/// mid-stream produces exactly the bits of a fixed-size pool (which
/// `rust/tests/shard.rs` already pins to the unsharded service).
#[test]
fn outputs_bitwise_identical_across_resize() {
    let inputs: Vec<_> = (0..18)
        .map(|i| signal(if i % 3 == 0 { 256 } else { 1024 }, 9000 + i as u64))
        .collect();

    let fixed = sharded(2);
    let base: Vec<Vec<(u32, u32)>> = fixed
        .run_batch(inputs.clone())
        .unwrap()
        .iter()
        .map(|r| bits(&r.output))
        .collect();
    fixed.shutdown();

    let elastic = sharded(1);
    let mut got: Vec<Vec<(u32, u32)>> = Vec::new();
    for r in elastic.run_batch(inputs[0..6].to_vec()).unwrap() {
        got.push(bits(&r.output));
    }
    elastic.add_shard();
    elastic.add_shard();
    for r in elastic.run_batch(inputs[6..12].to_vec()).unwrap() {
        got.push(bits(&r.output));
    }
    elastic.retire_shard().unwrap();
    for r in elastic.run_batch(inputs[12..18].to_vec()).unwrap() {
        got.push(bits(&r.output));
    }
    assert_eq!(elastic.shards(), 2);
    elastic.shutdown();

    assert_eq!(got.len(), base.len());
    for (i, (g, want)) in got.iter().zip(&base).enumerate() {
        assert_eq!(g, want, "job {i} diverged across the resize");
    }
}

/// Resizing mid-queue: jobs admitted before a retirement are all
/// served, through the drain-and-reroute path, with correct numerics.
#[test]
fn retirement_with_queued_work_reroutes_and_serves_everything() {
    // fft256 homes on position 2 of a 3-shard pool (trailing zeros 8),
    // which is exactly the slot retire_shard pops; the huge steal
    // threshold keeps the queue pinned there until retirement.
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 3,
        steal_threshold: 4096,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    let handles: Vec<_> =
        (0..24).map(|i| svc.request(FftRequest::new(signal(256, i)))).collect();
    let retired_id = svc.retire_shard().unwrap();
    assert_eq!(svc.shards(), 2);
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.recv().expect("reply arrives").unwrap_or_else(|e| {
            panic!("job {i} lost across retirement: {e:#}");
        });
        assert_eq!(r.output.len(), 256);
        let want = reference::fft(&reference::test_signal(256, i as u64));
        let got: Vec<_> = r
            .output
            .iter()
            .map(|&(re, im)| egpu_fft::fft::Cpx::new(re as f64, im as f64))
            .collect();
        assert!(reference::rms_rel_error(&got, &want) < egpu_fft::fft::F32_TOL);
    }
    let m = svc.metrics();
    assert_eq!(m.served, 24);
    let frozen = m.shards.iter().find(|s| s.shard == retired_id).expect("retired stat");
    assert!(frozen.retired);
    svc.shutdown();
}

/// The controller refuses a non-resizable (pool) backend and nonsense
/// policies.
#[test]
fn spawn_rejects_pool_backend_and_bad_policy() {
    let server = TrafficServer::start(
        ServiceHandle::Pool(
            FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap(),
        ),
        ServerConfig::default(),
    )
    .unwrap();
    assert!(AutoscaleController::spawn(&server, AutoscalePolicy::default()).is_err());
    server.shutdown();

    let server = TrafficServer::start(
        ServiceHandle::Sharded(sharded(1)),
        ServerConfig::default(),
    )
    .unwrap();
    let bad = AutoscalePolicy { min_shards: 0, max_shards: 2, ..Default::default() };
    assert!(AutoscaleController::spawn(&server, bad).is_err());
    // dispatchers bound backend in-flight work: a max_shards above the
    // server's dispatcher count (default 4) can never add capacity
    let too_wide = AutoscalePolicy { min_shards: 1, max_shards: 64, ..Default::default() };
    assert!(AutoscaleController::spawn(&server, too_wide).is_err());
    let ok = AutoscalePolicy { min_shards: 1, max_shards: 4, ..Default::default() };
    let controller = AutoscaleController::spawn(&server, ok).unwrap();
    controller.stop();
    server.shutdown();
}
