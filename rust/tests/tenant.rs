//! Integration tests for the tenancy layer — the ISSUE's isolation
//! contract, end to end through the traffic frontend:
//!
//! (a) a quota-throttled tenant never consumes a class-queue slot: its
//!     refused requests are answered immediately with a typed error,
//!     the class counters never see them, and a sibling tenant can
//!     still fill every slot the throttled requests did not take;
//! (b) quota units are released on completion, so a capped tenant
//!     admits again once its in-flight work drains;
//! (c) untenanted requests bypass the tenancy layer entirely even when
//!     the server has one configured, and an unknown tenant index is a
//!     typed error, not a panic;
//! (d) cross-pass preemption: a background tenant's decomposed request
//!     pauses at the between-pass checkpoint while a priority tenant's
//!     request waits in a class queue, resumes within the bounded
//!     yield cap, and still produces a bitwise-correct transform.

use std::time::Duration;

use egpu_fft::coordinator::{
    AdmissionPolicy, Backend, FftRequest, FftService, QosClass, ServerConfig, ServiceConfig,
    ServiceError, ServiceHandle, ShardPoolConfig, ShardedFftService, TenantSpec, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn pool_server(cores: usize, cfg: ServerConfig) -> TrafficServer {
    let inner = ServiceHandle::Pool(
        FftService::start(ServiceConfig {
            cores,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap(),
    );
    TrafficServer::start(inner, cfg).unwrap()
}

/// A bucket that never throttles in a test's lifetime.
fn generous(name: &str) -> TenantSpec {
    TenantSpec::new(name, 1e9, 1_000_000)
}

/// (a) + (b): a tenant capped at one in-flight job unit is throttled
/// immediately once its unit is out — and those refusals leave every
/// class-queue slot for the conforming tenant, which can still fill
/// the queue to its exact capacity. Completion releases the unit and
/// the capped tenant admits again.
#[test]
fn quota_throttled_requests_never_consume_queue_slots() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: vec![QosClass::new("only", 1).with_capacity(4)],
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            tenants: vec![generous("capped").with_quota(1), generous("free")],
            ..Default::default()
        },
    );
    // hold the single dispatcher so the queue actually fills
    let slow = server
        .request(FftRequest::new(signal(4096, 0)).with_class(0).with_tenant(1))
        .unwrap();

    let input = signal(1024, 3);
    // first capped request takes the tenant's single job unit...
    let capped = server
        .request(FftRequest::new(input.clone()).with_class(0).with_tenant(0))
        .unwrap();
    // ...every further one is a typed throttle, answered without
    // touching the queue
    for _ in 0..5 {
        match server.request(FftRequest::new(input.clone()).with_class(0).with_tenant(0)) {
            Err(ServiceError::TenantThrottled { tenant }) => assert_eq!(tenant, 0),
            other => panic!("expected TenantThrottled, got {other:?}"),
        }
    }
    // the queue holds exactly one capped request; the conforming
    // tenant can still take the remaining 3 slots of the 4-slot class
    let free_handles: Vec<_> = (0..3)
        .map(|_| {
            server
                .request(FftRequest::new(input.clone()).with_class(0).with_tenant(1))
                .expect("throttled requests must not have taken these slots")
        })
        .collect();
    // slot 5 overflows the class cap — proof the 5 throttled requests
    // occupied nothing
    match server.request(FftRequest::new(input.clone()).with_class(0).with_tenant(1)) {
        Err(ServiceError::QueueFull { capacity }) => assert_eq!(capacity, 4),
        other => panic!("expected QueueFull, got {other:?}"),
    }

    assert!(slow.recv().unwrap().is_ok());
    assert!(capped.recv().unwrap().is_ok());
    for rx in free_handles {
        assert!(rx.recv().unwrap().is_ok());
    }
    // (b) the completed request released its unit: the capped tenant
    // admits again
    let again = server
        .request(FftRequest::new(input.clone()).with_class(0).with_tenant(0))
        .expect("quota released on completion");
    assert!(again.recv().unwrap().is_ok());

    let snap = server.metrics();
    let capped_row = &snap.tenants[0];
    assert_eq!(capped_row.name, "capped");
    assert_eq!(capped_row.submitted, 7);
    assert_eq!(capped_row.admitted, 2);
    assert_eq!(capped_row.throttled, 5);
    assert_eq!(capped_row.completed, 2);
    assert_eq!(capped_row.job_units, 2, "both admitted requests billed one unit each");
    assert_eq!(capped_row.units_in_flight, 0, "nothing left charged after the drain");
    let free_row = &snap.tenants[1];
    assert_eq!(free_row.throttled, 0);
    // throttled requests are invisible to the class/server counters:
    // only the 6 served requests and the 1 shed overflow reached them
    let sv = &snap.server;
    assert_eq!(sv.submitted, 7, "the 5 throttled requests never touched the frontend");
    assert_eq!(sv.shed, 1);
    assert_eq!(sv.completed, 6);
    assert!(sv.accounted());
    server.shutdown();
}

/// (c) Untenanted requests bypass a configured tenancy layer (operator
/// and system traffic is never throttled), and an out-of-range tenant
/// index is the typed `UnknownTenant` error.
#[test]
fn untenanted_requests_bypass_and_unknown_tenants_are_typed_errors() {
    // a roster whose only tenant admits nothing after its 1-token burst
    let server = pool_server(
        1,
        ServerConfig {
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            tenants: vec![TenantSpec::new("starved", 0.0, 1)],
            ..Default::default()
        },
    );
    let input = signal(1024, 7);
    // untenanted traffic sails through regardless of the roster state
    for _ in 0..4 {
        let rx = server.request(FftRequest::new(input.clone())).unwrap();
        assert!(rx.recv().unwrap().is_ok());
    }
    // the starved tenant's single burst token admits exactly once
    assert!(server
        .request(FftRequest::new(input.clone()).with_tenant(0))
        .unwrap()
        .recv()
        .unwrap()
        .is_ok());
    for _ in 0..2 {
        match server.request(FftRequest::new(input.clone()).with_tenant(0)) {
            Err(ServiceError::TenantThrottled { tenant }) => assert_eq!(tenant, 0),
            other => panic!("expected TenantThrottled, got {other:?}"),
        }
    }
    match server.request(FftRequest::new(input.clone()).with_tenant(5)) {
        Err(ServiceError::UnknownTenant { tenant }) => assert_eq!(tenant, 5),
        other => panic!("expected UnknownTenant, got {other:?}"),
    }
    let snap = server.metrics();
    assert_eq!(snap.tenants[0].submitted, 3, "unknown-index probes are not counted");
    assert_eq!(snap.tenants[0].admitted, 1);
    assert_eq!(snap.tenants[0].throttled, 2);
    assert_eq!(snap.server.submitted, 5, "4 untenanted + 1 admitted tenant request");
    server.shutdown();
}

/// (d) Cross-pass preemption end to end: with one dispatcher, a
/// background tenant's 65536-point request is mid-decomposition when a
/// priority tenant's request lands in the queue. The priority request
/// cannot dispatch (the dispatcher is busy), so the registry's watch
/// stays raised through the background job's between-pass checkpoint —
/// the job must yield there (bounded by the 250ms cap), then finish
/// correctly, and the yield must be visible in the multipass counters.
#[test]
fn background_multipass_yields_to_a_waiting_priority_tenant() {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 1,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..4).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            // admission weighs the 65536-point request at its true 512
            // sub-job cost, so the class needs room for it plus the
            // priority request behind it
            classes: vec![QosClass::new("only", 1).with_capacity(1024)],
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            tenants: vec![
                TenantSpec::new("bg", 1e9, 1_000_000),
                TenantSpec::new("vip", 1e9, 1_000_000).with_priority(),
            ],
            ..Default::default()
        },
    )
    .unwrap();

    // the background transform: 65536 points = 256 stage-1 sub-jobs,
    // comfortably in flight by the time the vip request is enqueued
    let bg = server
        .request(FftRequest::new(signal(65_536, 21)).with_class(0).with_tenant(0))
        .unwrap();
    let vip = server
        .request(FftRequest::new(signal(1024, 22)).with_class(0).with_tenant(1))
        .unwrap();

    let bg_result = bg.recv().unwrap().expect("background job completes despite the yield");
    assert_eq!(bg_result.result.output.len(), 65_536);
    let vip_result = vip.recv().unwrap().expect("priority request served after");
    assert_eq!(vip_result.result.output.len(), 1024);

    let snap = server.metrics();
    assert!(
        snap.multipass.yielded >= 1,
        "the between-pass checkpoint must have paused for the waiting \
         priority tenant: {:?}",
        snap.multipass
    );
    assert_eq!(snap.multipass.preempted, 0, "a yield is not an abandonment");
    assert_eq!(snap.tenants[0].completed, 1);
    assert_eq!(snap.tenants[1].completed, 1);
    // the decomposed request was billed its true multi-pass cost
    assert!(
        snap.tenants[0].job_units > 1,
        "decomposed work bills n1 + n2 units: {:?}",
        snap.tenants[0]
    );
    assert_eq!(snap.tenants[1].job_units, 1);
    server.shutdown();
}

/// Settlement on the multipass abort path: a decomposed request
/// deadline-killed at the between-pass checkpoint must refund its full
/// remaining quota charge exactly once. The tenant holds TWO decomposed
/// requests in flight (512 units each) when the first is killed, so the
/// in-flight gauge can distinguish every settlement defect exactly:
/// 1024 left charged = no refund (leaked units starve the tenant
/// forever), 0 = double refund (`UnitQuota::release` saturates at zero,
/// which a single-request test could never tell apart from the correct
/// single refund — the survivor's 512 units are the sentinel).
#[test]
fn deadline_killed_multipass_refunds_quota_exactly_once() {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 1,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..4).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            // room for two 512-unit decomposed requests
            classes: vec![QosClass::new("only", 1).with_capacity(2048)],
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            tenants: vec![generous("bg")],
            ..Default::default()
        },
    )
    .unwrap();

    // Doomed: 65536 points = 256 + 256 = 512 quota units, and a
    // deadline far shorter than its first 256-sub-job stage (tens of
    // ms even in release builds) — it survives its ~µs queue wait but
    // expires before the between-pass checkpoint, where the
    // orchestration must kill it.
    let doomed = server
        .request(
            FftRequest::new(signal(65_536, 31))
                .with_class(0)
                .with_tenant(0)
                .with_deadline(Duration::from_millis(5)),
        )
        .unwrap();
    // Survivor: same shape, no deadline; charged at admission, so its
    // 512 units are in flight from this instant even while it waits
    // behind the single dispatcher.
    let survivor = server
        .request(FftRequest::new(signal(65_536, 32)).with_class(0).with_tenant(0))
        .unwrap();

    match doomed.recv().unwrap() {
        Err(ServiceError::DeadlineExceeded { .. }) => {}
        other => panic!("expected the decomposed job deadline-killed, got {other:?}"),
    }
    // The dispatcher settles the abort before answering, so this
    // snapshot is ordered after the refund.
    let mid = server.metrics();
    assert_eq!(
        mid.tenants[0].units_in_flight, 512,
        "exactly the survivor's charge may remain: 1024 = the kill \
         refunded nothing, 0 = it refunded twice (masked by release() \
         saturation without the second request): {:?}",
        mid.tenants[0]
    );
    assert!(
        mid.multipass.preempted >= 1,
        "the kill must land at the between-pass checkpoint: {:?}",
        mid.multipass
    );

    let served = survivor.recv().unwrap().expect("undeadlined sibling completes");
    assert_eq!(served.result.output.len(), 65_536);
    let snap = server.metrics();
    assert_eq!(snap.tenants[0].units_in_flight, 0, "full drain settles to zero");
    assert_eq!(snap.tenants[0].admitted, 2);
    assert_eq!(snap.tenants[0].completed, 1);
    assert_eq!(
        snap.tenants[0].job_units, 512,
        "only the completed request is billed; the killed one is refunded, not billed"
    );
    server.shutdown();
}

/// (d, bounded) The yield cap, not the priority tenant, decides the
/// worst case: a manually raised watch that never clears delays a
/// decomposed request by at most ~250ms per checkpoint — the request
/// still completes, bitwise equal to an unwatched run.
#[test]
fn stuck_preempt_watch_is_bounded_by_the_yield_cap() {
    use egpu_fft::coordinator::PreemptWatch;

    let svc = FftService::start(ServiceConfig {
        cores: 2,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let input = signal(8192, 5);
    let plain = svc.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();

    let watch = PreemptWatch::manual();
    watch.set(1); // raised forever: nothing will ever dispatch it away
    let t0 = std::time::Instant::now();
    let watched = svc
        .request(FftRequest::new(input).with_preempt_watch(watch))
        .recv()
        .unwrap()
        .expect("a stuck watch delays, never kills");
    let elapsed = t0.elapsed();
    assert!(
        elapsed >= Duration::from_millis(200),
        "the checkpoint must actually have paused (took {elapsed:?})"
    );
    let bits = |v: &[(f32, f32)]| -> Vec<(u32, u32)> {
        v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
    };
    assert_eq!(
        bits(&watched.output),
        bits(&plain.output),
        "yielding changes scheduling, never numerics"
    );
    assert!(svc.metrics().multipass.yielded >= 1);
    svc.shutdown();
}
