//! Numerics: every design point of the paper's campaign computes a
//! correct FFT on the simulated eGPU, for multiple input classes.

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, reference, Cpx};

fn check_signal(points: usize, radix: usize, v: Variant, input: &[Cpx], label: &str) {
    let cfg = SmConfig::for_radix(v, radix);
    let fp = fft::generate(&cfg, points, radix).unwrap();
    let in32: Vec<(f32, f32)> = input.iter().map(|c| c.to_f32_pair()).collect();
    let run = fft::run_fft(&fp, &cfg, &in32).unwrap();
    let got: Vec<Cpx> = run
        .output
        .iter()
        .map(|&(re, im)| Cpx::new(re as f64, im as f64))
        .collect();
    // compare against what f32-rounded inputs transform to
    let rounded: Vec<Cpx> = in32
        .iter()
        .map(|&(re, im)| Cpx::new(re as f64, im as f64))
        .collect();
    let want = reference::fft(&rounded);
    let err = reference::rms_rel_error(&got, &want);
    assert!(err < fft::F32_TOL, "{points}/{radix}/{v}/{label}: rms {err:e}");
}

/// The paper's full table space (every size × radix × variant cell of
/// Tables 1–3) on random data.
#[test]
fn full_campaign_random() {
    for (points, radices) in [
        (256usize, vec![4usize, 16]),
        (512, vec![8]),
        (1024, vec![4, 16]),
        (4096, vec![4, 8, 16]),
    ] {
        for radix in radices {
            for v in Variant::ALL6 {
                let sig = reference::test_signal(points, (points * radix) as u64);
                check_signal(points, radix, v, &sig, "random");
            }
        }
    }
}

/// Radix-2 (measured but unreported in the paper) still computes
/// correctly, including the capacity-blocked 4096-point case.
#[test]
fn radix2_all_sizes() {
    for points in [256usize, 512, 1024, 2048, 4096] {
        let sig = reference::test_signal(points, 77);
        check_signal(points, 2, Variant::DP, &sig, "radix2");
        check_signal(points, 2, Variant::DP_VM_COMPLEX, &sig, "radix2-vmc");
    }
}

/// Structured inputs: impulse, DC, single tones, alternating sign.
#[test]
fn structured_inputs() {
    let n = 1024;
    let impulse: Vec<Cpx> = (0..n)
        .map(|i| if i == 0 { Cpx::ONE } else { Cpx::ZERO })
        .collect();
    let dc: Vec<Cpx> = vec![Cpx::ONE; n];
    let alt: Vec<Cpx> = (0..n)
        .map(|i| Cpx::new(if i % 2 == 0 { 1.0 } else { -1.0 }, 0.0))
        .collect();
    let tone: Vec<Cpx> = (0..n)
        .map(|i| Cpx::cis(2.0 * std::f64::consts::PI * 100.0 * i as f64 / n as f64))
        .collect();
    for (sig, label) in [(impulse, "impulse"), (dc, "dc"), (alt, "alternating"), (tone, "tone")] {
        check_signal(n, 16, Variant::DP_VM_COMPLEX, &sig, label);
        check_signal(n, 4, Variant::QP_COMPLEX, &sig, label);
    }
}

/// Large-magnitude and tiny-magnitude inputs keep relative accuracy.
#[test]
fn dynamic_range() {
    let n = 256;
    let big: Vec<Cpx> = reference::test_signal(n, 5)
        .iter()
        .map(|c| Cpx::new(c.re * 1e6, c.im * 1e6))
        .collect();
    let small: Vec<Cpx> = reference::test_signal(n, 6)
        .iter()
        .map(|c| Cpx::new(c.re * 1e-6, c.im * 1e-6))
        .collect();
    check_signal(n, 4, Variant::DP, &big, "big");
    check_signal(n, 4, Variant::DP, &small, "small");
    check_signal(n, 16, Variant::DP_VM_COMPLEX, &big, "big");
}

/// Linearity of the simulated transform (an end-to-end property of the
/// whole codegen+simulator stack).
#[test]
fn linearity_through_the_simulator() {
    let n = 256;
    let cfg = SmConfig::for_radix(Variant::DP_VM, 4);
    let fp = fft::generate(&cfg, n, 4).unwrap();
    let a = reference::test_signal(n, 1);
    let b = reference::test_signal(n, 2);
    let run_one = |sig: &[Cpx]| -> Vec<Cpx> {
        let in32: Vec<(f32, f32)> = sig.iter().map(|c| c.to_f32_pair()).collect();
        fft::run_fft(&fp, &cfg, &in32)
            .unwrap()
            .output
            .iter()
            .map(|&(re, im)| Cpx::new(re as f64, im as f64))
            .collect()
    };
    let fa = run_one(&a);
    let fb = run_one(&b);
    let sum: Vec<Cpx> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
    let fsum = run_one(&sum);
    let combined: Vec<Cpx> = fa.iter().zip(&fb).map(|(x, y)| *x + *y).collect();
    let err = reference::rms_rel_error(&fsum, &combined);
    assert!(err < 1e-4, "linearity violated: {err:e}");
}

/// Parseval's theorem holds through the simulator.
#[test]
fn parseval_through_the_simulator() {
    let n = 1024;
    let cfg = SmConfig::for_radix(Variant::QP, 16);
    let fp = fft::generate(&cfg, n, 16).unwrap();
    let sig = reference::test_signal(n, 21);
    let in32: Vec<(f32, f32)> = sig.iter().map(|c| c.to_f32_pair()).collect();
    let out = fft::run_fft(&fp, &cfg, &in32).unwrap().output;
    let tx: f64 = in32.iter().map(|&(r, i)| (r as f64).powi(2) + (i as f64).powi(2)).sum();
    let ty: f64 = out.iter().map(|&(r, i)| (r as f64).powi(2) + (i as f64).powi(2)).sum();
    let ratio = ty / (n as f64 * tx);
    assert!((ratio - 1.0).abs() < 1e-5, "parseval ratio {ratio}");
}
