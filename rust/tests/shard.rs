//! Sharded-scheduler integration tests: size-affinity routing,
//! work-stealing under skewed load, bitwise identity of sharded vs
//! single-shard results, and plan-cache behaviour with N > 1 shards.

use egpu_fft::coordinator::{
    Backend, FftRequest, FftService, ServiceConfig, ShardPoolConfig, ShardedFftService,
};
use egpu_fft::fft::{self, reference};

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn pool(shards: usize, steal_threshold: usize) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

/// With a generous steal threshold and strictly sequential traffic,
/// every job of one size lands on exactly one shard — its home — and
/// nothing is ever stolen.
#[test]
fn same_size_affinity_routes_to_one_home_shard() {
    let svc = pool(4, 64);
    for seed in 0..6u64 {
        let r = svc.request(FftRequest::new(signal(1024, seed))).recv().unwrap().unwrap();
        assert_eq!(r.output.len(), 1024);
    }
    let m = svc.metrics();
    assert_eq!(m.served, 6);
    assert_eq!(m.steals, 0, "sequential light load never overflows");
    let serving: Vec<_> = m.shards.iter().filter(|s| s.handled > 0).collect();
    assert_eq!(serving.len(), 1, "one size -> one home shard: {:?}", m.shards);
    assert_eq!(serving[0].handled, 6);
    assert_eq!(serving[0].affine, 6);
    assert_eq!(serving[0].stolen, 0);
    svc.shutdown();
}

/// Two different sizes have different home shards (with 4 shards,
/// 256 -> tz 8 -> shard 0, 1024 -> tz 10 -> shard 2).
#[test]
fn distinct_sizes_get_distinct_homes() {
    let svc = pool(4, 64);
    for seed in 0..3u64 {
        svc.request(FftRequest::new(signal(256, seed))).recv().unwrap().unwrap();
        svc.request(FftRequest::new(signal(1024, seed))).recv().unwrap().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.shards[0].handled, 3, "fft256 home");
    assert_eq!(m.shards[2].handled, 3, "fft1024 home");
    assert_eq!(m.shards[1].handled + m.shards[3].handled, 0);
    svc.shutdown();
}

/// A skewed burst — every request the same size — must spill past its
/// home shard through the work-stealing overflow and use the pool.
#[test]
fn work_stealing_spreads_skewed_load() {
    let svc = pool(4, 0);
    let handles: Vec<_> = (0..32).map(|i| svc.request(FftRequest::new(signal(1024, i)))).collect();
    for h in handles {
        let r = h.recv().unwrap().unwrap();
        assert_eq!(r.output.len(), 1024);
    }
    let m = svc.metrics();
    assert_eq!(m.served, 32);
    assert!(m.steals >= 1, "a 32-deep same-size burst must overflow its home shard");
    let serving = m.shards.iter().filter(|s| s.handled > 0).count();
    assert!(serving >= 2, "stolen work must reach other shards: {:?}", m.shards);
    let stolen: u64 = m.shards.iter().map(|s| s.stolen).sum();
    assert!(stolen >= 1);
    assert_eq!(
        m.shards.iter().map(|s| s.handled).sum::<u64>(),
        32,
        "per-shard counts account for every job"
    );
    svc.shutdown();
}

/// The acceptance property: sharded `run_batch` output bits equal the
/// single-shard service's bits (which themselves equal the unsharded
/// `FftService`'s) — scheduling never changes numerics.
#[test]
fn sharded_run_batch_bitwise_identical_to_single_shard() {
    let inputs: Vec<_> = (0..12)
        .map(|i| signal(if i % 3 == 0 { 256 } else { 1024 }, 4000 + i as u64))
        .collect();

    let single = pool(1, 2);
    let base: Vec<Vec<(u32, u32)>> = single
        .run_batch(inputs.clone())
        .unwrap()
        .iter()
        .map(|r| bits(&r.output))
        .collect();
    single.shutdown();

    let sharded = pool(4, 0);
    let got = sharded.run_batch(inputs.clone()).unwrap();
    sharded.shutdown();
    assert_eq!(got.len(), base.len());
    for (i, (r, want)) in got.iter().zip(&base).enumerate() {
        assert_eq!(bits(&r.output), *want, "job {i}");
    }

    // and both match the unsharded single-queue service
    let flat = FftService::start(ServiceConfig {
        cores: 2,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let flat_results = flat.run_batch(inputs).unwrap();
    for (i, (r, want)) in flat_results.iter().zip(&base).enumerate() {
        assert_eq!(bits(&r.output), *want, "unsharded job {i}");
    }
    flat.shutdown();
}

/// `request_all` chunks a homogeneous batch across shards and still
/// returns bitwise-identical results in submission order.
#[test]
fn sharded_request_all_chunks_bitwise_identical_and_ordered() {
    let inputs: Vec<_> = (0..32).map(|i| signal(512, 7000 + i as u64)).collect();

    let flat = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let base: Vec<Vec<(u32, u32)>> = flat
        .request_all(inputs.clone().into_iter().map(FftRequest::new).collect())
        .unwrap()
        .iter()
        .map(|r| bits(&r.output))
        .collect();
    flat.shutdown();

    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 4,
        steal_threshold: 0,
        min_chunk: 4,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
    })
    .unwrap();
    let got = svc.request_all(inputs.into_iter().map(FftRequest::new).collect()).unwrap();
    assert_eq!(got.len(), 32);
    for w in got.windows(2) {
        assert!(w[0].id < w[1].id, "ids follow submission order");
    }
    for (i, (r, want)) in got.iter().zip(&base).enumerate() {
        assert_eq!(bits(&r.output), *want, "job {i}");
    }
    let m = svc.metrics();
    assert_eq!(m.served, 32);
    assert_eq!(m.batches, 4, "32 jobs / min_chunk 4 caps at one chunk per shard");
    let serving = m.shards.iter().filter(|s| s.handled > 0).count();
    assert!(serving >= 2, "chunks spread across the pool: {:?}", m.shards);
    svc.shutdown();
}

/// Steady-state traffic over N > 1 shards keeps the shared plan cache
/// hot: one generation (plus at most per-shard races) serves everyone.
#[test]
fn plan_cache_hit_rate_exceeds_090_with_multiple_shards() {
    let svc = pool(4, 0);
    let inputs: Vec<_> = (0..128).map(|i| signal(1024, i)).collect();
    let results = svc.run_batch(inputs).unwrap();
    assert_eq!(results.len(), 128);
    let m = svc.metrics();
    let pc = m.plan_cache;
    assert_eq!(pc.entries, 1, "one design point resident");
    assert!(
        pc.misses <= 4,
        "at most one double-build race per shard: {} misses",
        pc.misses
    );
    assert!(
        pc.hit_rate() > 0.9,
        "hit rate {:.3} ({} hits / {} misses)",
        pc.hit_rate(),
        pc.hits,
        pc.misses
    );
    svc.shutdown();
}

/// Mixed sizes through the sharded batch path: coalescing, chunking and
/// reassembly preserve order and correctness.
#[test]
fn sharded_mixed_size_batch_correct_and_ordered() {
    let svc = pool(3, 2);
    let sizes = [256usize, 1024, 256, 4096, 1024, 256];
    let inputs: Vec<_> = sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| signal(n, i as u64))
        .collect();
    let results = svc.request_all(inputs.into_iter().map(FftRequest::new).collect()).unwrap();
    assert_eq!(results.len(), sizes.len());
    for (idx, (r, &n)) in results.iter().zip(&sizes).enumerate() {
        assert_eq!(r.output.len(), n);
        let got: Vec<_> = r
            .output
            .iter()
            .map(|&(re, im)| fft::Cpx::new(re as f64, im as f64))
            .collect();
        let want = reference::fft(&reference::test_signal(n, idx as u64));
        assert!(reference::rms_rel_error(&got, &want) < fft::F32_TOL);
    }
    svc.shutdown();
}

/// Errors stay per-job and shards survive them.
#[test]
fn sharded_batch_with_bad_size_errors_cleanly() {
    let svc = pool(2, 2);
    assert!(svc.request_all(vec![signal(100, 0); 3].into_iter().map(FftRequest::new).collect()).is_err());
    let m = svc.metrics();
    assert_eq!(m.errors, 3);
    assert_eq!(m.served, 0);
    let ok = svc.request(FftRequest::new(signal(256, 1))).recv().unwrap();
    assert!(ok.is_ok());
    svc.shutdown();
}
