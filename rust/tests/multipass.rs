//! Large-N multi-pass integration tests: transforms past the 4096-point
//! single-pass ceiling served through the unified `FftRequest` API.
//!
//! The acceptance properties:
//!
//! (a) 2^13–2^16-point requests through the pool and 2^20 through the
//!     sharded service match the f64 four-step oracle
//!     (`multipass::four_step_reference`) within f32 tolerance, and the
//!     per-stage job counters account for every sub-job;
//! (b) scheduling never changes numerics: the reserved (staged-batch)
//!     path, the spilled (one-sub-job-at-a-time) path and the sharded
//!     pool produce bitwise-identical outputs for the same input;
//! (c) staged jobs never deadlock: concurrent large requests racing a
//!     flood of single-pass traffic on a one-core pool all complete,
//!     whether they won a reservation or spilled;
//! (d) the degrade ladder truncates the whole signal *before*
//!     decomposition — a Quarter-level large request through the
//!     traffic server is the four-step transform of the truncated
//!     input, not a stitch of per-pass truncations;
//! (e) admission accounts a large request at its true multi-pass cost:
//!     one 2^16-point admission saturates its class queue for
//!     subsequent traffic, yet is always admissible on an empty queue.

use std::sync::Arc;

use egpu_fft::coordinator::{
    default_two_class, AdmissionPolicy, Backend, DegradeLevel, FftRequest, FftService,
    ServerConfig, ServiceConfig, ServiceError, ServiceHandle, ShardPoolConfig, ShardedFftService,
    TrafficServer,
};
use egpu_fft::fft::{self, multipass, reference, MultipassPlan};

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

/// The f64 four-step oracle for `points` at the default 4096 ceiling.
fn oracle(points: usize, seed: u64) -> Vec<fft::Cpx> {
    let plan = MultipassPlan::new(points, fft::MAX_SINGLE_PASS_POINTS).unwrap();
    multipass::four_step_reference(&reference::test_signal(points, seed), &plan)
}

fn rms_vs(output: &[(f32, f32)], want: &[fft::Cpx]) -> f64 {
    let got: Vec<fft::Cpx> =
        output.iter().map(|&(re, im)| fft::Cpx::new(re as f64, im as f64)).collect();
    reference::rms_rel_error(&got, want)
}

/// (a) Pool path, 2^13 and 2^16: outputs match the four-step oracle and
/// the per-stage counters account exactly (2^13 = 64x128 -> 64 row jobs
/// + 128 column jobs; 2^16 = 256x256 -> 256 + 256).
#[test]
fn pool_serves_large_sizes_matching_the_four_step_oracle() {
    let svc = FftService::start(ServiceConfig {
        cores: 2,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    for (points, seed) in [(1usize << 13, 21u64), (1 << 16, 22)] {
        let r = svc.request(FftRequest::new(signal(points, seed))).recv().unwrap().unwrap();
        assert_eq!(r.output.len(), points);
        let err = rms_vs(&r.output, &oracle(points, seed));
        assert!(err < 5.0 * fft::F32_TOL, "fft{points}: rms {err:e}");
    }
    let mp = svc.metrics().multipass;
    assert_eq!(mp.requests, 2);
    assert_eq!(mp.completed, 2);
    assert_eq!(mp.row_jobs, 64 + 256);
    assert_eq!(mp.col_jobs, 128 + 256);
    assert_eq!(mp.preempted, 0);
    svc.shutdown();
}

/// (a) The headline size: a 2^20-point transform (1024x1024 at the 4096
/// ceiling) through a sharded pool, each stage chunked across shards.
#[test]
fn two_to_the_twenty_through_the_sharded_pool_matches_the_oracle() {
    let points = 1usize << 20;
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 4,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    let r = svc.request(FftRequest::new(signal(points, 5))).recv().unwrap().unwrap();
    assert_eq!(r.output.len(), points);
    let err = rms_vs(&r.output, &oracle(points, 5));
    assert!(err < 10.0 * fft::F32_TOL, "fft2^20: rms {err:e}");
    let m = svc.metrics();
    assert_eq!(m.multipass.requests, 1);
    assert_eq!(m.multipass.completed, 1);
    assert_eq!(m.multipass.row_jobs, 1024);
    assert_eq!(m.multipass.col_jobs, 1024);
    let serving = m.shards.iter().filter(|s| s.handled > 0).count();
    assert!(serving >= 2, "stage batches chunk across the pool: {:?}", m.shards);
    svc.shutdown();
}

/// (b) Reserved vs spilled vs sharded: identical inputs produce
/// bitwise-identical outputs on every serving path.
#[test]
fn reserved_spilled_and_sharded_paths_are_bitwise_identical() {
    let points = 1usize << 13;
    let input = signal(points, 33);

    let reserved = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Simulator,
        ..Default::default()
    })
    .unwrap();
    let a = reserved.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
    let mp = reserved.metrics().multipass;
    assert_eq!((mp.reserved, mp.spilled), (1, 0), "default gate reserves");
    reserved.shutdown();

    // a zero-permit gate forces the spill path: sub-jobs one at a time
    let spilled = FftService::start(ServiceConfig {
        cores: 1,
        backend: Backend::Simulator,
        max_inflight_multipass: 0,
        ..Default::default()
    })
    .unwrap();
    let b = spilled.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
    let mp = spilled.metrics().multipass;
    assert_eq!((mp.reserved, mp.spilled), (0, 1), "zero permits always spill");
    spilled.shutdown();

    let sharded = ShardedFftService::start(ShardPoolConfig {
        shards: 2,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    let c = sharded.request(FftRequest::new(input)).recv().unwrap().unwrap();
    sharded.shutdown();

    assert_eq!(bits(&a.output), bits(&b.output), "reserve vs spill diverged");
    assert_eq!(bits(&a.output), bits(&c.output), "pool vs sharded diverged");
    assert!(rms_vs(&a.output, &oracle(points, 33)) < 5.0 * fft::F32_TOL);
}

/// (c) No deadlock under contention: three concurrent large requests
/// (one reservation permit, so at least the gate arbitrates) race 32
/// single-pass jobs on a one-core pool; everything completes and the
/// large outputs are bitwise identical regardless of which path served
/// them.
#[test]
fn concurrent_large_requests_and_flood_complete_without_deadlock() {
    let svc = Arc::new(
        FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Simulator,
            max_inflight_multipass: 1,
            ..Default::default()
        })
        .unwrap(),
    );
    let input = signal(1 << 13, 44);
    let mut large = Vec::new();
    for _ in 0..3 {
        let svc = Arc::clone(&svc);
        let input = input.clone();
        large.push(std::thread::spawn(move || {
            svc.request(FftRequest::new(input)).recv().unwrap().unwrap().output
        }));
    }
    let flood: Vec<_> =
        (0..32).map(|i| svc.request(FftRequest::new(signal(256, i)))).collect();
    for rx in flood {
        assert!(rx.recv().unwrap().is_ok());
    }
    let outputs: Vec<_> = large.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(bits(&outputs[0]), bits(&outputs[1]));
    assert_eq!(bits(&outputs[0]), bits(&outputs[2]));
    let mp = svc.metrics().multipass;
    assert_eq!(mp.requests, 3);
    assert_eq!(mp.completed, 3);
    assert_eq!(mp.reserved + mp.spilled, 3, "every request took exactly one path");
    svc.shutdown();
}

/// (d) Degrade-ladder interaction through the traffic server: capacity
/// 1 pins every admission at Quarter, so a 2^15-point request serves
/// 8192 points — the four-step transform of the *truncated* signal
/// (truncate-then-decompose, not per-pass truncation).
#[test]
fn quarter_level_large_request_truncates_before_decomposition() {
    let inner = ServiceHandle::Pool(
        FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap(),
    );
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(1)).collect(),
            policy: AdmissionPolicy::Degrade,
            dispatchers: 1,
            min_degraded_points: 256,
            ..Default::default()
        },
    )
    .unwrap();
    let points = 1usize << 15;
    let served = server
        .request(FftRequest::new(signal(points, 6)))
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(served.level, DegradeLevel::Quarter);
    assert_eq!(served.result.output.len(), points >> 2);
    let truncated: Vec<_> = reference::test_signal(points, 6)[..points >> 2].to_vec();
    let plan = MultipassPlan::new(points >> 2, fft::MAX_SINGLE_PASS_POINTS).unwrap();
    let want = multipass::four_step_reference(&truncated, &plan);
    let err = rms_vs(&served.result.output, &want);
    assert!(err < 5.0 * fft::F32_TOL, "rms {err:e}");
    let snap = server.metrics();
    assert_eq!(snap.multipass.requests, 1);
    assert_eq!(snap.multipass.row_jobs, 64, "8192 = 64x128 after truncation");
    assert_eq!(snap.multipass.col_jobs, 128);
    server.shutdown();
}

/// (e) Admission cost accounting: a 2^16-point request weighs 512
/// single-pass job units, so one admission saturates an 8-slot class
/// queue — the next request sheds with the class's own capacity — yet
/// the large request itself was admitted on an empty queue.
#[test]
fn large_request_saturates_its_class_queue_then_drains() {
    let inner = ServiceHandle::Pool(
        FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap(),
    );
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(8)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    )
    .unwrap();
    // occupy the single dispatcher so the queue holds what follows
    let slow = server.request(FftRequest::new(signal(4096, 0))).unwrap();
    // 512 job units into an 8-slot queue: admitted (depth was 0) ...
    let large = server
        .request(FftRequest::new(signal(1 << 16, 1)))
        .expect("a large request on an empty class queue is always admissible");
    // ... but the class is now saturated for everyone behind it
    match server.request(FftRequest::new(signal(256, 2))) {
        Err(ServiceError::QueueFull { capacity }) => assert_eq!(capacity, 8),
        other => panic!("want QueueFull behind a 512-unit backlog, got {other:?}"),
    }
    assert!(slow.recv().unwrap().is_ok());
    let served = large.recv().unwrap().unwrap();
    assert_eq!(served.result.output.len(), 1 << 16);
    // the dispatcher released the backlog at pop: the class admits again
    let after = server.request(FftRequest::new(signal(256, 3)));
    assert!(after.is_ok(), "backlog must drain with the queue: {after:?}");
    assert!(after.unwrap().recv().unwrap().is_ok());
    let sv = server.metrics().server;
    assert_eq!(sv.shed, 1);
    assert!(sv.accounted());
    server.shutdown();
}
