//! Integration tests: the paper's §6 quantitative claims, checked
//! end-to-end against the simulator (not against hard-coded tables).

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, FftPlan};
use egpu_fft::isa::OpClass;
use egpu_fft::profile::Profile;

fn profile(points: usize, radix: usize, v: Variant) -> Profile {
    let cfg = SmConfig::for_radix(v, radix);
    let (p, err) = fft::validate(&cfg, points, radix, 99).unwrap();
    assert!(err < fft::F32_TOL, "{points}/{radix}/{v}: rms {err}");
    p
}

/// §6: "The twiddle loads accounts for about 10% of all memory
/// accesses" (radix-16, 4096, DP: 3840 of 34560 = 11.1%).
#[test]
fn twiddle_loads_are_about_ten_percent_of_memory() {
    let p = profile(4096, 16, Variant::DP);
    // twiddle loads = total loads − data loads (data loads = stores/4)
    let loads = p.get(OpClass::Load);
    let data_loads = p.get(OpClass::Store) / 4;
    let twiddle = loads - data_loads;
    let mem = loads + p.get(OpClass::Store);
    let share = twiddle as f64 / mem as f64;
    assert!((0.08..=0.14).contains(&share), "twiddle share {share}");
    // the exact §6 arithmetic on our counts
    assert_eq!(data_loads, 6144);
    assert_eq!(twiddle, 3840);
}

/// §6: "The use of the complex multiplier feature reduces the number of
/// cycles required for FP operations by about 25% ... translates into a
/// ≈5% performance increase."
#[test]
fn complex_fu_reduces_fp_by_quarter_and_total_by_5pct() {
    for (points, radix) in [(4096usize, 4usize), (4096, 8), (4096, 16)] {
        let base = profile(points, radix, Variant::DP);
        let cplx = profile(points, radix, Variant::DP_COMPLEX);
        let fp_base = base.get(OpClass::Fp) as f64;
        // FP cycles after = FP + complex-FU cycles doing the same work
        let fp_after =
            (cplx.get(OpClass::Fp) + cplx.get(OpClass::Complex)) as f64;
        let fp_cut = 1.0 - fp_after / fp_base;
        // the cut shrinks with radix: higher-radix kernels spend more FP
        // on internal constant rotations that stay on the real-FP path
        // (radix-4 ≈ 21 %, radix-8 ≈ 17 %, radix-16 ≈ 13 %; the paper's
        // "about 25 %" is its radix-4 hand assembly)
        assert!(
            (0.10..=0.45).contains(&fp_cut),
            "{points}/{radix}: FP cut {fp_cut}"
        );
        let perf_gain = 1.0 - cplx.total() as f64 / base.total() as f64;
        assert!(
            (0.01..=0.12).contains(&perf_gain),
            "{points}/{radix}: perf gain {perf_gain}"
        );
    }
}

/// §4/§6: the VM memory quadruples write bandwidth on eligible passes —
/// radix-4 4096: stores fall from 49152 to 16384 + 8192 banked.
#[test]
fn vm_store_cycles_match_paper_exactly() {
    let p = profile(4096, 4, Variant::DP_VM);
    assert_eq!(p.get(OpClass::Store), 16384);
    assert_eq!(p.get(OpClass::StoreVm), 8192);
    let dp = profile(4096, 4, Variant::DP);
    assert_eq!(dp.get(OpClass::Store), 49152);
    // radix-8: paper 16384 + 4096
    let p8 = profile(4096, 8, Variant::DP_VM);
    assert_eq!(p8.get(OpClass::Store), 16384);
    assert_eq!(p8.get(OpClass::StoreVm), 4096);
}

/// Abstract of the paper: the two enhancements together "improve the
/// efficiency of the design by 50% when executing the FFTs".
#[test]
fn combined_enhancements_improve_efficiency_by_about_half() {
    // radix-4 shows the full effect (ours: 14.1 % -> 20.8 %, +48 %)
    let base = profile(4096, 4, Variant::DP).efficiency_pct();
    let both = profile(4096, 4, Variant::DP_VM_COMPLEX).efficiency_pct();
    let gain = both / base - 1.0;
    assert!(
        (0.35..=0.65).contains(&gain),
        "4096/4: efficiency gain {gain:.2} (base {base:.1} -> {both:.1})"
    );
    // radix-16 gains less from VM (only pass 1 is bank-eligible; the
    // paper's Table 3 shows more because of its VM/QP store-cell swap —
    // EXPERIMENTS.md) but still improves markedly
    let base16 = profile(4096, 16, Variant::DP).efficiency_pct();
    let both16 = profile(4096, 16, Variant::DP_VM_COMPLEX).efficiency_pct();
    let gain16 = both16 / base16 - 1.0;
    assert!(
        (0.12..=0.60).contains(&gain16),
        "4096/16: efficiency gain {gain16:.2} (base {base16:.1} -> {both16:.1})"
    );
}

/// §6: "hazards are hidden completely if the wavefront depth is greater
/// than 8" — no NOP cycles at 4096/1024 points, NOPs appear at 256.
#[test]
fn hazard_nops_only_for_shallow_wavefronts() {
    assert_eq!(profile(4096, 4, Variant::DP).get(OpClass::Nop), 0);
    assert_eq!(profile(1024, 4, Variant::DP).get(OpClass::Nop), 0);
    assert_eq!(profile(4096, 8, Variant::DP).get(OpClass::Nop), 0);
    assert_eq!(profile(4096, 16, Variant::DP).get(OpClass::Nop), 0);
    assert!(profile(256, 4, Variant::DP).get(OpClass::Nop) > 0);
    assert!(profile(256, 16, Variant::DP).get(OpClass::Nop) > 0);
}

/// §6: memory accesses dominate — the Memory % row is 52–85 % across
/// the whole campaign, and always the majority for the big sizes.
#[test]
fn memory_dominates_cycles() {
    for radix in [4usize, 8, 16] {
        for v in Variant::ALL6 {
            let p = profile(4096, radix, v);
            let m = p.memory_pct();
            assert!((50.0..=90.0).contains(&m), "{radix}/{v}: memory {m}%");
        }
    }
}

/// §6: QP runs at 600 MHz — better cycle counts but the time advantage
/// shrinks; DP-VM-Complex is the fastest 4096-pt radix-4 variant.
#[test]
fn qp_clock_penalty_shapes_times() {
    let vmc = profile(4096, 4, Variant::DP_VM_COMPLEX);
    let qpc = profile(4096, 4, Variant::QP_COMPLEX);
    assert!(qpc.total() <= vmc.total() + 1000); // similar cycles
    assert!(vmc.time_us() < qpc.time_us()); // but DP wins on time
}

/// §6.1: crediting INT ops that perform FP work raises radix-8 DP
/// efficiency (paper: 19.13 % -> 20.5 %).
#[test]
fn effective_efficiency_exceeds_base_for_radix8() {
    let p = profile(4096, 8, Variant::DP);
    let base = p.efficiency_pct();
    let eff = p.effective_efficiency_pct();
    assert!(eff > base, "{eff} vs {base}");
    assert!(eff - base < 3.0, "credit too large: {} -> {}", base, eff);
}

/// §6.2 mixed radix: the 1024-point radix-16 FFT (16·16·4) must beat
/// the pure radix-4 1024-point FFT on efficiency (Table 3 vs Table 1).
#[test]
fn mixed_radix16_beats_radix4_at_1024() {
    let r16 = profile(1024, 16, Variant::DP);
    let r4 = profile(1024, 4, Variant::DP);
    assert!(r16.efficiency_pct() > r4.efficiency_pct());
    assert!(r16.time_us() < r4.time_us());
}

/// Higher radices raise efficiency (fewer passes -> fewer memory
/// round-trips): radix-2 < radix-4 < radix-8 < radix-16 at 4096 points.
#[test]
fn efficiency_increases_with_radix() {
    let effs: Vec<f64> = [2usize, 4, 8, 16]
        .iter()
        .map(|&r| profile(4096, r, Variant::DP).efficiency_pct())
        .collect();
    for w in effs.windows(2) {
        assert!(w[1] > w[0], "{effs:?}");
    }
}

/// The VM feature must be rejected by planning/simulation only where
/// the paper marks "-": 256-pt radix-16 has no bank-eligible pass.
#[test]
fn vm_dash_cells_match_paper() {
    let plan = FftPlan::new(256, 16, 512).unwrap();
    assert!(plan.passes.iter().all(|p| !p.vm_eligible));
    // but the program still runs correctly on a VM variant (it simply
    // never uses save_bank)
    let p = profile(256, 16, Variant::DP_VM);
    assert_eq!(p.get(OpClass::StoreVm), 0);
}

/// Figure 1 configuration invariants: 64 KB shared memory and 32 K
/// registers hold every design point's working set.
#[test]
fn working_sets_fit_the_sm() {
    for radix in [2usize, 4, 8, 16] {
        for points in [256usize, 512, 1024, 2048, 4096] {
            let cfg = SmConfig::for_radix(Variant::DP, radix);
            let fp = fft::generate(&cfg, points, radix).unwrap();
            assert!(fp.layout.words_used <= cfg.smem_words);
            assert!((fp.program.max_reg() as usize) < cfg.regs_per_thread);
        }
    }
}
