//! Integration tests for the N-class QoS frontend and the degrade-aware
//! controller — the ISSUE's acceptance criteria:
//!
//! (a) a 3-class overload keeps per-class shed / deadline / serve
//!     accounting exact (per-class counters sum to the globals, nothing
//!     silently dropped);
//! (b) the degrade-aware controller serves a short burst by walking the
//!     resolution ladder — no shard add — and restores full resolution
//!     once the burst clears; the pure control law pins the
//!     degrade-before-scale-up ordering deterministically in
//!     `coordinator::autoscale` unit tests, this file exercises the
//!     threaded loop end to end;
//! (c) backwards compatibility: the default two-class configuration
//!     reproduces the PR 3 server semantics — high before low, aging
//!     promotion, and outputs bitwise identical to the direct execution
//!     service;
//! (d) per-class capacities: explicit caps are honored independently,
//!     and classes built without one carry the builder default
//!     (`DEFAULT_CLASS_CAPACITY`).

use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, AutoscaleController, AutoscalePolicy, Backend,
    DegradeLevel, FftRequest, FftService, LoadgenConfig, QosClass, ServerConfig, ServiceConfig,
    ServiceError, ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
    DEFAULT_CLASS_CAPACITY,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed).iter().map(|c| c.to_f32_pair()).collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

fn pool_server(cores: usize, cfg: ServerConfig) -> TrafficServer {
    let inner = ServiceHandle::Pool(
        FftService::start(ServiceConfig {
            cores,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap(),
    );
    TrafficServer::start(inner, cfg).unwrap()
}

fn three_classes() -> Vec<QosClass> {
    vec![
        QosClass::new("gold", 5).with_capacity(16),
        QosClass::new("silver", 3).with_capacity(16),
        QosClass::new("bronze", 1).with_capacity(4),
    ]
}

/// (a) Overloading three classes keeps the per-class accounting exact:
/// every class's submitted/admitted/shed/completed line up, and the
/// per-class counters sum to the global ones.
#[test]
fn three_class_overload_accounts_per_class() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: three_classes(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    // occupy the single dispatcher so queues actually fill
    let slow = server.request(FftRequest::new(signal(4096, 0)).with_class(0)).unwrap();
    let input = signal(1024, 3);
    let mut handles = Vec::new();
    let mut shed_by_class = [0u64; 3];
    for round in 0..24 {
        let class = round % 3;
        match server.request(FftRequest::new(input.clone()).with_class(class)) {
            Ok(rx) => handles.push(rx),
            Err(ServiceError::QueueFull { capacity }) => {
                shed_by_class[class] += 1;
                let expect = server.config().classes[class].capacity;
                assert_eq!(capacity, expect, "shed reports the class's own cap");
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(
        shed_by_class[2] >= 1,
        "bronze (cap 4) must shed out of 8 submissions: {shed_by_class:?}"
    );
    assert!(slow.recv().unwrap().is_ok());
    for rx in handles {
        assert!(rx.recv().unwrap().is_ok());
    }
    let sv = server.metrics().server;
    assert_eq!(sv.per_class.len(), 3);
    for (c, stats) in sv.per_class.iter().enumerate() {
        let submitted = if c == 0 { 9 } else { 8 }; // + the slow warmer
        assert_eq!(stats.submitted, submitted, "class {c}");
        assert_eq!(stats.shed, shed_by_class[c], "class {c}");
        assert_eq!(stats.admitted, stats.submitted - stats.shed, "class {c}");
        assert_eq!(stats.completed, stats.admitted, "class {c}: all admitted served");
    }
    let sum = |f: fn(&egpu_fft::coordinator::ClassStats) -> u64| -> u64 {
        sv.per_class.iter().map(f).sum()
    };
    assert_eq!(sum(|c| c.submitted), sv.submitted, "per-class sums to global");
    assert_eq!(sum(|c| c.shed), sv.shed);
    assert_eq!(sum(|c| c.completed), sv.completed);
    assert!(sv.accounted());
    server.shutdown();
}

/// Measured single-shard fft1024 serving capacity, jobs/s (shared
/// library helper — the same anchor the benches calibrate with).
fn single_shard_rps() -> f64 {
    ShardedFftService::calibrate_single_shard_rps(1024).unwrap()
}

/// (b) A short burst against a degrade-armed controller is absorbed by
/// the resolution ladder — the operating level deepens, no shard is
/// added (the scale-up cooldown is deliberately longer than the burst)
/// — and full resolution is restored once the burst clears.
#[test]
fn short_burst_degrades_without_scaling_and_restores_after() {
    let base_rps = single_shard_rps();
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 1,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let control = server.degrade_control();
    let controller = AutoscaleController::spawn(
        &server,
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 10.0,
            max_shed_rate: 0.02,
            max_degrade: DegradeLevel::Quarter,
            degrade_cooldown: Duration::from_millis(50),
            restore_cooldown: Duration::from_millis(100),
            // the burst (≤ 800ms) ends before a shard add is even
            // allowed, so any overload reaction must be a degrade
            scale_up_cooldown: Duration::from_secs(30),
            scale_down_cooldown: Duration::from_secs(60),
            interval: Duration::from_millis(25),
            ..Default::default()
        },
    )
    .unwrap();

    // burst: ~3x one shard's capacity for 800ms
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 3.0 * base_rps,
            duration: Duration::from_millis(800),
            sizes: vec![1024],
            deadline: None,
            ..Default::default()
        },
    );
    assert!(report.accounted);
    let shards_now = server.service().as_sharded().unwrap().shards();
    assert_eq!(shards_now, 1, "a burst inside the scale-up cooldown adds no shard");

    // idle: healthy samples restore resolution step by step
    let deadline = Instant::now() + Duration::from_secs(5);
    while control.get() != DegradeLevel::Full && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(control.get(), DegradeLevel::Full, "resolution restored after the burst");

    let log = controller.stop();
    assert!(log.degrades() >= 1, "degrade events logged:\n{}", log.render());
    assert!(log.restores() >= 1, "restore events logged:\n{}", log.render());
    assert_eq!(log.scale_ups(), 0, "no shard add for a short burst:\n{}", log.render());
    assert!(report.degraded > 0, "burst requests actually served degraded: {report:?}");
    server.shutdown();
}

/// (c) Backwards compatibility, semantics: with the default two-class
/// configuration, outputs are bitwise identical to the direct execution
/// service — the QoS frontend changes scheduling, never numerics.
#[test]
fn two_class_config_outputs_bitwise_match_direct_service() {
    let inputs: Vec<_> = (0..10)
        .map(|i| signal(if i % 2 == 0 { 256 } else { 1024 }, 4000 + i as u64))
        .collect();

    let direct = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let want: Vec<Vec<(u32, u32)>> = direct
        .run_batch(inputs.clone())
        .unwrap()
        .iter()
        .map(|r| bits(&r.output))
        .collect();
    direct.shutdown();

    let server = pool_server(
        1,
        ServerConfig {
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    assert_eq!(server.config().classes.len(), 2, "default config is the legacy pair");
    assert_eq!(server.config().classes[0].name, "high");
    assert_eq!(server.config().classes[1].weight, 0, "low is a background class");
    for (i, input) in inputs.iter().enumerate() {
        let class = i % 2; // alternate high/low
        let served = server
            .request(FftRequest::new(input.clone()).with_class(class))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(served.class, class);
        assert_eq!(served.level, DegradeLevel::Full, "Shed policy never degrades");
        assert_eq!(bits(&served.result.output), want[i], "request {i} diverged");
    }
    let sv = server.metrics().server;
    assert_eq!(sv.served_high, 5);
    assert_eq!(sv.served_low, 5);
    assert!(sv.accounted());
    server.shutdown();
}

/// (c) Backwards compatibility, scheduling: under a high-priority
/// backlog the aged low request is still promoted within the bound —
/// the PR 3 starvation-freedom semantics through the N-class scheduler.
#[test]
fn two_class_aging_still_promotes_low_under_backlog() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(4096)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            aging: Duration::from_millis(10),
            ..Default::default()
        },
    );
    // a high backlog worth ~400ms of service (calibrated, so the test
    // means the same thing on fast and slow hosts), then one low
    // request
    let input = signal(1024, 1);
    let service_us = {
        let mut last = 0.0;
        for seed in 0..2 {
            let rx = server.request(FftRequest::new(signal(1024, seed)).with_class(0)).unwrap();
            last = rx.recv().unwrap().unwrap().service_us;
        }
        last
    };
    let n_high = ((400_000.0 / service_us).ceil() as usize).clamp(50, 2000);
    let highs: Vec<_> = (0..n_high)
        .map(|_| server.request(FftRequest::new(input.clone()).with_class(0)).unwrap())
        .collect();
    let low = server
        .request(FftRequest::new(signal(1024, 2)).with_class(1))
        .unwrap()
        .recv()
        .unwrap()
        .expect("low must complete");
    assert!(
        server.queue_depth() > 0,
        "the low request completed while high work was still queued — no starvation"
    );
    let sv = server.metrics().server;
    assert!(sv.aged >= 1, "the aging promotion fired");
    assert_eq!(sv.per_class[1].aged, sv.aged, "attributed to the background class");
    assert_eq!(sv.per_class[1].completed, 1);
    assert!(low.queue_us < 500_000.0, "served within the aging bound, not after drain");
    drop(highs);
    server.shutdown();
}

/// (d) Per-class capacities: an explicit cap sheds independently while
/// a sibling class (carrying the builder default) still admits — and
/// the configured caps are observable.
#[test]
fn explicit_and_default_class_capacities_coexist() {
    let server = pool_server(
        1,
        ServerConfig {
            classes: vec![
                QosClass::new("tiny", 1).with_capacity(2),
                QosClass::new("roomy", 1), // builder default capacity
            ],
            policy: AdmissionPolicy::Shed,
            dispatchers: 1,
            ..Default::default()
        },
    );
    assert_eq!(server.class_capacities(), &[2, DEFAULT_CLASS_CAPACITY]);
    // hold the dispatcher down so queues fill
    let slow = server.request(FftRequest::new(signal(4096, 0)).with_class(1)).unwrap();
    let input = signal(256, 1);
    let mut tiny_shed = 0;
    let mut tiny_handles = Vec::new();
    for _ in 0..6 {
        match server.request(FftRequest::new(input.clone()).with_class(0)) {
            Ok(rx) => tiny_handles.push(rx),
            Err(ServiceError::QueueFull { capacity }) => {
                assert_eq!(capacity, 2);
                tiny_shed += 1;
            }
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(tiny_shed >= 1, "the 2-slot class sheds");
    // the sibling with the default 64-slot cap still admits everything
    let roomy_handles: Vec<_> = (0..16)
        .map(|_| {
            server
                .request(FftRequest::new(input.clone()).with_class(1))
                .expect("roomy class must admit while tiny sheds")
        })
        .collect();
    assert!(slow.recv().unwrap().is_ok());
    for rx in tiny_handles.into_iter().chain(roomy_handles) {
        assert!(rx.recv().unwrap().is_ok());
    }
    let sv = server.metrics().server;
    assert_eq!(sv.per_class[0].shed, tiny_shed);
    assert_eq!(sv.per_class[1].shed, 0);
    assert!(sv.accounted());
    server.shutdown();
}

/// WFQ end to end: three weighted classes under sustained overload see
/// served shares near weight/Σweights, and per-class queue p99s are
/// populated — the frontend-level view of the scheduler-core property.
#[test]
fn three_class_overload_shares_track_weights_end_to_end() {
    let inner = ServiceHandle::Sharded(
        ShardedFftService::start(ShardPoolConfig {
            shards: 2,
            steal_threshold: 0,
            service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    );
    let server = TrafficServer::start(
        inner,
        ServerConfig {
            classes: vec![
                QosClass::new("gold", 5).with_capacity(32),
                QosClass::new("silver", 3).with_capacity(32),
                QosClass::new("bronze", 1).with_capacity(32),
            ],
            policy: AdmissionPolicy::Shed,
            dispatchers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    // 6x one shard's capacity across a two-shard pool: guaranteed
    // saturation, whatever this host's absolute speed
    let base_rps = single_shard_rps();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 6.0 * base_rps,
            duration: Duration::from_millis(1500),
            sizes: vec![1024],
            class_mix: vec![1.0, 1.0, 1.0],
            deadline: None,
            seed: 9,
            ..Default::default()
        },
    );
    assert!(report.accounted, "{report:?}");
    assert!(report.shed > 0, "the run must actually saturate: {report:?}");
    assert_eq!(report.per_class.len(), 3);
    let total: u64 = report.per_class.iter().map(|c| c.completed).sum();
    assert!(total > 50, "enough completions to measure shares: {report:?}");
    for (c, want) in report.per_class.iter().zip([5.0 / 9.0, 3.0 / 9.0, 1.0 / 9.0]) {
        let frac = c.completed as f64 / total as f64;
        assert!(
            (frac - want).abs() < 0.15,
            "{}: share {frac:.3} vs weight share {want:.3}\n{}",
            c.name,
            report.render()
        );
        assert!(c.queue_p99_us > 0.0, "{}: per-class queue p99 populated", c.name);
    }
    server.shutdown();
}
